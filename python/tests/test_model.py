"""L2 model correctness: the a/b (streams/pending) decomposition.

The anchor property: running `step` sequentially with *lazily* computed
pending columns must reproduce the training-style full forward exactly.
This validates the red-cell/gray-tile split that the whole Flash Inference
tiling rests on — any indexing error in rho offsets or stream definitions
breaks it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def lazy_rollout(cfg, w, rho, emb, steps):
    """Sequential step() with O(i) lazy pending computation (the paper's
    lazy baseline, in python). Returns streams [M,B,T,D], outs [B,T,·]."""
    step = M.step_fn(cfg)
    rho_np = np.asarray(rho)
    rho0 = rho[:, 0, :]
    ws = [w[n] for n in M.step_weight_names(cfg)]
    scstate = (jnp.zeros((cfg.ops, 2, cfg.B, 3 * cfg.D), jnp.float32)
               if cfg.variant == "hyena" else None)
    streams = np.zeros((cfg.M, cfg.B, steps, cfg.D), np.float32)
    outs = []
    for i in range(steps):
        pend = np.zeros((cfg.M, cfg.B, cfg.D), np.float32)
        for l in range(cfg.M):
            for j in range(i):
                pend[l] += streams[l, :, j, :] * rho_np[l, i - j, :]
        a0 = emb[:, i, :]
        if cfg.variant == "synthetic":
            s_col, out = step(jnp.asarray(pend), a0, rho0, *ws)
        else:
            s_col, out, scstate = step(jnp.asarray(pend), a0, scstate,
                                       rho0, *ws)
        streams[:, :, i, :] = np.asarray(s_col)
        outs.append(np.asarray(out))
    return streams, np.stack(outs, axis=1)


def make(variant, **kw):
    d = dict(variant=variant, M=4, D=16, H=32, L=64, B=2, V=32, seed=3)
    d.update(kw)
    cfg = M.ModelConfig(**d)
    cfg.validate()
    w = M.init_weights(cfg)
    rho = M.filter_gen(cfg, w["filt.w1"], w["filt.b1"], w["filt.w2"],
                       w["filt.alpha"])
    return cfg, w, rho


@pytest.mark.parametrize("variant", ["synthetic", "hyena"])
@pytest.mark.parametrize("steps", [1, 2, 17, 24])
def test_step_matches_forward(variant, steps):
    cfg, w, rho = make(variant)
    rng = np.random.default_rng(7)
    emb = jnp.asarray(rng.standard_normal((cfg.B, steps, cfg.D)), jnp.float32)
    fwd = M.forward_fn(cfg, steps)
    ws = [w[n] for n in M.step_weight_names(cfg)]
    streams_full, outs_full = fwd(emb, rho, *ws)
    streams_seq, outs_seq = lazy_rollout(cfg, w, rho, emb, steps)
    np.testing.assert_allclose(streams_seq, np.asarray(streams_full),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(outs_seq, np.asarray(outs_full),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("variant", ["synthetic", "hyena"])
def test_weight_specs_cover_step_and_filter(variant):
    cfg, w, _ = make(variant)
    names = {n for n, _ in M.weight_specs(cfg)}
    for n in M.step_weight_names(cfg) + M.filter_weight_names(cfg):
        assert n in names
    for n, shape in M.weight_specs(cfg):
        assert tuple(w[n].shape) == shape


def test_filter_gen_shape_and_normalization():
    cfg, w, rho = make("synthetic")
    assert rho.shape == (cfg.M, cfg.L, cfg.D)
    # normalized: conv with any bounded stream stays bounded
    l1 = np.sum(np.abs(np.asarray(rho)), axis=1)
    assert np.all(l1 <= 1.0 + 1e-5)
    assert np.all(np.isfinite(np.asarray(rho)))


def test_filter_gen_decay():
    """Later filter taps are exponentially damped."""
    cfg, w, rho = make("synthetic", L=256)
    r = np.abs(np.asarray(rho))
    head = r[:, :32, :].mean()
    tail = r[:, -32:, :].mean()
    assert tail < head * 0.5


def test_rmsnorm_unit_scale():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 16)),
                    jnp.float32)
    y = M.rmsnorm(x)
    np.testing.assert_allclose(np.mean(np.square(np.asarray(y)), axis=-1),
                               np.ones(4), rtol=1e-4)


@pytest.mark.parametrize("variant", ["synthetic", "hyena"])
def test_step_deterministic(variant):
    cfg, w, rho = make(variant)
    step = M.step_fn(cfg)
    ws = [w[n] for n in M.step_weight_names(cfg)]
    rho0 = rho[:, 0, :]
    rng = np.random.default_rng(0)
    pend = jnp.asarray(rng.standard_normal((cfg.M, cfg.B, cfg.D)), jnp.float32)
    a0 = jnp.asarray(rng.standard_normal((cfg.B, cfg.D)), jnp.float32)
    if variant == "synthetic":
        o1 = step(pend, a0, rho0, *ws)
        o2 = step(pend, a0, rho0, *ws)
    else:
        sc = jnp.zeros((cfg.ops, 2, cfg.B, 3 * cfg.D), jnp.float32)
        o1 = step(pend, a0, sc, rho0, *ws)
        o2 = step(pend, a0, sc, rho0, *ws)
    for a, b in zip(jax.tree_util.tree_leaves(o1),
                    jax.tree_util.tree_leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hyena_rejects_odd_m():
    with pytest.raises(AssertionError):
        M.ModelConfig(variant="hyena", M=3).validate()


def test_l_power_of_two_enforced():
    with pytest.raises(AssertionError):
        M.ModelConfig(L=100).validate()


@pytest.mark.parametrize("variant", ["synthetic", "hyena"])
def test_prefill_matches_lazy_continuation(variant):
    """Prefill fut[l, :, t, :] must equal the prompt's aggregated
    contribution to position P+1+t — i.e. continuing generation after
    prefill sees exactly the pending a lazy full-history run would."""
    P = 8
    cfg, w, rho = make(variant, L=32)
    rng = np.random.default_rng(11)
    emb = jnp.asarray(rng.standard_normal((cfg.B, P, cfg.D)), jnp.float32)
    ws = [w[n] for n in M.step_weight_names(cfg)]
    pf = M.prefill_fn(cfg, P)
    res = pf(emb, rho, *ws)
    streams, fut = res[0], res[1]
    rho_np, s_np = np.asarray(rho), np.asarray(streams)
    for l in range(cfg.M):
        for t in range(cfg.L - P):
            want = np.zeros((cfg.B, cfg.D), np.float32)
            for i in range(P):
                want += s_np[l, :, i, :] * rho_np[l, (P + t) - i, :]
            np.testing.assert_allclose(np.asarray(fut)[l, :, t, :], want,
                                       rtol=3e-4, atol=3e-4)
