"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes; every property asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fft_tile import cmul, fft_tile
from compile.kernels.tile_conv import tile_conv

SET = dict(deadline=None, max_examples=25, derandomize=True)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@st.composite
def tile_shapes(draw):
    g = draw(st.integers(1, 6))
    logu = draw(st.integers(0, 6))
    d = draw(st.sampled_from([1, 2, 3, 16, 64, 128, 256]))
    return g, 2 ** logu, d


@settings(**SET)
@given(tile_shapes(), st.integers(0, 2 ** 31 - 1))
def test_tile_conv_matches_ref(shape, seed):
    g, u, d = shape
    rng = np.random.default_rng(seed)
    y = rand(rng, g, u, d)
    rho = rand(rng, g, 2 * u, d)
    got = tile_conv(y, rho)
    want = ref.tau_ref(y, rho)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(**SET)
@given(tile_shapes(), st.integers(0, 2 ** 31 - 1))
def test_fft_tile_matches_ref(shape, seed):
    g, u, d = shape
    rng = np.random.default_rng(seed)
    y = rand(rng, g, u, d)
    rho = rand(rng, g, 2 * u, d)
    rf = jnp.fft.rfft(rho, n=2 * u, axis=1)
    got = fft_tile(y, jnp.real(rf).astype(jnp.float32),
                   jnp.imag(rf).astype(jnp.float32))
    want = ref.tau_ref(y, rho)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@settings(**SET)
@given(tile_shapes(), st.integers(0, 2 ** 31 - 1))
def test_fft_tile_ref_matches_direct_ref(shape, seed):
    """Appendix C: the 2U cyclic convolution does not corrupt the kept slice."""
    g, u, d = shape
    rng = np.random.default_rng(seed)
    y = rand(rng, g, u, d)
    rho = rand(rng, g, 2 * u, d)
    np.testing.assert_allclose(ref.fft_tile_ref(y, rho), ref.tau_ref(y, rho),
                               rtol=3e-4, atol=3e-4)


@settings(**SET)
@given(st.integers(1, 5), st.integers(1, 40), st.integers(1, 8),
       st.integers(0, 2 ** 31 - 1))
def test_cmul_matches_ref(g, f, d, seed):
    rng = np.random.default_rng(seed)
    a, b, c, e = (rand(rng, g, f, d) for _ in range(4))
    gre, gim = cmul(a, b, c, e)
    wre, wim = ref.cmul_ref(a, b, c, e)
    np.testing.assert_allclose(gre, wre, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gim, wim, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("u", [1, 2, 4, 8, 16])
def test_tile_matches_absolute_tau(u):
    """Tile-local indexing == Lemma-1 absolute-coordinate tau at i = u."""
    rng = np.random.default_rng(u)
    d, t = 3, 2 * u + 2
    yfull = rand(rng, t, d)
    rho = rand(rng, t, d)
    i = u
    want = ref.tau_ref_absolute(yfull, rho, i - u + 1, i, i + 1, i + u)
    got = ref.tau_ref(yfull[None, i - u:i, :], rho[None, :2 * u, :])[0]
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)
    pallas = tile_conv(yfull[None, i - u:i, :], rho[None, :2 * u, :])[0]
    np.testing.assert_allclose(pallas, want, rtol=3e-5, atol=3e-5)


def test_causal_conv_fft_matches_naive():
    rng = np.random.default_rng(0)
    y = rand(rng, 17, 5)
    rho = rand(rng, 17, 5)
    np.testing.assert_allclose(ref.causal_conv_ref(y, rho),
                               ref.causal_conv_naive(y, rho),
                               rtol=3e-5, atol=3e-5)


def test_tile_conv_rejects_bad_shapes():
    y = jnp.zeros((2, 4, 3))
    with pytest.raises(AssertionError):
        tile_conv(y, jnp.zeros((2, 7, 3)))


def test_tile_conv_zero_filter_is_zero():
    rng = np.random.default_rng(1)
    y = rand(rng, 2, 8, 4)
    out = tile_conv(y, jnp.zeros((2, 16, 4)))
    np.testing.assert_array_equal(out, np.zeros_like(out))


def test_tile_conv_impulse_filter_shifts():
    """rho = delta at lag U reproduces y exactly (out[k] = y[k])."""
    g, u, d = 1, 8, 2
    rng = np.random.default_rng(2)
    y = rand(rng, g, u, d)
    rho = np.zeros((g, 2 * u, d), np.float32)
    rho[:, u, :] = 1.0  # lag U: out[k] = y[j] where U+k-j = U  =>  j = k
    out = tile_conv(y, jnp.asarray(rho))
    np.testing.assert_allclose(out, y, rtol=1e-6, atol=1e-6)
