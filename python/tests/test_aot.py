"""AOT pipeline: manifest structure, tensorbin round-trip, artifact ABI.

Builds a tiny config into tmp_path and checks the contract the rust
runtime depends on (names, shapes, file presence, golden trace shape).
"""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model as M, tensorbin


@pytest.fixture(scope="module", params=["synthetic", "hyena"])
def build(request, tmp_path_factory):
    out = str(tmp_path_factory.mktemp(f"art_{request.param}"))
    cfg = M.ModelConfig(variant=request.param, M=4, D=16, H=32, L=32, B=1,
                        V=32, seed=5)
    aot.build_one(cfg, out, golden_steps=10, prefill=8)
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    return cfg, out, manifest


def test_manifest_config(build):
    cfg, out, man = build
    c = man["config"]
    assert c["variant"] == cfg.variant
    assert (c["M"], c["D"], c["L"], c["B"], c["G"]) == \
        (cfg.M, cfg.D, cfg.L, cfg.B, cfg.G)


def test_all_artifact_files_exist_and_parse(build):
    cfg, out, man = build
    names = {a["name"] for a in man["artifacts"]}
    assert "step" in names and "filter_gen" in names
    u = 1
    while u <= cfg.L // 2:
        assert f"tau_fft_{u}" in names and f"tau_direct_{u}" in names
        u *= 2
    for a in man["artifacts"]:
        path = os.path.join(out, a["file"])
        assert os.path.exists(path)
        text = open(path).read()
        assert text.startswith("HloModule"), a["name"]
        assert "ENTRY" in text


def test_tau_artifact_shapes(build):
    cfg, out, man = build
    for a in man["artifacts"]:
        if a.get("kind") == "tau_fft":
            u = a["u"]
            shapes = [tuple(i["shape"]) for i in a["inputs"]]
            assert shapes == [(cfg.G, u, cfg.D), (cfg.G, u + 1, cfg.D),
                              (cfg.G, u + 1, cfg.D)]
            assert tuple(a["outputs"][0]["shape"]) == (cfg.G, u, cfg.D)
        if a.get("kind") == "tau_direct":
            u = a["u"]
            shapes = [tuple(i["shape"]) for i in a["inputs"]]
            assert shapes == [(cfg.G, u, cfg.D), (cfg.G, 2 * u, cfg.D)]


def test_step_io_convention(build):
    cfg, out, man = build
    step = next(a for a in man["artifacts"] if a["name"] == "step")
    in_names = [i["name"] for i in step["inputs"]]
    assert in_names[0] == "$pending_col"
    assert in_names[1] == "$a0"
    assert "@rho0" in in_names
    # every non-$/@ input exists in model.bin
    weights = tensorbin.read(os.path.join(out, "model.bin"))
    for i in step["inputs"]:
        n = i["name"]
        if not n.startswith(("$", "@")):
            assert n in weights
            assert list(weights[n].shape) == i["shape"]


def test_model_bin_roundtrip(build):
    cfg, out, man = build
    w0 = M.init_weights(cfg)
    w1 = tensorbin.read(os.path.join(out, "model.bin"))
    assert set(w1) == set(w0)
    for k in w0:
        np.testing.assert_array_equal(np.asarray(w0[k]), w1[k])


def test_golden_trace_shape_and_determinism(build):
    cfg, out, man = build
    g = tensorbin.read(os.path.join(out, "golden.bin"))
    steps = man["golden"]["steps"]
    assert g["streams"].shape == (cfg.M, cfg.B, steps, cfg.D)
    assert np.all(np.isfinite(g["streams"]))
    if cfg.variant == "hyena":
        assert "tokens" in g
        assert g["tokens"].shape[1] == steps


def test_tensorbin_roundtrip_bytes(tmp_path):
    rng = np.random.default_rng(0)
    tensors = {
        "a": rng.standard_normal((3, 4)).astype(np.float32),
        "b.c": rng.standard_normal((2, 1, 5)).astype(np.float32),
        "scalar": np.asarray([1.5], np.float32),
    }
    p = str(tmp_path / "t.bin")
    tensorbin.write(p, tensors)
    back = tensorbin.read(p)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(tensors[k], back[k])


def test_hlo_text_is_loadable_format(build):
    """The HLO text must carry f32 tuples — spot-check the step entry."""
    cfg, out, man = build
    text = open(os.path.join(out, "step.hlo.txt")).read()
    assert "f32[" in text
    # return_tuple=True: the root is a tuple
    assert "tuple(" in text or "(f32[" in text
