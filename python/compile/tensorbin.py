"""tensor-bin v1: the weight interchange format between aot.py and rust.

Layout (little-endian):

    8 bytes   magic  b"FTBIN1\\0\\0"
    8 bytes   u64    header_len (bytes of UTF-8 JSON that follow)
    N bytes   JSON   {"tensors": [{"name", "shape", "dtype", "offset", "nbytes"}]}
    ...       raw tensor data, each tensor at `offset` from the start of the
              data section, contiguous row-major

Only f32 is used today; the dtype field exists so the format never needs a
version bump for bf16/f64. The rust reader lives in rust/src/model/weights.rs
and is covered by a byte-level round-trip test on both sides.
"""

from __future__ import annotations

import json
import struct
from typing import Dict

import numpy as np

MAGIC = b"FTBIN1\x00\x00"


def write(path: str, tensors: Dict[str, np.ndarray]) -> None:
    entries = []
    offset = 0
    blobs = []
    for name in sorted(tensors):
        arr = np.ascontiguousarray(tensors[name], dtype=np.float32)
        nbytes = arr.nbytes
        entries.append({
            "name": name,
            "shape": list(arr.shape),
            "dtype": "f32",
            "offset": offset,
            "nbytes": nbytes,
        })
        blobs.append(arr.tobytes())
        offset += nbytes
    header = json.dumps({"tensors": entries}).encode("utf-8")
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<Q", len(header)))
        f.write(header)
        for b in blobs:
            f.write(b)


def read(path: str) -> Dict[str, np.ndarray]:
    """Reader (tests + debugging; rust has its own)."""
    with open(path, "rb") as f:
        magic = f.read(8)
        assert magic == MAGIC, f"bad magic {magic!r}"
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen).decode("utf-8"))
        data = f.read()
    out = {}
    for e in header["tensors"]:
        assert e["dtype"] == "f32"
        raw = data[e["offset"]:e["offset"] + e["nbytes"]]
        out[e["name"]] = np.frombuffer(raw, np.float32).reshape(e["shape"]).copy()
    return out
