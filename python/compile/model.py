"""L2: the LCSM model (JAX), in the paper's a/b decomposition.

Two variants share one artifact ABI (DESIGN.md §1):

  * ``synthetic`` — the paper's §5 synthetic setting: M depthwise long-conv
    mixers, block_l = MLP(D -> 2D -> D, GELU) with residual, sampler is
    "last activation + noise" (noise added rust-side).
  * ``hyena``     — §5.1: M/2 order-3 Hyena operators. Each operator:
    RMSNorm, in-projection D -> 3D split into (v, x1, x2) after a width-3
    causal short conv, two long-conv mixers gated by x1/x2, out-projection,
    residual; LM head over a V-token vocab.

The decomposition mirrors the paper exactly:

  streams[l]  = the sequence the l-th mixer convolves (its `y`),
  pending[l]  = b_l, the partially-accumulated mixer output, filled by
                gray tiles (tau, L3) and finished by the red cell here,
  step        = the per-position red-cell + block chain across all M
                layers (Algorithms 2-4, lines 6-8), as a lax.scan.

Everything here is lowered ONCE by aot.py to HLO text; python never runs at
inference time. The `step` scan is the only sequential-in-layers piece —
the gray tiles (tau artifacts / native rust kernels) are what the paper
parallelizes across layers (Algorithm 3), and they live entirely in L3.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static model/artifact configuration (baked into artifact shapes)."""

    variant: str = "synthetic"  # "synthetic" | "hyena"
    M: int = 6          # number of mixer layers (hyena: 2 * ops)
    D: int = 64         # embedding dim
    H: int = 128        # block MLP hidden dim (synthetic)
    L: int = 4096       # max sequence length (power of two)
    B: int = 1          # batch (requests stepped in lockstep)
    V: int = 256        # vocab size (hyena LM head)
    filter_hidden: int = 32   # implicit-filter MLP hidden dim
    filter_freqs: int = 8     # sinusoidal feature pairs
    seed: int = 0

    @property
    def ops(self) -> int:
        assert self.variant == "hyena"
        assert self.M % 2 == 0, "hyena needs an even number of mixers"
        return self.M // 2

    @property
    def G(self) -> int:
        """Fused tile group axis: batch x mixer layers."""
        return self.B * self.M

    def validate(self) -> None:
        assert self.variant in ("synthetic", "hyena"), self.variant
        assert self.L & (self.L - 1) == 0, "L must be a power of two"
        assert self.M >= 1 and self.D >= 1 and self.B >= 1
        if self.variant == "hyena":
            assert self.M % 2 == 0


# ---------------------------------------------------------------------------
# weights
# ---------------------------------------------------------------------------

def weight_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical (name, shape) list — the step/filter artifact input order
    and the model.bin tensor inventory are both derived from this."""
    specs: List[Tuple[str, Tuple[int, ...]]] = []
    M, D, H = cfg.M, cfg.D, cfg.H
    if cfg.variant == "synthetic":
        specs += [
            ("blk.w1", (M, D, H)),
            ("blk.b1", (M, H)),
            ("blk.w2", (M, H, D)),
            ("blk.b2", (M, D)),
        ]
    else:
        ops = cfg.ops
        specs += [
            ("op.wp", (ops, D, 3 * D)),      # in-projection
            ("op.bp", (ops, 3 * D)),
            ("op.scw", (ops, 3, 3 * D)),     # width-3 causal short conv
            ("op.wo", (ops, D, D)),          # out-projection
            ("op.bo", (ops, D)),
            ("head.wv", (D, cfg.V)),         # LM head
            ("embed", (cfg.V, D)),           # token embedding (also used rust-side)
        ]
    # implicit filter parameterization (shared structure across variants)
    K = 2 * cfg.filter_freqs + 1
    specs += [
        ("filt.w1", (K, cfg.filter_hidden)),
        ("filt.b1", (cfg.filter_hidden,)),
        ("filt.w2", (cfg.filter_hidden, M * D)),
        ("filt.alpha", (M, D)),              # per-channel decay rates
    ]
    return specs


def filter_weight_names(cfg: ModelConfig) -> List[str]:
    return ["filt.w1", "filt.b1", "filt.w2", "filt.alpha"]


def step_weight_names(cfg: ModelConfig) -> List[str]:
    if cfg.variant == "synthetic":
        return ["blk.w1", "blk.b1", "blk.w2", "blk.b2"]
    return ["op.wp", "op.bp", "op.scw", "op.wo", "op.bo", "head.wv"]


def init_weights(cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    """Random init (paper §5: values do not affect runtime). Scales are
    chosen so activations stay bounded over L-step rollouts."""
    key = jax.random.PRNGKey(cfg.seed)
    out: Dict[str, jnp.ndarray] = {}
    for name, shape in weight_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith((".b1", ".b2", ".bp", ".bo")):
            w = jnp.zeros(shape, jnp.float32)
        elif name == "filt.alpha":
            # decay exponents in [2, 12]: effective filter support ~ L/alpha
            w = jax.random.uniform(sub, shape, jnp.float32, 2.0, 12.0)
        elif name == "op.scw":
            # near-identity short conv
            w = 0.1 * jax.random.normal(sub, shape, jnp.float32)
            w = w.at[:, 0, :].add(1.0)
        elif name == "embed":
            w = jax.random.normal(sub, shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            w = jax.random.normal(sub, shape, jnp.float32) / math.sqrt(fan_in)
        out[name] = w
    return out


# ---------------------------------------------------------------------------
# shared nn pieces
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    return x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x, approximate=True)


# ---------------------------------------------------------------------------
# implicit filter (rho) generation — run once at engine init
# ---------------------------------------------------------------------------

def pos_features(L: int, freqs: int) -> jnp.ndarray:
    """Sinusoidal positional features, [L, 2*freqs + 1]."""
    t = jnp.arange(L, dtype=jnp.float32) / float(L)
    feats = [t[:, None]]
    for k in range(1, freqs + 1):
        feats.append(jnp.sin(2.0 * jnp.pi * k * t)[:, None])
        feats.append(jnp.cos(2.0 * jnp.pi * k * t)[:, None])
    return jnp.concatenate(feats, axis=1)


def filter_gen(cfg: ModelConfig, w1, b1, w2, alpha) -> jnp.ndarray:
    """Hyena implicit filter: rho[m, t, d] = decay * MLP(pos_feats)(t).

    Normalized per (m, d) so that sum_t |rho| <= 1: keeps long-rollout
    activations bounded regardless of random init (values never affect
    runtime, but NaNs would poison exactness tests).
    Returns rho in [M, L, D].
    """
    feats = pos_features(cfg.L, cfg.filter_freqs)          # [L, K]
    h = gelu(feats @ w1 + b1)                              # [L, Fh]
    r = h @ w2                                             # [L, M*D]
    r = r.reshape(cfg.L, cfg.M, cfg.D).transpose(1, 0, 2)  # [M, L, D]
    t = jnp.arange(cfg.L, dtype=jnp.float32) / float(cfg.L)
    decay = jnp.exp(-jnp.abs(alpha)[:, None, :] * t[None, :, None])
    rho = r * decay
    norm = jnp.sum(jnp.abs(rho), axis=1, keepdims=True) + 1.0
    return (rho / norm).astype(jnp.float32)


def filter_gen_fn(cfg: ModelConfig):
    def fn(w1, b1, w2, alpha):
        return (filter_gen(cfg, w1, b1, w2, alpha),)
    return fn


# ---------------------------------------------------------------------------
# step: per-position red-cell + block chain (Algorithm 2/4 lines 6-8)
# ---------------------------------------------------------------------------

def step_fn(cfg: ModelConfig):
    """Build the per-position step function for AOT lowering.

    Inputs (runtime values prefixed $ in the manifest):
      pending_col [M, B, D]  b_{l,i} accumulated by past gray tiles
      a0          [B, D]     current token embedding / previous output
      scstate     [ops, 2, B, 3D]   (hyena only) short-conv state
      *weights               per step_weight_names(cfg)

    Outputs:
      streams_col [M, B, D]  mixer-input streams at position i (tile fodder)
      out         [B, D] (synthetic: a_M) | [B, V] (hyena: logits)
      rho0 read   happens in-graph: rho0 [M, D] is a runtime input too —
                  it is a slice of filter_gen output owned by rust.
      scstate_new            (hyena only)
    """
    # NOTE (perf, EXPERIMENTS.md §Perf L2): the layer loop is UNROLLED in
    # python rather than expressed as lax.scan. XLA-CPU lowers scan to a
    # while loop with per-iteration dynamic slices of the stacked weights,
    # which costs ~3x the fused static graph at these sizes (M <= 36); the
    # unrolled HLO stays small because M is small.
    if cfg.variant == "synthetic":

        def step(pending_col, a0, rho0, w1, b1, w2, b2):
            u = a0
            streams = []
            for l in range(cfg.M):
                streams.append(u)
                b = pending_col[l] + u * rho0[l][None, :]   # red cell
                h = gelu(rmsnorm(b) @ w1[l] + b1[l])        # block_l
                u = b + h @ w2[l] + b2[l]                   # residual
            return jnp.stack(streams), rmsnorm(u)

        return step

    def step(pending_col, a0, scstate, rho0, wp, bp, scw, wo, bo, wv):
        ops = cfg.ops
        pend_ops = pending_col.reshape(ops, 2, cfg.B, cfg.D)
        rho0_ops = rho0.reshape(ops, 2, cfg.D)
        u = a0
        streams = []
        new_states = []
        for op in range(ops):
            z = rmsnorm(u) @ wp[op] + bp[op]                 # [B, 3D]
            # causal width-3 short conv: state = (z_{i-1}, z_{i-2})
            zc = scw[op, 0][None, :] * z \
                + scw[op, 1][None, :] * scstate[op, 0] \
                + scw[op, 2][None, :] * scstate[op, 1]
            new_states.append(jnp.stack([z, scstate[op, 0]]))
            v, x1, x2 = jnp.split(zc, 3, axis=-1)
            b1_ = pend_ops[op, 0] + v * rho0_ops[op, 0][None, :]   # red cell
            h1 = x1 * b1_                                    # gate (block_{2op})
            b2_ = pend_ops[op, 1] + h1 * rho0_ops[op, 1][None, :]  # red cell
            h2 = x2 * b2_                                    # gate (block_{2op+1})
            u = u + h2 @ wo[op] + bo[op]                     # out-proj + residual
            streams += [v, h1]
        logits = rmsnorm(u) @ wv                             # [B, V]
        return jnp.stack(streams), logits, jnp.stack(new_states)

    return step


# ---------------------------------------------------------------------------
# full forward (training-style) — tests, golden traces, prefill
# ---------------------------------------------------------------------------

def forward_fn(cfg: ModelConfig, T: int):
    """Teacher-forced forward over T positions; must agree exactly (up to
    f32 roundoff) with running `step` sequentially with lazily computed
    pending columns. This is the correctness anchor for the whole a/b
    decomposition."""
    from .kernels.ref import causal_conv_ref

    if cfg.variant == "synthetic":

        def fwd(emb, rho, w1, b1, w2, b2):
            # emb [B, T, D]; rho [M, L, D]
            u = emb
            streams = []
            for l in range(cfg.M):
                streams.append(u)
                z = causal_conv_ref(u, rho[l, :T])           # [B, T, D]
                h = gelu(rmsnorm(z) @ w1[l] + b1[l])
                u = z + h @ w2[l] + b2[l]
            outs = rmsnorm(u)                                # [B, T, D]
            return jnp.stack(streams), outs

        return fwd

    def fwd(emb, rho, wp, bp, scw, wo, bo, wv):
        u = emb  # [B, T, D]
        streams = []
        for op in range(cfg.ops):
            z = rmsnorm(u) @ wp[op] + bp[op]                 # [B, T, 3D]
            zm1 = jnp.pad(z, ((0, 0), (1, 0), (0, 0)))[:, :T]
            zm2 = jnp.pad(z, ((0, 0), (2, 0), (0, 0)))[:, :T]
            zc = scw[op, 0] * z + scw[op, 1] * zm1 + scw[op, 2] * zm2
            v, x1, x2 = jnp.split(zc, 3, axis=-1)
            c1 = causal_conv_ref(v, rho[2 * op, :T])
            h1 = x1 * c1
            c2 = causal_conv_ref(h1, rho[2 * op + 1, :T])
            h2 = x2 * c2
            u = u + h2 @ wo[op] + bo[op]
            streams += [v, h1]
        logits = rmsnorm(u) @ wv                             # [B, T, V]
        return jnp.stack(streams), logits

    return fwd


def prefill_fn(cfg: ModelConfig, P: int):
    """Prompt handling (Massaroli et al. Lemma 2.1 / paper §2.3.1): run a
    training-style forward over the P prompt positions, then emit the
    aggregated contribution of prompt streams to every future position
    ("fill in all contributions of y_[1..P] to z_[P+1..L] and forget the
    prompt ever existed"). After this, Algorithm 2 runs with re-based
    indices and P=0 semantics.

    Returns:
      streams [M, B, P, D], fut [M, B, L-P, D], out (last position),
      scstate at position P (hyena).
    """
    from .kernels.ref import causal_conv_ref

    fwd = forward_fn(cfg, P)

    def future_contrib(streams, rho):
        # fut[l, b, t, d] = sum_{i=1..P} streams[l,b,i,d] * rho[l, (P+t)-i, d]
        # one length-2L' FFT per (l, b): pad streams to L, convolve, slice.
        n = 2 * cfg.L
        sf = jnp.fft.rfft(streams, n=n, axis=2)              # [M, B, F, D]
        rf = jnp.fft.rfft(rho, n=n, axis=1)                  # [M, F, D]
        z = jnp.fft.irfft(sf * rf[:, None], n=n, axis=2)
        return z[:, :, P:cfg.L, :].astype(jnp.float32)

    if cfg.variant == "synthetic":

        def fn(emb, rho, w1, b1, w2, b2):
            streams, outs = fwd(emb, rho, w1, b1, w2, b2)
            fut = future_contrib(streams, rho)
            return streams, fut, outs[:, -1]

        return fn

    def fn(emb, rho, wp, bp, scw, wo, bo, wv):
        streams, logits = fwd(emb, rho, wp, bp, scw, wo, bo, wv)
        fut = future_contrib(streams, rho)
        # reconstruct short-conv state at the end of the prompt:
        # state = (z_P, z_{P-1}) per op, where z is the pre-shortconv proj.
        states = []
        u = emb
        for op in range(cfg.ops):
            z = rmsnorm(u) @ wp[op] + bp[op]
            zm1 = jnp.pad(z, ((0, 0), (1, 0), (0, 0)))[:, :P]
            zm2 = jnp.pad(z, ((0, 0), (2, 0), (0, 0)))[:, :P]
            zc = scw[op, 0] * z + scw[op, 1] * zm1 + scw[op, 2] * zm2
            v, x1, x2 = jnp.split(zc, 3, axis=-1)
            c1 = causal_conv_ref(v, rho[2 * op, :P])
            h1 = x1 * c1
            c2 = causal_conv_ref(h1, rho[2 * op + 1, :P])
            h2 = x2 * c2
            states.append(jnp.stack([z[:, -1], z[:, -2] if P >= 2
                                     else jnp.zeros_like(z[:, -1])]))
            u = u + h2 @ wo[op] + bo[op]
        scstate = jnp.stack(states)                          # [ops, 2, B, 3D]
        return streams, fut, logits[:, -1], scstate

    return fn
