"""AOT lowering: JAX/Pallas -> HLO-text artifacts + manifest + weights.

This is the ONLY python entrypoint in the system (`make artifacts`). It
emits, per model build:

    artifacts/<name>/
        manifest.json        artifact + config + ABI description
        model.bin            weights (tensorbin v1)
        golden.bin           deterministic rollout trace (exactness oracle)
        step.hlo.txt         per-position red-cell + block chain (Alg 2 l.6-8)
        filter_gen.hlo.txt   implicit filter -> rho[M, L, D]
        tau_fft_{U}.hlo.txt  FFT tile, one per power-of-two U (Appendix C)
        tau_direct_{U}.hlo.txt  Pallas direct tile (Conv1D analogue)
        prefill_{P}.hlo.txt  optional prompt prefill

HLO *text* is the interchange format: jax >= 0.5 serializes HloModuleProto
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
published `xla` crate binds) rejects; the text parser reassigns ids.

Input-name convention in the manifest:
    "$name"  runtime value, fresh every call (pending column, token, ...)
    "@name"  derived once at engine init (rho0, rho DFT caches, ...)
    "name"   weight from model.bin, uploaded once as a persistent buffer
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as mdl
from . import tensorbin
from .kernels.fft_tile import fft_tile
from .kernels.tile_conv import tile_conv


def to_hlo_text(lowered) -> str:
    """Lowered jax computation -> XLA HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _io_entry(name: str, arr_or_spec) -> Dict[str, Any]:
    shape = list(arr_or_spec.shape)
    return {"name": name, "shape": shape, "dtype": "f32"}


class Build:
    """One artifact directory for one ModelConfig."""

    def __init__(self, cfg: mdl.ModelConfig, out_dir: str):
        cfg.validate()
        self.cfg = cfg
        self.out = out_dir
        os.makedirs(out_dir, exist_ok=True)
        self.weights = mdl.init_weights(cfg)
        self.manifest: Dict[str, Any] = {
            "version": 1,
            "config": {
                "variant": cfg.variant, "M": cfg.M, "D": cfg.D, "H": cfg.H,
                "L": cfg.L, "B": cfg.B, "V": cfg.V, "G": cfg.G,
                "filter_hidden": cfg.filter_hidden,
                "filter_freqs": cfg.filter_freqs, "seed": cfg.seed,
            },
            "weights_file": "model.bin",
            "golden": None,
            "artifacts": [],
        }

    def _emit(self, name: str, fn, arg_names: Sequence[str], args,
              out_names: Sequence[str], extra: Dict[str, Any] | None = None):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *args)
        if not isinstance(outs, tuple):
            outs = (outs,)
        entry = {
            "name": name,
            "file": fname,
            "inputs": [_io_entry(n, a) for n, a in zip(arg_names, args)],
            "outputs": [_io_entry(n, o) for n, o in zip(out_names, outs)],
        }
        if extra:
            entry.update(extra)
        self.manifest["artifacts"].append(entry)
        print(f"  [{time.time()-t0:6.2f}s] {name}: "
              f"{[tuple(a.shape) for a in args]} -> {[tuple(o.shape) for o in outs]}")

    # ---- individual artifacts -------------------------------------------

    def emit_filter_gen(self):
        cfg = self.cfg
        names = mdl.filter_weight_names(cfg)
        args = [jax.ShapeDtypeStruct(self.weights[n].shape, jnp.float32)
                for n in names]
        self._emit("filter_gen", mdl.filter_gen_fn(cfg), names, args, ["rho"])

    def emit_step(self):
        cfg = self.cfg
        step = mdl.step_fn(cfg)
        wnames = mdl.step_weight_names(cfg)
        sd = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
        if cfg.variant == "synthetic":
            arg_names = ["$pending_col", "$a0", "@rho0"] + wnames
            args = [sd(cfg.M, cfg.B, cfg.D), sd(cfg.B, cfg.D), sd(cfg.M, cfg.D)]
            out_names = ["streams_col", "out"]
        else:
            arg_names = ["$pending_col", "$a0", "$scstate", "@rho0"] + wnames
            args = [sd(cfg.M, cfg.B, cfg.D), sd(cfg.B, cfg.D),
                    sd(cfg.ops, 2, cfg.B, 3 * cfg.D), sd(cfg.M, cfg.D)]
            out_names = ["streams_col", "out", "scstate"]
        args += [sd(*self.weights[n].shape) for n in wnames]
        self._emit("step", step, arg_names, args, out_names)

    def emit_taus(self):
        cfg = self.cfg
        sd = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
        u = 1
        while u <= cfg.L // 2:
            # FFT tile (precomputed filter DFT, split re/im — Appendix C)
            self._emit(
                f"tau_fft_{u}",
                lambda y, re, im: (fft_tile(y, re, im),),
                ["$y", "@rho_re", "@rho_im"],
                [sd(cfg.G, u, cfg.D), sd(cfg.G, u + 1, cfg.D),
                 sd(cfg.G, u + 1, cfg.D)],
                ["out"],
                {"kind": "tau_fft", "u": u},
            )
            # Pallas direct tile (quadratic in U)
            self._emit(
                f"tau_direct_{u}",
                lambda y, seg: (tile_conv(y, seg),),
                ["$y", "@rho_seg"],
                [sd(cfg.G, u, cfg.D), sd(cfg.G, 2 * u, cfg.D)],
                ["out"],
                {"kind": "tau_direct", "u": u},
            )
            u *= 2

    def emit_prefill(self, P: int):
        cfg = self.cfg
        assert 0 < P < cfg.L and P & (P - 1) == 0
        sd = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
        wnames = mdl.step_weight_names(cfg)
        fn = mdl.prefill_fn(cfg, P)
        args = [sd(cfg.B, P, cfg.D), sd(cfg.M, cfg.L, cfg.D)]
        args += [sd(*self.weights[n].shape) for n in wnames]
        arg_names = ["$emb", "@rho"] + wnames
        if cfg.variant == "synthetic":
            out_names = ["streams", "fut", "out"]
        else:
            out_names = ["streams", "fut", "out", "scstate"]
        self._emit(f"prefill_{P}", fn, arg_names, args, out_names,
                   {"kind": "prefill", "p": P})

    # ---- golden rollout (exactness oracle for the rust engines) ---------

    def emit_golden(self, steps: int):
        cfg = self.cfg
        w = self.weights
        rho = mdl.filter_gen(cfg, w["filt.w1"], w["filt.b1"], w["filt.w2"],
                             w["filt.alpha"])
        rho_np = np.asarray(rho)
        step = mdl.step_fn(cfg)
        wnames = mdl.step_weight_names(cfg)
        ws = [w[n] for n in wnames]
        rho0 = rho[:, 0, :]

        # deterministic start: embedding of token 0 (hyena) or unit vec
        if cfg.variant == "hyena":
            a0 = jnp.tile(w["embed"][0][None, :], (cfg.B, 1))
        else:
            a0 = jnp.ones((cfg.B, cfg.D), jnp.float32) / np.sqrt(cfg.D)
        scstate = (jnp.zeros((cfg.ops, 2, cfg.B, 3 * cfg.D), jnp.float32)
                   if cfg.variant == "hyena" else None)

        streams = np.zeros((cfg.M, cfg.B, steps, cfg.D), np.float32)
        outs = []
        tokens = []
        a0s = []
        for i in range(steps):
            a0s.append(np.asarray(a0))
            pend = np.zeros((cfg.M, cfg.B, cfg.D), np.float32)
            for l in range(cfg.M):
                for j in range(i):
                    pend[l] += streams[l, :, j, :] * rho_np[l, i - j, :]
            if cfg.variant == "synthetic":
                s_col, out = step(jnp.asarray(pend), a0, rho0, *ws)
                a0 = out  # noise-free sampler (sigma = 0)
            else:
                s_col, out, scstate = step(jnp.asarray(pend), a0, scstate,
                                           rho0, *ws)
                tok = int(jnp.argmax(out[0]))
                tokens.append(tok)
                a0 = jnp.tile(w["embed"][tok][None, :], (cfg.B, 1))
            streams[:, :, i, :] = np.asarray(s_col)
            outs.append(np.asarray(out))
        tensors = {
            "streams": streams,
            "outs": np.stack(outs, axis=1),  # [B, steps, ·]
            "a0s": np.stack(a0s, axis=1),    # [B, steps, D]
        }
        if tokens:
            tensors["tokens"] = np.asarray(tokens, np.float32)[None, :]
        tensorbin.write(os.path.join(self.out, "golden.bin"), tensors)
        self.manifest["golden"] = {"file": "golden.bin", "steps": steps}
        print(f"  golden rollout: {steps} steps")

    def finish(self):
        tensorbin.write(os.path.join(self.out, "model.bin"),
                        {k: np.asarray(v) for k, v in self.weights.items()})
        with open(os.path.join(self.out, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"  wrote {self.out}/manifest.json "
              f"({len(self.manifest['artifacts'])} artifacts)")


def build_one(cfg: mdl.ModelConfig, out_dir: str, golden_steps: int,
              prefill: int) -> None:
    print(f"build {out_dir}: variant={cfg.variant} M={cfg.M} D={cfg.D} "
          f"L={cfg.L} B={cfg.B}")
    b = Build(cfg, out_dir)
    b.emit_filter_gen()
    b.emit_step()
    b.emit_taus()
    if prefill:
        b.emit_prefill(prefill)
    if golden_steps:
        b.emit_golden(golden_steps)
    b.finish()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts root directory")
    ap.add_argument("--variant", default="both",
                    choices=["synthetic", "hyena", "both"])
    ap.add_argument("--m", type=int, default=6)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--hidden", type=int, default=0, help="0 = 2*D")
    ap.add_argument("--l", type=int, default=4096)
    ap.add_argument("--b", type=int, default=1)
    ap.add_argument("--v", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--golden-steps", type=int, default=48)
    ap.add_argument("--prefill", type=int, default=0,
                    help="also emit a prefill artifact for this prompt length")
    ap.add_argument("--name", default="", help="subdirectory name override")
    args = ap.parse_args()

    variants = ["synthetic", "hyena"] if args.variant == "both" else [args.variant]
    builds = []
    for variant in variants:
        cfg = mdl.ModelConfig(
            variant=variant, M=args.m, D=args.d,
            H=args.hidden or 2 * args.d, L=args.l, B=args.b, V=args.v,
            seed=args.seed)
        sub = args.name or variant
        out_dir = os.path.join(args.out, sub)
        build_one(cfg, out_dir, args.golden_steps, args.prefill)
        builds.append(sub)
    # top-level stamp (Makefile dependency anchor)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"builds": builds}, f, indent=1)


if __name__ == "__main__":
    main()
