"""Pure-jnp oracles for the L1 kernels.

Every Pallas kernel in this package is checked against the functions here
(pytest + hypothesis). These are also the semantic definition of the tile
primitive `tau` from the paper:

    tau(y, [l,r], rho, [l',r'])_t = sum_{i=l}^{r} y_i * rho_{t-i}     (Lemma 1)

with the Flash-Inference tile shape l = i-U+1, r = i, l' = i+1, r' = i+U,
so in tile-local coordinates (j = input offset, k = output offset):

    out[k] = sum_{j=0}^{U-1} y[j] * rho[U + k - j],   k = 0..U-1

where rho is the length-2U filter prefix rho[0..2U-1] (index 0 is unused by
the tile — it belongs to the red cell / diagonal).
"""

from __future__ import annotations

import jax.numpy as jnp


def tau_ref(y: jnp.ndarray, rho_seg: jnp.ndarray) -> jnp.ndarray:
    """Reference tile contribution.

    Args:
      y:        [G, U, D] tile inputs (positions i-U+1 .. i of the stream).
      rho_seg:  [G, 2U, D] filter prefix rho[0 .. 2U-1] per group/channel.

    Returns:
      [G, U, D] contributions to outputs at positions i+1 .. i+U.
    """
    G, U, D = y.shape
    assert rho_seg.shape == (G, 2 * U, D)
    # out[g, k, d] = sum_j y[g, j, d] * rho[g, U + k - j, d]
    ks = jnp.arange(U)[:, None]  # [U, 1]
    js = jnp.arange(U)[None, :]  # [1, U]
    idx = U + ks - js  # [U, U] values in [1, 2U-1]
    gathered = rho_seg[:, idx, :]  # [G, U, U, D]
    return jnp.einsum("gjd,gkjd->gkd", y, gathered)


def tau_ref_absolute(y_full: jnp.ndarray, rho: jnp.ndarray, l: int, r: int,
                     lp: int, rp: int) -> jnp.ndarray:
    """Lemma-1 tau in absolute coordinates (1-indexed inclusive ranges).

    y_full: [T, D] full stream, rho: [T, D]. Returns [rp-lp+1, D] where
    row t-lp = sum_{i=l}^{r} y_i * rho_{t-i} for t in [lp, rp].
    """
    out = []
    for t in range(lp, rp + 1):
        acc = jnp.zeros(y_full.shape[1], y_full.dtype)
        for i in range(l, r + 1):
            if 0 <= t - i:
                acc = acc + y_full[i - 1] * rho[t - i]
        out.append(acc)
    return jnp.stack(out)


def causal_conv_ref(y: jnp.ndarray, rho: jnp.ndarray) -> jnp.ndarray:
    """Full causal depthwise convolution (training-style).

    y: [..., T, D], rho: [T, D]  ->  z[..., t, d] = sum_{i<=t} y_i * rho_{t-i}.
    FFT-based, exact up to f32 roundoff.
    """
    T = y.shape[-2]
    n = 2 * T
    yf = jnp.fft.rfft(y, n=n, axis=-2)
    rf = jnp.fft.rfft(rho, n=n, axis=-2)
    z = jnp.fft.irfft(yf * rf, n=n, axis=-2)
    return z[..., :T, :].astype(y.dtype)


def causal_conv_naive(y: jnp.ndarray, rho: jnp.ndarray) -> jnp.ndarray:
    """O(T^2) direct causal conv — the ultimate ground truth for tests."""
    T, D = y.shape[-2], y.shape[-1]
    out = jnp.zeros_like(y)
    for t in range(T):
        acc = jnp.zeros(y.shape[:-2] + (D,), y.dtype)
        for i in range(t + 1):
            acc = acc + y[..., i, :] * rho[t - i]
        out = out.at[..., t, :].set(acc)
    return out


def cmul_ref(are: jnp.ndarray, aim: jnp.ndarray, bre: jnp.ndarray,
             bim: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Complex multiply on split-real tensors (same shape each)."""
    return are * bre - aim * bim, are * bim + aim * bre


def fft_tile_ref(y: jnp.ndarray, rho_seg: jnp.ndarray) -> jnp.ndarray:
    """FFT-path tile (Appendix C: one 2U cyclic convolution, middle U kept).

    Same I/O contract as tau_ref; used to check the fft_tile artifact path.
    """
    G, U, D = y.shape
    n = 2 * U
    yf = jnp.fft.rfft(y, n=n, axis=1)
    rf = jnp.fft.rfft(rho_seg, n=n, axis=1)
    z = jnp.fft.irfft(yf * rf, n=n, axis=1)
    return z[:, U:2 * U, :].astype(y.dtype)
