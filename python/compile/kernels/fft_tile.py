"""L1 FFT-path tile: rfft -> Pallas split-real complex multiply -> irfft.

This is the quasilinear tau implementation (the paper's FFT / FlashFFT
analogue), engineered per Appendix C:

  * one cyclic FFT of order 2U (not a 4U padded one) — the wrap-around of
    outputs [2U, 3U-2] onto [0, U-2] never touches the kept slice [U, 2U-1];
  * the filter prefix DFT rho_hat = rfft(rho[0:2U]) is PRECOMPUTED by the
    rust coordinator once per (layer, U) and passed in as split re/im
    tensors, so each tile costs 2 DFTs instead of 3 (the paper's x1.5).

The spectral pointwise product is a Pallas kernel (`cmul`) — on TPU this is
the VPU-bound stage whose BlockSpec tiles the (U+1) frequency bins x D
lanes; the FFTs themselves lower to the backend's native FFT op.

Complex tensors never cross the artifact ABI: the xla 0.1.6 crate has no
c64 literal constructors, so everything is split re/im f32 and recombined
with lax.complex inside the graph.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_D = 128


def _cmul_kernel(are_ref, aim_ref, bre_ref, bim_ref, ore_ref, oim_ref):
    are, aim = are_ref[...], aim_ref[...]
    bre, bim = bre_ref[...], bim_ref[...]
    ore_ref[...] = are * bre - aim * bim
    oim_ref[...] = are * bim + aim * bre


@functools.partial(jax.jit, static_argnames=("interpret",))
def cmul(are, aim, bre, bim, *, interpret: bool = True):
    """Split-real complex multiply, elementwise over [G, F, D] tensors."""
    G, F, D = are.shape
    db = BLOCK_D if D % BLOCK_D == 0 else D
    grid = (G, D // db)
    spec = pl.BlockSpec((None, F, db), lambda g, d: (g, 0, d))
    out_shape = jax.ShapeDtypeStruct((G, F, D), are.dtype)
    return pl.pallas_call(
        _cmul_kernel,
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=(spec, spec),
        out_shape=(out_shape, out_shape),
        interpret=interpret,
    )(are, aim, bre, bim)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fft_tile(y: jnp.ndarray, rho_re: jnp.ndarray, rho_im: jnp.ndarray, *,
             interpret: bool = True) -> jnp.ndarray:
    """FFT tile with precomputed filter DFT.

    y:       [G, U, D] tile inputs.
    rho_re/rho_im: [G, U+1, D] split rfft of the length-2U filter prefix.
    Returns [G, U, D].
    """
    G, U, D = y.shape
    assert rho_re.shape == (G, U + 1, D)
    assert rho_im.shape == (G, U + 1, D)
    n = 2 * U
    yf = jnp.fft.rfft(y, n=n, axis=1)  # [G, U+1, D] complex
    pre, pim = cmul(jnp.real(yf).astype(y.dtype), jnp.imag(yf).astype(y.dtype),
                    rho_re, rho_im, interpret=interpret)
    prod = jax.lax.complex(pre, pim)
    z = jnp.fft.irfft(prod, n=n, axis=1)
    return z[:, U:2 * U, :].astype(y.dtype)
