"""L1 Pallas kernel: direct depthwise tile convolution (the `tau` tile).

This is the quadratic-in-U tile primitive — the analogue of the paper's
Conv1D / FlashConv1D implementations of tau. Its FLOP count is U^2 * D per
group, but for small tiles it beats the FFT path because it has no
transform overhead; the Hybrid dispatcher (rust, L3) picks it for small U
exactly like the paper's hybrid picks Conv1D/FlashConv1D.

Tile-local contract (see kernels/ref.py):

    out[g, k, d] = sum_{j=0}^{U-1} y[g, j, d] * rho_seg[g, U + k - j, d]

TPU mapping (DESIGN.md §Hardware-Adaptation): grid is (G, D/BLOCK_D); each
program holds y[U, BLOCK_D] and rho_seg[2U, BLOCK_D] in VMEM and runs a
U-step MAC loop on the VPU (depthwise conv has no contraction dimension, so
the MXU is idle — the FFT path is the MXU-free roofline alternative).
VMEM footprint: (U + 2U + U) * BLOCK_D * 4B; at U=2048, BLOCK_D=128 this is
4 MB, comfortably under the ~16 MB budget and double-bufferable.

Kernels are lowered with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret mode emits plain HLO with identical
semantics (correctness is what we measure on this testbed — see DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# D-blocking used when D is a multiple of the block; otherwise a single
# program spans the whole D axis (correctness first, structure documented).
BLOCK_D = 128


def _tile_conv_kernel(y_ref, rho_ref, o_ref):
    """One (g, d-block) program: U-step shifted MAC over the tile."""
    U = y_ref.shape[0]
    y = y_ref[...]          # [U, Db]   (VMEM-resident)
    rho = rho_ref[...]      # [2U, Db]

    def body(j, acc):
        # rho[U - j + k] for k = 0..U-1  ->  slice [U-j, 2U-j)
        seg = jax.lax.dynamic_slice_in_dim(rho, U - j, U, axis=0)
        return acc + y[j][None, :] * seg

    o_ref[...] = jax.lax.fori_loop(0, U, body, jnp.zeros_like(o_ref))


@functools.partial(jax.jit, static_argnames=("interpret",))
def tile_conv(y: jnp.ndarray, rho_seg: jnp.ndarray, *,
              interpret: bool = True) -> jnp.ndarray:
    """Direct tile convolution. y: [G, U, D], rho_seg: [G, 2U, D] -> [G, U, D]."""
    G, U, D = y.shape
    assert rho_seg.shape == (G, 2 * U, D), (y.shape, rho_seg.shape)
    db = BLOCK_D if D % BLOCK_D == 0 else D
    grid = (G, D // db)
    return pl.pallas_call(
        _tile_conv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, U, db), lambda g, d: (g, 0, d)),
            pl.BlockSpec((None, 2 * U, db), lambda g, d: (g, 0, d)),
        ],
        out_specs=pl.BlockSpec((None, U, db), lambda g, d: (g, 0, d)),
        out_shape=jax.ShapeDtypeStruct((G, U, D), y.dtype),
        interpret=interpret,
    )(y, rho_seg)
