//! Figure 2b: cumulative time spent in the mixer (long-convolution) part
//! of Hyena inference as generation progresses — the paper's "50x better
//! scaling" plot. Quadratic baselines vs the quasilinear tiling.
//!
//! Knobs: FI_ARTIFACTS_HYENA, FI_MAX_LEN.

use flash_inference::engine::{Engine, EngineOpts, Method};
use flash_inference::runtime::Runtime;
use flash_inference::tau::TauKind;
use flash_inference::util::benchkit::{self, Table};

fn main() -> anyhow::Result<()> {
    let Some(dir) =
        benchkit::require_artifacts(&benchkit::env_str("FI_ARTIFACTS_HYENA", "artifacts/hyena"))
    else {
        return Ok(());
    };
    let rt = Runtime::load(&dir)?;
    let len = benchkit::env_usize("FI_MAX_LEN", rt.dims.l);

    println!("\n=== Fig 2b: cumulative mixer time vs position (Hyena, L={len}) ===\n");

    let methods: [(&str, Method, TauKind); 3] = [
        ("lazy", Method::Lazy, TauKind::RustDirect),
        ("eager", Method::Eager, TauKind::RustDirect),
        ("hybrid", Method::Flash, TauKind::Hybrid),
    ];
    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    for (name, method, tau) in methods {
        let mut eng = Engine::new(&rt, EngineOpts { method, tau, ..Default::default() })?;
        eng.prewarm(len)?;
        // one warmup, one measured (paper protocol scaled to this testbed)
        eng.generate(len)?;
        let out = eng.generate(len)?;
        series.push((name.to_string(), out.metrics.cumulative_mixer_ns()));
    }

    let mut table = Table::new(&["position", "lazy_ms", "eager_ms", "hybrid_ms", "lazy/hybrid"]);
    let mut cp = 64;
    while cp <= len {
        let at = |s: &[f64]| s[cp - 1] / 1e6;
        let lazy = at(&series[0].1);
        let eager = at(&series[1].1);
        let hybrid = at(&series[2].1);
        table.row(vec![
            cp.to_string(),
            format!("{lazy:.1}"),
            format!("{eager:.1}"),
            format!("{hybrid:.2}"),
            format!("{:.1}x", lazy / hybrid.max(1e-9)),
        ]);
        cp *= 2;
    }
    table.print();
    let final_ratio = series[0].1[len - 1] / series[2].1[len - 1].max(1e-9);
    println!(
        "\nfinal cumulative mixer ratio (lazy/hybrid) at L={len}: {final_ratio:.1}x \
         (paper: up to 50x at L=2^17 on H100)"
    );
    let csv = table.write_csv("fig2b_mixer_cumulative")?;
    println!("csv: {}", csv.display());
    Ok(())
}
