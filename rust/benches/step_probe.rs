//! Perf probe: decompose the per-token `step` cost (upload / execute /
//! fetch) — the quantitative basis for EXPERIMENTS.md §Perf's conclusion
//! that the non-mixer path sits at the PJRT-CPU compute floor (the paper's
//! Fig 3c observation on this testbed).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use flash_inference::runtime::{BoundArtifact, Runtime};
use flash_inference::util::benchkit;

fn main() -> anyhow::Result<()> {
    let Some(dir) = benchkit::require_artifacts(&benchkit::env_str(
        "FI_ARTIFACTS_SYN",
        "artifacts/synthetic",
    )) else {
        return Ok(());
    };
    let rt = Runtime::load(&dir)?;
    let dims = rt.dims;
    let (m, b, d, g) = (dims.m, dims.b, dims.d, dims.g);
    let rho0 = vec![0.01f32; m * d];
    let mut derived = HashMap::new();
    derived.insert("@rho0".to_string(), Arc::new(rt.upload(&rho0, &[m, d])?));
    let step = BoundArtifact::bind(&rt, "step", &derived)?;
    let pend = vec![0.1f32; g * d];
    let a0 = vec![0.2f32; b * d];
    let n = benchkit::env_usize("FI_RUNS", 2000);

    for _ in 0..100 {
        let pb = rt.upload(&pend, &[m, b, d])?;
        let ab = rt.upload(&a0, &[b, d])?;
        let _ = step.call(&[&pb, &ab])?;
    }

    println!("\n=== step-call cost decomposition ({n} iters) ===\n");
    let t0 = Instant::now();
    for _ in 0..n {
        let _pb = rt.upload(&pend, &[m, b, d])?;
        let _ab = rt.upload(&a0, &[b, d])?;
    }
    let upload = t0.elapsed().as_secs_f64() * 1e6 / n as f64;

    let pb = rt.upload(&pend, &[m, b, d])?;
    let ab = rt.upload(&a0, &[b, d])?;
    let exe = rt.executable("step")?;
    let mut wi = Vec::new();
    for inp in &exe.spec.inputs {
        if inp.is_weight() {
            wi.push(rt.weight_buffer(&inp.name)?);
        }
    }
    let rho0b = rt.upload(&rho0, &[m, d])?;
    let mut widx = 0;
    let args: Vec<&xla::PjRtBuffer> = exe
        .spec
        .inputs
        .iter()
        .map(|inp| {
            if inp.name == "$pending_col" {
                &pb
            } else if inp.name == "$a0" {
                &ab
            } else if inp.name == "@rho0" {
                &rho0b
            } else {
                let r = wi[widx].as_ref();
                widx += 1;
                r
            }
        })
        .collect();

    let t0 = Instant::now();
    for _ in 0..n {
        let _outs = exe.call_buffers(&args)?;
    }
    let execute = t0.elapsed().as_secs_f64() * 1e6 / n as f64;

    let t0 = Instant::now();
    for _ in 0..n {
        let outs = exe.call_buffers(&args)?;
        let _lit = outs[0][0].to_literal_sync()?;
    }
    let exec_lit = t0.elapsed().as_secs_f64() * 1e6 / n as f64;

    let t0 = Instant::now();
    for _ in 0..n {
        let outs = step.call(&[&pb, &ab])?;
        let _v: Vec<f32> = outs[0].to_vec()?;
    }
    let full = t0.elapsed().as_secs_f64() * 1e6 / n as f64;

    println!("  upload ($-inputs)      {upload:>8.1} us");
    println!("  execute (on-device)    {execute:>8.1} us");
    println!("  + literal fetch        {:>8.1} us", exec_lit - execute);
    println!("  + decompose + to_vec   {:>8.1} us", full - exec_lit);
    println!("  = full step            {full:>8.1} us");
    println!(
        "\nweight streaming floor: M(2DH)·4B = {} KB/token ⇒ the execute cost \
         is dominated by real XLA-CPU compute, not dispatch (~10us, cf. the \
         U=1 pjrt tau call in fig3a).",
        m * 2 * d * dims.h * 4 / 1024
    );
    Ok(())
}
