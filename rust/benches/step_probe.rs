//! Perf probe for the per-token critical path, in two parts:
//!
//! 1. **Overlap probe** (artifact-free, always runs, emits
//!    `BENCH_step_probe.json`): drives the deadline-fenced pipeline shape
//!    on synthetic data — submit a gray-tile rfft job to the executor
//!    worker, emulate the red-step critical path for a configurable
//!    budget, then fence — and reports fence-wait vs hidden tau time per
//!    tile size U. This is the quantitative evidence that tau time moved
//!    off the critical path, runnable on any machine (the CI bench-smoke
//!    job uploads the JSON).
//! 2. **Step decomposition** (needs `make artifacts`): the original
//!    upload / execute / fetch split of the PJRT `step` call — the basis
//!    for EXPERIMENTS.md §Perf's conclusion that the non-mixer path sits
//!    at the PJRT-CPU compute floor.
//!
//! The overlap probe sweeps a **workers** dimension (FI_WORKERS, default
//! "1,2,4"): at W workers the gray tile is sharded into W disjoint-dst
//! jobs — each with its own output buffer and scratch, so nothing
//! serializes them — submitted concurrently before the red work. The
//! per-worker-count `async_us_w{W}` / `fence_wait_us_w{W}` columns in
//! `BENCH_step_probe.json` make the "fence-wait → ~0 at large U" gate
//! machine-checkable against the single-worker baseline.
//!
//! Knobs: FI_MIN_U, FI_MAX_U, FI_G, FI_D, FI_RED_US, FI_RUNS, FI_WORKERS,
//! FI_BENCH_OUT, FI_ARTIFACTS_SYN.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use flash_inference::fft::{self, RfftPlan, TileScratch};
use flash_inference::runtime::{BoundArtifact, Runtime};
use flash_inference::util::benchkit::{self, Table};
use flash_inference::util::json::Json;
use flash_inference::util::prng::Prng;
use flash_inference::util::threadpool::ThreadPool;

/// Busy red-path emulation: `iters` FMA sweeps over `buf`.
fn red_work(buf: &mut [f32], iters: usize) {
    for _ in 0..iters {
        for v in buf.iter_mut() {
            *v = *v * 1.000_000_1 + 1e-9;
        }
    }
}

/// Calibrate how many `red_work` sweeps of `buf` fill `target_us`.
fn calibrate_red(buf: &mut [f32], target_us: f64) -> usize {
    let probe = 64;
    let t0 = Instant::now();
    red_work(buf, probe);
    let per_iter_us = t0.elapsed().as_secs_f64() * 1e6 / probe as f64;
    ((target_us / per_iter_us).ceil() as usize).max(1)
}

fn overlap_probe() -> anyhow::Result<()> {
    let min_u = benchkit::env_usize("FI_MIN_U", 16);
    let max_u = benchkit::env_usize("FI_MAX_U", 1024);
    let g = benchkit::env_usize("FI_G", 8);
    let d = benchkit::env_usize("FI_D", 64);
    let red_us = benchkit::env_usize("FI_RED_US", 100) as f64;
    let runs = benchkit::env_usize("FI_RUNS", 100);
    let out_path = benchkit::env_str("FI_BENCH_OUT", "BENCH_step_probe.json");
    let workers_list: Vec<usize> = benchkit::env_str("FI_WORKERS", "1,2,4")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .filter(|&w: &usize| w >= 1)
        .collect();
    assert!(min_u.is_power_of_two() && max_u.is_power_of_two() && min_u <= max_u);
    assert!(!workers_list.is_empty(), "FI_WORKERS must name at least one worker count");

    println!("\n=== overlap probe: deadline-fenced tau vs the red critical path ===");
    println!(
        "G={g} D={d} | red-path budget {red_us:.0}us | workers {workers_list:?} | \
         medians-of-means over {runs} runs\n"
    );

    let mut rng = Prng::new(0x0F_F10AD);
    let mut red_buf: Vec<f32> = (0..4096).map(|_| rng.normal_f32()).collect();
    let red_iters = calibrate_red(&mut red_buf, red_us);

    let mut headers: Vec<String> = ["U", "tau_us", "sync_us"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    for &w in &workers_list {
        headers.push(format!("async_us_w{w}"));
        headers.push(format!("fence_us_w{w}"));
    }
    headers.push("hidden_%".into());
    headers.push("speedup".into());
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut table = Table::new(&header_refs);
    let mut rows = Vec::new();

    let mut u = min_u;
    while u <= max_u {
        let plan = Arc::new(RfftPlan::new(2 * u));
        let rho: Vec<f32> = (0..2 * u * d).map(|_| rng.normal_f32()).collect();
        let (sre, sim) = fft::spectrum_halfplanes(&plan, &rho, d);
        let spec = Arc::new((sre, sim));
        let y: Arc<Vec<f32>> =
            Arc::new((0..g * u * d).map(|_| rng.normal_f32()).collect());

        let tile = {
            // out + scratch live behind one lock: the job owns them while
            // in flight, the main thread only touches them after the fence
            let state = Arc::new(Mutex::new((vec![0.0f32; g * u * d], TileScratch::default())));
            let (y, spec, plan) = (y.clone(), spec.clone(), plan.clone());
            move || {
                let mut st = state.lock().unwrap();
                let (out, scratch) = &mut *st;
                for gi in 0..g {
                    fft::tile_conv_rfft_into(
                        &plan,
                        &y[gi * u * d..(gi + 1) * u * d],
                        &spec.0,
                        &spec.1,
                        &mut out[gi * u * d..(gi + 1) * u * d],
                        scratch,
                        d,
                    );
                }
            }
        };

        // sync baseline: tau inline, then red work — everything on path
        let tau_only = benchkit::bench(2, runs, tile.clone());
        let sync = {
            let t = tile.clone();
            benchkit::bench(2, runs, || {
                t();
                red_work(&mut red_buf, red_iters);
            })
        };

        // async pipeline per worker count W: shard the tile into W
        // disjoint-dst jobs (contiguous group ranges), submit all, run the
        // red work, then fence. Each shard owns its *own* out buffer and
        // scratch — a shared lock would serialize the shards and report
        // fake concurrency.
        let mut per_w: Vec<(usize, f64, f64)> = Vec::new();
        for &w in &workers_list {
            let w_eff = w.min(g).max(1);
            let states: Vec<Arc<Mutex<(Vec<f32>, TileScratch)>>> = (0..w_eff)
                .map(|s| {
                    let (lo, hi) = (s * g / w_eff, (s + 1) * g / w_eff);
                    Arc::new(Mutex::new((
                        vec![0.0f32; (hi - lo) * u * d],
                        TileScratch::default(),
                    )))
                })
                .collect();
            let pool = ThreadPool::new(w_eff);
            let mut fence_ns_acc = 0.0f64;
            let async_stats = benchkit::bench(2, runs, || {
                let handles: Vec<_> = (0..w_eff)
                    .map(|s| {
                        let (lo, hi) = (s * g / w_eff, (s + 1) * g / w_eff);
                        let (y, spec, plan, state) =
                            (y.clone(), spec.clone(), plan.clone(), states[s].clone());
                        pool.submit(Box::new(move || {
                            let mut st = state.lock().unwrap();
                            let (out, scratch) = &mut *st;
                            for gi in lo..hi {
                                fft::tile_conv_rfft_into(
                                    &plan,
                                    &y[gi * u * d..(gi + 1) * u * d],
                                    &spec.0,
                                    &spec.1,
                                    &mut out[(gi - lo) * u * d..(gi - lo + 1) * u * d],
                                    scratch,
                                    d,
                                );
                            }
                        }))
                    })
                    .collect();
                red_work(&mut red_buf, red_iters);
                let f0 = Instant::now();
                for h in handles {
                    h.join().expect("tau shard");
                }
                fence_ns_acc += f0.elapsed().as_nanos() as f64;
            });
            let fence_us = fence_ns_acc / (runs + 2) as f64 / 1e3;
            per_w.push((w, async_stats.median_ns / 1e3, fence_us));
        }

        // legacy single-number columns keep their meaning: the W=1 run
        // (every FI_WORKERS list is expected to include 1 as baseline;
        // fall back to the first entry if not)
        let (_, async_us_1, fence_us_1) = *per_w
            .iter()
            .find(|(w, _, _)| *w == 1)
            .unwrap_or(&per_w[0]);
        let tau_us = tau_only.median_ns / 1e3;
        let hidden_pct = 100.0 * (tau_us - fence_us_1).max(0.0) / tau_us.max(1e-9);
        let speedup = sync.median_ns / 1e3 / async_us_1.max(1e-9);

        let mut cells = vec![
            u.to_string(),
            format!("{tau_us:.1}"),
            format!("{:.1}", sync.median_ns / 1e3),
        ];
        for &(_, a_us, f_us) in &per_w {
            cells.push(format!("{a_us:.1}"));
            cells.push(format!("{f_us:.1}"));
        }
        cells.push(format!("{hidden_pct:.1}"));
        cells.push(format!("{speedup:.2}x"));
        table.row(cells);

        let mut pairs = vec![
            ("u".to_string(), Json::Num(u as f64)),
            ("tau_us".to_string(), Json::Num(tau_us)),
            ("sync_us".to_string(), Json::Num(sync.median_ns / 1e3)),
            ("async_us".to_string(), Json::Num(async_us_1)),
            ("fence_wait_us".to_string(), Json::Num(fence_us_1)),
            ("hidden_pct".to_string(), Json::Num(hidden_pct)),
            ("overlap_speedup".to_string(), Json::Num(speedup)),
        ];
        for &(w, a_us, f_us) in &per_w {
            pairs.push((format!("async_us_w{w}"), Json::Num(a_us)));
            pairs.push((format!("fence_wait_us_w{w}"), Json::Num(f_us)));
        }
        rows.push(Json::from_pairs(
            pairs.iter().map(|(k, v)| (k.as_str(), v.clone())).collect(),
        ));
        u *= 2;
    }
    table.print();
    println!(
        "\nreading: while tau_us <= the red budget ({red_us:.0}us) the fence wait \
         stays near zero — the tile is fully hidden; past the crossover the \
         exposed residue is tau_us - {red_us:.0}us, which the multi-worker \
         columns show shrinking toward ~0 as W grows (disjoint-dst shards run \
         concurrently) and the split-tile path amortizes over later red steps."
    );

    let doc = Json::from_pairs(vec![
        ("bench", Json::Str("step_probe_overlap".into())),
        ("meta", benchkit::bench_meta(workers_list.iter().copied().max())),
        ("g", Json::Num(g as f64)),
        ("d", Json::Num(d as f64)),
        ("red_us", Json::Num(red_us)),
        ("runs", Json::Num(runs as f64)),
        (
            "workers",
            Json::Arr(workers_list.iter().map(|&w| Json::Num(w as f64)).collect()),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write(&out_path, doc.to_string_pretty())?;
    println!("wrote {out_path}");
    table.write_csv("step_probe_overlap")?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    overlap_probe()?;

    let Some(dir) = benchkit::require_artifacts(&benchkit::env_str(
        "FI_ARTIFACTS_SYN",
        "artifacts/synthetic",
    )) else {
        return Ok(());
    };
    let rt = Runtime::load(&dir)?;
    let dims = rt.dims;
    let (m, b, d, g) = (dims.m, dims.b, dims.d, dims.g);
    let rho0 = vec![0.01f32; m * d];
    let mut derived = HashMap::new();
    derived.insert("@rho0".to_string(), Arc::new(rt.upload(&rho0, &[m, d])?));
    let step = BoundArtifact::bind(&rt, "step", &derived)?;
    let pend = vec![0.1f32; g * d];
    let a0 = vec![0.2f32; b * d];
    let n = benchkit::env_usize("FI_RUNS", 2000);

    for _ in 0..100 {
        let pb = rt.upload(&pend, &[m, b, d])?;
        let ab = rt.upload(&a0, &[b, d])?;
        let _ = step.call(&[&pb, &ab])?;
    }

    println!("\n=== step-call cost decomposition ({n} iters) ===\n");
    let t0 = Instant::now();
    for _ in 0..n {
        let _pb = rt.upload(&pend, &[m, b, d])?;
        let _ab = rt.upload(&a0, &[b, d])?;
    }
    let upload = t0.elapsed().as_secs_f64() * 1e6 / n as f64;

    let pb = rt.upload(&pend, &[m, b, d])?;
    let ab = rt.upload(&a0, &[b, d])?;
    let exe = rt.executable("step")?;
    let mut wi = Vec::new();
    for inp in &exe.spec.inputs {
        if inp.is_weight() {
            wi.push(rt.weight_buffer(&inp.name)?);
        }
    }
    let rho0b = rt.upload(&rho0, &[m, d])?;
    let mut widx = 0;
    let args: Vec<&xla::PjRtBuffer> = exe
        .spec
        .inputs
        .iter()
        .map(|inp| {
            if inp.name == "$pending_col" {
                &pb
            } else if inp.name == "$a0" {
                &ab
            } else if inp.name == "@rho0" {
                &rho0b
            } else {
                let r = wi[widx].as_ref();
                widx += 1;
                r
            }
        })
        .collect();

    let t0 = Instant::now();
    for _ in 0..n {
        let _outs = exe.call_buffers(&args)?;
    }
    let execute = t0.elapsed().as_secs_f64() * 1e6 / n as f64;

    let t0 = Instant::now();
    for _ in 0..n {
        let outs = exe.call_buffers(&args)?;
        let _lit = outs[0][0].to_literal_sync()?;
    }
    let exec_lit = t0.elapsed().as_secs_f64() * 1e6 / n as f64;

    let t0 = Instant::now();
    for _ in 0..n {
        let outs = step.call(&[&pb, &ab])?;
        let _v: Vec<f32> = outs[0].to_vec()?;
    }
    let full = t0.elapsed().as_secs_f64() * 1e6 / n as f64;

    println!("  upload ($-inputs)      {upload:>8.1} us");
    println!("  execute (on-device)    {execute:>8.1} us");
    println!("  + literal fetch        {:>8.1} us", exec_lit - execute);
    println!("  + decompose + to_vec   {:>8.1} us", full - exec_lit);
    println!("  = full step            {full:>8.1} us");
    println!(
        "\nweight streaming floor: M(2DH)·4B = {} KB/token ⇒ the execute cost \
         is dominated by real XLA-CPU compute, not dispatch (~10us, cf. the \
         U=1 pjrt tau call in fig3a). The execute window is what the overlap \
         probe's red budget emulates: tau tiles up to that cost hide entirely.",
        m * 2 * d * dims.h * 4 / 1024
    );
    Ok(())
}
