//! Perf probe for the per-token critical path, in two parts:
//!
//! 1. **Overlap probe** (artifact-free, always runs, emits
//!    `BENCH_step_probe.json`): drives the deadline-fenced pipeline shape
//!    on synthetic data — submit a gray-tile rfft job to the executor
//!    worker, emulate the red-step critical path for a configurable
//!    budget, then fence — and reports fence-wait vs hidden tau time per
//!    tile size U. This is the quantitative evidence that tau time moved
//!    off the critical path, runnable on any machine (the CI bench-smoke
//!    job uploads the JSON).
//! 2. **Step decomposition** (needs `make artifacts`): the original
//!    upload / execute / fetch split of the PJRT `step` call — the basis
//!    for EXPERIMENTS.md §Perf's conclusion that the non-mixer path sits
//!    at the PJRT-CPU compute floor.
//!
//! Knobs: FI_MIN_U, FI_MAX_U, FI_G, FI_D, FI_RED_US, FI_RUNS,
//! FI_BENCH_OUT, FI_ARTIFACTS_SYN.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use flash_inference::fft::{self, RfftPlan, TileScratch};
use flash_inference::runtime::{BoundArtifact, Runtime};
use flash_inference::util::benchkit::{self, Table};
use flash_inference::util::json::Json;
use flash_inference::util::prng::Prng;
use flash_inference::util::threadpool::ThreadPool;

/// Busy red-path emulation: `iters` FMA sweeps over `buf`.
fn red_work(buf: &mut [f32], iters: usize) {
    for _ in 0..iters {
        for v in buf.iter_mut() {
            *v = *v * 1.000_000_1 + 1e-9;
        }
    }
}

/// Calibrate how many `red_work` sweeps of `buf` fill `target_us`.
fn calibrate_red(buf: &mut [f32], target_us: f64) -> usize {
    let probe = 64;
    let t0 = Instant::now();
    red_work(buf, probe);
    let per_iter_us = t0.elapsed().as_secs_f64() * 1e6 / probe as f64;
    ((target_us / per_iter_us).ceil() as usize).max(1)
}

fn overlap_probe() -> anyhow::Result<()> {
    let min_u = benchkit::env_usize("FI_MIN_U", 16);
    let max_u = benchkit::env_usize("FI_MAX_U", 1024);
    let g = benchkit::env_usize("FI_G", 8);
    let d = benchkit::env_usize("FI_D", 64);
    let red_us = benchkit::env_usize("FI_RED_US", 100) as f64;
    let runs = benchkit::env_usize("FI_RUNS", 100);
    let out_path = benchkit::env_str("FI_BENCH_OUT", "BENCH_step_probe.json");
    assert!(min_u.is_power_of_two() && max_u.is_power_of_two() && min_u <= max_u);

    println!("\n=== overlap probe: deadline-fenced tau vs the red critical path ===");
    println!("G={g} D={d} | red-path budget {red_us:.0}us | medians-of-means over {runs} runs\n");

    let mut rng = Prng::new(0x0F_F10AD);
    let mut red_buf: Vec<f32> = (0..4096).map(|_| rng.normal_f32()).collect();
    let red_iters = calibrate_red(&mut red_buf, red_us);

    let mut table = Table::new(&[
        "U", "tau_us", "sync_us", "async_us", "fence_wait_us", "hidden_%", "speedup",
    ]);
    let mut rows = Vec::new();

    let mut u = min_u;
    while u <= max_u {
        let plan = Arc::new(RfftPlan::new(2 * u));
        let rho: Vec<f32> = (0..2 * u * d).map(|_| rng.normal_f32()).collect();
        let (sre, sim) = fft::spectrum_halfplanes(&plan, &rho, d);
        let spec = Arc::new((sre, sim));
        let y: Arc<Vec<f32>> =
            Arc::new((0..g * u * d).map(|_| rng.normal_f32()).collect());
        // out + scratch live behind one lock: the job owns them while in
        // flight, the main thread only touches them after the fence
        let state = Arc::new(Mutex::new((vec![0.0f32; g * u * d], TileScratch::default())));

        let tile = {
            let (y, spec, state, plan) = (y.clone(), spec.clone(), state.clone(), plan.clone());
            move || {
                let mut st = state.lock().unwrap();
                let (out, scratch) = &mut *st;
                for gi in 0..g {
                    fft::tile_conv_rfft_into(
                        &plan,
                        &y[gi * u * d..(gi + 1) * u * d],
                        &spec.0,
                        &spec.1,
                        &mut out[gi * u * d..(gi + 1) * u * d],
                        scratch,
                        d,
                    );
                }
            }
        };

        // sync baseline: tau inline, then red work — everything on path
        let tau_only = benchkit::bench(2, runs, tile.clone());
        let sync = {
            let t = tile.clone();
            benchkit::bench(2, runs, || {
                t();
                red_work(&mut red_buf, red_iters);
            })
        };

        // async pipeline: submit, red work, fence — tau hides if it fits
        let pool = ThreadPool::new(1);
        let mut fence_ns_acc = 0.0f64;
        let async_stats = benchkit::bench(2, runs, || {
            let handle = pool.submit(Box::new(tile.clone()));
            red_work(&mut red_buf, red_iters);
            let f0 = Instant::now();
            handle.join().expect("tau job");
            fence_ns_acc += f0.elapsed().as_nanos() as f64;
        });
        let fence_us = fence_ns_acc / (runs + 2) as f64 / 1e3;
        let tau_us = tau_only.median_ns / 1e3;
        let hidden_pct = 100.0 * (tau_us - fence_us).max(0.0) / tau_us.max(1e-9);
        let speedup = sync.median_ns / async_stats.median_ns;

        table.row(vec![
            u.to_string(),
            format!("{tau_us:.1}"),
            format!("{:.1}", sync.median_ns / 1e3),
            format!("{:.1}", async_stats.median_ns / 1e3),
            format!("{fence_us:.1}"),
            format!("{hidden_pct:.1}"),
            format!("{speedup:.2}x"),
        ]);
        rows.push(Json::from_pairs(vec![
            ("u", Json::Num(u as f64)),
            ("tau_us", Json::Num(tau_us)),
            ("sync_us", Json::Num(sync.median_ns / 1e3)),
            ("async_us", Json::Num(async_stats.median_ns / 1e3)),
            ("fence_wait_us", Json::Num(fence_us)),
            ("hidden_pct", Json::Num(hidden_pct)),
            ("overlap_speedup", Json::Num(speedup)),
        ]));
        u *= 2;
    }
    table.print();
    println!(
        "\nreading: while tau_us <= the red budget ({red_us:.0}us) the fence wait \
         stays near zero — the tile is fully hidden; past the crossover the \
         exposed residue is tau_us - {red_us:.0}us, which is where the split-tile \
         path (urgent column now, FFT under the *next* red step too) takes over."
    );

    let doc = Json::from_pairs(vec![
        ("bench", Json::Str("step_probe_overlap".into())),
        ("g", Json::Num(g as f64)),
        ("d", Json::Num(d as f64)),
        ("red_us", Json::Num(red_us)),
        ("runs", Json::Num(runs as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write(&out_path, doc.to_string_pretty())?;
    println!("wrote {out_path}");
    table.write_csv("step_probe_overlap")?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    overlap_probe()?;

    let Some(dir) = benchkit::require_artifacts(&benchkit::env_str(
        "FI_ARTIFACTS_SYN",
        "artifacts/synthetic",
    )) else {
        return Ok(());
    };
    let rt = Runtime::load(&dir)?;
    let dims = rt.dims;
    let (m, b, d, g) = (dims.m, dims.b, dims.d, dims.g);
    let rho0 = vec![0.01f32; m * d];
    let mut derived = HashMap::new();
    derived.insert("@rho0".to_string(), Arc::new(rt.upload(&rho0, &[m, d])?));
    let step = BoundArtifact::bind(&rt, "step", &derived)?;
    let pend = vec![0.1f32; g * d];
    let a0 = vec![0.2f32; b * d];
    let n = benchkit::env_usize("FI_RUNS", 2000);

    for _ in 0..100 {
        let pb = rt.upload(&pend, &[m, b, d])?;
        let ab = rt.upload(&a0, &[b, d])?;
        let _ = step.call(&[&pb, &ab])?;
    }

    println!("\n=== step-call cost decomposition ({n} iters) ===\n");
    let t0 = Instant::now();
    for _ in 0..n {
        let _pb = rt.upload(&pend, &[m, b, d])?;
        let _ab = rt.upload(&a0, &[b, d])?;
    }
    let upload = t0.elapsed().as_secs_f64() * 1e6 / n as f64;

    let pb = rt.upload(&pend, &[m, b, d])?;
    let ab = rt.upload(&a0, &[b, d])?;
    let exe = rt.executable("step")?;
    let mut wi = Vec::new();
    for inp in &exe.spec.inputs {
        if inp.is_weight() {
            wi.push(rt.weight_buffer(&inp.name)?);
        }
    }
    let rho0b = rt.upload(&rho0, &[m, d])?;
    let mut widx = 0;
    let args: Vec<&xla::PjRtBuffer> = exe
        .spec
        .inputs
        .iter()
        .map(|inp| {
            if inp.name == "$pending_col" {
                &pb
            } else if inp.name == "$a0" {
                &ab
            } else if inp.name == "@rho0" {
                &rho0b
            } else {
                let r = wi[widx].as_ref();
                widx += 1;
                r
            }
        })
        .collect();

    let t0 = Instant::now();
    for _ in 0..n {
        let _outs = exe.call_buffers(&args)?;
    }
    let execute = t0.elapsed().as_secs_f64() * 1e6 / n as f64;

    let t0 = Instant::now();
    for _ in 0..n {
        let outs = exe.call_buffers(&args)?;
        let _lit = outs[0][0].to_literal_sync()?;
    }
    let exec_lit = t0.elapsed().as_secs_f64() * 1e6 / n as f64;

    let t0 = Instant::now();
    for _ in 0..n {
        let outs = step.call(&[&pb, &ab])?;
        let _v: Vec<f32> = outs[0].to_vec()?;
    }
    let full = t0.elapsed().as_secs_f64() * 1e6 / n as f64;

    println!("  upload ($-inputs)      {upload:>8.1} us");
    println!("  execute (on-device)    {execute:>8.1} us");
    println!("  + literal fetch        {:>8.1} us", exec_lit - execute);
    println!("  + decompose + to_vec   {:>8.1} us", full - exec_lit);
    println!("  = full step            {full:>8.1} us");
    println!(
        "\nweight streaming floor: M(2DH)·4B = {} KB/token ⇒ the execute cost \
         is dominated by real XLA-CPU compute, not dispatch (~10us, cf. the \
         U=1 pjrt tau call in fig3a). The execute window is what the overlap \
         probe's red budget emulates: tau tiles up to that cost hide entirely.",
        m * 2 * d * dims.h * 4 / 1024
    );
    Ok(())
}
