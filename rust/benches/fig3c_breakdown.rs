//! Figure 3c: end-to-end cumulative token time, split mixer vs non-mixer,
//! per tau implementation (synthetic setting). The paper's observation:
//! tiling-based methods shrink mixer time so much that fixed per-step
//! dispatch overhead (GPU kernel launch there, PJRT execute here) becomes
//! the visible cost — the non-mixer share grows.
//!
//! Knobs: FI_ARTIFACTS_SYN, FI_MAX_LEN.

use flash_inference::engine::{Engine, EngineOpts, Method};
use flash_inference::runtime::Runtime;
use flash_inference::tau::TauKind;
use flash_inference::util::benchkit::{self, Table};

fn main() -> anyhow::Result<()> {
    let Some(dir) = benchkit::require_artifacts(&benchkit::env_str(
        "FI_ARTIFACTS_SYN",
        "artifacts/synthetic",
    )) else {
        return Ok(());
    };
    let rt = Runtime::load(&dir)?;
    let len = benchkit::env_usize("FI_MAX_LEN", rt.dims.l.min(2048));

    println!("\n=== Fig 3c: e2e cumulative breakdown, mixer vs non-mixer (L={len}) ===\n");

    let settings: Vec<(&str, Method, TauKind)> = vec![
        ("lazy", Method::Lazy, TauKind::RustDirect),
        ("eager", Method::Eager, TauKind::RustDirect),
        ("pjrt-direct", Method::Flash, TauKind::PjrtDirect),
        ("pjrt-fft", Method::Flash, TauKind::PjrtFft),
        ("rust-direct", Method::Flash, TauKind::RustDirect),
        ("rust-fft", Method::Flash, TauKind::RustFft),
        ("hybrid", Method::Flash, TauKind::Hybrid),
    ];

    let mut table = Table::new(&[
        "method", "total_ms", "mixer_ms", "step_ms", "sample_ms", "mixer_%", "non_mixer_%",
    ]);
    for (name, method, tau) in settings {
        let mut eng = Engine::new(&rt, EngineOpts { method, tau, ..Default::default() })?;
        eng.prewarm(len)?;
        eng.generate(len)?; // warmup
        let out = eng.generate(len)?;
        let t = &out.metrics.totals;
        table.row(vec![
            name.to_string(),
            format!("{:.1}", t.total_ns() / 1e6),
            format!("{:.1}", t.mixer_ns / 1e6),
            format!("{:.1}", t.step_ns / 1e6),
            format!("{:.2}", t.sample_ns / 1e6),
            format!("{:.1}", 100.0 * t.mixer_ns / t.total_ns()),
            format!("{:.1}", 100.0 * t.non_mixer_ns() / t.total_ns()),
        ]);
    }
    table.print();
    println!(
        "\nnote: tiling methods expose the per-step dispatch overhead (paper §5.3's \
         CPU-dispatch observation) — the non-mixer share dominates once mixer \
         work is quasilinear."
    );
    table.write_csv("fig3c_breakdown")?;
    Ok(())
}
