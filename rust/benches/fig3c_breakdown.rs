//! Figure 3c: end-to-end cumulative token time, split mixer vs non-mixer,
//! per tau implementation (synthetic setting). The paper's observation:
//! tiling-based methods shrink mixer time so much that fixed per-step
//! dispatch overhead (GPU kernel launch there, PJRT execute here) becomes
//! the visible cost — the non-mixer share grows.
//!
//! Extended for the deadline-fenced executor: the sync rows pin every
//! gray tile to the critical path (the paper's original accounting); the
//! async rows run the same tau on the executor worker and report how much
//! of it the fence re-exposed (`fence_ms`) vs hid behind the red path
//! (`hidden_ms`). `total_ms` is always critical-path time, so
//! sync-vs-async rows are directly comparable.
//!
//! Knobs: FI_ARTIFACTS_SYN, FI_MAX_LEN, FI_SPLIT_MIN_U.

use flash_inference::engine::{Engine, EngineOpts, Method};
use flash_inference::runtime::Runtime;
use flash_inference::tau::TauKind;
use flash_inference::util::benchkit::{self, Table};

fn main() -> anyhow::Result<()> {
    let Some(dir) = benchkit::require_artifacts(&benchkit::env_str(
        "FI_ARTIFACTS_SYN",
        "artifacts/synthetic",
    )) else {
        return Ok(());
    };
    let rt = Runtime::load(&dir)?;
    let len = benchkit::env_usize("FI_MAX_LEN", rt.dims.l.min(2048));
    let split_u = benchkit::env_usize("FI_SPLIT_MIN_U", 64);

    println!("\n=== Fig 3c: e2e cumulative breakdown, mixer vs non-mixer (L={len}) ===\n");

    struct Setting {
        name: &'static str,
        method: Method,
        tau: TauKind,
        async_mixer: bool,
        split_min_u: usize,
    }
    let row = |name, method, tau, async_mixer, split_min_u| Setting {
        name,
        method,
        tau,
        async_mixer,
        split_min_u,
    };
    let settings = vec![
        row("lazy", Method::Lazy, TauKind::RustDirect, false, 0),
        row("eager", Method::Eager, TauKind::RustDirect, false, 0),
        row("pjrt-direct", Method::Flash, TauKind::PjrtDirect, false, 0),
        row("pjrt-fft", Method::Flash, TauKind::PjrtFft, false, 0),
        row("rust-direct", Method::Flash, TauKind::RustDirect, false, 0),
        row("rust-fft", Method::Flash, TauKind::RustFft, false, 0),
        row("hybrid", Method::Flash, TauKind::Hybrid, false, 0),
        // deadline-fenced executor: same tau FLOPs, off the critical path
        row("rust-direct+async", Method::Flash, TauKind::RustDirect, true, 0),
        row("rust-fft+async", Method::Flash, TauKind::RustFft, true, 0),
        row("rust-fft+async+split", Method::Flash, TauKind::RustFft, true, split_u),
    ];

    let mut table = Table::new(&[
        "method", "total_ms", "mixer_ms", "fence_ms", "hidden_ms", "step_ms", "sample_ms",
        "mixer_%", "non_mixer_%",
    ]);
    for s in settings {
        let mut eng = Engine::new(
            &rt,
            EngineOpts {
                method: s.method,
                tau: s.tau,
                async_mixer: s.async_mixer,
                split_min_u: s.split_min_u,
                ..Default::default()
            },
        )?;
        eng.prewarm(len)?;
        eng.generate(len)?; // warmup
        let out = eng.generate(len)?;
        let t = &out.metrics.totals;
        table.row(vec![
            s.name.to_string(),
            format!("{:.1}", t.total_ns() / 1e6),
            format!("{:.1}", t.mixer_ns / 1e6),
            format!("{:.2}", t.fence_ns / 1e6),
            format!("{:.2}", t.hidden_mixer_ns() / 1e6),
            format!("{:.1}", t.step_ns / 1e6),
            format!("{:.2}", t.sample_ns / 1e6),
            format!("{:.1}", 100.0 * (t.mixer_ns + t.fence_ns) / t.total_ns()),
            format!("{:.1}", 100.0 * t.non_mixer_ns() / t.total_ns()),
        ]);
    }
    table.print();
    println!(
        "\nnote: tiling methods expose the per-step dispatch overhead (paper §5.3's \
         CPU-dispatch observation) — the non-mixer share dominates once mixer \
         work is quasilinear. The async rows then take most of the remaining \
         mixer time off the critical path: `hidden_ms` is tau compute that ran \
         under the red step, `fence_ms` the residue the deadline could not hide."
    );
    table.write_csv("fig3c_breakdown")?;
    Ok(())
}
