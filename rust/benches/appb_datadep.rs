//! Appendix B: data-dependent filters via Algorithm 5 (van der Hoeven's
//! parallelogram tiling). Reproduces the appendix's claims:
//! exactness vs lazy, quasilinear scaling, and ~2x the FLOPs of the
//! data-independent tiling (Algorithm 2).
//!
//! Knobs: FI_MAX_LEN, FI_DD_M, FI_DD_D.

use flash_inference::engine::datadep::{DataDepCfg, DataDepEngine};
use flash_inference::tiling::flops;
use flash_inference::util::benchkit::{self, Table};

fn main() -> anyhow::Result<()> {
    let max_len = benchkit::env_usize("FI_MAX_LEN", 4096);
    let m = benchkit::env_usize("FI_DD_M", 4);
    let d = benchkit::env_usize("FI_DD_D", 32);

    println!("\n=== Appendix B: data-dependent filters (Algorithm 5) ===");
    println!("demo model: M={m} D={d}, rho[t] = base[t] * sigmoid(y[t])\n");

    let eng = DataDepEngine::new(DataDepCfg { m, d, len: max_len, seed: 0 });
    let mut table = Table::new(&[
        "L", "lazy_ms", "alg5_ms", "speedup", "rel_l2", "alg5_flops", "lazy_flops",
        "static_flops", "dyn/static",
    ]);
    let mut len = 256;
    while len <= max_len {
        let lazy = eng.generate_lazy(len);
        let alg5 = eng.generate_alg5(len);
        let err = alg5.streams.rel_l2(&lazy.streams);
        let static_flops = flops::flash_total_flops(len, m, d, true);
        table.row(vec![
            len.to_string(),
            format!("{:.1}", lazy.wall.as_secs_f64() * 1e3),
            format!("{:.1}", alg5.wall.as_secs_f64() * 1e3),
            format!("{:.2}x", lazy.wall.as_secs_f64() / alg5.wall.as_secs_f64()),
            format!("{err:.1e}"),
            format!("{:.2e}", alg5.flops.mixer_flops as f64),
            format!("{:.2e}", lazy.flops.mixer_flops as f64),
            format!("{:.2e}", static_flops as f64),
            format!("{:.2}x", alg5.flops.mixer_flops as f64 / static_flops as f64),
        ]);
        len *= 4;
    }
    table.print();
    println!(
        "\npaper (App. B): same O(L log² L) asymptotics with data-dependent \
         filters, at ~2x the FLOPs of the data-independent tiling \
         (parallelogram tiles need two convolutions with fresh DFTs)."
    );
    table.write_csv("appb_datadep")?;
    Ok(())
}
