//! Figure 2c: per-token response time. Hybrid shows low variance except
//! spikes exactly at positions processing large tiles — and those are rare
//! (93.75% of tokens use U <= 8).
//!
//! Knobs: FI_ARTIFACTS_HYENA, FI_MAX_LEN.

use flash_inference::engine::{Engine, EngineOpts, Method};
use flash_inference::runtime::Runtime;
use flash_inference::tau::TauKind;
use flash_inference::tiling::tile_side;
use flash_inference::util::benchkit::{self, fmt_ns, Table};

fn main() -> anyhow::Result<()> {
    let Some(dir) =
        benchkit::require_artifacts(&benchkit::env_str("FI_ARTIFACTS_HYENA", "artifacts/hyena"))
    else {
        return Ok(());
    };
    let rt = Runtime::load(&dir)?;
    let len = benchkit::env_usize("FI_MAX_LEN", rt.dims.l);

    println!("\n=== Fig 2c: per-token response time (Hyena hybrid, L={len}) ===\n");
    let mut eng = Engine::new(
        &rt,
        EngineOpts { method: Method::Flash, tau: TauKind::Hybrid, ..Default::default() },
    )?;
    eng.prewarm(len)?;
    eng.generate(len)?; // warmup
    let out = eng.generate(len)?;
    let lats = out.metrics.token_latencies_ns();

    // bucket by tile side processed at each position
    let mut table = Table::new(&["tile_U", "positions", "share_%", "mean_tok_ms", "max_tok_ms"]);
    let mut u = 1usize;
    while u <= len / 2 {
        let idx: Vec<usize> =
            (1..len).filter(|&i| tile_side(i) == u).collect();
        if idx.is_empty() {
            break;
        }
        let mean = idx.iter().map(|&i| lats[i - 1]).sum::<f64>() / idx.len() as f64;
        let max = idx.iter().map(|&i| lats[i - 1]).fold(0.0, f64::max);
        table.row(vec![
            u.to_string(),
            idx.len().to_string(),
            format!("{:.2}", 100.0 * idx.len() as f64 / (len - 1) as f64),
            format!("{:.3}", mean / 1e6),
            format!("{:.3}", max / 1e6),
        ]);
        u *= 2;
    }
    table.print();

    let small = (1..len).filter(|&i| tile_side(i) <= 8).count();
    println!(
        "\npositions with U <= 8: {:.2}% (paper: 93.75%)",
        100.0 * small as f64 / (len - 1) as f64
    );

    // variance summary + the spike positions
    let mut sorted = lats.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "token latency: p50 {} | p90 {} | p99 {} | max {}",
        fmt_ns(sorted[len / 2]),
        fmt_ns(sorted[len * 9 / 10]),
        fmt_ns(sorted[len * 99 / 100]),
        fmt_ns(sorted[len - 1]),
    );
    let mut spikes: Vec<(usize, f64)> = (1..=len).map(|i| (i, lats[i - 1])).collect();
    spikes.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("slowest positions (expect large power-of-two tile sites):");
    for (pos, ns) in spikes.iter().take(6) {
        let u = if *pos < len { tile_side(*pos) } else { 0 };
        println!("  position {pos:>6} (tile U={u:>5}): {}", fmt_ns(*ns));
    }
    table.write_csv("fig2c_per_token")?;
    Ok(())
}
