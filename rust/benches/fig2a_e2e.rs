//! Figure 2a: end-to-end inference time breakdown on the Hyena
//! architecture — Hybrid vs the (layer-parallel) lazy and eager baselines,
//! across sequence lengths. The paper reports up to 1.6x end-to-end; the
//! crossover structure (flash wins, margin grows with L) is the claim.
//!
//! Knobs: FI_ARTIFACTS_HYENA (dir), FI_MAX_LEN, FI_WARMUP, FI_RUNS.

use flash_inference::engine::{Engine, EngineOpts, Method};
use flash_inference::runtime::Runtime;
use flash_inference::tau::TauKind;
use flash_inference::util::benchkit::{self, Table};

fn main() -> anyhow::Result<()> {
    let Some(dir) =
        benchkit::require_artifacts(&benchkit::env_str("FI_ARTIFACTS_HYENA", "artifacts/hyena"))
    else {
        return Ok(());
    };
    let rt = Runtime::load(&dir)?;
    let max_len = benchkit::env_usize("FI_MAX_LEN", rt.dims.l);
    let warmup = benchkit::env_usize("FI_WARMUP", 1);
    let runs = benchkit::env_usize("FI_RUNS", 2);

    println!("\n=== Fig 2a: end-to-end inference time breakdown (Hyena) ===");
    println!(
        "model: M={} D={} B={} | warmup={warmup} runs={runs}\n",
        rt.dims.m, rt.dims.d, rt.dims.b
    );

    let methods: [(&str, Method, TauKind); 3] = [
        ("lazy", Method::Lazy, TauKind::RustDirect),
        ("eager", Method::Eager, TauKind::RustDirect),
        ("hybrid", Method::Flash, TauKind::Hybrid),
    ];

    let mut table = Table::new(&[
        "L", "method", "total_ms", "mixer_ms", "non_mixer_ms", "tok_per_s", "speedup",
    ]);
    let mut len = 256;
    while len <= max_len {
        let mut totals: Vec<(String, f64, f64, f64)> = Vec::new();
        for (name, method, tau) in methods {
            let mut eng = Engine::new(&rt, EngineOpts { method, tau, ..Default::default() })?;
            eng.prewarm(len)?;
            let mut mixer = 0.0;
            let mut non_mixer = 0.0;
            let stats = benchkit::bench(warmup, runs, || {
                let out = eng.generate(len).expect("generate");
                mixer = out.metrics.totals.mixer_ns;
                non_mixer = out.metrics.totals.non_mixer_ns();
            });
            totals.push((name.to_string(), stats.median_ns, mixer, non_mixer));
        }
        let best_baseline =
            totals.iter().filter(|t| t.0 != "hybrid").map(|t| t.1).fold(f64::MAX, f64::min);
        for (name, total, mixer, non_mixer) in &totals {
            table.row(vec![
                len.to_string(),
                name.clone(),
                format!("{:.1}", total / 1e6),
                format!("{:.1}", mixer / 1e6),
                format!("{:.1}", non_mixer / 1e6),
                format!("{:.0}", len as f64 / (total / 1e9)),
                if name == "hybrid" {
                    format!("{:.2}x", best_baseline / total)
                } else {
                    "-".into()
                },
            ]);
        }
        len *= 4;
    }
    table.print();
    let csv = table.write_csv("fig2a_e2e")?;
    println!("\ncsv: {}", csv.display());
    Ok(())
}
