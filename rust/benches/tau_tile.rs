//! τ tile-kernel microbench: `rust-direct` vs `rust-fft` (complex, rfft
//! half-spectrum, and fused D-blocked rfft pipelines) across tile sizes,
//! emitting `BENCH_tau_tile.json` — the machine-readable perf-trajectory
//! baseline, `meta`-stamped so runs are attributable across runners.
//!
//! Pure native kernels on synthetic data: needs no artifacts, so it runs
//! anywhere (including the CI bench-smoke job at a tiny config, once per
//! simd feature mode). The measured direct↔FFT crossover printed at the
//! end — against the *fused* kernel, the path the engine actually runs —
//! is the empirical counterpart of `tau::calibrate::predicted_crossover`;
//! the engine's own table is still produced by `flashinfer calibrate`
//! (it includes the PJRT impls and real dims).
//!
//! Knobs: FI_TAU_TILE_MIN_U, FI_TAU_TILE_MAX_U, FI_D, FI_WARMUP, FI_RUNS,
//! FI_BENCH_OUT, FI_SIMD (=0 forces the scalar backend).

use flash_inference::fft::{self, BlockedSpectrum, Plan, RfftPlan, TileScratch};
use flash_inference::tiling::flops;
use flash_inference::util::benchkit::{self, fmt_ns, Table};
use flash_inference::util::json::Json;
use flash_inference::util::prng::Prng;

fn main() -> anyhow::Result<()> {
    let min_u = benchkit::env_usize("FI_TAU_TILE_MIN_U", 16);
    let max_u = benchkit::env_usize("FI_TAU_TILE_MAX_U", 4096);
    let d = benchkit::env_usize("FI_D", 64);
    let warmup = benchkit::env_usize("FI_WARMUP", 2);
    let runs = benchkit::env_usize("FI_RUNS", 4);
    let out_path = benchkit::env_str("FI_BENCH_OUT", "BENCH_tau_tile.json");
    assert!(min_u.is_power_of_two() && max_u.is_power_of_two() && min_u <= max_u);

    println!("\n=== tau tile kernels: direct vs fft(complex) vs rfft vs rfft-fused ===");
    println!(
        "D={d} | simd backend: {} | per-tile medians over {runs} runs, {warmup} warmup\n",
        fft::simd::backend_name()
    );

    let mut rng = Prng::new(0x7A117);
    let mut table = Table::new(&[
        "U",
        "rust_direct",
        "fft_complex",
        "fft_rfft",
        "fft_rfft_fused",
        "fused_vs_rfft",
        "fused_vs_direct",
    ]);
    let mut rows = Vec::new();
    let mut crossover: Option<usize> = None;

    let mut u = min_u;
    while u <= max_u {
        let y: Vec<f32> = (0..u * d).map(|_| rng.normal_f32()).collect();
        let rho: Vec<f32> = (0..2 * u * d).map(|_| rng.normal_f32()).collect();
        let mut out = vec![0.0f32; u * d];
        let mut scratch = TileScratch::with_capacity(2 * u, d);

        let direct = benchkit::bench(warmup, runs, || {
            out.fill(0.0);
            fft::tile_conv_direct_into(&y, &rho, &mut out, d);
        });

        let plan_c = Plan::new(2 * u);
        let (fre, fim) = fft::spectrum_planes(&plan_c, &rho, d);
        let complex = benchkit::bench(warmup, runs, || {
            out.fill(0.0);
            fft::tile_conv_fft_into(&plan_c, &y, &fre, &fim, &mut out, &mut scratch, d);
        });

        let plan_r = RfftPlan::new(2 * u);
        let (hre, him) = fft::spectrum_halfplanes(&plan_r, &rho, d);
        let rfft = benchkit::bench(warmup, runs, || {
            out.fill(0.0);
            fft::tile_conv_rfft_into(&plan_r, &y, &hre, &him, &mut out, &mut scratch, d);
        });

        let blocked = BlockedSpectrum::from_halfplanes(&hre, &him, d);
        let fused = benchkit::bench(warmup, runs, || {
            out.fill(0.0);
            fft::tile_conv_rfft_fused_into(&plan_r, &y, &blocked, &mut out, &mut scratch, d);
        });

        // the crossover the engine cares about is against the hot path —
        // the fused kernel, not the PR 2 unfused one
        if crossover.is_none() && fused.median_ns < direct.median_ns {
            crossover = Some(u);
        }
        table.row(vec![
            u.to_string(),
            fmt_ns(direct.median_ns),
            fmt_ns(complex.median_ns),
            fmt_ns(rfft.median_ns),
            fmt_ns(fused.median_ns),
            format!("{:.2}x", rfft.median_ns / fused.median_ns),
            format!("{:.2}x", direct.median_ns / fused.median_ns),
        ]);
        rows.push(Json::from_pairs(vec![
            ("u", Json::Num(u as f64)),
            ("direct_ns", Json::Num(direct.median_ns)),
            ("fft_complex_ns", Json::Num(complex.median_ns)),
            ("fft_rfft_ns", Json::Num(rfft.median_ns)),
            ("fft_rfft_fused_ns", Json::Num(fused.median_ns)),
            ("direct_flops", Json::Num(flops::tile_direct_flops(u, d) as f64)),
            ("fft_complex_flops", Json::Num(flops::tile_fft_flops(u, d) as f64)),
            ("fft_rfft_flops", Json::Num(flops::tile_rfft_flops(u, d) as f64)),
            // fused FLOPs == rfft FLOPs by construction; what changes is
            // scratch traffic/residency — emit the byte models alongside
            ("rfft_scratch_bytes", Json::Num(flops::tile_rfft_scratch_bytes(u, d) as f64)),
            (
                "fused_scratch_bytes",
                Json::Num(
                    flops::tile_rfft_fused_scratch_bytes(u, fft::simd::fused_block_d()) as f64,
                ),
            ),
        ]));
        u *= 2;
    }
    table.print();

    let predicted = flash_inference::tau::calibrate::predicted_crossover();
    match crossover {
        Some(c) => println!(
            "\nmeasured direct->fft(fused) crossover: U = {c} (model predicts {predicted}); \
             run `flashinfer calibrate` to persist the full hybrid table."
        ),
        None => println!(
            "\nno crossover in [{min_u}, {max_u}] — direct won throughout \
             (model predicts {predicted}); widen FI_TAU_TILE_MAX_U."
        ),
    }

    let doc = Json::from_pairs(vec![
        ("bench", Json::Str("tau_tile".into())),
        ("meta", benchkit::bench_meta(None)),
        ("d", Json::Num(d as f64)),
        ("warmup", Json::Num(warmup as f64)),
        ("runs", Json::Num(runs as f64)),
        ("rows", Json::Arr(rows)),
        (
            "measured_crossover_u",
            crossover.map_or(Json::Null, |c| Json::Num(c as f64)),
        ),
        ("predicted_crossover_u", Json::Num(predicted as f64)),
    ]);
    std::fs::write(&out_path, doc.to_string_pretty())?;
    println!("wrote {out_path}");
    table.write_csv("tau_tile")?;
    Ok(())
}
