//! Figure 3a: isolated tau latency vs tile size — the four implementations
//! form a Pareto frontier (direct wins small U on overhead, FFT wins large
//! U on FLOPs; native beats framework-dispatched at both ends), which the
//! Hybrid traces.
//!
//! Knobs: FI_ARTIFACTS_SYN, FI_MAX_LEN, FI_WARMUP, FI_RUNS.

use flash_inference::runtime::Runtime;
use flash_inference::tau::{calibrate, RhoCache};
use flash_inference::util::benchkit::{self, fmt_ns, Table};

fn main() -> anyhow::Result<()> {
    let Some(dir) = benchkit::require_artifacts(&benchkit::env_str(
        "FI_ARTIFACTS_SYN",
        "artifacts/synthetic",
    )) else {
        return Ok(());
    };
    let rt = Runtime::load(&dir)?;
    let max_u = benchkit::env_usize("FI_MAX_LEN", rt.dims.l) / 2;
    let warmup = benchkit::env_usize("FI_WARMUP", 2);
    let runs = benchkit::env_usize("FI_RUNS", 4);

    println!("\n=== Fig 3a: tau implementations pareto frontier (synthetic) ===");
    println!("G={} D={} | per-tile medians over {runs} runs, {warmup} warmup\n", rt.dims.g, rt.dims.d);

    let cache = RhoCache::new(&rt)?;
    let (table, rows) = calibrate(&cache, max_u, warmup, runs)?;

    let mut t = Table::new(&[
        "U", "rust_direct", "rust_fft", "pjrt_direct", "pjrt_fft", "winner",
    ]);
    for row in &rows {
        let mut cells = vec![row.u.to_string()];
        for (_, ns) in &row.medians_ns {
            cells.push(fmt_ns(*ns));
        }
        cells.push(row.winner.as_str().to_string());
        t.row(cells);
    }
    t.print();

    // the frontier claim: the winner changes across the U range
    let winners: std::collections::BTreeSet<&str> =
        rows.iter().map(|r| r.winner.as_str()).collect();
    println!(
        "\ndistinct per-U winners: {winners:?} — {}",
        if winners.len() > 1 {
            "pareto frontier confirmed (no single impl dominates)"
        } else {
            "single impl dominates on this testbed"
        }
    );

    let path = dir.join("hybrid.json");
    table.save(&path)?;
    println!("wrote calibration to {}", path.display());
    t.write_csv("fig3a_tau_pareto")?;
    Ok(())
}
