//! Propositions 1 & 2: the tau-call histogram (2^{P-1-q} calls of side
//! 2^q) and the O(L log² L) vs Ω(L²) FLOP totals — measured from an
//! instrumented run and checked against the closed forms.
//!
//! Knobs: FI_ARTIFACTS_SYN, FI_MAX_LEN.

use flash_inference::engine::{Engine, EngineOpts, Method};
use flash_inference::runtime::Runtime;
use flash_inference::tau::TauKind;
use flash_inference::tiling::{flops, tau_call_histogram};
use flash_inference::util::benchkit::{self, Table};

fn main() -> anyhow::Result<()> {
    let Some(dir) = benchkit::require_artifacts(&benchkit::env_str(
        "FI_ARTIFACTS_SYN",
        "artifacts/synthetic",
    )) else {
        return Ok(());
    };
    let rt = Runtime::load(&dir)?;
    let (g, d) = (rt.dims.g, rt.dims.d);
    let mut failures = 0;

    for len in [256usize, benchkit::env_usize("FI_MAX_LEN", rt.dims.l)] {
        println!("\n=== Propositions 1 & 2 at L={len} (G={g}, D={d}) ===\n");
        let mut eng = Engine::new(
            &rt,
            EngineOpts { method: Method::Flash, tau: TauKind::RustFft, ..Default::default() },
        )?;
        eng.prewarm(len)?;
        let out = eng.generate(len)?;

        // Proposition 1: call histogram
        let mut table = Table::new(&["U", "measured_calls", "predicted_calls", "ok"]);
        let predicted: std::collections::BTreeMap<usize, usize> =
            tau_call_histogram(len).into_iter().collect();
        for (&u, &c) in &out.flops.tau_call_hist {
            let want = predicted.get(&u).copied().unwrap_or(0) as u64;
            if c != want {
                failures += 1;
            }
            table.row(vec![
                u.to_string(),
                c.to_string(),
                want.to_string(),
                if c == want { "✓".into() } else { "MISMATCH".into() },
            ]);
        }
        table.print();

        // Proposition 2 / §5.4(1): FLOP totals
        let measured = out.flops.mixer_flops;
        let predicted_flops = flops::flash_total_flops(len, g, d, true);
        let lazy = flops::lazy_total_flops(len, g, d);
        let eager = flops::eager_total_flops(len, g, d);
        let ok = measured == predicted_flops;
        if !ok {
            failures += 1;
        }
        println!("\nmixer FLOPs:");
        println!("  flash measured:  {measured:>16}");
        println!("  flash predicted: {predicted_flops:>16}  {}", if ok { "✓" } else { "MISMATCH" });
        println!("  lazy  closed:    {lazy:>16}  ({:.1}x flash)", lazy as f64 / measured as f64);
        println!("  eager closed:    {eager:>16}");
        println!(
            "  tau activation IO: {} values = {:.1}% of the O(L^2) the baselines touch",
            out.flops.tau_io_values,
            100.0 * out.flops.tau_io_values as f64 / (lazy as f64 / 2.0 / d as f64 * d as f64)
        );
    }

    println!(
        "\nprop_flops: {}",
        if failures == 0 { "ALL CHECKS PASS" } else { "FAILURES PRESENT" }
    );
    std::process::exit(i32::from(failures > 0));
}
