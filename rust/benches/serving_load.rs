//! Serving load test (beyond the paper's figures, backing the serving
//! claims of the framework): replay Poisson traces against the HTTP
//! server at increasing arrival rates, report throughput and latency.
//!
//! Knobs: FI_ARTIFACTS_SYN, FI_REQS.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Instant;

use flash_inference::config::ServerConfig;
use flash_inference::metrics::LatencyRecorder;
use flash_inference::server::Server;
use flash_inference::trace::{TraceConfig, WorkloadTrace};
use flash_inference::util::benchkit::{self, Table};

fn post_generate(addr: std::net::SocketAddr, max_tokens: usize) -> anyhow::Result<f64> {
    let body = format!("{{\"max_tokens\": {max_tokens}}}");
    let raw = format!(
        "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    let t0 = Instant::now();
    let mut s = TcpStream::connect(addr)?;
    s.write_all(raw.as_bytes())?;
    let mut buf = String::new();
    s.read_to_string(&mut buf)?;
    anyhow::ensure!(buf.contains("200 OK"), "bad response: {}", &buf[..buf.len().min(200)]);
    Ok(t0.elapsed().as_secs_f64() * 1e3)
}

fn main() -> anyhow::Result<()> {
    let Some(dir) = benchkit::require_artifacts(&benchkit::env_str(
        "FI_ARTIFACTS_SYN",
        "artifacts/synthetic",
    )) else {
        return Ok(());
    };
    let n = benchkit::env_usize("FI_REQS", 16);

    println!("\n=== serving load: Poisson replay vs arrival rate ===\n");
    let server = Server::start(ServerConfig {
        port: 0,
        artifacts: dir,
        ..Default::default()
    })?;
    let addr = server.addr;

    let mut table = Table::new(&[
        "rate_rps", "requests", "ok", "wall_s", "tok_per_s", "p50_ms", "p95_ms", "max_ms",
    ]);
    for rate in [1.0f64, 4.0, 16.0] {
        let trace = WorkloadTrace::generate(TraceConfig {
            rate,
            num_requests: n,
            min_tokens: 16,
            max_tokens: 128,
            seed: 42,
        });
        let total_tokens = trace.total_tokens();
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for req in trace.requests {
            handles.push(std::thread::spawn(move || {
                let wait = std::time::Duration::from_secs_f64(req.arrival_s);
                let since = t0.elapsed();
                if wait > since {
                    std::thread::sleep(wait - since);
                }
                post_generate(addr, req.max_tokens)
            }));
        }
        let mut lat = LatencyRecorder::unbounded();
        let mut ok = 0;
        for h in handles {
            if let Ok(ms) = h.join().unwrap() {
                lat.record_ns(ms * 1e6);
                ok += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        table.row(vec![
            format!("{rate:.0}"),
            n.to_string(),
            ok.to_string(),
            format!("{wall:.2}"),
            format!("{:.0}", total_tokens as f64 / wall),
            format!("{:.1}", lat.percentile_ns(50.0) / 1e6),
            format!("{:.1}", lat.percentile_ns(95.0) / 1e6),
            format!("{:.1}", lat.max_ns() / 1e6),
        ]);
    }
    table.print();
    table.write_csv("serving_load")?;
    server.stop();
    Ok(())
}
