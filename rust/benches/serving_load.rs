//! Serving load test (beyond the paper's figures, backing the serving
//! claims of the framework), in two parts:
//!
//! 1. **throughput sweep** — replay Poisson traces against the HTTP
//!    server at increasing arrival rates, report throughput and latency;
//! 2. **arrival-process A/B** — the continuous-admission experiment: the
//!    *same* Poisson trace of streaming requests replayed against a
//!    server with admission on and with admission off
//!    (drain-then-refill), reporting p50/p99 **time-to-first-token**. A
//!    request arriving mid-batch under drain-then-refill waits for the
//!    whole batch; under admission it is seeded into a free lane at the
//!    next step boundary — TTFT is where that shows up.
//!
//! Emits `BENCH_serving_load.json` (the machine-readable perf-trajectory
//! artifact CI publishes to the step summary).
//!
//! Knobs: FI_ARTIFACTS_SYN, FI_REQS, FI_RATE, FI_TOKENS_MIN,
//! FI_TOKENS_MAX, FI_BENCH_OUT.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Instant;

use flash_inference::config::ServerConfig;
use flash_inference::metrics::LatencyRecorder;
use flash_inference::server::Server;
use flash_inference::trace::{TraceConfig, WorkloadTrace};
use flash_inference::util::benchkit::{self, Table};
use flash_inference::util::json::Json;

fn post_generate(addr: std::net::SocketAddr, max_tokens: usize) -> anyhow::Result<f64> {
    let body = format!("{{\"max_tokens\": {max_tokens}}}");
    let raw = format!(
        "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    let t0 = Instant::now();
    let mut s = TcpStream::connect(addr)?;
    s.write_all(raw.as_bytes())?;
    let mut buf = String::new();
    s.read_to_string(&mut buf)?;
    anyhow::ensure!(buf.contains("200 OK"), "bad response: {}", &buf[..buf.len().min(200)]);
    Ok(t0.elapsed().as_secs_f64() * 1e3)
}

/// Streaming request; returns (time-to-first-token ms, total ms).
fn stream_generate(addr: std::net::SocketAddr, max_tokens: usize) -> anyhow::Result<(f64, f64)> {
    let body = format!("{{\"max_tokens\": {max_tokens}, \"stream\": true}}");
    let raw = format!(
        "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    let t0 = Instant::now();
    let mut s = TcpStream::connect(addr)?;
    s.write_all(raw.as_bytes())?;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut first: Option<f64> = None;
    loop {
        let n = s.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
        if first.is_none() && buf.windows(6).any(|w| w == b"\"pos\":") {
            first = Some(t0.elapsed().as_secs_f64() * 1e3);
        }
    }
    let total = t0.elapsed().as_secs_f64() * 1e3;
    let head = String::from_utf8_lossy(&buf[..buf.len().min(200)]).to_string();
    anyhow::ensure!(head.contains("200 OK"), "bad response: {head}");
    let ttft = first.ok_or_else(|| anyhow::anyhow!("no event line in: {head}"))?;
    Ok((ttft, total))
}

/// Replay `trace` as streaming requests; returns per-request
/// (ttft_ms, total_ms) in completion order (failures dropped).
fn replay_streaming(
    addr: std::net::SocketAddr,
    trace: &WorkloadTrace,
) -> (Vec<(f64, f64)>, f64) {
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for req in trace.requests.clone() {
        handles.push(std::thread::spawn(move || {
            let wait = std::time::Duration::from_secs_f64(req.arrival_s);
            let since = t0.elapsed();
            if wait > since {
                std::thread::sleep(wait - since);
            }
            stream_generate(addr, req.max_tokens)
        }));
    }
    let mut results = Vec::new();
    for h in handles {
        if let Ok(r) = h.join().unwrap() {
            results.push(r);
        }
    }
    (results, t0.elapsed().as_secs_f64())
}

fn main() -> anyhow::Result<()> {
    let Some(dir) = benchkit::require_artifacts(&benchkit::env_str(
        "FI_ARTIFACTS_SYN",
        "artifacts/synthetic",
    )) else {
        return Ok(());
    };
    let n = benchkit::env_usize("FI_REQS", 16);
    let rate = benchkit::env_usize("FI_RATE", 4) as f64;
    let min_tokens = benchkit::env_usize("FI_TOKENS_MIN", 16);
    let max_tokens = benchkit::env_usize("FI_TOKENS_MAX", 128);
    let out_path = benchkit::env_str("FI_BENCH_OUT", "BENCH_serving_load.json");

    // ---- part 1: throughput sweep (admission on) ----------------------
    println!("\n=== serving load: Poisson replay vs arrival rate ===\n");
    let server = Server::start(ServerConfig {
        port: 0,
        artifacts: dir.clone(),
        ..Default::default()
    })?;
    let addr = server.addr;

    let mut table = Table::new(&[
        "rate_rps", "requests", "ok", "wall_s", "tok_per_s", "p50_ms", "p95_ms", "max_ms",
    ]);
    let mut sweep_rows = Vec::new();
    for rate in [1.0f64, 4.0, 16.0] {
        let trace = WorkloadTrace::generate(TraceConfig {
            rate,
            num_requests: n,
            min_tokens,
            max_tokens,
            seed: 42,
        });
        let total_tokens = trace.total_tokens();
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for req in trace.requests {
            handles.push(std::thread::spawn(move || {
                let wait = std::time::Duration::from_secs_f64(req.arrival_s);
                let since = t0.elapsed();
                if wait > since {
                    std::thread::sleep(wait - since);
                }
                post_generate(addr, req.max_tokens)
            }));
        }
        let mut lat = LatencyRecorder::unbounded();
        let mut ok = 0;
        for h in handles {
            if let Ok(ms) = h.join().unwrap() {
                lat.record_ns(ms * 1e6);
                ok += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let tok_per_s = total_tokens as f64 / wall;
        table.row(vec![
            format!("{rate:.0}"),
            n.to_string(),
            ok.to_string(),
            format!("{wall:.2}"),
            format!("{tok_per_s:.0}"),
            format!("{:.1}", lat.percentile_ns(50.0) / 1e6),
            format!("{:.1}", lat.percentile_ns(95.0) / 1e6),
            format!("{:.1}", lat.max_ns() / 1e6),
        ]);
        sweep_rows.push(Json::from_pairs(vec![
            ("rate_rps", Json::Num(rate)),
            ("ok", Json::Num(ok as f64)),
            ("tok_per_s", Json::Num(tok_per_s)),
            ("p50_ms", Json::Num(lat.percentile_ns(50.0) / 1e6)),
            ("p95_ms", Json::Num(lat.percentile_ns(95.0) / 1e6)),
        ]));
    }
    table.print();
    table.write_csv("serving_load")?;
    server.stop();

    // ---- part 2: arrival-process TTFT, admission on vs off ------------
    println!("\n=== arrival process: time-to-first-token, admission on vs off ===\n");
    let mut ab_table = Table::new(&[
        "admission", "ok", "mid_batch", "ttft_p50_ms", "ttft_p99_ms", "total_p50_ms",
        "total_p99_ms", "wall_s",
    ]);
    let mut mode_rows = Vec::new();
    for admission in [true, false] {
        let server = Server::start(ServerConfig {
            port: 0,
            artifacts: dir.clone(),
            continuous_admission: admission,
            ..Default::default()
        })?;
        let trace = WorkloadTrace::generate(TraceConfig {
            rate,
            num_requests: n,
            min_tokens,
            max_tokens,
            seed: 7, // same trace for both modes: a paired experiment
        });
        let (results, wall) = replay_streaming(server.addr, &trace);
        let mut ttft = LatencyRecorder::unbounded();
        let mut total = LatencyRecorder::unbounded();
        for (f, t) in &results {
            ttft.record_ns(f * 1e6);
            total.record_ns(t * 1e6);
        }
        let mid_batch =
            benchkit::scrape_metric(server.addr, "fi_admissions_mid_batch").unwrap_or(-1.0);
        server.stop();
        ab_table.row(vec![
            if admission { "on" } else { "off" }.into(),
            results.len().to_string(),
            format!("{mid_batch:.0}"),
            format!("{:.1}", ttft.percentile_ns(50.0) / 1e6),
            format!("{:.1}", ttft.percentile_ns(99.0) / 1e6),
            format!("{:.1}", total.percentile_ns(50.0) / 1e6),
            format!("{:.1}", total.percentile_ns(99.0) / 1e6),
            format!("{wall:.2}"),
        ]);
        mode_rows.push(Json::from_pairs(vec![
            ("admission", Json::Bool(admission)),
            ("ok", Json::Num(results.len() as f64)),
            ("mid_batch_admissions", Json::Num(mid_batch)),
            ("ttft_p50_ms", Json::Num(ttft.percentile_ns(50.0) / 1e6)),
            ("ttft_p99_ms", Json::Num(ttft.percentile_ns(99.0) / 1e6)),
            ("total_p50_ms", Json::Num(total.percentile_ns(50.0) / 1e6)),
            ("total_p99_ms", Json::Num(total.percentile_ns(99.0) / 1e6)),
            ("wall_s", Json::Num(wall)),
        ]));
    }
    ab_table.print();
    ab_table.write_csv("serving_load_admission")?;
    println!(
        "\nreading: with admission ON, a request that lands mid-batch starts at the \
         next step boundary, so ttft ~ queue-to-lane + one step; OFF, it waits for \
         the running batch to drain first."
    );

    let doc = Json::from_pairs(vec![
        ("bench", Json::Str("serving_load".into())),
        ("meta", benchkit::bench_meta(None)),
        ("requests", Json::Num(n as f64)),
        ("arrival_rate_rps", Json::Num(rate)),
        ("tokens_min", Json::Num(min_tokens as f64)),
        ("tokens_max", Json::Num(max_tokens as f64)),
        ("sweep", Json::Arr(sweep_rows)),
        ("arrival_modes", Json::Arr(mode_rows)),
    ]);
    std::fs::write(&out_path, doc.to_string_pretty())?;
    println!("wrote {out_path}");
    Ok(())
}
