//! First-token-latency probe built on `Session::step()`: how quickly a
//! serving lane observes position 1 under each scheduling method, versus
//! the amortized per-token cost of the full rollout. The buffered
//! `generate()` path hides this number entirely — a lane only sees tokens
//! after the whole session — which is exactly what the Session state
//! machine + streaming mode fix. Flash's first step does no mixer work at
//! all (the first gray tile lands after position 1), so its first-token
//! latency is the non-mixer floor regardless of L.
//!
//!     FI_LEN=1024 FI_RUNS=5 cargo bench --bench first_token

use std::time::Instant;

use flash_inference::engine::{Engine, EngineOpts, Method};
use flash_inference::runtime::Runtime;
use flash_inference::tau::TauKind;
use flash_inference::util::benchkit::{self, Table};

fn main() -> anyhow::Result<()> {
    let Some(dir) = benchkit::require_artifacts(&benchkit::env_str(
        "FI_ARTIFACTS_SYN",
        "artifacts/synthetic",
    )) else {
        return Ok(());
    };
    let rt = Runtime::load(&dir)?;
    let len = benchkit::env_usize("FI_LEN", 1024).next_power_of_two().min(rt.dims.l);
    let runs = benchkit::env_usize("FI_RUNS", 5);

    let mut table = Table::new(&[
        "method",
        "first token",
        "full session",
        "amortized/token",
        "first/amortized",
    ]);
    for method in [Method::Flash, Method::Lazy, Method::Eager] {
        let mut eng = Engine::new(
            &rt,
            EngineOpts { method, tau: TauKind::Hybrid, ..Default::default() },
        )?;
        eng.prewarm(len)?;
        eng.generate(len)?; // warmup: one-time rho/PJRT derivation out of the timings

        let (mut first, mut total) = (f64::MAX, f64::MAX);
        for _ in 0..runs {
            let t0 = Instant::now();
            let mut session = eng.session(len)?;
            session.step()?;
            let f = t0.elapsed().as_nanos() as f64;
            while !session.is_done() {
                session.step()?;
            }
            let t = t0.elapsed().as_nanos() as f64;
            let out = session.finish();
            assert_eq!(out.steps, len);
            first = first.min(f);
            total = total.min(t);
        }
        let amortized = total / len as f64;
        table.row(vec![
            method.as_str().to_string(),
            benchkit::fmt_ns(first),
            benchkit::fmt_ns(total),
            benchkit::fmt_ns(amortized),
            format!("{:.2}x", first / amortized),
        ]);
    }

    println!("\n=== first-token latency via Session::step (len={len}, best of {runs}) ===\n");
    table.print();
    println!(
        "\nfirst token ~= one step-artifact call for every method; the methods \
         separate in amortized cost (flash O(log^2 L) vs lazy/eager O(L)), \
         which is why streaming + early per-token delivery matters for serving."
    );
    Ok(())
}
