//! Figure 3b: cumulative mixer time of a full generation run under each
//! fixed tau implementation vs the Hybrid — Hybrid achieves the best of
//! all of them (it picks the frontier point per tile size).
//!
//! Knobs: FI_ARTIFACTS_SYN, FI_MAX_LEN.

use flash_inference::engine::{Engine, EngineOpts, Method};
use flash_inference::runtime::Runtime;
use flash_inference::tau::TauKind;
use flash_inference::util::benchkit::{self, Table};

fn main() -> anyhow::Result<()> {
    let Some(dir) = benchkit::require_artifacts(&benchkit::env_str(
        "FI_ARTIFACTS_SYN",
        "artifacts/synthetic",
    )) else {
        return Ok(());
    };
    let rt = Runtime::load(&dir)?;
    let len = benchkit::env_usize("FI_MAX_LEN", rt.dims.l.min(2048));

    println!("\n=== Fig 3b: cumulative mixer time per tau impl (synthetic, L={len}) ===\n");

    let kinds = [
        TauKind::RustDirect,
        TauKind::RustFft,
        TauKind::PjrtDirect,
        TauKind::PjrtFft,
        TauKind::Hybrid,
    ];
    let mut series = Vec::new();
    for kind in kinds {
        let mut eng = Engine::new(
            &rt,
            EngineOpts { method: Method::Flash, tau: kind, ..Default::default() },
        )?;
        eng.prewarm(len)?;
        eng.generate(len)?; // warmup
        let out = eng.generate(len)?;
        series.push((kind, out.metrics.cumulative_mixer_ns()));
    }

    let mut headers = vec!["position".to_string()];
    headers.extend(kinds.iter().map(|k| format!("{}_ms", k.as_str().replace('-', "_"))));
    let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut table = Table::new(&hdr_refs);
    let mut cp = 64;
    while cp <= len {
        let mut row = vec![cp.to_string()];
        for (_, s) in &series {
            row.push(format!("{:.2}", s[cp - 1] / 1e6));
        }
        table.row(row);
        cp *= 2;
    }
    table.print();

    println!("\nfinal cumulative mixer time (lower is better):");
    let hybrid_total = series.last().unwrap().1[len - 1];
    for (kind, s) in &series {
        let total = s[len - 1];
        println!(
            "  {:<12} {:>9.2} ms{}",
            kind.as_str(),
            total / 1e6,
            if *kind != TauKind::Hybrid && hybrid_total <= total * 1.05 {
                "   (hybrid <= this impl ✓)"
            } else {
                ""
            }
        );
    }
    table.write_csv("fig3b_mixer_impls")?;
    Ok(())
}
