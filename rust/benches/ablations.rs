//! §5.4(4) engineering ablations, each toggled individually:
//!
//! (a) precomputed filter DFTs (2 DFTs/tile) vs recomputing the filter
//!     spectrum per tile (3 DFTs/tile) — paper claims a further 1.5x;
//! (b) order-2U cyclic FFT vs the canonical 4U zero-padded FFT — paper
//!     claims right-padding + cyclicity halves the transform;
//! (c) across-layer parallelism (thread-pool fan-out of the G axis) —
//!     on this 1-core testbed the expected result is *no* gain, which is
//!     itself the paper's point that the benefit needs parallel hardware.
//!
//! Knobs: FI_ARTIFACTS_SYN, FI_WARMUP, FI_RUNS.

use flash_inference::fft::{self, Plan, TileScratch};
use flash_inference::runtime::Runtime;
use flash_inference::tau::{make_impl, RhoCache, TauKind};
use flash_inference::tiling::Tile;
use flash_inference::util::benchkit::{self, fmt_ns, Table};
use flash_inference::util::prng::Prng;
use flash_inference::util::tensor::{CellTensor, Tensor};

fn main() -> anyhow::Result<()> {
    let Some(dir) = benchkit::require_artifacts(&benchkit::env_str(
        "FI_ARTIFACTS_SYN",
        "artifacts/synthetic",
    )) else {
        return Ok(());
    };
    let rt = Runtime::load(&dir)?;
    let cache = RhoCache::new(&rt)?;
    let d = rt.dims.d;
    let warmup = benchkit::env_usize("FI_WARMUP", 2);
    let runs = benchkit::env_usize("FI_RUNS", 6);
    let mut rng = Prng::new(0xAB1A);

    // ---- (a) cached vs per-tile filter DFT --------------------------------
    println!("\n=== Ablation (a): precomputed filter DFT (2 vs 3 DFTs per tile) ===\n");
    let mut ta = Table::new(&["U", "cached_rho_dft", "recompute_rho_dft", "speedup"]);
    for u in [64usize, 512, 2048] {
        let plan = cache.plan(u); // rfft plan, real order 2U
        let y: Vec<f32> = (0..u * d).map(|_| rng.normal_f32()).collect();
        let seg = cache.seg(0, u).to_vec();
        let spectra = cache.spectra(u);
        let (sre, sim) = spectra.halfplanes(0);
        let mut scratch = TileScratch::with_capacity(2 * u, d);
        let mut out = vec![0.0f32; u * d];

        let cached = benchkit::bench(warmup, runs, || {
            fft::tile_conv_rfft_into(&plan, &y, &sre, &sim, &mut out, &mut scratch, d);
        });
        let recompute = benchkit::bench(warmup, runs, || {
            let (re, im) = fft::spectrum_halfplanes(&plan, &seg, d); // the 3rd DFT
            fft::tile_conv_rfft_into(&plan, &y, &re, &im, &mut out, &mut scratch, d);
        });
        ta.row(vec![
            u.to_string(),
            fmt_ns(cached.median_ns),
            fmt_ns(recompute.median_ns),
            format!("{:.2}x", recompute.median_ns / cached.median_ns),
        ]);
    }
    ta.print();
    println!("paper: caching the filter DFT saves a further ~1.5x on the tile.");

    // ---- (b) 2U cyclic vs 4U padded FFT -----------------------------------
    println!("\n=== Ablation (b): order-2U cyclic rfft vs canonical 4U padded FFT ===\n");
    let mut tb = Table::new(&["U", "cyclic_2U_rfft", "padded_4U", "speedup", "max_diff"]);
    for u in [64usize, 512, 2048] {
        let plan2 = cache.plan(u); // rfft plan, real order 2U
        let plan4 = Plan::new(4 * u);
        let y: Vec<f32> = (0..u * d).map(|_| rng.normal_f32()).collect();
        let seg = cache.seg(0, u);
        let spectra = cache.spectra(u);
        let (sre, sim) = spectra.halfplanes(0);
        let (sre4, sim4) = fft::spectrum_planes(&plan4, seg, d);
        let mut scratch = TileScratch::with_capacity(4 * u, d);

        let mut out2 = vec![0.0f32; u * d];
        let cyclic = benchkit::bench(warmup, runs, || {
            out2.fill(0.0);
            fft::tile_conv_rfft_into(&plan2, &y, &sre, &sim, &mut out2, &mut scratch, d);
        });

        // canonical: zero-pad input to 4U, full linear conv, slice [U, 2U)
        let mut out4 = vec![0.0f32; u * d];
        let mut re = vec![0.0f32; 4 * u * d];
        let mut im = vec![0.0f32; 4 * u * d];
        let padded = benchkit::bench(warmup, runs, || {
            re.fill(0.0);
            im.fill(0.0);
            re[..u * d].copy_from_slice(&y);
            flash_inference::fft::vecfft::forward(&plan4, &mut re, &mut im, d);
            flash_inference::fft::vecfft::cmul_inplace(&mut re, &mut im, &sre4, &sim4);
            flash_inference::fft::vecfft::inverse_unscaled(&plan4, &mut re, &mut im, d);
            let s = 1.0 / (4 * u) as f32;
            for (o, v) in out4.iter_mut().zip(&re[u * d..2 * u * d]) {
                *o = v * s;
            }
        });
        let diff = out2
            .iter()
            .zip(&out4)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        tb.row(vec![
            u.to_string(),
            fmt_ns(cyclic.median_ns),
            fmt_ns(padded.median_ns),
            format!("{:.2}x", padded.median_ns / cyclic.median_ns),
            format!("{diff:.1e}"),
        ]);
    }
    tb.print();
    println!("paper: exploiting cyclic-convolution wrap-around halves the FFT order.");

    // ---- (c) across-layer thread fan-out ----------------------------------
    println!("\n=== Ablation (c): across-layer parallelism (thread fan-out of G) ===\n");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("available cores: {cores}");
    let mut tc = Table::new(&["U", "threads=0", "threads=2", "threads=4", "best_speedup"]);
    for u in [256usize, 2048] {
        let tile = Tile::at(u);
        let mut init = Tensor::zeros(&[rt.dims.g, tile.dst_r, d]);
        rng.fill_normal(init.data_mut(), 1.0);
        let streams = CellTensor::from_tensor(&init);
        let pending = CellTensor::zeros(&[rt.dims.g, tile.dst_r, d]);
        let mut medians = Vec::new();
        for threads in [0usize, 2, 4] {
            let mut imp = make_impl(TauKind::RustFft, &cache, threads)?;
            let st = benchkit::bench(warmup, runs, || {
                imp.apply(&streams, &pending, tile).unwrap();
            });
            medians.push(st.median_ns);
        }
        tc.row(vec![
            u.to_string(),
            fmt_ns(medians[0]),
            fmt_ns(medians[1]),
            fmt_ns(medians[2]),
            format!("{:.2}x", medians[0] / medians[1..].iter().cloned().fold(f64::MAX, f64::min)),
        ]);
    }
    tc.print();
    println!(
        "note: with {cores} core(s) the expected speedup here is ~1x — Algorithm 3's \
         benefit requires parallel hardware; the batched-G single call is the \
         realization that carries on this testbed (DESIGN.md §3)."
    );
    Ok(())
}
