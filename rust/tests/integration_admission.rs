//! Integration: continuous admission (`Session::admit`) is *semantically
//! invisible* to the admitted request. Seeding a request into a lane of a
//! running batch at position `i` must produce **bit-identical** outputs to
//! a fresh single-request run of the same request — including with the
//! Appendix D half store wrapped past its halfway point and with the
//! deadline-fenced async mixer in flight at the admission boundary.
//!
//! Why bit-identity is even possible: the direct τ kernel accumulates one
//! `y·ρ` product at a time in ascending source order, the filter index
//! depends only on source→destination distance (shift-invariant), and a
//! recycled lane's cleared rows contribute exact `+0.0`s — so the admitted
//! lane sees the same float operations in the same order as a fresh run,
//! just translated along the global schedule. The FFT τ kernel mixes a
//! tile's sources through transforms, so *across different admission
//! positions* it is only tolerance-equal; what must still be bit-exact for
//! it is async-vs-sync under one fixed admission schedule (the admission
//! fence drains in-flight tiles before the lane reset — a missed fence
//! panics via `RowReadiness`).

use std::path::Path;

use flash_inference::engine::{Engine, EngineOpts, LaneInit, Method, SamplerCfg};
use flash_inference::runtime::Runtime;
use flash_inference::tau::TauKind;

fn runtime(variant: &str) -> Option<Runtime> {
    let dir = Path::new("artifacts").join(variant);
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&dir).expect("load runtime"))
}

fn opts(tau: TauKind, async_mixer: bool) -> EngineOpts {
    EngineOpts { method: Method::Flash, tau, async_mixer, ..Default::default() }
}

/// Run a `len`-position session, admit `init` into `lane` after
/// `admit_at` completed positions, and return the lane's per-position
/// checksums for its `limit` generated positions.
fn drive_admitted(
    engine: &Engine,
    len: usize,
    lane: usize,
    admit_at: usize,
    init: LaneInit,
) -> Vec<f32> {
    let mut sess = engine.session(len).expect("session");
    for _ in 0..admit_at {
        sess.step().expect("pre-admission step");
    }
    let limit = init.limit;
    sess.admit(lane, init).expect("admit");
    assert_eq!(sess.lane_start(lane), admit_at);
    assert_eq!(sess.lane_pos(lane), 0);
    let mut checksums = Vec::with_capacity(limit);
    for _ in 0..limit {
        let step = sess.step().expect("post-admission step");
        checksums.push(step.lane_checksums[lane]);
    }
    assert!(sess.lane_done(lane));
    sess.finish();
    checksums
}

#[test]
fn admitted_lane_is_bit_identical_to_fresh_run() {
    let Some(rt) = runtime("synthetic") else { return };
    let lane = rt.dims.b - 1;
    // async mixer ON (the acceptance criterion) + per-request sampling:
    // the admitted lane's noise stream must restart exactly as a fresh
    // run's does, independent of the batch's global position
    let engine = Engine::new(&rt, opts(TauKind::RustDirect, true)).unwrap();
    let init = LaneInit {
        limit: 32,
        sampler_cfg: Some(SamplerCfg::Synthetic { sigma: 0.25 }),
        seed: Some(77),
        pending_seed: None,
    };
    let fresh = drive_admitted(&engine, 64, lane, 0, init.clone());
    for admit_at in [1, 16, 17] {
        let mid = drive_admitted(&engine, 64, lane, admit_at, init.clone());
        assert_eq!(fresh, mid, "admission at position {admit_at} diverged");
    }
}

#[test]
fn admission_after_half_store_wrap_is_bit_identical() {
    let Some(rt) = runtime("synthetic") else { return };
    let lane = 0;
    let engine = Engine::new(
        &rt,
        EngineOpts { half_store: true, ..opts(TauKind::RustDirect, true) },
    )
    .unwrap();
    let init = LaneInit {
        limit: 16,
        sampler_cfg: Some(SamplerCfg::Synthetic { sigma: 0.5 }),
        seed: Some(3),
        pending_seed: None,
    };
    // len 64 -> 32 wrapped rows; admitting at 40 recycles rows that have
    // already wrapped once, and the lane's tiles straddle row_of() seams
    let fresh = drive_admitted(&engine, 64, lane, 0, init.clone());
    let wrapped = drive_admitted(&engine, 64, lane, 40, init);
    assert_eq!(fresh, wrapped, "half-store admission diverged");
}

#[test]
fn async_admission_matches_sync_admission_rust_fft() {
    let Some(rt) = runtime("synthetic") else { return };
    let lane = rt.dims.b - 1;
    let init = LaneInit {
        limit: 32,
        sampler_cfg: Some(SamplerCfg::Synthetic { sigma: 0.25 }),
        seed: Some(11),
        pending_seed: None,
    };
    // same admission schedule, async vs forced-sync: the admission fence
    // drains the in-flight FFT tile before the lane reset, so the
    // arithmetic (and therefore every checksum bit) must match; a dropped
    // fence would instead panic in RowReadiness or corrupt the rollout
    let run = |async_mixer| {
        let engine = Engine::new(&rt, opts(TauKind::RustFft, async_mixer)).unwrap();
        drive_admitted(&engine, 64, lane, 24, init.clone())
    };
    assert_eq!(run(true), run(false), "async admission diverged from sync");
}

#[test]
fn recycled_lane_leaves_no_stale_rows() {
    let Some(rt) = runtime("synthetic") else { return };
    let dims = rt.dims;
    let lane = dims.b - 1;
    let engine = Engine::new(
        &rt,
        EngineOpts { record_streams: true, ..opts(TauKind::RustFft, true) },
    )
    .unwrap();
    let mut sess = engine.session(32).unwrap();
    sess.admit(lane, LaneInit { limit: 8, ..Default::default() }).unwrap();
    for _ in 0..16 {
        sess.step().unwrap();
    }
    // recycle the lane mid-batch; its first rollout's rows must vanish
    sess.admit(lane, LaneInit { limit: 8, seed: Some(4), ..Default::default() }).unwrap();
    for _ in 0..8 {
        sess.step().unwrap();
    }
    let out = sess.finish();
    let streams = out.streams.expect("record_streams");
    let mut gi = lane;
    while gi < dims.g {
        // rows before the re-admission point (and after the early finish)
        // were zeroed by the recycle and never rewritten
        for row in (0..16).chain(24..32) {
            assert!(
                streams.at2(gi, row).iter().all(|&v| v == 0.0),
                "stale activation in group {gi} row {row}"
            );
        }
        gi += dims.b;
    }
}

#[test]
fn per_lane_seed_is_deterministic_under_admission_churn() {
    let Some(rt) = runtime("synthetic") else { return };
    let lane = 0;
    let engine = Engine::new(
        &rt,
        EngineOpts { threads: 2, ..opts(TauKind::RustDirect, true) },
    )
    .unwrap();
    let init = LaneInit {
        limit: 16,
        sampler_cfg: Some(SamplerCfg::Synthetic { sigma: 0.3 }),
        seed: Some(123),
        pending_seed: None,
    };
    // one continuously running batch, the same request admitted into the
    // same lane three times at different global positions: every rollout
    // must replay the identical checksum trajectory
    let mut sess = engine.session(64).unwrap();
    let mut rollouts: Vec<Vec<f32>> = Vec::new();
    for _round in 0..3 {
        sess.admit(lane, init.clone()).unwrap();
        let mut cs = Vec::new();
        for _ in 0..16 {
            cs.push(sess.step().unwrap().lane_checksums[lane]);
        }
        rollouts.push(cs);
    }
    sess.finish();
    assert_eq!(rollouts[0], rollouts[1], "second admission diverged");
    assert_eq!(rollouts[0], rollouts[2], "third admission diverged");
}

#[test]
fn admission_bookkeeping_and_errors() {
    let Some(rt) = runtime("synthetic") else { return };
    let b = rt.dims.b;
    let engine = Engine::new(&rt, opts(TauKind::RustDirect, true)).unwrap();

    let mut sess = engine.session(16).unwrap();
    for _ in 0..8 {
        sess.step().unwrap();
    }
    // capacity: only 8 positions remain
    assert!(sess.admit(0, LaneInit { limit: 16, ..Default::default() }).is_err());
    // lane range
    assert!(sess.admit(b, LaneInit { limit: 4, ..Default::default() }).is_err());
    // limit 0 = run to the end of the schedule
    sess.admit(0, LaneInit::default()).unwrap();
    assert_eq!(sess.lane_limit(0), 8);
    assert_eq!(sess.lane_start(0), 8);
    assert!(!sess.lane_done(0));
    while !sess.is_done() {
        sess.step().unwrap();
    }
    assert!(sess.lane_done(0));
    // complete session refuses admissions
    assert!(sess.admit(0, LaneInit { limit: 1, ..Default::default() }).is_err());
    sess.finish();

    // teacher forcing owns every lane's inputs: no admission while active
    let dims = rt.dims;
    let forced = vec![0.5f32; 8 * dims.b * dims.d];
    let mut sess = engine.session_teacher_forced(16, &forced).unwrap();
    sess.step().unwrap();
    assert!(
        sess.admit(0, LaneInit { limit: 4, ..Default::default() }).is_err(),
        "admission during teacher forcing must fail"
    );
    sess.finish();
}

#[test]
fn admitted_lane_tokens_match_fresh_run_lm() {
    let Some(rt) = runtime("hyena") else { return };
    let lane = rt.dims.b - 1;
    let engine = Engine::new(&rt, opts(TauKind::RustDirect, true)).unwrap();
    let init = LaneInit {
        limit: 16,
        sampler_cfg: Some(SamplerCfg::Lm { temperature: 0.7, top_k: 8 }),
        seed: Some(9),
        pending_seed: None,
    };
    let drive = |admit_at: usize| {
        let mut sess = engine.session(32).unwrap();
        for _ in 0..admit_at {
            sess.step().unwrap();
        }
        sess.admit(lane, init.clone()).unwrap();
        let mut toks = Vec::new();
        for _ in 0..16 {
            let step = sess.step().unwrap();
            toks.push(step.tokens.expect("LM tokens")[lane]);
        }
        sess.finish();
        toks
    };
    assert_eq!(drive(0), drive(8), "admitted LM lane sampled different tokens");
}
