//! Integration: session paging (`Session::suspend` / `Session::restore`
//! + the scheduler's eviction policy) is *semantically invisible* to the
//! evicted request. A lane checkpointed out of a running batch and
//! restored later — in the same session or a completely different one —
//! must produce **bit-identical** per-position lane checksums to the same
//! request run uninterrupted, with the deadline-fenced async mixer in
//! flight at both the suspend and the restore boundary, and with the
//! Appendix D half store wrapped past its halfway point.
//!
//! Why the restore position is constrained: the fractal tile schedule
//! partitions a lane's (source → destination) contribution pairs by the
//! lane's alignment in the *global* clock. The checkpointed pending rows
//! hold partial sums for exactly the pairs whose covering tile had
//! already run at suspension; only at the same global position do the
//! remaining tiles complement that set exactly (each contribution lands
//! once, in the same float order). `Session::restore` enforces this —
//! and these tests prove the payoff: resumed == uninterrupted, bit for
//! bit.

use std::path::Path;

use flash_inference::engine::{
    Engine, EngineOpts, LaneInit, Method, Pager, SamplerCfg, SessionInit,
};
use flash_inference::runtime::Runtime;
use flash_inference::tau::TauKind;

fn runtime(variant: &str) -> Option<Runtime> {
    let dir = Path::new("artifacts").join(variant);
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&dir).expect("load runtime"))
}

fn opts(tau: TauKind) -> EngineOpts {
    // async mixer ON — the acceptance criterion: suspend/restore must
    // fence in-flight gray tiles (a missed fence panics via RowReadiness)
    EngineOpts { method: Method::Flash, tau, async_mixer: true, ..Default::default() }
}

fn init(limit: usize, sigma: f32, seed: u64) -> LaneInit {
    LaneInit {
        limit,
        sampler_cfg: Some(SamplerCfg::Synthetic { sigma }),
        seed: Some(seed),
        pending_seed: None,
    }
}

/// Baseline: admit `init` into `lane` at `admit_at` and run it
/// uninterrupted, returning its per-position checksums.
fn drive_uninterrupted(
    engine: &Engine,
    len: usize,
    lane: usize,
    admit_at: usize,
    li: LaneInit,
) -> Vec<f32> {
    let limit = li.limit;
    let mut sess = engine.session(len).expect("session");
    for _ in 0..admit_at {
        sess.step().expect("step");
    }
    sess.admit(lane, li).expect("admit");
    let mut cs = Vec::with_capacity(limit);
    for _ in 0..limit {
        cs.push(sess.step().expect("step").lane_checksums[lane]);
    }
    sess.finish();
    cs
}

#[test]
fn evict_then_resume_in_later_session_is_bit_identical() {
    let Some(rt) = runtime("synthetic") else { return };
    let lane = rt.dims.b - 1;
    let engine = Engine::new(&rt, opts(TauKind::RustDirect)).unwrap();
    let mut pager = engine.make_pager(64);
    let (len, admit_at, limit, suspend_at) = (64usize, 8usize, 32usize, 20usize);
    let li = init(limit, 0.25, 77);

    let want = drive_uninterrupted(&engine, len, lane, admit_at, li.clone());

    // session 1: admit at 8, run to global position 20, suspend
    let mut s1 = engine.session(len).unwrap();
    for _ in 0..admit_at {
        s1.step().unwrap();
    }
    s1.admit(lane, li).unwrap();
    let mut got = Vec::new();
    for _ in 0..(suspend_at - admit_at) {
        got.push(s1.step().unwrap().lane_checksums[lane]);
    }
    let ckpt = s1.suspend(lane, &mut pager).expect("suspend");
    assert_eq!(ckpt.pos(), suspend_at);
    assert_eq!(ckpt.lane_start(), admit_at);
    assert_eq!(ckpt.lane_limit(), limit);
    assert!(s1.lane_done(lane), "suspended lane reads as idle");
    // the checkpoint pages rows from the lane's admission point only:
    // streams rows admit_at..suspend_at, pending rows admit_at..2*suspend_at
    let want_blocks = pager.blocks_for(suspend_at - admit_at)
        + pager.blocks_for(2 * suspend_at - admit_at);
    assert_eq!(
        pager.resident_values(),
        want_blocks * pager.block_values(),
        "checkpoint must exclude the zero prefix below lane_start"
    );
    // the donor session keeps running (other lanes unaffected)
    for _ in 0..6 {
        s1.step().unwrap();
    }
    s1.finish();

    // session 2: a *different* session serves other content on that lane,
    // then the clock reaches the suspension position and the lane resumes
    let mut s2 = engine.session(len).unwrap();
    for _ in 0..suspend_at {
        s2.step().unwrap();
    }
    s2.restore(lane, ckpt, &mut pager).expect("restore");
    assert_eq!(pager.free_blocks(), pager.total_blocks(), "restore frees the slab");
    assert_eq!(s2.lane_start(lane), admit_at, "admission clock survives the round trip");
    assert_eq!(s2.lane_pos(lane), suspend_at - admit_at);
    while !s2.lane_done(lane) {
        got.push(s2.step().unwrap().lane_checksums[lane]);
    }
    s2.finish();

    assert_eq!(want.len(), got.len());
    assert_eq!(want, got, "evict-then-resume diverged from the uninterrupted run");
}

#[test]
fn evict_then_resume_with_half_store_wrap_is_bit_identical() {
    let Some(rt) = runtime("synthetic") else { return };
    let lane = 0;
    let engine = Engine::new(
        &rt,
        EngineOpts { half_store: true, ..opts(TauKind::RustDirect) },
    )
    .unwrap();
    let mut pager = engine.make_pager(64);
    // len 64 -> 32 wrapped rows; suspending at 40 checkpoints a store
    // whose rows have already been recycled once, and the resumed lane's
    // tiles keep crossing row_of() seams
    let (len, limit, suspend_at) = (64usize, 64usize, 40usize);
    let li = init(limit, 0.5, 3);

    let want = drive_uninterrupted(&engine, len, lane, 0, li.clone());

    let mut s1 = engine.session(len).unwrap();
    s1.admit(lane, li).unwrap();
    let mut got = Vec::new();
    for _ in 0..suspend_at {
        got.push(s1.step().unwrap().lane_checksums[lane]);
    }
    let ckpt = s1.suspend(lane, &mut pager).expect("suspend under wrap");
    for _ in 0..4 {
        s1.step().unwrap();
    }
    s1.finish();

    let mut s2 = engine.session(len).unwrap();
    for _ in 0..suspend_at {
        s2.step().unwrap();
    }
    s2.restore(lane, ckpt, &mut pager).expect("restore under wrap");
    while !s2.lane_done(lane) {
        got.push(s2.step().unwrap().lane_checksums[lane]);
    }
    s2.finish();
    assert_eq!(want, got, "half-store evict/resume diverged");
}

#[test]
fn suspend_restore_same_boundary_roundtrip() {
    // degenerate but legal: suspend and restore at the same step boundary
    // of the same session — the pure inverse-copy property, plus the
    // rust-fft kernel under a fixed alignment (exact for any tau impl)
    let Some(rt) = runtime("synthetic") else { return };
    let lane = rt.dims.b - 1;
    let engine = Engine::new(&rt, opts(TauKind::RustFft)).unwrap();
    let mut pager = engine.make_pager(64);
    let li = init(32, 0.25, 11);

    let want = drive_uninterrupted(&engine, 64, lane, 0, li.clone());
    let mut sess = engine.session(64).unwrap();
    sess.admit(lane, li).unwrap();
    let mut got = Vec::new();
    for _ in 0..17 {
        got.push(sess.step().unwrap().lane_checksums[lane]);
    }
    let ckpt = sess.suspend(lane, &mut pager).unwrap();
    sess.restore(lane, ckpt, &mut pager).unwrap();
    while !sess.lane_done(lane) {
        got.push(sess.step().unwrap().lane_checksums[lane]);
    }
    sess.finish();
    assert_eq!(want, got, "same-boundary suspend/restore round trip diverged");
}

#[test]
fn folded_suspend_resumes_at_any_boundary_bit_identical() {
    // The tentpole property: a *folded* checkpoint carries no clock
    // alignment. Suspend at several positions and restore each into a
    // different session at a global position that is earlier than, later
    // than, or exactly at the lane's generated count — never at the
    // aligned position — and require bit-identity with the uninterrupted
    // run (rust-direct: ascending-source-order accumulation makes the
    // rebased tile decomposition sum in the same float order).
    let Some(rt) = runtime("synthetic") else { return };
    let lane = rt.dims.b - 1;
    let engine = Engine::new(&rt, opts(TauKind::RustDirect)).unwrap();
    let mut pager = engine.make_pager(64);
    let (len, admit_at, limit) = (64usize, 8usize, 32usize);

    for (suspend_at, restore_at) in [(12usize, 5usize), (20, 31), (27, 19)] {
        let lane_pos = suspend_at - admit_at;
        let span = limit - lane_pos;
        assert!(restore_at >= lane_pos && restore_at + span <= len, "bad case");
        let li = init(limit, 0.25, 1000 + suspend_at as u64);
        let want = drive_uninterrupted(&engine, len, lane, admit_at, li.clone());

        let mut s1 = engine.session(len).unwrap();
        for _ in 0..admit_at {
            s1.step().unwrap();
        }
        s1.admit(lane, li).unwrap();
        let mut got = Vec::new();
        for _ in 0..lane_pos {
            got.push(s1.step().unwrap().lane_checksums[lane]);
        }
        let ckpt = s1.suspend_folded(lane, &mut pager).expect("suspend_folded");
        assert!(ckpt.folded());
        assert_eq!(ckpt.span(), span);
        // a folded checkpoint pages only the pending tail — no history
        assert_eq!(
            pager.resident_values(),
            pager.blocks_for(span) * pager.block_values(),
            "folded checkpoint must hold exactly the [M, span, D] tail"
        );
        assert!(s1.lane_done(lane));
        for _ in 0..3 {
            s1.step().unwrap();
        }
        s1.finish();

        // a different session, at an arbitrary step boundary — no
        // clock-catch-up wait, the aligned path's defining restriction
        let mut s2 = engine.session(len).unwrap();
        for _ in 0..restore_at {
            s2.step().unwrap();
        }
        s2.restore(lane, ckpt, &mut pager).expect("folded restore");
        assert_eq!(pager.free_blocks(), pager.total_blocks(), "restore frees the slab");
        assert_eq!(s2.lane_start(lane), restore_at - lane_pos, "lane clock rebased");
        assert_eq!(s2.lane_pos(lane), lane_pos);
        while !s2.lane_done(lane) {
            got.push(s2.step().unwrap().lane_checksums[lane]);
        }
        s2.finish();
        assert_eq!(want.len(), got.len());
        assert_eq!(
            want, got,
            "folded resume (suspend at {suspend_at}, restore at {restore_at}) diverged"
        );
    }
}

#[test]
fn folded_suspend_with_half_store_wrap_is_bit_identical() {
    let Some(rt) = runtime("synthetic") else { return };
    let lane = 0;
    let engine = Engine::new(
        &rt,
        EngineOpts { half_store: true, ..opts(TauKind::RustDirect) },
    )
    .unwrap();
    let mut pager = engine.make_pager(64);
    // len 64 -> 32 wrapped rows; suspending at global 40 folds a store
    // that has already recycled rows once, and the tail (span 8) fits the
    // wrapped window; the restore lands at an unaligned position
    let (len, admit_at, limit) = (64usize, 16usize, 32usize);
    let (suspend_at, restore_at) = (40usize, 26usize);
    let lane_pos = suspend_at - admit_at;
    let li = init(limit, 0.5, 21);
    let want = drive_uninterrupted(&engine, len, lane, admit_at, li.clone());

    let mut s1 = engine.session(len).unwrap();
    for _ in 0..admit_at {
        s1.step().unwrap();
    }
    s1.admit(lane, li).unwrap();
    let mut got = Vec::new();
    for _ in 0..lane_pos {
        got.push(s1.step().unwrap().lane_checksums[lane]);
    }
    let ckpt = s1.suspend_folded(lane, &mut pager).expect("fold under wrap");
    assert!(ckpt.folded());
    for _ in 0..4 {
        s1.step().unwrap();
    }
    s1.finish();

    let mut s2 = engine.session(len).unwrap();
    for _ in 0..restore_at {
        s2.step().unwrap();
    }
    s2.restore(lane, ckpt, &mut pager).expect("folded restore under wrap");
    while !s2.lane_done(lane) {
        got.push(s2.step().unwrap().lane_checksums[lane]);
    }
    s2.finish();
    assert_eq!(want, got, "half-store folded evict/resume diverged");
}

#[test]
fn folded_restore_guards_fit_and_rebase() {
    let Some(rt) = runtime("synthetic") else { return };
    let engine = Engine::new(&rt, opts(TauKind::RustDirect)).unwrap();
    let mut pager = engine.make_pager(64);

    // fold a lane with 22 remaining positions at lane clock 10
    let mut s1 = engine.session(32).unwrap();
    s1.admit(0, init(32, 0.25, 5)).unwrap();
    for _ in 0..10 {
        s1.step().unwrap();
    }
    let ckpt = s1.suspend_folded(0, &mut pager).unwrap();
    s1.finish();

    // restoring before the lane's generated count would rebase the
    // admission point before the session origin: refused, slab freed
    let mut s2 = engine.session(32).unwrap();
    for _ in 0..5 {
        s2.step().unwrap();
    }
    assert!(s2.restore(0, ckpt, &mut pager).is_err(), "restore at pos < lane_pos must fail");
    assert_eq!(pager.free_blocks(), pager.total_blocks(), "failed restore must not leak");

    // a tail that cannot fit the remaining schedule is refused too
    let mut s3 = engine.session(32).unwrap();
    s3.admit(1, init(32, 0.25, 6)).unwrap();
    for _ in 0..10 {
        s3.step().unwrap();
    }
    let ckpt = s3.suspend_folded(1, &mut pager).unwrap();
    s3.finish();
    let mut late = engine.session(32).unwrap();
    for _ in 0..12 {
        late.step().unwrap();
    }
    // span 22 > 20 remaining of the 32-step schedule
    assert!(late.restore(1, ckpt, &mut pager).is_err());
    assert_eq!(pager.free_blocks(), pager.total_blocks());
    late.finish();

    // half store: a fold whose tail exceeds the wrapped window bails
    // without touching the lane
    let half = Engine::new(
        &rt,
        EngineOpts { half_store: true, ..opts(TauKind::RustDirect) },
    )
    .unwrap();
    let mut s4 = half.session(16).unwrap(); // 8 wrapped rows
    s4.admit(0, init(16, 0.25, 7)).unwrap();
    for _ in 0..4 {
        s4.step().unwrap();
    }
    // remaining span 12 > 8 rows
    assert!(s4.suspend_folded(0, &mut pager).is_err());
    s4.step().unwrap(); // the lane is untouched and keeps stepping
    s4.finish();
}

#[test]
fn restore_guards_position_capacity_and_geometry() {
    let Some(rt) = runtime("synthetic") else { return };
    let dims = rt.dims;
    let engine = Engine::new(&rt, opts(TauKind::RustDirect)).unwrap();
    let mut pager = engine.make_pager(64);

    let mut sess = engine.session(32).unwrap();
    for _ in 0..10 {
        sess.step().unwrap();
    }
    // lane out of range
    assert!(sess.suspend(dims.b, &mut pager).is_err());
    // geometry mismatch: a pager built for the wrong lane shape refuses
    let mut bad = Pager::new(dims.g / dims.b + 1, dims.d, 16, 64);
    assert!(sess.suspend(0, &mut bad).is_err());

    // wrong-position restore fails and releases the slab blocks
    let ckpt = sess.suspend(0, &mut pager).unwrap();
    sess.step().unwrap();
    assert!(sess.restore(0, ckpt, &mut pager).is_err(), "restore at pos+1 must fail");
    assert_eq!(pager.free_blocks(), pager.total_blocks(), "failed restore must not leak");

    // a pager with no room (capacity 0 MB = a single block; this
    // checkpoint needs 3) fails the suspend without touching the lane
    let mut tiny = Pager::new(dims.g / dims.b, dims.d, 16, 0);
    assert!(!tiny.fits(tiny.blocks_for(11) + tiny.blocks_for(22)));
    assert!(sess.suspend(1, &mut tiny).is_err());
    // the lane is untouched: the session keeps stepping normally
    sess.step().unwrap();
    sess.finish();
}

#[test]
fn pending_seed_larger_than_half_store_bails() {
    // regression (satellite): Session::new used to silently truncate a
    // prompt's future contributions to the wrapped store's rows in
    // half-store mode, generating wrong activations past len/2
    let Some(rt) = runtime("synthetic") else { return };
    let dims = rt.dims;
    let (g, d, b) = (dims.g, dims.d, dims.b);
    let len = 16usize;
    let span = len; // contributions reaching past rows = len/2
    let seed_init = || SessionInit {
        a0: vec![0.1; b * d],
        pending_seed: Some((vec![0.01; g * span * d], span)),
        ..Default::default()
    };

    let half = Engine::new(&rt, EngineOpts { half_store: true, ..opts(TauKind::RustDirect) })
        .unwrap();
    let err = flash_inference::engine::Session::new(&half, len, seed_init());
    assert!(err.is_err(), "half store must refuse a seed wider than its rows");

    // the full store accepts the same seed (dropped columns are positions
    // past the session's end — never generated, so truncation is exact)
    let full = Engine::new(&rt, opts(TauKind::RustDirect)).unwrap();
    let sess = flash_inference::engine::Session::new(&full, len, seed_init());
    assert!(sess.is_ok(), "full store accepts seeds clipped to the session length");

    // suspend on a seeded session must checkpoint the whole seed span,
    // not just 2*pos — the prompt's future contributions live in pending
    // rows the clock has not reached yet
    let mut sess = sess.unwrap();
    let mut pager = full.make_pager(64);
    sess.step().unwrap(); // pos 1: 2*pos << span
    let ckpt = sess.suspend(0, &mut pager).expect("suspend seeded session");
    let want = (pager.blocks_for(1) + pager.blocks_for(span)) * pager.block_values();
    assert_eq!(
        pager.resident_values(),
        want,
        "checkpoint must cover the pending seed span"
    );
    sess.restore(0, ckpt, &mut pager).unwrap();
    sess.step().unwrap();
    sess.finish();
}

mod server_pressure {
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::path::Path;

    use flash_inference::config::ServerConfig;
    use flash_inference::engine::EngineOpts;
    use flash_inference::server::http::decode_chunked;
    use flash_inference::server::Server;
    use flash_inference::tau::TauKind;
    use flash_inference::util::json::Json;

    fn raw_post(body: &str) -> String {
        format!(
            "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
    }

    fn post_json(addr: std::net::SocketAddr, body: &str) -> Json {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(raw_post(body).as_bytes()).unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        assert!(buf.contains("200 OK"), "non-200: {}", &buf[..buf.len().min(300)]);
        let payload = buf.split("\r\n\r\n").nth(1).unwrap_or("{}");
        Json::parse(payload).expect("parse reply")
    }

    fn read_until(s: &mut TcpStream, needle: &[u8]) -> Vec<u8> {
        let mut buf = Vec::new();
        let mut chunk = [0u8; 1024];
        loop {
            let n = s.read(&mut chunk).expect("read");
            assert!(n > 0, "stream closed early: {}", String::from_utf8_lossy(&buf));
            buf.extend_from_slice(&chunk[..n]);
            if buf.windows(needle.len()).any(|w| w == needle) {
                return buf;
            }
        }
    }

    fn metric(addr: std::net::SocketAddr, name: &str) -> f64 {
        flash_inference::util::benchkit::scrape_metric(addr, name).unwrap_or(-1.0)
    }

    /// The paging acceptance test at the scheduler level: hold every lane
    /// with long streaming requests, queue a short one, and require that
    /// (a) the short admits mid-batch (eviction freed it a lane), (b) the
    /// evicted request still completes, and (c) its checksum equals a
    /// fresh uninterrupted rerun of the identical request.
    #[test]
    fn eviction_under_pressure_completes_all_with_fresh_checksums() {
        if !Path::new("artifacts/synthetic/manifest.json").exists() {
            eprintln!("SKIP: run `make artifacts` first");
            return;
        }
        let cfg = ServerConfig {
            port: 0,
            artifacts: "artifacts/synthetic".into(),
            max_max_tokens: 128,
            default_max_tokens: 16,
            engine: EngineOpts {
                // rust-direct: bit-identity holds across admission/resume
                // alignments (and keeps the async executor on the path)
                tau: TauKind::RustDirect,
                ..ServerConfig::default().engine
            },
            ..Default::default()
        };
        let server = Server::start(cfg).expect("start server");
        let addr = server.addr;
        let info = {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GET /v1/info HTTP/1.1\r\n\r\n").unwrap();
            let mut buf = String::new();
            s.read_to_string(&mut buf).unwrap();
            Json::parse(buf.split("\r\n\r\n").nth(1).unwrap_or("{}")).unwrap()
        };
        let b = info.req_usize("B").expect("info B");
        assert_eq!(info.get("paging").and_then(Json::as_bool), Some(true));

        let long_body = |seed: usize| {
            format!("{{\"max_tokens\": 120, \"sigma\": 0.05, \"seed\": {seed}, \"stream\": true}}")
        };
        let short_body = "{\"max_tokens\": 8, \"sigma\": 0.05, \"seed\": 7}";

        let mut observed = None;
        for attempt in 0..3 {
            let seed0 = 100 + attempt * 10;
            let evict0 = metric(addr, "fi_evictions_total");
            // occupy every lane with a long streaming request; its first
            // event proves the lane is admitted and running
            let mut longs = Vec::new();
            for i in 0..b {
                let mut s = TcpStream::connect(addr).unwrap();
                s.write_all(raw_post(&long_body(seed0 + i)).as_bytes()).unwrap();
                read_until(&mut s, b"\"pos\":");
                longs.push(s);
            }
            // queue pressure: a short request with every lane busy
            let short = post_json(addr, short_body);
            assert_eq!(short.req_usize("steps").unwrap(), 8);
            // drain the longs (they must all complete, evicted or not)
            let tails: Vec<Json> = longs
                .into_iter()
                .map(|mut s| {
                    let mut raw = String::new();
                    s.read_to_string(&mut raw).unwrap();
                    let payload =
                        decode_chunked(raw.split("\r\n\r\n").nth(1).unwrap_or(""));
                    let done = payload
                        .lines()
                        .rfind(|l| l.contains("\"done\""))
                        .expect("summary line")
                        .to_string();
                    Json::parse(&done).expect("parse tail")
                })
                .collect();
            for t in &tails {
                assert!(t.get("error").is_none(), "long request errored: {t}");
            }
            if metric(addr, "fi_evictions_total") > evict0 {
                observed = Some((seed0, tails, short));
                break;
            }
            eprintln!("attempt {attempt}: no eviction observed (longs finished first?), retrying");
        }
        let (seed0, tails, short) =
            observed.expect("no eviction in 3 attempts under full-lane pressure");

        // the queued short was admitted into a freed lane of the running
        // batch — eviction, not batch drain, is what made room for it
        assert!(
            short.get("admitted_pos").and_then(Json::as_f64).unwrap_or(-1.0) > 0.0,
            "short request did not admit mid-batch: {short}"
        );
        assert_eq!(short.get("evictions").and_then(Json::as_f64), Some(0.0));

        // every long completed; at least one was evicted and resumed, and
        // each one's checksum matches a fresh uninterrupted rerun
        let evicted: Vec<&Json> = tails
            .iter()
            .filter(|t| t.get("evictions").and_then(Json::as_f64).unwrap_or(0.0) > 0.0)
            .collect();
        assert!(!evicted.is_empty(), "no tail reports an eviction");
        for (i, t) in tails.iter().enumerate() {
            let body = format!("{{\"max_tokens\": 120, \"sigma\": 0.05, \"seed\": {}}}", seed0 + i);
            let fresh = post_json(addr, &body);
            assert_eq!(
                t.get("checksum").and_then(Json::as_f64),
                fresh.get("checksum").and_then(Json::as_f64),
                "request seed {} diverged from its fresh rerun (evictions={:?})",
                seed0 + i,
                t.get("evictions")
            );
        }
        assert!(metric(addr, "fi_resumes_total") >= 1.0, "no resume counted");
        assert_eq!(metric(addr, "fi_requests_failed"), 0.0);
        server.stop();
    }
}

/// Slab property check over the public API: random checkpoint sizes
/// churned through a small pager never corrupt each other's payloads
/// (no block overlap) and every block is reusable after release.
#[test]
fn pager_slab_property_no_overlap_full_reuse() {
    use flash_inference::util::propcheck::{self, ensure};
    use flash_inference::util::prng::Prng;

    propcheck::check(
        "public_slab_churn",
        48,
        |rng: &mut Prng| {
            let ops: Vec<usize> = (0..rng.range(6, 30)).map(|_| rng.range(0, 13)).collect();
            (rng.range(1, 3), rng.range(1, 4), ops)
        },
        |(groups, d, ops)| {
            // capacity 0 MB still yields >= 1 block; use rows_chunk 4 and
            // small dims so a few ops exhaust capacity and force reuse
            let mut p = Pager::new(*groups, *d, 4, 0);
            let cap = p.total_blocks();
            let mut live: Vec<(flash_inference::engine::pager::PagedRows, Vec<f32>)> = Vec::new();
            let mut stamp = 1.0f32;
            for &rows in ops {
                if rows == 0 || !p.fits(p.blocks_for(rows)) {
                    if !live.is_empty() {
                        let (pr, want) = live.remove(0);
                        let mut got = Vec::new();
                        p.fetch_rows(pr, &mut got);
                        ensure(got == want, "payload corrupted".to_string())?;
                    }
                    continue;
                }
                let data: Vec<f32> =
                    (0..groups * rows * d).map(|i| stamp + i as f32).collect();
                stamp += 500.0;
                let pr = p.store_rows(&data, rows).map_err(|e| e.to_string())?;
                live.push((pr, data));
            }
            for (pr, want) in live.drain(..) {
                let mut got = Vec::new();
                p.fetch_rows(pr, &mut got);
                ensure(got == want, "payload corrupted at drain".to_string())?;
            }
            ensure(
                p.free_blocks() == cap,
                format!("leaked blocks: {} of {cap} free", p.free_blocks()),
            )
        },
    );
}
