//! Integration: PJRT runtime against the real artifacts built by
//! `make artifacts`. Exercises the full aot.py → manifest → compile →
//! execute contract and cross-checks artifact numerics against the native
//! rust kernels.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use flash_inference::fft::{self, Plan};
use flash_inference::model::Variant;
use flash_inference::runtime::{BoundArtifact, Runtime};
use flash_inference::util::prng::Prng;

fn runtime() -> Option<Runtime> {
    let dir = Path::new("artifacts/synthetic");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(dir).expect("load runtime"))
}

fn rand_vec(rng: &mut Prng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32()).collect()
}

#[test]
fn loads_manifest_weights_and_dims() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.dims.variant, Variant::Synthetic);
    assert!(rt.dims.l.is_power_of_two());
    assert!(rt.weights.len() >= 8);
    // step + filter_gen + 2 tau families over log2(L) sizes
    let expected = 2 + 2 * (rt.dims.l / 2).trailing_zeros() as usize + 2;
    assert!(rt.manifest.artifacts.len() >= expected - 1);
}

#[test]
fn filter_gen_produces_normalized_rho() {
    let Some(rt) = runtime() else { return };
    let exe = rt.executable("filter_gen").expect("compile filter_gen");
    let args: Vec<_> = exe
        .spec
        .inputs
        .iter()
        .map(|i| rt.weight_buffer(&i.name).unwrap())
        .collect();
    let arg_refs: Vec<&xla::PjRtBuffer> = args.iter().map(|a| a.as_ref()).collect();
    let outs = exe.call(&arg_refs).expect("run filter_gen");
    let rho = Runtime::literal_to_vec(&outs[0], rt.dims.m * rt.dims.l * rt.dims.d).unwrap();
    assert!(rho.iter().all(|v| v.is_finite()));
    // per (m, d): sum_t |rho| <= 1 (aot normalization)
    let (m, l, d) = (rt.dims.m, rt.dims.l, rt.dims.d);
    for mi in 0..m {
        for di in (0..d).step_by(17) {
            let s: f32 = (0..l).map(|t| rho[(mi * l + t) * d + di].abs()).sum();
            assert!(s <= 1.0 + 1e-4, "m={mi} d={di} sum={s}");
        }
    }
}

#[test]
fn tau_artifacts_match_native_kernels() {
    let Some(rt) = runtime() else { return };
    let (g, d) = (rt.dims.g, rt.dims.d);
    let mut rng = Prng::new(42);
    for u in [1usize, 4, 32] {
        let y = rand_vec(&mut rng, g * u * d);
        let rho_seg = rand_vec(&mut rng, g * 2 * u * d);

        // native direct
        let mut want = vec![0.0f32; g * u * d];
        for gi in 0..g {
            fft::tile_conv_direct_into(
                &y[gi * u * d..(gi + 1) * u * d],
                &rho_seg[gi * 2 * u * d..(gi + 1) * 2 * u * d],
                &mut want[gi * u * d..(gi + 1) * u * d],
                d,
            );
        }

        // pjrt direct (pallas kernel artifact)
        let exe = rt.executable(&format!("tau_direct_{u}")).unwrap();
        let yb = rt.upload(&y, &[g, u, d]).unwrap();
        let sb = rt.upload(&rho_seg, &[g, 2 * u, d]).unwrap();
        let outs = exe.call(&[&yb, &sb]).unwrap();
        let got = Runtime::literal_to_vec(&outs[0], g * u * d).unwrap();
        let tol = 1e-3_f32 * (u as f32).sqrt();
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < tol, "direct u={u}: {a} vs {b}");
        }

        // pjrt fft (needs the filter spectrum split re/im, rfft layout)
        let plan = Plan::new(2 * u);
        let mut re_all = vec![0.0f32; g * (u + 1) * d];
        let mut im_all = vec![0.0f32; g * (u + 1) * d];
        for gi in 0..g {
            let (re, im) =
                fft::spectrum_planes(&plan, &rho_seg[gi * 2 * u * d..(gi + 1) * 2 * u * d], d);
            // keep rfft bins [0, u]
            re_all[gi * (u + 1) * d..(gi + 1) * (u + 1) * d]
                .copy_from_slice(&re[..(u + 1) * d]);
            im_all[gi * (u + 1) * d..(gi + 1) * (u + 1) * d]
                .copy_from_slice(&im[..(u + 1) * d]);
        }
        let exe = rt.executable(&format!("tau_fft_{u}")).unwrap();
        let yb = rt.upload(&y, &[g, u, d]).unwrap();
        let rb = rt.upload(&re_all, &[g, u + 1, d]).unwrap();
        let ib = rt.upload(&im_all, &[g, u + 1, d]).unwrap();
        let outs = exe.call(&[&yb, &rb, &ib]).unwrap();
        let got = Runtime::literal_to_vec(&outs[0], g * u * d).unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < tol, "fft u={u}: {a} vs {b}");
        }
    }
}

#[test]
fn step_artifact_runs_via_bound_artifact() {
    let Some(rt) = runtime() else { return };
    let (m, b, d) = (rt.dims.m, rt.dims.b, rt.dims.d);
    let mut rng = Prng::new(7);

    // derived input: rho0 — zeros are fine for an ABI smoke test
    let rho0 = vec![0.0f32; m * d];
    let mut derived = HashMap::new();
    derived.insert(
        "@rho0".to_string(),
        Arc::new(rt.upload(&rho0, &[m, d]).unwrap()),
    );
    let bound = BoundArtifact::bind(&rt, "step", &derived).expect("bind step");
    assert_eq!(bound.runtime_arity(), 2); // $pending_col, $a0

    let pend = rand_vec(&mut rng, m * b * d);
    let a0 = rand_vec(&mut rng, b * d);
    let pb = rt.upload(&pend, &[m, b, d]).unwrap();
    let ab = rt.upload(&a0, &[b, d]).unwrap();
    let outs = bound.call(&[&pb, &ab]).expect("run step");
    let streams = Runtime::literal_to_vec(&outs[0], m * b * d).unwrap();
    let out = Runtime::literal_to_vec(&outs[1], b * rt.dims.out_width()).unwrap();
    assert!(streams.iter().all(|v| v.is_finite()));
    assert!(out.iter().all(|v| v.is_finite()));
    // first stream row is the mixer-1 input = a0 itself (synthetic)
    for (s, a) in streams[..b * d].iter().zip(&a0) {
        assert!((s - a).abs() < 1e-6);
    }

    // wrong arity is rejected
    assert!(bound.call(&[&pb]).is_err());
}

#[test]
fn executable_cache_compiles_once() {
    let Some(rt) = runtime() else { return };
    let a = rt.executable("tau_fft_1").unwrap();
    let b = rt.executable("tau_fft_1").unwrap();
    assert!(Arc::ptr_eq(&a, &b));
}
