//! Router/fleet integration: multi-replica serving under fault injection.
//!
//! The replica fleet (server/replica.rs + server/router.rs) promises
//! failure-domain isolation: killing one of two replicas mid-load fails
//! only that replica's in-flight lanes (structured 500s), fails over its
//! never-admitted queued requests to the healthy replica bit-identically,
//! reports `degraded` (not 503) on `/health` throughout the outage, and
//! respawns the quarantined replica — after its backoff and a clean probe
//! window it is back in full rotation. A fleet of one must preserve PR
//! 7's surface exactly.
//!
//! The fault registry (`util::faultpoint`) is process-global, so every
//! test serializes on one mutex and disarms on exit (panic included).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::{Mutex, PoisonError};
use std::time::{Duration, Instant};

use flash_inference::config::ServerConfig;
use flash_inference::server::Server;
use flash_inference::util::faultpoint;
use flash_inference::util::json::Json;

static SERIAL: Mutex<()> = Mutex::new(());

/// Serialize tests and guarantee the global registry is disarmed when the
/// test ends, even if it fails partway with faults still installed.
struct FaultGuard<'a>(#[allow(dead_code)] std::sync::MutexGuard<'a, ()>);

impl Drop for FaultGuard<'_> {
    fn drop(&mut self) {
        faultpoint::clear();
    }
}

fn serial() -> FaultGuard<'static> {
    let g = SERIAL.lock().unwrap_or_else(PoisonError::into_inner);
    faultpoint::clear();
    FaultGuard(g)
}

fn start_server(cfg: ServerConfig) -> Option<Server> {
    if !Path::new("artifacts/synthetic/manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    Some(Server::start(cfg).expect("start server"))
}

fn base_cfg() -> ServerConfig {
    ServerConfig { port: 0, artifacts: "artifacts/synthetic".into(), ..Default::default() }
}

fn fleet_cfg(replicas: usize) -> ServerConfig {
    ServerConfig { replicas, ..base_cfg() }
}

fn request_raw(addr: std::net::SocketAddr, raw: &str) -> (u16, String, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(raw.as_bytes()).unwrap();
    s.flush().unwrap();
    // Tolerant read: a shed connection may be closed with the request
    // bytes unread, so the kernel can follow the response with an RST —
    // keep whatever arrived before it instead of panicking.
    let mut bytes = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        match s.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => bytes.extend_from_slice(&chunk[..n]),
            Err(_) => break,
        }
    }
    let buf = String::from_utf8_lossy(&bytes).into_owned();
    let status = buf.split_whitespace().nth(1).and_then(|t| t.parse::<u16>().ok()).unwrap_or(0);
    let headers = buf.split("\r\n\r\n").next().unwrap_or("").to_string();
    let body = buf.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, headers, body)
}

fn request(addr: std::net::SocketAddr, raw: &str) -> (u16, String) {
    let (status, _, body) = request_raw(addr, raw);
    (status, body)
}

fn post_generate(addr: std::net::SocketAddr, body: &str) -> (u16, String) {
    request(
        addr,
        &format!(
            "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        ),
    )
}

fn metrics(addr: std::net::SocketAddr) -> String {
    let (code, body) = request(addr, "GET /metrics HTTP/1.1\r\n\r\n");
    assert_eq!(code, 200);
    body
}

/// Parse one `fi_<name> <value>` line out of the metrics text. `name` may
/// include a label set (`fi_router_queue_depth{replica="0"}`).
fn metric(text: &str, name: &str) -> u64 {
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(name) {
            if let Ok(v) = rest.trim().parse::<f64>() {
                return v as u64;
            }
        }
    }
    panic!("metric {name} not found in:\n{text}");
}

fn health(addr: std::net::SocketAddr) -> (u16, Json) {
    let (code, body) = request(addr, "GET /health HTTP/1.1\r\n\r\n");
    (code, Json::parse(&body).expect("health body"))
}

fn health_status(addr: std::net::SocketAddr) -> (u16, String) {
    let (code, j) = health(addr);
    (code, j.req_str("status").expect("status").to_string())
}

fn info(addr: std::net::SocketAddr) -> Json {
    let (code, body) = request(addr, "GET /v1/info HTTP/1.1\r\n\r\n");
    assert_eq!(code, 200);
    Json::parse(&body).expect("info body")
}

/// Poll `cond` until it holds or `ms` elapses; panics with `what` on
/// timeout so a hung recovery path fails loudly instead of wedging CI.
fn wait_until(what: &str, ms: u64, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_millis(ms);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn checksum_of(body: &str) -> f64 {
    Json::parse(body).expect("json body").get("checksum").unwrap().as_f64().unwrap()
}

fn replica_of(body: &str) -> usize {
    Json::parse(body).expect("json body").req_usize("replica").unwrap()
}

// ---------------------------------------------------------------------------
// Fleet surface: health aggregation, per-replica breakdowns, affinity
// ---------------------------------------------------------------------------

#[test]
fn two_replicas_serve_bit_identically_and_report_fleet_health() {
    let _g = serial();
    let Some(server) = start_server(fleet_cfg(2)) else { return };
    let addr = server.addr;

    let (code, h) = health(addr);
    assert_eq!(code, 200);
    assert_eq!(h.req_str("status").unwrap(), "healthy");
    assert_eq!(h.req_usize("replicas_total").unwrap(), 2);
    assert_eq!(h.req_usize("replicas_serving").unwrap(), 2);

    // both replicas run the same artifacts: answers are bit-identical
    // regardless of which one serves
    let (code, body) = post_generate(addr, "{\"max_tokens\": 16, \"seed\": 7}");
    assert_eq!(code, 200, "{body}");
    let baseline = checksum_of(&body);
    assert!(replica_of(&body) < 2);
    let (code, body) = post_generate(addr, "{\"max_tokens\": 16, \"seed\": 7}");
    assert_eq!(code, 200, "{body}");
    assert_eq!(checksum_of(&body), baseline, "replicas must answer identically");

    let m = metrics(addr);
    assert_eq!(metric(&m, "fi_replicas"), 2, "{m}");
    assert_eq!(metric(&m, "fi_replicas_healthy"), 2, "{m}");
    assert_eq!(metric(&m, "fi_replica_restarts_total"), 0, "{m}");
    assert_eq!(metric(&m, "fi_failovers_total"), 0, "{m}");
    // per-replica queue-depth series exist for both replicas
    assert_eq!(metric(&m, "fi_router_queue_depth{replica=\"0\"}"), 0, "{m}");
    assert_eq!(metric(&m, "fi_router_queue_depth{replica=\"1\"}"), 0, "{m}");

    let i = info(addr);
    assert_eq!(i.req_usize("replicas").unwrap(), 2);
    assert_eq!(i.req_usize("replicas_serviceable").unwrap(), 2);
    let states = i.get("replica_states").unwrap().to_string();
    assert!(states.contains("\"serving\""), "{states}");

    server.stop();
}

#[test]
fn session_key_pins_requests_to_one_replica() {
    let _g = serial();
    let Some(server) = start_server(fleet_cfg(2)) else { return };
    let addr = server.addr;

    // a "session" key is a checkpoint-affinity hint: repeat requests land
    // on the replica whose pager may hold their evicted checkpoint
    let (code, body) = post_generate(addr, "{\"max_tokens\": 8, \"session\": \"abc\"}");
    assert_eq!(code, 200, "{body}");
    let home = replica_of(&body);
    for _ in 0..3 {
        let (code, body) = post_generate(addr, "{\"max_tokens\": 8, \"session\": \"abc\"}");
        assert_eq!(code, 200, "{body}");
        assert_eq!(replica_of(&body), home, "session must stay pinned");
    }

    // a non-string session is a client error, not a silent coercion
    let (code, body) = post_generate(addr, "{\"max_tokens\": 8, \"session\": 7}");
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("session must be a string"), "{body}");

    server.stop();
}

// ---------------------------------------------------------------------------
// The acceptance chaos scenario: kill one of two replicas mid-load
// ---------------------------------------------------------------------------

#[test]
fn killed_replica_fails_over_bit_identically_and_respawns_into_rotation() {
    let _g = serial();
    let cfg = ServerConfig {
        // zero tolerance: the first panic quarantines the replica
        restart_budget: 0,
        quarantine_backoff_ms: 400,
        quarantine_backoff_max_ms: 2000,
        probe_window_ms: 100,
        ..fleet_cfg(2)
    };
    let Some(server) = start_server(cfg) else { return };
    let addr = server.addr;
    let b = info(addr).req_usize("B").unwrap();

    let gen_body = "{\"max_tokens\": 96, \"seed\": 7}";
    let (code, body) = post_generate(addr, gen_body);
    assert_eq!(code, 200, "{body}");
    let baseline = checksum_of(&body);

    // slow every step so both replicas stay saturated with queued work
    // long enough for the kill to land mid-load
    faultpoint::install("engine_step:delay:2@0").unwrap();
    let total = 2 * b + 6;
    let mut loaded = Vec::new();
    for _ in 0..total {
        loaded.push(std::thread::spawn(move || post_generate(addr, gen_body)));
    }
    wait_until("both replicas saturated with queued work", 15_000, || {
        let m = metrics(addr);
        metric(&m, "fi_lanes_busy") as usize == 2 * b
            && metric(&m, "fi_router_queue_depth{replica=\"0\"}") >= 1
            && metric(&m, "fi_router_queue_depth{replica=\"1\"}") >= 1
    });

    // kill: the next engine step (on whichever replica gets there first)
    // panics; budget 0 means that replica quarantines immediately. The
    // install replaces the delay spec, so recovery is not slowed.
    faultpoint::install("engine_step:panic@1").unwrap();

    // the outage is an aggregate *degradation*: /health stays 200 with a
    // per-replica breakdown naming the quarantined replica — a 503 here
    // would tell a load balancer the whole box is dead, which it is not
    wait_until("health to report degraded", 10_000, || {
        health_status(addr) == (200, "degraded".into())
    });
    let (_, h) = health(addr);
    assert_eq!(h.req_usize("replicas_serviceable").unwrap(), 1, "{h}");
    assert!(h.get("replicas").unwrap().to_string().contains("\"quarantined\""), "{h}");

    // every in-flight lane on the dead replica gets a structured 500
    // carrying the panic; every queued request fails over and completes
    // bit-identically on the survivor
    let (mut ok, mut killed) = (0, 0);
    for t in loaded {
        let (code, body) = t.join().unwrap();
        match code {
            200 => {
                assert_eq!(checksum_of(&body), baseline, "failover must be bit-identical");
                ok += 1;
            }
            500 => {
                assert!(body.contains("panicked"), "{body}");
                killed += 1;
            }
            other => panic!("unexpected status {other}: {body}"),
        }
    }
    assert!(ok >= 1, "the surviving replica must keep serving");
    assert!(killed >= 1, "the killed replica's busy lanes must fail structurally");
    let m = metrics(addr);
    assert!(metric(&m, "fi_failovers_total") >= 1, "queued work must fail over: {m}");

    // the supervisor respawns the quarantined replica after its backoff;
    // a clean probe window later the fleet is whole again
    wait_until("the quarantined replica to respawn and rejoin", 20_000, || {
        health_status(addr) == (200, "healthy".into())
    });
    let m = metrics(addr);
    assert!(metric(&m, "fi_replica_restarts_total") >= 1, "{m}");
    assert_eq!(metric(&m, "fi_replicas_healthy"), 2, "{m}");
    let (code, body) = post_generate(addr, gen_body);
    assert_eq!(code, 200, "{body}");
    assert_eq!(checksum_of(&body), baseline, "the healed fleet must answer identically");

    // machine-readable evidence for the CI router-smoke summary
    if let Ok(path) = std::env::var("FI_ROUTER_OUT") {
        let doc = Json::from_pairs(vec![
            ("bench", Json::Str("router_failover".into())),
            ("meta", flash_inference::util::benchkit::bench_meta(None)),
            ("fault", Json::Str("engine_step:panic@1".into())),
            ("replicas", Json::Num(2.0)),
            ("baseline_checksum", Json::Num(baseline)),
            ("requests_ok", Json::Num(ok as f64)),
            ("requests_killed", Json::Num(killed as f64)),
            ("failovers", Json::Num(metric(&m, "fi_failovers_total") as f64)),
            ("replica_restarts", Json::Num(metric(&m, "fi_replica_restarts_total") as f64)),
            ("healed", Json::Bool(true)),
            (
                "scenarios",
                Json::Arr(vec![
                    Json::from_pairs(vec![
                        ("scenario", Json::Str("panic kills one of two replicas".into())),
                        ("status", Json::Str("degraded, 200 (never 503)".into())),
                        ("recovered", Json::Bool(true)),
                    ]),
                    Json::from_pairs(vec![
                        ("scenario", Json::Str("queued requests fail over".into())),
                        ("status", Json::Str("200, bit-identical".into())),
                        ("recovered", Json::Bool(true)),
                    ]),
                    Json::from_pairs(vec![
                        ("scenario", Json::Str("quarantine respawn + probe window".into())),
                        ("status", Json::Str("back in full rotation".into())),
                        ("recovered", Json::Bool(true)),
                    ]),
                ]),
            ),
        ]);
        std::fs::write(&path, doc.to_string()).expect("write router bench json");
    }

    server.stop();
}

// ---------------------------------------------------------------------------
// Checkpoint shipping: a quarantined replica's paged-out lanes re-home
// ---------------------------------------------------------------------------

#[test]
fn quarantined_replica_ships_checkpoints_and_they_resume_elsewhere_bit_identically() {
    let _g = serial();
    let cfg = ServerConfig {
        restart_budget: 0,
        quarantine_backoff_ms: 400,
        quarantine_backoff_max_ms: 2000,
        probe_window_ms: 100,
        max_max_tokens: 128,
        default_max_tokens: 16,
        engine: flash_inference::engine::EngineOpts {
            // rust-direct τ: the folded checkpoint's history-vs-future
            // deposit is bit-identical, so a shipped continuation must
            // reproduce the uninterrupted checksum exactly
            tau: flash_inference::tau::TauKind::RustDirect,
            ..ServerConfig::default().engine
        },
        ..fleet_cfg(2)
    };
    let Some(server) = start_server(cfg) else { return };
    let addr = server.addr;
    let b = info(addr).req_usize("B").unwrap();

    // every request in this test shares one session key: the probe pins it
    // to `home`, so the whole load lands on one replica while the other
    // stays idle — and the kill is deterministic (only `home` steps)
    let long_body = "{\"max_tokens\": 120, \"sigma\": 0.05, \"seed\": 40, \"session\": \"ship\"}";
    let (code, body) = post_generate(addr, long_body);
    assert_eq!(code, 200, "{body}");
    let baseline = checksum_of(&body);
    let home = replica_of(&body);

    // saturate home's lanes with identical longs, slowed so they are
    // nowhere near done when the kill lands
    faultpoint::install("engine_step:delay:5@0").unwrap();
    let mut longs = Vec::new();
    for _ in 0..b {
        longs.push(std::thread::spawn(move || post_generate(addr, long_body)));
    }
    wait_until("home's lanes to fill", 15_000, || {
        metric(&metrics(addr), "fi_lanes_busy") as usize == b
    });

    // queue pressure on home: the scheduler folds the longest-remaining
    // long into the pager (long tail → fold, not aligned) and admits the
    // short; the parked checkpoint cannot resume until the short's lane
    // frees — that is the window the quarantine lands in
    let short_body = "{\"max_tokens\": 24, \"sigma\": 0.05, \"seed\": 7, \"session\": \"ship\"}";
    let short = std::thread::spawn(move || post_generate(addr, short_body));
    wait_until("a long to be folded out", 15_000, || {
        metric(&metrics(addr), "fi_folds_total") >= 1
    });

    // kill home while the folded checkpoint is parked: budget 0 means the
    // next step's panic quarantines it, and ship_evicted must hand the
    // checkpoint to the supervisor instead of failing the request with a
    // 500. (The install replaces the delay, so the re-homed run is fast.)
    faultpoint::install("engine_step:panic@1").unwrap();
    wait_until("health to report degraded", 10_000, || {
        health_status(addr) == (200, "degraded".into())
    });

    // the evicted long completes on the *other* replica with the exact
    // uninterrupted checksum; home's busy lanes die structurally
    let (mut shipped_ok, mut killed) = (0, 0);
    for t in longs {
        let (code, body) = t.join().unwrap();
        match code {
            200 => {
                assert_eq!(checksum_of(&body), baseline, "shipped resume must be bit-identical");
                assert_ne!(replica_of(&body), home, "continuation must re-home: {body}");
                let tail = Json::parse(&body).unwrap();
                assert!(
                    tail.req_usize("evictions").unwrap() >= 2,
                    "fold + ship are two checkpoint cycles: {body}"
                );
                shipped_ok += 1;
            }
            500 => {
                assert!(body.contains("panicked"), "{body}");
                killed += 1;
            }
            other => panic!("unexpected status {other}: {body}"),
        }
    }
    assert_eq!(shipped_ok, 1, "exactly the folded lane survives the kill");
    assert_eq!(killed, b - 1, "the other busy lanes die with the replica");
    let (code, body) = short.join().unwrap();
    assert!(code == 200 || code == 500, "unexpected short status {code}: {body}");

    let m = metrics(addr);
    assert!(metric(&m, "fi_checkpoints_shipped_total") >= 1, "{m}");
    assert!(metric(&m, "fi_folds_total") >= 1, "{m}");
    assert!(metric(&m, "fi_resumes_total") >= 1, "the receiver must restore it: {m}");

    // the fleet heals like any other quarantine, and the session key keeps
    // serving (re-pinned to wherever the router sends it next)
    wait_until("the quarantined replica to respawn and rejoin", 20_000, || {
        health_status(addr) == (200, "healthy".into())
    });
    let (code, body) = post_generate(addr, long_body);
    assert_eq!(code, 200, "{body}");
    assert_eq!(checksum_of(&body), baseline, "the healed fleet must answer identically");

    server.stop();
}

// ---------------------------------------------------------------------------
// Shed unification and the boot/dispatch fault points
// ---------------------------------------------------------------------------

#[test]
fn global_shed_is_429_for_one_replica_and_503_for_a_fleet() {
    let _g = serial();

    // Ramp the lanes one at a time: queue_full keys off published gauges,
    // and `lanes_busy` only publishes at step boundaries — a parallel
    // burst against max_queue=1 would shed during ramp-up and the queue
    // would never actually fill.
    fn saturate(
        addr: std::net::SocketAddr,
        lanes: usize,
        extra: usize,
    ) -> Vec<std::thread::JoinHandle<(u16, String)>> {
        let mut loaded = Vec::new();
        for i in 0..lanes {
            loaded.push(std::thread::spawn(move || {
                post_generate(addr, "{\"max_tokens\": 128}")
            }));
            wait_until("the lane to be admitted", 15_000, || {
                metric(&metrics(addr), "fi_lanes_busy") as usize > i
            });
        }
        for _ in 0..extra {
            loaded.push(std::thread::spawn(move || {
                post_generate(addr, "{\"max_tokens\": 128}")
            }));
        }
        loaded
    }

    // fleet of one: PR 7's shape — a full queue sheds 429, with the same
    // Retry-After contract as every other shed path
    let cfg = ServerConfig { max_queue: 1, ..base_cfg() };
    let Some(server) = start_server(cfg) else { return };
    let addr = server.addr;
    let b = info(addr).req_usize("B").unwrap();
    faultpoint::install("engine_step:delay:5@0").unwrap();
    let loaded = saturate(addr, b, 1);
    wait_until("the single replica's queue to fill", 15_000, || {
        metric(&metrics(addr), "fi_router_queue_depth{replica=\"0\"}") >= 1
    });
    let (code, headers, body) =
        request_raw(addr, "POST /v1/generate HTTP/1.1\r\nContent-Length: 2\r\n\r\n{}");
    assert_eq!(code, 429, "single-replica overload is PR 7's 429: {body}");
    assert!(headers.contains("Retry-After: 1"), "{headers}");
    assert!(body.contains("queue full"), "{body}");
    faultpoint::clear();
    for t in loaded {
        let (code, body) = t.join().unwrap();
        assert!(code == 200 || code == 429, "unexpected status {code}: {body}");
    }
    server.stop();

    // fleet of two: the shed only fires when *every* replica's queue is
    // full, and it is a 503 — a capacity statement about the deployment
    let cfg = ServerConfig { max_queue: 1, ..fleet_cfg(2) };
    let Some(server) = start_server(cfg) else { return };
    let addr = server.addr;
    faultpoint::install("engine_step:delay:5@0").unwrap();
    let loaded = saturate(addr, 2 * b, 2);
    wait_until("every replica's queue to fill", 15_000, || {
        let m = metrics(addr);
        metric(&m, "fi_router_queue_depth{replica=\"0\"}") >= 1
            && metric(&m, "fi_router_queue_depth{replica=\"1\"}") >= 1
    });
    let (code, headers, body) =
        request_raw(addr, "POST /v1/generate HTTP/1.1\r\nContent-Length: 2\r\n\r\n{}");
    assert_eq!(code, 503, "fleet-wide overload is a 503: {body}");
    assert!(headers.contains("Retry-After: 1"), "{headers}");
    assert!(body.contains("all replica queues full"), "{body}");
    assert!(metric(&metrics(addr), "fi_requests_shed") >= 1);
    faultpoint::clear();
    for t in loaded {
        let (code, body) = t.join().unwrap();
        assert!(code == 200 || code == 503, "unexpected status {code}: {body}");
    }
    server.stop();
}

#[test]
fn router_dispatch_fault_fails_one_request_structurally() {
    let _g = serial();
    let Some(server) = start_server(base_cfg()) else { return };
    let addr = server.addr;

    faultpoint::install("router_dispatch:fail@1").unwrap();
    let (code, body) = post_generate(addr, "{\"max_tokens\": 4}");
    assert_eq!(code, 500, "{body}");
    assert!(body.contains("fault injection: router_dispatch"), "{body}");

    // one-shot: the very next dispatch goes through
    let (code, body) = post_generate(addr, "{\"max_tokens\": 4}");
    assert_eq!(code, 200, "{body}");
    assert!(metric(&metrics(addr), "fi_requests_failed") >= 1);

    server.stop();
}

#[test]
fn boot_failure_degrades_the_fleet_until_the_respawn_succeeds() {
    let _g = serial();
    // armed *before* start: replica 0's first boot fails; the server must
    // come up anyway on replica 1 and heal itself
    faultpoint::install("replica_spawn:fail@1").unwrap();
    let cfg = ServerConfig {
        quarantine_backoff_ms: 500,
        quarantine_backoff_max_ms: 2000,
        probe_window_ms: 100,
        ..fleet_cfg(2)
    };
    let Some(server) = start_server(cfg) else { return };
    let addr = server.addr;

    let (code, status) = health_status(addr);
    assert_eq!((code, status.as_str()), (200, "degraded"), "one dead replica degrades");
    let (code, body) = post_generate(addr, "{\"max_tokens\": 8}");
    assert_eq!(code, 200, "the booted replica must serve: {body}");

    // the fault was one-shot: the supervisor's respawn boots clean, and
    // after the probe window the fleet reports whole
    wait_until("the failed replica to boot on respawn", 20_000, || {
        health_status(addr) == (200, "healthy".into())
    });
    assert!(metric(&metrics(addr), "fi_replica_restarts_total") >= 1);

    server.stop();
}

// ---------------------------------------------------------------------------
// A fleet of one must be PR 7, exactly
// ---------------------------------------------------------------------------

#[test]
fn single_replica_preserves_the_pr7_surface() {
    let _g = serial();
    let Some(server) = start_server(base_cfg()) else { return };
    let addr = server.addr;

    // /health keeps PR 7's exact body, not the fleet aggregate
    let (code, body) = request(addr, "GET /health HTTP/1.1\r\n\r\n");
    assert_eq!(code, 200);
    assert_eq!(body.trim(), "{\"status\":\"ok\"}");

    let (code, body) = post_generate(addr, "{\"max_tokens\": 8}");
    assert_eq!(code, 200, "{body}");
    assert_eq!(replica_of(&body), 0);

    // every PR 7 metric name is still present; the fleet lines are
    // additive and report the trivial fleet
    let m = metrics(addr);
    assert_eq!(metric(&m, "fi_healthy"), 1, "{m}");
    assert_eq!(metric(&m, "fi_requests_total"), 1, "{m}");
    assert_eq!(metric(&m, "fi_replicas"), 1, "{m}");
    assert_eq!(metric(&m, "fi_replicas_healthy"), 1, "{m}");
    assert_eq!(info(addr).req_usize("replicas").unwrap(), 1);

    server.stop();
}
