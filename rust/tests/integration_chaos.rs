//! Chaos integration: fault injection against the live engine and server.
//!
//! The supervised engine loop (server/api.rs) promises that an injected
//! panic — in `Session::step` or inside an async tile job on a pool
//! worker — fails only the lanes that were busy, with a structured 500,
//! and that the server then rebuilds a fresh session and keeps serving
//! *bit-identically*. Suspended-lane checkpoints live in the pager,
//! outside the session, so they must survive the restart. Exhausting the
//! restart budget flips `/health` to a latched 503. Request lifecycles
//! (deadlines, client disconnects, connection-cap shed, graceful drain)
//! are exercised here too.
//!
//! The fault registry (`util::faultpoint`) is process-global, so every
//! test serializes on one mutex and disarms on exit (panic included).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::{Mutex, PoisonError};
use std::time::{Duration, Instant};

use flash_inference::config::ServerConfig;
use flash_inference::engine::{Engine, EngineOpts, GenOutput, Method};
use flash_inference::runtime::Runtime;
use flash_inference::server::Server;
use flash_inference::tau::TauKind;
use flash_inference::util::faultpoint;
use flash_inference::util::json::Json;

static SERIAL: Mutex<()> = Mutex::new(());

/// Serialize tests and guarantee the global registry is disarmed when the
/// test ends, even if it fails partway with faults still installed.
struct FaultGuard<'a>(#[allow(dead_code)] std::sync::MutexGuard<'a, ()>);

impl Drop for FaultGuard<'_> {
    fn drop(&mut self) {
        faultpoint::clear();
    }
}

fn serial() -> FaultGuard<'static> {
    let g = SERIAL.lock().unwrap_or_else(PoisonError::into_inner);
    faultpoint::clear();
    FaultGuard(g)
}

fn runtime() -> Option<Runtime> {
    let dir = Path::new("artifacts").join("synthetic");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&dir).expect("load runtime"))
}

fn start_server(cfg: ServerConfig) -> Option<Server> {
    if !Path::new("artifacts/synthetic/manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    Some(Server::start(cfg).expect("start server"))
}

fn base_cfg() -> ServerConfig {
    ServerConfig { port: 0, artifacts: "artifacts/synthetic".into(), ..Default::default() }
}

fn request_raw(addr: std::net::SocketAddr, raw: &str) -> (u16, String, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(raw.as_bytes()).unwrap();
    s.flush().unwrap();
    // Tolerant read: a connection shed at the accept loop closes with the
    // request bytes unread, so the kernel may follow the response with an
    // RST — keep whatever arrived before it instead of panicking.
    let mut bytes = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        match s.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => bytes.extend_from_slice(&chunk[..n]),
            Err(_) => break,
        }
    }
    let buf = String::from_utf8_lossy(&bytes).into_owned();
    let status = buf.split_whitespace().nth(1).and_then(|t| t.parse::<u16>().ok()).unwrap_or(0);
    let headers = buf.split("\r\n\r\n").next().unwrap_or("").to_string();
    let body = buf.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, headers, body)
}

fn request(addr: std::net::SocketAddr, raw: &str) -> (u16, String) {
    let (status, _, body) = request_raw(addr, raw);
    (status, body)
}

fn post_generate(addr: std::net::SocketAddr, body: &str) -> (u16, String) {
    request(
        addr,
        &format!(
            "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        ),
    )
}

fn metrics(addr: std::net::SocketAddr) -> String {
    let (code, body) = request(addr, "GET /metrics HTTP/1.1\r\n\r\n");
    assert_eq!(code, 200);
    body
}

/// Parse one `fi_<name> <value>` line out of the metrics text.
fn metric(text: &str, name: &str) -> u64 {
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(name) {
            if let Ok(v) = rest.trim().parse::<f64>() {
                return v as u64;
            }
        }
    }
    panic!("metric {name} not found in:\n{text}");
}

/// Poll `cond` until it holds or `ms` elapses; panics with `what` on
/// timeout so a hung recovery path fails loudly instead of wedging CI.
fn wait_until(what: &str, ms: u64, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_millis(ms);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn checksum_of(body: &str) -> f64 {
    Json::parse(body).expect("json body").get("checksum").unwrap().as_f64().unwrap()
}

// ---------------------------------------------------------------------------
// Engine level: a panicked tile job must be contained and recoverable
// ---------------------------------------------------------------------------

fn async_opts() -> EngineOpts {
    EngineOpts {
        method: Method::Flash,
        tau: TauKind::RustFft,
        async_mixer: true,
        record_streams: true,
        ..Default::default()
    }
}

fn drive(engine: &Engine, len: usize) -> GenOutput {
    let mut session = engine.session(len).expect("session");
    while !session.is_done() {
        session.step().expect("step");
    }
    session.finish()
}

fn assert_identical(a: &GenOutput, b: &GenOutput, what: &str) {
    assert_eq!(a.outs_checksum, b.outs_checksum, "{what}: outs_checksum");
    assert_eq!(a.checksum_total, b.checksum_total, "{what}: checksum_total");
    assert_eq!(a.steps, b.steps, "{what}: steps");
    assert_eq!(a.tokens, b.tokens, "{what}: tokens");
    assert_eq!(a.last_out, b.last_out, "{what}: last_out");
}

#[test]
fn tile_panic_fails_the_session_deterministically_and_recovery_is_bit_identical() {
    let _g = serial();
    let Some(rt) = runtime() else { return };
    let engine = Engine::new(&rt, async_opts()).expect("engine");
    let golden = drive(&engine, 64);

    // arm: the first async tile job panics on its pool worker. The fence
    // must surface that as a deterministic step error — never a hang.
    faultpoint::install("tau_tile:panic@1").unwrap();
    let mut session = engine.session(64).expect("session");
    let mut err = None;
    while !session.is_done() {
        match session.step() {
            Ok(_) => {}
            Err(e) => {
                err = Some(format!("{e:#}"));
                break;
            }
        }
    }
    let err = err.expect("a panicked tile job must surface as a step error at the fence");
    assert!(
        err.contains("panicked") && err.contains("fault injection"),
        "error should carry the panic payload: {err}"
    );

    // tearing the poisoned session down must neither hang nor re-panic
    // (the worker-side readiness guard balanced end_write on unwind)
    let dropped = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || drop(session)));
    assert!(dropped.is_ok(), "dropping a poisoned session re-panicked");

    // the fault was one-shot: a fresh session on the *same* engine (same
    // pool, same store) recovers bit-identically
    let again = drive(&engine, 64);
    assert_identical(&golden, &again, "post-panic rollout");
}

#[test]
fn engine_step_fail_is_transient_and_leaves_the_rollout_bit_identical() {
    let _g = serial();
    let Some(rt) = runtime() else { return };
    let engine = Engine::new(&rt, async_opts()).expect("engine");
    let golden = drive(&engine, 32);

    // `fail` (the Result path) errors exactly one step, touching nothing:
    // the same session continues and still matches the golden rollout
    faultpoint::install("engine_step:fail@1").unwrap();
    let mut session = engine.session(32).expect("session");
    let e = session.step().expect_err("armed step must fail");
    assert!(format!("{e:#}").contains("fault injection"), "{e:#}");
    while !session.is_done() {
        session.step().expect("steps after the one-shot fault succeed");
    }
    assert_identical(&golden, &session.finish(), "rollout after a failed step");
}

// ---------------------------------------------------------------------------
// Server level: supervised recovery, restart budget, checkpoint survival
// ---------------------------------------------------------------------------

#[test]
fn server_recovers_bit_identically_after_an_engine_panic() {
    let _g = serial();
    let Some(server) = start_server(base_cfg()) else { return };
    let addr = server.addr;

    let (code, body) = post_generate(addr, "{\"max_tokens\": 24}");
    assert_eq!(code, 200, "{body}");
    let baseline = checksum_of(&body);

    // the server shares this process's fault registry: arm a panic on the
    // next engine step, hit it, and expect a *structured* 500
    faultpoint::install("engine_step:panic@1").unwrap();
    let (code, body) = post_generate(addr, "{\"max_tokens\": 24}");
    assert_eq!(code, 500, "panicked lane must get a structured 500: {body}");
    let err = Json::parse(&body).unwrap().req_str("error").unwrap().to_string();
    assert!(err.contains("engine panicked"), "{err}");
    assert!(err.contains("fault injection: engine_step"), "{err}");

    // supervisor rebuilt a fresh session: same request, same bits
    let (code, body) = post_generate(addr, "{\"max_tokens\": 24}");
    assert_eq!(code, 200, "server must keep serving after the panic: {body}");
    let recovered = checksum_of(&body);
    assert_eq!(baseline, recovered, "recovered rollout must be bit-identical");

    // one panic is inside the default budget: still healthy, but counted
    let (code, _) = request(addr, "GET /health HTTP/1.1\r\n\r\n");
    assert_eq!(code, 200);
    let m = metrics(addr);
    assert_eq!(metric(&m, "fi_engine_restarts_total"), 1, "{m}");
    assert_eq!(metric(&m, "fi_lanes_failed_total"), 1, "{m}");
    assert_eq!(metric(&m, "fi_healthy"), 1, "{m}");

    // /v1/info surfaces the restart count and the armed fault spec
    let (code, body) = request(addr, "GET /v1/info HTTP/1.1\r\n\r\n");
    assert_eq!(code, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.req_usize("engine_restarts").unwrap(), 1);
    assert_eq!(j.get("healthy").and_then(Json::as_bool), Some(true));
    assert!(j.req_str("faults").unwrap().contains("engine_step"), "{body}");

    // machine-readable evidence for the CI chaos-smoke summary
    if let Ok(path) = std::env::var("FI_CHAOS_OUT") {
        let doc = Json::from_pairs(vec![
            ("bench", Json::Str("chaos_recovery".into())),
            ("meta", flash_inference::util::benchkit::bench_meta(None)),
            ("fault", Json::Str("engine_step:panic@1".into())),
            ("baseline_checksum", Json::Num(baseline)),
            ("recovered_checksum", Json::Num(recovered)),
            ("checksum_match", Json::Bool(baseline == recovered)),
            ("engine_restarts", Json::Num(1.0)),
            ("lanes_failed", Json::Num(metric(&m, "fi_lanes_failed_total") as f64)),
            ("healthy_after", Json::Bool(true)),
            (
                "scenarios",
                Json::Arr(vec![
                    Json::from_pairs(vec![
                        ("scenario", Json::Str("panic hits busy lane".into())),
                        ("status", Json::Str("structured 500".into())),
                        ("recovered", Json::Bool(true)),
                    ]),
                    Json::from_pairs(vec![
                        ("scenario", Json::Str("request after restart".into())),
                        ("status", Json::Str("200, bit-identical".into())),
                        ("recovered", Json::Bool(baseline == recovered)),
                    ]),
                ]),
            ),
        ]);
        std::fs::write(&path, doc.to_string()).expect("write chaos bench json");
    }

    server.stop();
}

#[test]
fn exhausted_restart_budget_latches_health_to_503() {
    let _g = serial();
    // zero tolerance: the very first panic exceeds the budget
    let cfg = ServerConfig { restart_budget: 0, ..base_cfg() };
    let Some(server) = start_server(cfg) else { return };
    let addr = server.addr;

    faultpoint::install("engine_step:panic@1").unwrap();
    let (code, _) = post_generate(addr, "{\"max_tokens\": 8}");
    assert_eq!(code, 500);

    // the latch happens just after the 500 is sent; poll briefly
    wait_until("health to flip to 503", 2000, || {
        request(addr, "GET /health HTTP/1.1\r\n\r\n").0 == 503
    });
    let (code, body) = request(addr, "GET /health HTTP/1.1\r\n\r\n");
    assert_eq!(code, 503);
    assert!(body.contains("unhealthy"), "{body}");
    assert!(body.contains("engine_restarts"), "{body}");

    // degraded, not dead: generation still works while unhealthy, and the
    // latch never flaps back to 200 on success
    let (code, body) = post_generate(addr, "{\"max_tokens\": 8}");
    assert_eq!(code, 200, "{body}");
    let (code, _) = request(addr, "GET /health HTTP/1.1\r\n\r\n");
    assert_eq!(code, 503, "health latch must not flap");
    assert_eq!(metric(&metrics(addr), "fi_healthy"), 0);

    server.stop();
}

#[test]
fn suspended_checkpoints_survive_an_engine_restart() {
    let _g = serial();
    let Some(server) = start_server(base_cfg()) else { return };
    let addr = server.addr;

    let (code, body) = request(addr, "GET /v1/info HTTP/1.1\r\n\r\n");
    assert_eq!(code, 200);
    let info = Json::parse(&body).unwrap();
    let b = info.req_usize("B").unwrap();
    if info.get("paging").and_then(Json::as_bool) != Some(true) {
        eprintln!("SKIP-local: paging disabled, checkpoint survival not applicable");
        server.stop();
        return;
    }

    // slow every step a little so the eviction → panic → resume sequence
    // has a wide-open window regardless of host speed
    faultpoint::install("engine_step:delay:1@0").unwrap();

    let (code, body) = post_generate(addr, "{\"max_tokens\": 192}");
    assert_eq!(code, 200, "{body}");
    let baseline = checksum_of(&body);

    // saturate all B lanes with long requests...
    let mut long = Vec::new();
    for _ in 0..b {
        long.push(std::thread::spawn(move || post_generate(addr, "{\"max_tokens\": 192}")));
    }
    wait_until("all lanes busy", 10_000, || {
        metric(&metrics(addr), "fi_lanes_busy") as usize == b
    });
    // ...then force an eviction with a short request under queue pressure
    let short = std::thread::spawn(move || post_generate(addr, "{\"max_tokens\": 4}"));
    wait_until("a lane to be evicted into the pager", 10_000, || {
        metric(&metrics(addr), "fi_evictions_total") >= 1
    });

    // panic the engine while the checkpoint is paged out: busy lanes fail,
    // the pager-resident checkpoint must survive the session rebuild
    // (this install replaces the delay spec — no longer needed)
    faultpoint::install("engine_step:panic@1").unwrap();
    wait_until("the supervisor to record the restart", 10_000, || {
        metric(&metrics(addr), "fi_engine_restarts_total") >= 1
    });

    let mut evicted_ok = 0;
    for h in long {
        let (code, body) = h.join().unwrap();
        if code != 200 {
            assert_eq!(code, 500, "{body}");
            assert!(body.contains("engine panicked"), "{body}");
            continue;
        }
        let j = Json::parse(&body).unwrap();
        assert_eq!(
            checksum_of(&body),
            baseline,
            "a surviving long rollout must be bit-identical"
        );
        if j.req_usize("evictions").unwrap() >= 1 {
            evicted_ok += 1;
        }
    }
    let _ = short.join().unwrap(); // hit or missed by the panic: either is fine
    assert!(evicted_ok >= 1, "the evicted request must resume after the restart and succeed");

    let m = metrics(addr);
    assert!(metric(&m, "fi_evictions_total") >= 1, "{m}");
    assert!(metric(&m, "fi_resumes_total") >= 1, "{m}");
    assert_eq!(metric(&m, "fi_engine_restarts_total"), 1, "{m}");

    server.stop();
}

// ---------------------------------------------------------------------------
// Request lifecycle: deadlines, disconnects, connection cap, graceful drain
// ---------------------------------------------------------------------------

#[test]
fn per_request_deadline_fails_with_a_structured_error() {
    let _g = serial();
    let Some(server) = start_server(base_cfg()) else { return };
    let addr = server.addr;

    // slow steps so a 1 ms budget cannot possibly be met
    faultpoint::install("engine_step:delay:2@0").unwrap();
    let (code, body) = post_generate(addr, "{\"max_tokens\": 192, \"deadline_ms\": 1}");
    assert_eq!(code, 500, "{body}");
    assert!(body.contains("deadline exceeded"), "{body}");
    assert!(metric(&metrics(addr), "fi_requests_deadline_exceeded") >= 1);

    // malformed deadline is rejected up front
    let (code, body) = post_generate(addr, "{\"max_tokens\": 4, \"deadline_ms\": -3}");
    assert_eq!(code, 400, "{body}");

    server.stop();
}

#[test]
fn client_disconnect_frees_the_lane() {
    let _g = serial();
    let Some(server) = start_server(base_cfg()) else { return };
    let addr = server.addr;

    faultpoint::install("engine_step:delay:2@0").unwrap();
    {
        // start a long request, then hang up without reading the reply
        let mut s = TcpStream::connect(addr).expect("connect");
        let body = "{\"max_tokens\": 192}";
        s.write_all(
            format!(
                "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
                body.len(),
                body
            )
            .as_bytes(),
        )
        .unwrap();
        wait_until("the lane to be admitted", 10_000, || {
            metric(&metrics(addr), "fi_lanes_busy") >= 1
        });
    } // socket dropped here

    // the conn thread notices the EOF, flags cancel, and the scheduler
    // frees the lane at a step boundary instead of serving a ghost
    wait_until("the disconnect to cancel the lane", 10_000, || {
        metric(&metrics(addr), "fi_clients_disconnected") >= 1
    });
    wait_until("the lane to free", 10_000, || metric(&metrics(addr), "fi_lanes_busy") == 0);

    faultpoint::clear();
    let (code, body) = post_generate(addr, "{\"max_tokens\": 4}");
    assert_eq!(code, 200, "freed lane must serve again: {body}");

    server.stop();
}

#[test]
fn connection_cap_sheds_with_retryable_503() {
    let _g = serial();
    let cfg = ServerConfig { max_connections: 1, ..base_cfg() };
    let Some(server) = start_server(cfg) else { return };
    let addr = server.addr;

    // occupy the single slot with a half-written request
    let mut hold = TcpStream::connect(addr).expect("connect");
    hold.write_all(b"POST /v1/generate HTTP/1.1\r\n").unwrap();
    std::thread::sleep(Duration::from_millis(300)); // let the conn thread spawn

    let (code, headers, body) = request_raw(addr, "GET /health HTTP/1.1\r\n\r\n");
    assert_eq!(code, 503, "over-cap connection must be shed: {body}");
    assert!(headers.contains("Retry-After: 1"), "{headers}");
    assert!(body.contains("connection capacity"), "{body}");

    drop(hold);
    // the freed slot admits connections again, and the shed was counted
    wait_until("the slot to free after hangup", 10_000, || {
        request(addr, "GET /health HTTP/1.1\r\n\r\n").0 == 200
    });
    assert!(metric(&metrics(addr), "fi_conn_shed_total") >= 1);

    server.stop();
}

#[test]
fn graceful_stop_drains_and_fails_stragglers_with_retryable_503() {
    let _g = serial();
    let cfg = ServerConfig { drain_deadline_ms: 150, ..base_cfg() };
    let Some(server) = start_server(cfg) else { return };
    let addr = server.addr;

    // a request slow enough to outlive the drain window
    faultpoint::install("engine_step:delay:4@0").unwrap();
    let straggler = std::thread::spawn(move || {
        request_raw(
            addr,
            &format!(
                "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
                "{\"max_tokens\": 192}".len(),
                "{\"max_tokens\": 192}"
            ),
        )
    });
    wait_until("the straggler to be admitted", 10_000, || {
        metric(&metrics(addr), "fi_lanes_busy") >= 1
    });

    let t0 = Instant::now();
    server.stop();
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "stop() must drain and return, not hang on the busy lane"
    );

    let (code, headers, body) = straggler.join().unwrap();
    assert_eq!(code, 503, "straggler must get a retryable 503: {body}");
    assert!(headers.contains("Retry-After"), "{headers}");
    assert!(body.contains("shutting down"), "{body}");
}
