//! Theorem 2 in executable form: for P.1 + P.2 mixers the generic flash
//! driver equals the lazy evaluator with the Prop-1 call count to A; for
//! P.1-only mixers (attention) the driver refuses and lazy matches the
//! direct softmax reference.

use flash_inference::framework::{
    attention, AttentionMixer, DecaySumMixer, GenericModel, LcsmMixer,
};
use flash_inference::util::prng::Prng;
use flash_inference::util::tensor::Tensor;

fn rand_tensor(rng: &mut Prng, shape: &[usize], scale: f32) -> Tensor {
    let mut t = Tensor::zeros(shape);
    for v in t.data_mut() {
        *v = rng.normal_f32() * scale;
    }
    t
}

fn model<M: flash_inference::framework::ContributionMixer>(
    mixers: Vec<M>,
    d: usize,
) -> GenericModel<M> {
    GenericModel {
        mixers,
        // block: bounded elementwise nonlinearity (keeps rollouts finite)
        block: Box::new(|_l, x| x.iter().map(|v| v.tanh()).collect()),
        sampler: Box::new(|a| a.iter().map(|v| 0.9 * v + 0.05).collect()),
        d,
    }
}

fn decayed_filter(rng: &mut Prng, len: usize, d: usize) -> Tensor {
    let mut rho = rand_tensor(rng, &[len, d], 1.0);
    for t in 0..len {
        let w = (-(6.0 * t as f32) / len as f32).exp() / (1.0 + t as f32).sqrt();
        for v in rho.data_mut()[t * d..(t + 1) * d].iter_mut() {
            *v *= w * 0.3;
        }
    }
    rho
}

#[test]
fn theorem2_lcsm_flash_equals_lazy_with_prop1_calls() {
    let mut rng = Prng::new(1);
    let (len, d, m) = (64usize, 8usize, 3usize);
    let mixers: Vec<LcsmMixer> =
        (0..m).map(|_| LcsmMixer::new(decayed_filter(&mut rng, len, d))).collect();
    let gm = model(mixers, d);
    let a01 = vec![0.3; d];

    let flash = gm.generate_flash(&a01, len).unwrap();
    let lazy = gm.generate_lazy(&a01, len).unwrap();
    for (fa, la) in flash.activations.iter().zip(&lazy.activations) {
        let err = fa.rel_l2(la);
        assert!(err < 1e-4, "rel_l2 {err}");
    }
    // Theorem 2: L-1 calls to A per layer
    assert_eq!(flash.a_calls, m * (len - 1));
}

#[test]
fn theorem2_decaying_sum_mixer_beyond_convolutions() {
    let (len, d, m) = (128usize, 4usize, 2usize);
    let mixers: Vec<DecaySumMixer> =
        (0..m).map(|i| DecaySumMixer::new(0.8 + 0.1 * i as f32, d)).collect();
    let gm = model(mixers, d);
    let a01 = vec![0.5; d];
    let flash = gm.generate_flash(&a01, len).unwrap();
    let lazy = gm.generate_lazy(&a01, len).unwrap();
    for (fa, la) in flash.activations.iter().zip(&lazy.activations) {
        assert!(fa.rel_l2(la) < 1e-4);
    }
}

#[test]
fn rank1_range_contrib_matches_bruteforce() {
    use flash_inference::framework::ContributionMixer;
    let mut rng = Prng::new(3);
    let d = 4;
    let mx = DecaySumMixer::new(0.9, d);
    let y = rand_tensor(&mut rng, &[32, d], 1.0);
    // tile at i = 8, U = 8
    let fast = mx.range_contrib(&y, 1, 8, 9, 16);
    for (k, p) in (9..=16).enumerate() {
        let mut acc = mx.neutral();
        for i in 1..=8 {
            mx.agg(&mut acc, &mx.cont(&y, i, p));
        }
        for (a, b) in fast[k].iter().zip(&acc) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}

#[test]
fn attention_violates_p2_and_is_rejected_by_the_tiling() {
    let mut rng = Prng::new(5);
    let d = 6;
    let mx = AttentionMixer::new(
        rand_tensor(&mut rng, &[d, d], 0.4),
        rand_tensor(&mut rng, &[d, d], 0.4),
        rand_tensor(&mut rng, &[d, d], 0.4),
    );
    let gm = model(vec![mx], d);
    let a01 = vec![0.2; d];
    let err = match gm.generate_flash(&a01, 16) {
        Err(e) => e,
        Ok(_) => panic!("P.2 violation must be rejected"),
    };
    assert!(err.to_string().contains("query-independent"), "{err}");
    // the lazy evaluator still works — and is KV-cache decoding
    let lazy = gm.generate_lazy(&a01, 16).unwrap();
    assert!(lazy.activations[1].data().iter().all(|v| v.is_finite()));
}

#[test]
fn attention_lazy_matches_direct_softmax_reference() {
    use flash_inference::framework::ContributionMixer;
    let mut rng = Prng::new(9);
    let d = 5;
    let mx = AttentionMixer::new(
        rand_tensor(&mut rng, &[d, d], 0.5),
        rand_tensor(&mut rng, &[d, d], 0.5),
        rand_tensor(&mut rng, &[d, d], 0.5),
    );
    let y = rand_tensor(&mut rng, &[12, d], 1.0);
    let want = attention::attention_reference(&mx, &y);
    for j in 1..=12usize {
        let mut acc = mx.neutral();
        for i in 1..=j {
            mx.agg(&mut acc, &mx.cont(&y, i, j));
        }
        let got = mx.read(&acc);
        for (a, b) in got.iter().zip(&want.data()[(j - 1) * d..j * d]) {
            assert!((a - b).abs() < 1e-4, "j={j}: {a} vs {b}");
        }
    }
}
