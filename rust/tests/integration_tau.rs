//! Integration: the four τ implementations (and Hybrid) agree numerically
//! on real artifacts, serial == parallel, and calibration round-trips.

use std::path::Path;

use flash_inference::fft;
use flash_inference::tau::{self, make_impl, CalibrationTable, RhoCache, TauImpl, TauKind};
use flash_inference::tiling::Tile;
use flash_inference::runtime::Runtime;
use flash_inference::util::prng::Prng;
use flash_inference::util::tensor::{CellTensor, Tensor};

fn runtime() -> Option<Runtime> {
    let dir = Path::new("artifacts/synthetic");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(dir).expect("load runtime"))
}

fn random_state(rt: &Runtime, l: usize, seed: u64) -> (CellTensor, Tensor) {
    let dims = rt.dims;
    let mut rng = Prng::new(seed);
    let mut streams = Tensor::zeros(&[dims.g, l, dims.d]);
    rng.fill_normal(streams.data_mut(), 1.0);
    let pending = Tensor::zeros(&[dims.g, l, dims.d]);
    (CellTensor::from_tensor(&streams), pending)
}

#[test]
fn all_impls_agree_on_every_tile_size() {
    let Some(rt) = runtime() else { return };
    let cache = RhoCache::new(&rt).expect("rho cache");
    for u in [1usize, 2, 8, 64] {
        let tile = Tile::at(u);
        let l = tile.dst_r;
        let (streams, base_pending) = random_state(&rt, l, u as u64);

        let mut results = Vec::new();
        for kind in TauKind::ALL_FIXED {
            let mut imp = make_impl(kind, &cache, 0).unwrap();
            let pending = CellTensor::from_tensor(&base_pending);
            imp.apply(&streams, &pending, tile).unwrap();
            results.push((kind, pending.to_tensor()));
        }
        let (_, reference) = &results[0];
        for (kind, pending) in &results[1..] {
            let diff = pending.max_abs_diff(reference);
            assert!(
                diff < 2e-3 * (u as f32).sqrt(),
                "impl {} differs from rust-direct at u={u}: {diff}",
                kind.as_str()
            );
        }
    }
}

#[test]
fn parallel_matches_serial() {
    let Some(rt) = runtime() else { return };
    let cache = RhoCache::new(&rt).expect("rho cache");
    for kind in [TauKind::RustDirect, TauKind::RustFft] {
        let tile = Tile::at(16);
        let (streams, base) = random_state(&rt, tile.dst_r, 3);
        let serial = CellTensor::from_tensor(&base);
        make_impl(kind, &cache, 0).unwrap().apply(&streams, &serial, tile).unwrap();
        let parallel = CellTensor::from_tensor(&base);
        make_impl(kind, &cache, 3).unwrap().apply(&streams, &parallel, tile).unwrap();
        // identical summation order per group => bitwise equal
        assert_eq!(
            serial.to_tensor().max_abs_diff(&parallel.to_tensor()),
            0.0,
            "{}",
            kind.as_str()
        );
    }
}

#[test]
fn tau_accumulates_into_prior_pending() {
    let Some(rt) = runtime() else { return };
    let cache = RhoCache::new(&rt).expect("rho cache");
    let tile = Tile::at(4);
    let (streams, zero) = random_state(&rt, tile.dst_r, 9);
    let from_zero = CellTensor::from_tensor(&zero);
    let mut imp = make_impl(TauKind::RustFft, &cache, 0).unwrap();
    imp.apply(&streams, &from_zero, tile).unwrap();

    let mut ones = zero.clone();
    ones.data_mut().iter_mut().for_each(|v| *v = 1.0);
    let primed = CellTensor::from_tensor(&ones);
    imp.apply(&streams, &primed, tile).unwrap();
    // primed = 1 + contribution everywhere in the dst block
    let d = rt.dims.d;
    for gi in 0..rt.dims.g {
        for t in tile.dst_l - 1..tile.dst_r {
            for k in 0..d {
                let a = primed.at2(gi, t)[k];
                let b = from_zero.at2(gi, t)[k];
                assert!((a - 1.0 - b).abs() < 1e-5);
            }
        }
    }
}

#[test]
fn hybrid_dispatches_by_table() {
    let Some(rt) = runtime() else { return };
    let cache = RhoCache::new(&rt).expect("rho cache");
    let table = CalibrationTable::heuristic(rt.dims.l);
    let hybrid = tau::Hybrid::new(&cache, table, 0);
    assert_eq!(hybrid.choice(1), TauKind::RustDirect);
    assert_eq!(hybrid.choice(rt.dims.l / 2), TauKind::RustFft);
    assert_eq!(hybrid.kind(), TauKind::Hybrid);
}

#[test]
fn calibration_produces_complete_table() {
    let Some(rt) = runtime() else { return };
    let cache = RhoCache::new(&rt).expect("rho cache");
    // tiny calibration (max_u = 8) to keep test time bounded
    let (table, rows) = tau::calibrate(&cache, 8, 1, 2).expect("calibrate");
    assert_eq!(rows.len(), 4); // u = 1, 2, 4, 8
    assert_eq!(table.levels(), 4);
    for row in &rows {
        assert_eq!(row.medians_ns.len(), 4);
        assert!(row.medians_ns.iter().all(|(_, ns)| *ns > 0.0));
        assert!(TauKind::ALL_FIXED.contains(&row.winner));
    }
}

#[test]
fn spectra_are_half_spectrum_planes() {
    // the rho cache stores per-m half-spectrum state (D-blocked for the
    // fused kernel): half the memory of the former [M, 2U, D] full
    // planes, and bin-for-bin the content the PJRT @rho_re/@rho_im
    // buffers are built from (bins [0, U] of the full order-2U
    // filter-prefix DFT) once un-blocked via halfplanes().
    let Some(rt) = runtime() else { return };
    let cache = RhoCache::new(&rt).expect("rho cache");
    let d = rt.dims.d;
    for u in [1usize, 4, 32] {
        let spectra = cache.spectra(u);
        let bins = u + 1;
        assert_eq!(spectra.bins(), bins);
        assert_eq!(spectra.d, d);

        let full_plan = fft::Plan::new(2 * u);
        let tol = 1e-3 * (u as f32).sqrt();
        for m in 0..rt.dims.m {
            let (full_re, full_im) = fft::spectrum_planes(&full_plan, cache.seg(m, u), d);
            let (hre, him) = spectra.halfplanes(m);
            assert_eq!(hre.len(), bins * d);
            assert_eq!(spectra.blocked(m).bins(), bins);
            for k in 0..bins * d {
                assert!(
                    (hre[k] - full_re[k]).abs() < tol && (him[k] - full_im[k]).abs() < tol,
                    "u={u} m={m} k={k}"
                );
            }
        }
    }
}

#[test]
fn flop_accounting_kinds() {
    // direct's quadratic vs fft's quasilinear tile costs
    let d = 64;
    let g = 6;
    assert!(TauKind::RustDirect.tile_flops(2048, g, d) > TauKind::RustFft.tile_flops(2048, g, d));
    assert!(TauKind::RustDirect.tile_flops(2, g, d) < TauKind::RustFft.tile_flops(2, g, d));
    assert_eq!(
        TauKind::PjrtDirect.tile_flops(16, g, d),
        TauKind::RustDirect.tile_flops(16, g, d)
    );
}
