//! Integration: the engine extensions — Appendix D half-store, prompt
//! prefill (§2.3.1 with P > 0), and teacher forcing — all validated by
//! exact / near-exact equivalence against the plain engine.

use std::path::Path;

use flash_inference::engine::{Engine, EngineOpts, Method};
use flash_inference::runtime::Runtime;
use flash_inference::tau::TauKind;
use flash_inference::util::prng::Prng;

fn runtime(variant: &str) -> Option<Runtime> {
    let dir = Path::new("artifacts").join(variant);
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&dir).expect("load runtime"))
}

fn opts(tau: TauKind) -> EngineOpts {
    EngineOpts { method: Method::Flash, tau, record_streams: true, ..Default::default() }
}

// ---------------------------------------------------------------- App. D

#[test]
fn half_store_produces_identical_trajectory() {
    let Some(rt) = runtime("synthetic") else { return };
    let len = 128;
    let full = {
        let mut e = Engine::new(&rt, opts(TauKind::RustFft)).unwrap();
        e.generate(len).unwrap()
    };
    let half = {
        let mut e = Engine::new(
            &rt,
            EngineOpts { half_store: true, ..opts(TauKind::RustFft) },
        )
        .unwrap();
        e.generate(len).unwrap()
    };
    // identical outputs at every position…
    assert_eq!(full.outs_checksum, half.outs_checksum);
    // …with half the resident activation memory
    assert_eq!(half.resident_values * 2, full.resident_values);
}

#[test]
fn half_store_works_for_every_tau_impl() {
    let Some(rt) = runtime("synthetic") else { return };
    let len = 64;
    let reference = {
        let mut e = Engine::new(&rt, opts(TauKind::RustDirect)).unwrap();
        e.generate(len).unwrap().outs_checksum
    };
    for tau in [TauKind::RustDirect, TauKind::PjrtFft, TauKind::Hybrid] {
        let mut e =
            Engine::new(&rt, EngineOpts { half_store: true, ..opts(tau) }).unwrap();
        let got = e.generate(len).unwrap().outs_checksum;
        for (a, b) in got.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-2 * a.abs().max(1.0), "{}", tau.as_str());
        }
    }
}

#[test]
fn half_store_rejects_quadratic_methods() {
    let Some(rt) = runtime("synthetic") else { return };
    let mut e = Engine::new(
        &rt,
        EngineOpts { method: Method::Lazy, half_store: true, ..Default::default() },
    )
    .unwrap();
    assert!(e.generate(16).is_err());
}

// ------------------------------------------------------------- prefill

#[test]
fn prefill_matches_teacher_forced_run_synthetic() {
    let Some(rt) = runtime("synthetic") else { return };
    let dims = rt.dims;
    let Some(spec) = rt.manifest.best_prefill(dims.l) else {
        eprintln!("SKIP: no prefill artifact in this build");
        return;
    };
    let p = spec.param.unwrap();
    let gen_len = 64usize;

    // random prompt embeddings [B, P, D]
    let mut rng = Prng::new(123);
    let prompt: Vec<f32> = (0..dims.b * p * dims.d).map(|_| rng.normal_f32()).collect();

    // path A: prefill artifact + re-based Algorithm 2
    let out_a = {
        let mut e = Engine::new(&rt, opts(TauKind::RustFft)).unwrap();
        e.generate_with_prompt(&prompt, gen_len).unwrap()
    };

    // path B: teacher-force the prompt through the ordinary engine.
    // forced rows are [T0, B, D]; row i is the input at position i+1, so
    // the generated region starts at position p+1.
    // NOTE: prompt is [B, P, D]; transpose to [P, B, D].
    let mut forced = vec![0.0f32; p * dims.b * dims.d];
    for bi in 0..dims.b {
        for t in 0..p {
            let src = &prompt[(bi * p + t) * dims.d..(bi * p + t + 1) * dims.d];
            forced[(t * dims.b + bi) * dims.d..(t * dims.b + bi + 1) * dims.d]
                .copy_from_slice(src);
        }
    }
    let total = (p + gen_len).next_power_of_two();
    let out_b = {
        let mut e = Engine::new(&rt, opts(TauKind::RustFft)).unwrap();
        e.generate_teacher_forced(total, &forced).unwrap()
    };

    // compare the overlapping generated region: re-based position j of A is
    // absolute position p+j of B.
    let compare = gen_len.min(total - p);
    let mut max_rel = 0.0f32;
    for j in 0..compare {
        let a = out_a.outs_checksum[j];
        let b = out_b.outs_checksum[p + j];
        max_rel = max_rel.max((a - b).abs() / a.abs().max(1.0));
    }
    assert!(max_rel < 5e-3, "prefill vs teacher-forced: max_rel={max_rel}");
}

#[test]
fn prefill_rejects_wrong_prompt_length() {
    let Some(rt) = runtime("synthetic") else { return };
    if rt.manifest.best_prefill(rt.dims.l).is_none() {
        return;
    }
    let mut e = Engine::new(&rt, opts(TauKind::RustFft)).unwrap();
    let bad = vec![0.0f32; rt.dims.b * 13 * rt.dims.d]; // 13 != built P
    assert!(e.generate_with_prompt(&bad, 32).is_err());
}

#[test]
fn prefill_hyena_continues_generation() {
    let Some(rt) = runtime("hyena") else { return };
    let dims = rt.dims;
    let Some(spec) = rt.manifest.best_prefill(dims.l) else { return };
    let p = spec.param.unwrap();
    // embed a real token prompt
    let embed = rt.weights.get("embed").unwrap();
    let toks: Vec<usize> = (0..p).map(|i| (i * 7 + 3) % dims.v).collect();
    let mut prompt = vec![0.0f32; dims.b * p * dims.d];
    for bi in 0..dims.b {
        for (t, &tok) in toks.iter().enumerate() {
            prompt[(bi * p + t) * dims.d..(bi * p + t + 1) * dims.d]
                .copy_from_slice(embed.row(tok));
        }
    }
    let mut e = Engine::new(&rt, opts(TauKind::Hybrid)).unwrap();
    let out = e.generate_with_prompt(&prompt, 32).unwrap();
    let toks_out = out.tokens.unwrap();
    // 32 positions + the token sampled from the prompt's last logits
    assert_eq!(toks_out[0].len(), 33);
    assert!(toks_out[0].iter().all(|&t| (t as usize) < dims.v));
    assert!(out.outs_checksum.iter().all(|v| v.is_finite()));
}

// ------------------------------------------------------- teacher forcing

#[test]
fn teacher_forcing_overrides_the_sampler() {
    let Some(rt) = runtime("synthetic") else { return };
    let dims = rt.dims;
    let len = 32;
    let mut rng = Prng::new(5);
    let forced: Vec<f32> =
        (0..8 * dims.b * dims.d).map(|_| rng.normal_f32()).collect();
    let mut e = Engine::new(&rt, opts(TauKind::RustDirect)).unwrap();
    let a = e.generate_teacher_forced(len, &forced).unwrap();
    let b = e.generate(len).unwrap();
    // different inputs ⇒ different trajectories
    assert_ne!(a.outs_checksum, b.outs_checksum);
    // but deterministic given the same forcing
    let c = e.generate_teacher_forced(len, &forced).unwrap();
    assert_eq!(a.outs_checksum, c.outs_checksum);
}
