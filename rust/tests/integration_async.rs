//! Integration: the deadline-fenced async τ executor is *semantically
//! invisible*. With tile splitting off, an async session must be
//! bit-identical to the forced-sync path — same checksums, tokens, FLOP
//! accounting — for the plain Flash path, the Appendix D half store, and
//! teacher forcing (the async jobs run the exact same per-group arithmetic
//! in the exact same order, just on another thread). With splitting on,
//! the urgent column's direct-vs-FFT rounding bounds the difference to
//! kernel tolerance. A churn test shakes out fence/ordering bugs by
//! running many short sessions with worker threads enabled.
//!
//! The `multi_worker_*` tests extend all of that to `mixer_workers > 1`:
//! the dependency-tracked queue must keep the unsplit path bit-identical
//! (dep edges reproduce the sync accumulation order for overlapping dst
//! ranges), survive half-store row reuse, staged-chunk churn, mid-flight
//! drops, and paging suspend/resume, and cleanly reject configs that
//! cannot run concurrently (PJRT-backed kinds, forced-sync, 0 workers).

use std::path::Path;

use flash_inference::engine::{Engine, EngineOpts, GenOutput, LaneInit, Method, SamplerCfg};
use flash_inference::runtime::Runtime;
use flash_inference::tau::TauKind;
use flash_inference::util::prng::Prng;

fn runtime(variant: &str) -> Option<Runtime> {
    let dir = Path::new("artifacts").join(variant);
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&dir).expect("load runtime"))
}

fn opts(tau: TauKind, async_mixer: bool) -> EngineOpts {
    EngineOpts {
        method: Method::Flash,
        tau,
        async_mixer,
        record_streams: true,
        ..Default::default()
    }
}

fn assert_bit_identical(a: &GenOutput, b: &GenOutput, what: &str) {
    assert_eq!(a.outs_checksum, b.outs_checksum, "{what}: outs_checksum");
    assert_eq!(a.checksum_total, b.checksum_total, "{what}: checksum_total");
    assert_eq!(a.steps, b.steps, "{what}: steps");
    assert_eq!(a.tokens, b.tokens, "{what}: tokens");
    assert_eq!(a.last_out, b.last_out, "{what}: last_out");
    assert_eq!(a.flops.mixer_flops, b.flops.mixer_flops, "{what}: flops");
    assert_eq!(a.flops.tau_calls, b.flops.tau_calls, "{what}: tau calls");
    let (sa, sb) = (a.streams.as_ref().unwrap(), b.streams.as_ref().unwrap());
    assert_eq!(sa.max_abs_diff(sb), 0.0, "{what}: streams");
}

#[test]
fn async_unsplit_is_bit_identical_to_sync() {
    let Some(rt) = runtime("synthetic") else { return };
    let len = 64;
    for tau in [TauKind::RustFft, TauKind::RustDirect] {
        let sync = Engine::new(&rt, opts(tau, false)).unwrap().generate(len).unwrap();
        let asy = Engine::new(&rt, opts(tau, true)).unwrap().generate(len).unwrap();
        assert_bit_identical(&sync, &asy, tau.as_str());
        // the async run actually ran off-thread (hidden-time accounting
        // sees worker-side compute); the sync run never does
        assert!(asy.metrics.totals.tau_worker_ns > 0.0, "{}: no worker time", tau.as_str());
        assert_eq!(sync.metrics.totals.tau_worker_ns, 0.0);
        assert_eq!(sync.metrics.totals.fence_ns, 0.0);
    }
}

#[test]
fn async_matches_sync_with_half_store() {
    let Some(rt) = runtime("synthetic") else { return };
    let len = 64;
    let half = |async_mixer| EngineOpts {
        half_store: true,
        ..opts(TauKind::RustFft, async_mixer)
    };
    let sync = Engine::new(&rt, half(false)).unwrap().generate(len).unwrap();
    let asy = Engine::new(&rt, half(true)).unwrap().generate(len).unwrap();
    assert_bit_identical(&sync, &asy, "half_store");
    assert_eq!(sync.resident_values, asy.resident_values);
}

#[test]
fn async_matches_sync_teacher_forced() {
    let Some(rt) = runtime("synthetic") else { return };
    let dims = rt.dims;
    let len = 32;
    let mut rng = Prng::new(23);
    let forced: Vec<f32> = (0..8 * dims.b * dims.d).map(|_| rng.normal_f32()).collect();
    let sync = Engine::new(&rt, opts(TauKind::RustFft, false))
        .unwrap()
        .generate_teacher_forced(len, &forced)
        .unwrap();
    let asy = Engine::new(&rt, opts(TauKind::RustFft, true))
        .unwrap()
        .generate_teacher_forced(len, &forced)
        .unwrap();
    assert_bit_identical(&sync, &asy, "teacher_forced");
}

#[test]
fn async_step_driven_matches_one_shot() {
    let Some(rt) = runtime("synthetic") else { return };
    let len = 32;
    let mut eng = Engine::new(&rt, opts(TauKind::RustFft, true)).unwrap();
    let oneshot = eng.generate(len).unwrap();
    let mut session = eng.session(len).unwrap();
    while !session.is_done() {
        session.step().unwrap();
    }
    let stepped = session.finish();
    assert_bit_identical(&oneshot, &stepped, "step-driven");
}

#[test]
fn split_tiles_match_sync_within_tolerance() {
    let Some(rt) = runtime("synthetic") else { return };
    let len = 64;
    let sync = Engine::new(&rt, opts(TauKind::RustFft, false)).unwrap().generate(len).unwrap();
    // aggressive threshold: every tile with U >= 2 splits
    let split = Engine::new(
        &rt,
        EngineOpts { split_min_u: 2, ..opts(TauKind::RustFft, true) },
    )
    .unwrap()
    .generate(len)
    .unwrap();
    assert_eq!(sync.steps, split.steps);
    assert_eq!(sync.tokens, split.tokens);
    let (ss, sp) = (sync.streams.as_ref().unwrap(), split.streams.as_ref().unwrap());
    let err = sp.rel_l2(ss);
    assert!(err < 1e-4, "split-vs-sync streams err {err}");
}

#[test]
fn split_tiles_respect_half_store_wrap() {
    let Some(rt) = runtime("synthetic") else { return };
    let len = 64;
    let mk = |async_mixer, split| EngineOpts {
        half_store: true,
        split_min_u: split,
        ..opts(TauKind::RustFft, async_mixer)
    };
    let sync = Engine::new(&rt, mk(false, 0)).unwrap().generate(len).unwrap();
    let split = Engine::new(&rt, mk(true, 2)).unwrap().generate(len).unwrap();
    assert_eq!(sync.steps, split.steps);
    // wrapped store: the largest tile must not split (2U > rows) and the
    // result stays within kernel tolerance of the sync rollout
    let (ss, sp) = (sync.streams.as_ref().unwrap(), split.streams.as_ref().unwrap());
    let err = sp.rel_l2(ss);
    assert!(err < 1e-4, "half+split streams err {err}");
    assert_eq!(sync.resident_values, split.resident_values);
}

#[test]
fn stress_many_short_sessions_on_worker_pool() {
    // fence/ordering churn: alternating session shapes over a 2-worker
    // kernel pool plus the executor worker, compared against the sync
    // reference every time — any dropped fence, stale job, or ordering
    // violation shows up as a checksum mismatch (or a readiness panic)
    let Some(rt) = runtime("synthetic") else { return };
    let len = 16;
    for round in 0..12u64 {
        let half = round % 2 == 1;
        let tau = if round % 4 < 2 { TauKind::RustFft } else { TauKind::RustDirect };
        let mk = |async_mixer, split_min_u| EngineOpts {
            threads: 2,
            half_store: half,
            split_min_u,
            seed: round,
            ..opts(tau, async_mixer)
        };
        let sync = Engine::new(&rt, mk(false, 0)).unwrap().generate(len).unwrap();
        let asy = Engine::new(&rt, mk(true, 0)).unwrap().generate(len).unwrap();
        assert_bit_identical(&sync, &asy, &format!("round {round} unsplit"));

        let split = Engine::new(&rt, mk(true, 2)).unwrap().generate(len).unwrap();
        let (ss, sp) = (sync.streams.as_ref().unwrap(), split.streams.as_ref().unwrap());
        let err = sp.rel_l2(ss);
        assert!(err < 1e-4, "round {round} split err {err}");
    }
}

#[test]
fn async_session_abandoned_mid_flight_drains_cleanly() {
    let Some(rt) = runtime("synthetic") else { return };
    let len = 32;
    let eng = Engine::new(
        &rt,
        EngineOpts { split_min_u: 2, ..opts(TauKind::RustFft, true) },
    )
    .unwrap();

    // finish() with a split remainder still in flight must fence first
    let mut session = eng.session(len).unwrap();
    for _ in 0..len / 2 {
        session.step().unwrap();
    }
    let out = session.finish();
    assert_eq!(out.steps, len / 2);
    assert_eq!(out.outs_checksum.len(), len / 2);

    // dropping without finish() must drain too (AsyncTau::drop), not
    // leave a worker writing into a freed store
    let mut session = eng.session(len).unwrap();
    for _ in 0..3 {
        session.step().unwrap();
    }
    drop(session);
}

#[test]
fn multi_worker_unsplit_is_bit_identical_to_sync() {
    // dependency edges preserve the submission (= sync accumulation)
    // order wherever dst ranges overlap, so the unsplit async pipeline is
    // bit-identical to sync at ANY worker count — not just the FIFO W=1
    let Some(rt) = runtime("synthetic") else { return };
    let len = 64;
    for tau in [TauKind::RustFft, TauKind::RustDirect] {
        let sync = Engine::new(&rt, opts(tau, false)).unwrap().generate(len).unwrap();
        for workers in [2usize, 4] {
            let asy = Engine::new(
                &rt,
                EngineOpts { mixer_workers: workers, ..opts(tau, true) },
            )
            .unwrap()
            .generate(len)
            .unwrap();
            assert_bit_identical(&sync, &asy, &format!("{} workers={workers}", tau.as_str()));
            assert!(
                asy.metrics.totals.tau_worker_ns > 0.0,
                "{} workers={workers}: no worker time",
                tau.as_str()
            );
        }
    }
}

#[test]
fn fused_simd_kernel_bit_identical_through_executor_at_all_worker_counts() {
    // PR 9 equivalence matrix, end to end: the async executor's FFT branch
    // now runs the fused D-blocked rfft kernel over dispatched simd row
    // primitives. Because the vector paths never use FMA and lane blocking
    // never reorders a lane's op sequence, the rollout must stay
    // bit-identical to the sync reference at mixer_workers ∈ {1, 2, 4} —
    // in BOTH cargo feature modes (`simd` on/off) and under FI_SIMD=0.
    // CI runs this file once per feature mode, so a vectorization change
    // that perturbs even one ulp anywhere in the pipeline fails here.
    let Some(rt) = runtime("synthetic") else { return };
    let len = 64;
    let sync = Engine::new(&rt, opts(TauKind::RustFft, false)).unwrap().generate(len).unwrap();
    for workers in [1usize, 2, 4] {
        let asy = Engine::new(
            &rt,
            EngineOpts { mixer_workers: workers, ..opts(TauKind::RustFft, true) },
        )
        .unwrap()
        .generate(len)
        .unwrap();
        assert_bit_identical(&sync, &asy, &format!("fused rfft workers={workers}"));
    }
}

#[test]
fn multi_worker_matches_sync_with_half_store() {
    // the wrapped store's row reuse is the hardest aliasing case for
    // concurrent tiles: per-row versioning + dep edges must still yield
    // the sync rollout exactly
    let Some(rt) = runtime("synthetic") else { return };
    let len = 64;
    let mk = |async_mixer, workers| EngineOpts {
        half_store: true,
        mixer_workers: workers,
        ..opts(TauKind::RustFft, async_mixer)
    };
    let sync = Engine::new(&rt, mk(false, 1)).unwrap().generate(len).unwrap();
    for workers in [2usize, 4] {
        let asy = Engine::new(&rt, mk(true, workers)).unwrap().generate(len).unwrap();
        assert_bit_identical(&sync, &asy, &format!("half_store workers={workers}"));
        assert_eq!(sync.resident_values, asy.resident_values);
    }
}

#[test]
fn multi_worker_split_churn_overlapping_dst() {
    // staged deadlines + aggressive splitting put many chunks in flight
    // with a mix of disjoint and overlapping dst ranges, over a 2-thread
    // kernel pool and {2, 4} mixer workers — any missing dependency edge
    // or missed fence shows up as a tolerance blowout or readiness panic
    let Some(rt) = runtime("synthetic") else { return };
    let len = 32;
    for round in 0..8u64 {
        let workers = if round % 2 == 0 { 2 } else { 4 };
        let half = round % 4 >= 2;
        let mk = |async_mixer, split_min_u, workers| EngineOpts {
            threads: 2,
            half_store: half,
            split_min_u,
            mixer_workers: workers,
            seed: round,
            ..opts(TauKind::RustFft, async_mixer)
        };
        let sync = Engine::new(&rt, mk(false, 0, 1)).unwrap().generate(len).unwrap();
        let unsplit = Engine::new(&rt, mk(true, 0, workers)).unwrap().generate(len).unwrap();
        assert_bit_identical(&sync, &unsplit, &format!("round {round} w={workers} unsplit"));

        let split = Engine::new(&rt, mk(true, 2, workers)).unwrap().generate(len).unwrap();
        let (ss, sp) = (sync.streams.as_ref().unwrap(), split.streams.as_ref().unwrap());
        let err = sp.rel_l2(ss);
        assert!(err < 1e-4, "round {round} w={workers} split err {err}");
    }
}

#[test]
fn multi_worker_drop_mid_flight_drains_cleanly() {
    // dropping a session with staged chunks queued across 4 workers must
    // drain every in-flight job (AsyncTau::drop → fence_all), not leave a
    // worker writing into freed cell planes
    let Some(rt) = runtime("synthetic") else { return };
    let len = 32;
    let eng = Engine::new(
        &rt,
        EngineOpts {
            split_min_u: 2,
            mixer_workers: 4,
            ..opts(TauKind::RustFft, true)
        },
    )
    .unwrap();

    let mut session = eng.session(len).unwrap();
    for _ in 0..len / 2 {
        session.step().unwrap();
    }
    let out = session.finish();
    assert_eq!(out.steps, len / 2);

    let mut session = eng.session(len).unwrap();
    for _ in 0..3 {
        session.step().unwrap();
    }
    drop(session);
}

#[test]
fn multi_worker_paging_suspend_resume_is_deterministic() {
    // suspend/restore must fence a multi-worker queue with staged chunks
    // in flight; the resumed lane's rollout must equal the uninterrupted
    // run under the identical config (the computation is deterministic:
    // chunk dsts are disjoint and overlapping-dst order is edge-enforced)
    let Some(rt) = runtime("synthetic") else { return };
    let lane = rt.dims.b - 1;
    let engine = Engine::new(
        &rt,
        EngineOpts {
            split_min_u: 2,
            mixer_workers: 2,
            ..opts(TauKind::RustFft, true)
        },
    )
    .unwrap();
    let mut pager = engine.make_pager(64);
    let (len, admit_at, limit, suspend_at) = (64usize, 8usize, 32usize, 20usize);
    let li = LaneInit {
        limit,
        sampler_cfg: Some(SamplerCfg::Synthetic { sigma: 0.25 }),
        seed: Some(77),
        pending_seed: None,
    };

    // uninterrupted baseline with the same multi-worker config
    let mut base = engine.session(len).unwrap();
    for _ in 0..admit_at {
        base.step().unwrap();
    }
    base.admit(lane, li.clone()).unwrap();
    let mut want = Vec::with_capacity(limit);
    for _ in 0..limit {
        want.push(base.step().unwrap().lane_checksums[lane]);
    }
    base.finish();

    // interrupted run: suspend mid-flight, resume in a later session
    let mut s1 = engine.session(len).unwrap();
    for _ in 0..admit_at {
        s1.step().unwrap();
    }
    s1.admit(lane, li).unwrap();
    let mut got = Vec::new();
    for _ in 0..(suspend_at - admit_at) {
        got.push(s1.step().unwrap().lane_checksums[lane]);
    }
    let ckpt = s1.suspend(lane, &mut pager).expect("suspend");
    for _ in 0..4 {
        s1.step().unwrap();
    }
    s1.finish();

    let mut s2 = engine.session(len).unwrap();
    for _ in 0..suspend_at {
        s2.step().unwrap();
    }
    s2.restore(lane, ckpt, &mut pager).expect("restore");
    while !s2.lane_done(lane) {
        got.push(s2.step().unwrap().lane_checksums[lane]);
    }
    s2.finish();

    assert_eq!(want, got, "suspend/resume diverged from the uninterrupted multi-worker run");
}

#[test]
fn multi_worker_rejected_for_unsupported_configs() {
    // config validation, not silent fallback: PJRT-backed kinds (incl.
    // Hybrid) and the forced-sync path must refuse mixer_workers > 1
    let Some(rt) = runtime("synthetic") else { return };
    let cases = [
        ("hybrid async", opts(TauKind::Hybrid, true)),
        ("pjrt-fft async", opts(TauKind::PjrtFft, true)),
        ("native sync", opts(TauKind::RustFft, false)),
    ];
    for (what, base) in cases {
        let eng = Engine::new(&rt, EngineOpts { mixer_workers: 2, ..base }).unwrap();
        let err = eng.session(16).err().unwrap_or_else(|| panic!("{what}: accepted workers=2"));
        assert!(
            err.to_string().contains("mixer_workers"),
            "{what}: unhelpful error: {err}"
        );
    }
    // zero workers is meaningless at any kind
    let eng = Engine::new(
        &rt,
        EngineOpts { mixer_workers: 0, ..opts(TauKind::RustFft, true) },
    )
    .unwrap();
    assert!(eng.session(16).is_err(), "workers=0 accepted");
}

#[test]
fn checksum_ring_bounds_history_but_not_total() {
    let Some(rt) = runtime("synthetic") else { return };
    let len = 32;
    let full = Engine::new(&rt, opts(TauKind::RustFft, true)).unwrap().generate(len).unwrap();
    let bounded = Engine::new(
        &rt,
        EngineOpts { checksum_history: 8, ..opts(TauKind::RustFft, true) },
    )
    .unwrap()
    .generate(len)
    .unwrap();
    assert_eq!(full.outs_checksum.len(), len);
    assert_eq!(bounded.outs_checksum.len(), 8, "ring keeps the last K");
    assert_eq!(&full.outs_checksum[len - 8..], &bounded.outs_checksum[..]);
    // the running total is over all positions regardless of retention
    assert_eq!(full.checksum_total, bounded.checksum_total);
    let want: f64 = full.outs_checksum.iter().map(|&c| c as f64).sum();
    assert_eq!(full.checksum_total, want);
}
