//! Integration: the three engines (flash / lazy / eager) are exact — they
//! agree with each other, across τ implementations, and with the python
//! golden rollout emitted by aot.py. This is the paper's central claim:
//! the tiling computes *exactly* the same function in O(L log² L).

use std::path::Path;

use flash_inference::engine::{Engine, EngineOpts, Method};
use flash_inference::model::{Variant, Weights};
use flash_inference::runtime::Runtime;
use flash_inference::tau::TauKind;

fn runtime(variant: &str) -> Option<Runtime> {
    let dir = Path::new("artifacts").join(variant);
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&dir).expect("load runtime"))
}

fn gen(rt: &Runtime, method: Method, tau: TauKind, len: usize) -> flash_inference::engine::GenOutput {
    let mut eng = Engine::new(
        rt,
        EngineOpts { method, tau, record_streams: true, ..Default::default() },
    )
    .expect("engine");
    eng.generate(len).expect("generate")
}

#[test]
fn flash_equals_lazy_equals_eager_synthetic() {
    let Some(rt) = runtime("synthetic") else { return };
    let len = 64;
    let flash = gen(&rt, Method::Flash, TauKind::RustFft, len);
    let lazy = gen(&rt, Method::Lazy, TauKind::RustFft, len);
    let eager = gen(&rt, Method::Eager, TauKind::RustFft, len);

    let fs = flash.streams.as_ref().unwrap();
    let ls = lazy.streams.as_ref().unwrap();
    let es = eager.streams.as_ref().unwrap();
    assert!(fs.rel_l2(ls) < 1e-4, "flash vs lazy: {}", fs.rel_l2(ls));
    assert!(es.rel_l2(ls) < 1e-5, "eager vs lazy: {}", es.rel_l2(ls));
    assert!(fs.data().iter().all(|v| v.is_finite()));
}

#[test]
fn all_tau_impls_produce_same_generation() {
    let Some(rt) = runtime("synthetic") else { return };
    let len = 32;
    let reference = gen(&rt, Method::Flash, TauKind::RustDirect, len);
    let rs = reference.streams.as_ref().unwrap();
    for tau in [TauKind::RustFft, TauKind::PjrtDirect, TauKind::PjrtFft, TauKind::Hybrid] {
        let out = gen(&rt, Method::Flash, tau, len);
        let os = out.streams.as_ref().unwrap();
        let err = os.rel_l2(rs);
        assert!(err < 1e-4, "tau {} err {err}", tau.as_str());
    }
}

#[test]
fn flash_matches_python_golden_synthetic() {
    let Some(rt) = runtime("synthetic") else { return };
    let Some(golden) = rt.manifest.golden.clone() else { return };
    let g = Weights::load(&golden.file).expect("golden.bin");
    let want = g.get("streams").unwrap(); // [M, B, steps, D]
    let steps = golden.steps;
    // golden steps may not be a power of two; generate the next pow2 and
    // compare the prefix — identical history ⇒ identical prefix.
    let len = steps.next_power_of_two();
    let out = gen(&rt, Method::Flash, TauKind::Hybrid, len);
    let got = out.streams.as_ref().unwrap(); // [G, len, D]
    let dims = rt.dims;
    let mut max_err = 0.0f32;
    for m in 0..dims.m {
        for b in 0..dims.b {
            let gi = m * dims.b + b;
            for t in 0..steps {
                let grow = got.at2(gi, t);
                for k in 0..dims.d {
                    let w = want.data()
                        [((m * dims.b + b) * steps + t) * dims.d + k];
                    max_err = max_err.max((grow[k] - w).abs());
                }
            }
        }
    }
    assert!(max_err < 5e-3, "golden mismatch: {max_err}");
}

#[test]
fn flash_matches_python_golden_hyena_tokens() {
    let Some(rt) = runtime("hyena") else { return };
    let Some(golden) = rt.manifest.golden.clone() else { return };
    let g = Weights::load(&golden.file).expect("golden.bin");
    let want_tokens = g.get("tokens").unwrap(); // [1, steps] as f32
    let steps = golden.steps;
    let len = steps.next_power_of_two();
    let out = gen(&rt, Method::Flash, TauKind::Hybrid, len);
    let toks = out.tokens.as_ref().unwrap();
    // token-exact for a long prefix; fp divergence may flip late argmaxes
    let check = steps.min(24);
    for t in 0..check {
        assert_eq!(
            toks[0][t] as f32, want_tokens.data()[t],
            "token {t} diverged"
        );
    }
}

#[test]
fn hyena_methods_agree() {
    let Some(rt) = runtime("hyena") else { return };
    let len = 32;
    let flash = gen(&rt, Method::Flash, TauKind::RustDirect, len);
    let lazy = gen(&rt, Method::Lazy, TauKind::RustDirect, len);
    let fs = flash.streams.as_ref().unwrap();
    let ls = lazy.streams.as_ref().unwrap();
    assert!(fs.rel_l2(ls) < 1e-4, "err {}", fs.rel_l2(ls));
    assert_eq!(flash.tokens, lazy.tokens);
}

#[test]
fn flop_counts_match_proposition_1() {
    let Some(rt) = runtime("synthetic") else { return };
    let len = 64;
    let out = gen(&rt, Method::Flash, TauKind::RustFft, len);
    // Proposition 1: 2^{P-1-q} tau calls of size 2^q
    let p = len.trailing_zeros() as usize;
    assert_eq!(out.flops.tau_calls as usize, len - 1);
    for (q, (&u, &count)) in out.flops.tau_call_hist.iter().enumerate() {
        assert_eq!(u, 1 << q);
        assert_eq!(count as usize, 1 << (p - 1 - q));
    }
    // §3.3: total tau IO = 2 * (L/2) log2 L * G * D values
    let dims = rt.dims;
    let want_io = (2 * (len / 2) * p * dims.g * dims.d) as u64;
    assert_eq!(out.flops.tau_io_values, want_io);
}

#[test]
fn metrics_cover_every_position() {
    let Some(rt) = runtime("synthetic") else { return };
    let out = gen(&rt, Method::Flash, TauKind::RustDirect, 16);
    assert_eq!(out.metrics.per_token.len(), 16);
    assert!(out.metrics.totals.step_ns > 0.0);
    assert!(out.metrics.totals.mixer_ns > 0.0);
    assert_eq!(out.metrics.cumulative_mixer_ns().len(), 16);
}

#[test]
fn synthetic_noise_changes_trajectory_deterministically() {
    let Some(rt) = runtime("synthetic") else { return };
    let mk = |sigma: f32, seed: u64| {
        let mut eng = Engine::new(
            &rt,
            EngineOpts {
                sample_sigma: sigma,
                seed,
                tau: TauKind::RustDirect,
                record_streams: true,
                ..Default::default()
            },
        )
        .unwrap();
        eng.generate(16).unwrap()
    };
    let a = mk(0.1, 1);
    let b = mk(0.1, 1);
    let c = mk(0.1, 2);
    assert_eq!(
        a.streams.as_ref().unwrap().max_abs_diff(b.streams.as_ref().unwrap()),
        0.0
    );
    assert!(a.streams.as_ref().unwrap().max_abs_diff(c.streams.as_ref().unwrap()) > 0.0);
}

#[test]
fn rejects_bad_lengths() {
    let Some(rt) = runtime("synthetic") else { return };
    let mut eng = Engine::new(&rt, EngineOpts::default()).unwrap();
    assert!(eng.generate(100).is_err()); // not a power of two
    assert!(eng.generate(rt.dims.l * 2).is_err()); // beyond L
}

#[test]
fn variant_is_wired_correctly() {
    let Some(rt) = runtime("hyena") else { return };
    assert_eq!(rt.dims.variant, Variant::Hyena);
    let out = gen(&rt, Method::Flash, TauKind::RustDirect, 16);
    let toks = out.tokens.expect("hyena emits tokens");
    assert_eq!(toks.len(), rt.dims.b);
    assert_eq!(toks[0].len(), 16);
    assert!(toks[0].iter().all(|&t| (t as usize) < rt.dims.v));
}
