//! Property tests (no artifacts needed — pure native paths): the fractal
//! tiling, driven over random shapes/filters, computes exactly the full
//! causal convolution; plus fuzz coverage of the JSON substrate.

use flash_inference::fft::{self, Plan};
use flash_inference::tiling::{schedule, verify_invariants};
use flash_inference::util::json::Json;
use flash_inference::util::prng::Prng;
use flash_inference::util::propcheck::{self, ensure, gen};

/// Full causal conv z_t = sum_{j<=t} y_j * rho_{t-j} via the tile schedule
/// (red cells + gray tiles), using the requested tile kernel.
fn tiled_causal_conv(y: &[f32], rho: &[f32], len: usize, d: usize, use_fft: bool) -> Vec<f32> {
    let mut z = vec![0.0f32; len * d];
    let mut scratch = fft::TileScratch::default();
    // red cells: z_i += y_i * rho_0
    for i in 0..len {
        for k in 0..d {
            z[i * d + k] += y[i * d + k] * rho[k];
        }
    }
    for tile in schedule::schedule(len) {
        let u = tile.u;
        let yblk = &y[(tile.src_l - 1) * d..tile.src_r * d];
        let out = &mut z[(tile.dst_l - 1) * d..tile.dst_r * d];
        if use_fft {
            let plan = Plan::new(2 * u);
            let (sre, sim) = fft::spectrum_planes(&plan, &rho[..2 * u * d], d);
            fft::tile_conv_fft_into(&plan, yblk, &sre, &sim, out, &mut scratch, d);
        } else {
            fft::tile_conv_direct_into(yblk, &rho[..2 * u * d], out, d);
        }
    }
    z
}

fn naive_causal_conv(y: &[f32], rho: &[f32], len: usize, d: usize) -> Vec<f32> {
    let mut z = vec![0.0f32; len * d];
    for t in 0..len {
        for j in 0..=t {
            for k in 0..d {
                z[t * d + k] += y[j * d + k] * rho[(t - j) * d + k];
            }
        }
    }
    z
}

#[test]
fn property_tiled_conv_equals_naive_direct() {
    propcheck::check(
        "tiled-direct == naive causal conv",
        12,
        |rng: &mut Prng| {
            let len = gen::pow2(rng, 1, 7);
            let d = rng.range(1, 9);
            let y = gen::vec_f32(rng, len * d);
            let rho = gen::vec_f32(rng, len * d);
            (len, d, y, rho)
        },
        |(len, d, y, rho)| {
            let want = naive_causal_conv(y, rho, *len, *d);
            let got = tiled_causal_conv(y, rho, *len, *d, false);
            for (a, b) in got.iter().zip(&want) {
                propcheck::ensure_close(*a, *b, 1e-4, "direct")?;
            }
            Ok(())
        },
    );
}

#[test]
fn property_tiled_conv_equals_naive_fft() {
    propcheck::check(
        "tiled-fft == naive causal conv",
        10,
        |rng: &mut Prng| {
            let len = gen::pow2(rng, 1, 8);
            let d = rng.range(1, 6);
            let y = gen::vec_f32(rng, len * d);
            let rho = gen::vec_f32(rng, len * d);
            (len, d, y, rho)
        },
        |(len, d, y, rho)| {
            let want = naive_causal_conv(y, rho, *len, *d);
            let got = tiled_causal_conv(y, rho, *len, *d, true);
            for (a, b) in got.iter().zip(&want) {
                propcheck::ensure_close(*a, *b, 5e-4 * (*len as f32).sqrt(), "fft")?;
            }
            Ok(())
        },
    );
}

#[test]
fn property_schedule_invariants_random_lengths() {
    propcheck::check(
        "schedule invariants",
        8,
        |rng: &mut Prng| gen::pow2(rng, 1, 10),
        |&len| verify_invariants(len).map_err(|e| e),
    );
}

#[test]
fn property_vecfft_linearity() {
    // FFT(a x + b y) == a FFT(x) + b FFT(y) on the vectorized transform
    propcheck::check(
        "vecfft linearity",
        10,
        |rng: &mut Prng| {
            let n = gen::pow2(rng, 1, 9);
            let d = rng.range(1, 5);
            let x = gen::vec_f32(rng, n * d);
            let y = gen::vec_f32(rng, n * d);
            (n, d, x, y, rng.normal_f32(), rng.normal_f32())
        },
        |(n, d, x, y, a, b)| {
            let plan = Plan::new(*n);
            let run = |v: &[f32]| {
                let mut re = v.to_vec();
                let mut im = vec![0.0; v.len()];
                fft::vecfft::forward(&plan, &mut re, &mut im, *d);
                (re, im)
            };
            let combo: Vec<f32> =
                x.iter().zip(y).map(|(xv, yv)| a * xv + b * yv).collect();
            let (cre, cim) = run(&combo);
            let (xre, xim) = run(x);
            let (yre, yim) = run(y);
            let tol = 1e-3 * (*n as f32).sqrt();
            for i in 0..x.len() {
                propcheck::ensure_close(cre[i], a * xre[i] + b * yre[i], tol, "re")?;
                propcheck::ensure_close(cim[i], a * xim[i] + b * yim[i], tol, "im")?;
            }
            Ok(())
        },
    );
}

#[test]
fn property_json_roundtrip_fuzz() {
    fn random_json(rng: &mut Prng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.normal() * 1e3).round()),
            3 => {
                let n = rng.below(12);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            let c = rng.below(96) as u8 + 32;
                            c as char
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.below(4) {
                    m.insert(format!("k{i}"), random_json(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    propcheck::check(
        "json parse(serialize(v)) == v",
        60,
        |rng: &mut Prng| random_json(rng, 3),
        |v| {
            let compact = Json::parse(&v.to_string()).map_err(|e| e.to_string())?;
            ensure(&compact == v, format!("compact mismatch: {v}"))?;
            let pretty = Json::parse(&v.to_string_pretty()).map_err(|e| e.to_string())?;
            ensure(&pretty == v, "pretty mismatch")
        },
    );
}

#[test]
fn property_prng_below_uniformity() {
    propcheck::check(
        "prng below() covers all buckets roughly uniformly",
        4,
        |rng: &mut Prng| rng.range(2, 16),
        |&n| {
            let mut rng = Prng::new(n as u64 * 7919);
            let mut counts = vec![0usize; n];
            let draws = 4000 * n;
            for _ in 0..draws {
                counts[rng.below(n)] += 1;
            }
            let expect = draws / n;
            for (i, &c) in counts.iter().enumerate() {
                ensure(
                    c > expect / 2 && c < expect * 2,
                    format!("bucket {i}: {c} vs {expect}"),
                )?;
            }
            Ok(())
        },
    );
}
