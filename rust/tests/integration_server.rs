//! Integration: HTTP server round-trip over loopback — health, info,
//! metrics, generation, error paths, and concurrent clients through the
//! batcher.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;

use flash_inference::config::ServerConfig;
use flash_inference::server::Server;
use flash_inference::util::json::Json;

fn request(addr: std::net::SocketAddr, raw: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(raw.as_bytes()).unwrap();
    s.flush().unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let status: u16 = buf.split_whitespace().nth(1).unwrap().parse().unwrap();
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (status, body)
}

fn post_generate(addr: std::net::SocketAddr, body: &str) -> (u16, String) {
    request(
        addr,
        &format!(
            "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        ),
    )
}

fn start_server() -> Option<Server> {
    if !Path::new("artifacts/synthetic/manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    let cfg = ServerConfig {
        port: 0,
        artifacts: "artifacts/synthetic".into(),
        ..Default::default()
    };
    Some(Server::start(cfg).expect("start server"))
}

#[test]
fn full_http_round_trip() {
    let Some(server) = start_server() else { return };
    let addr = server.addr;

    // health
    let (code, body) = request(addr, "GET /health HTTP/1.1\r\n\r\n");
    assert_eq!(code, 200);
    assert!(body.contains("\"ok\""));

    // info reflects the manifest
    let (code, body) = request(addr, "GET /v1/info HTTP/1.1\r\n\r\n");
    assert_eq!(code, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.req_str("variant").unwrap(), "synthetic");
    assert_eq!(j.req_usize("L").unwrap(), 4096);

    // generate
    let (code, body) = post_generate(addr, "{\"max_tokens\": 16}");
    assert_eq!(code, 200, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.req_usize("steps").unwrap(), 16);
    assert_eq!(j.req_usize("max_tokens").unwrap(), 16);
    assert!(j.get("gen_ms").unwrap().as_f64().unwrap() > 0.0);

    // non-pow2 request is padded up
    let (code, body) = post_generate(addr, "{\"max_tokens\": 20}");
    assert_eq!(code, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.req_usize("steps").unwrap(), 32);

    // bad requests
    let (code, _) = post_generate(addr, "{\"max_tokens\": 0}");
    assert_eq!(code, 400);
    let (code, _) = post_generate(addr, "{nonsense");
    assert_eq!(code, 400);
    let (code, _) = request(addr, "GET /nope HTTP/1.1\r\n\r\n");
    assert_eq!(code, 404);

    // metrics counted the traffic
    let (code, body) = request(addr, "GET /metrics HTTP/1.1\r\n\r\n");
    assert_eq!(code, 200);
    assert!(body.contains("fi_requests_total 4"), "{body}");
    assert!(body.contains("fi_tokens_generated 36"), "{body}");

    server.stop();
}

#[test]
fn concurrent_clients_are_all_served() {
    let Some(server) = start_server() else { return };
    let addr = server.addr;
    let mut handles = Vec::new();
    for _ in 0..4 {
        handles.push(std::thread::spawn(move || post_generate(addr, "{\"max_tokens\": 8}")));
    }
    for h in handles {
        let (code, body) = h.join().unwrap();
        assert_eq!(code, 200, "{body}");
        let j = Json::parse(&body).unwrap();
        assert!(j.req_usize("steps").unwrap() >= 8);
    }
    server.stop();
}
