//! Integration: HTTP server round-trip over loopback — health, info,
//! metrics, generation, streaming generation (incremental chunked
//! delivery + per-lane early stop), error paths, and concurrent clients
//! through the batcher.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;

use flash_inference::config::ServerConfig;
use flash_inference::server::http::decode_chunked;
use flash_inference::server::Server;
use flash_inference::util::json::Json;

/// Send a raw request; return (status, header block, raw body).
fn request_raw(addr: std::net::SocketAddr, raw: &str) -> (u16, String, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(raw.as_bytes()).unwrap();
    s.flush().unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let status: u16 = buf.split_whitespace().nth(1).unwrap().parse().unwrap();
    let headers = buf.split("\r\n\r\n").next().unwrap_or("").to_string();
    let body = buf.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, headers, body)
}

fn request(addr: std::net::SocketAddr, raw: &str) -> (u16, String) {
    let (status, _, body) = request_raw(addr, raw);
    (status, body)
}

fn post_generate(addr: std::net::SocketAddr, body: &str) -> (u16, String) {
    request(
        addr,
        &format!(
            "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        ),
    )
}

fn start_server() -> Option<Server> {
    if !Path::new("artifacts/synthetic/manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    let cfg = ServerConfig {
        port: 0,
        artifacts: "artifacts/synthetic".into(),
        ..Default::default()
    };
    Some(Server::start(cfg).expect("start server"))
}

#[test]
fn full_http_round_trip() {
    let Some(server) = start_server() else { return };
    let addr = server.addr;

    // health
    let (code, body) = request(addr, "GET /health HTTP/1.1\r\n\r\n");
    assert_eq!(code, 200);
    assert!(body.contains("\"ok\""));

    // info reflects the manifest
    let (code, body) = request(addr, "GET /v1/info HTTP/1.1\r\n\r\n");
    assert_eq!(code, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.req_str("variant").unwrap(), "synthetic");
    assert_eq!(j.req_usize("L").unwrap(), 4096);

    // generate
    let (code, body) = post_generate(addr, "{\"max_tokens\": 16}");
    assert_eq!(code, 200, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.req_usize("steps").unwrap(), 16);
    assert_eq!(j.req_usize("max_tokens").unwrap(), 16);
    assert!(j.get("gen_ms").unwrap().as_f64().unwrap() > 0.0);

    // non-pow2 request is padded up
    let (code, body) = post_generate(addr, "{\"max_tokens\": 20}");
    assert_eq!(code, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.req_usize("steps").unwrap(), 32);

    // bad requests
    let (code, _) = post_generate(addr, "{\"max_tokens\": 0}");
    assert_eq!(code, 400);
    let (code, _) = post_generate(addr, "{nonsense");
    assert_eq!(code, 400);
    let (code, _) = request(addr, "GET /nope HTTP/1.1\r\n\r\n");
    assert_eq!(code, 404);

    // metrics counted the traffic
    let (code, body) = request(addr, "GET /metrics HTTP/1.1\r\n\r\n");
    assert_eq!(code, 200);
    assert!(body.contains("fi_requests_total 4"), "{body}");
    assert!(body.contains("fi_tokens_generated 36"), "{body}");

    server.stop();
}

#[test]
fn streaming_generation_delivers_incremental_events() {
    let Some(server) = start_server() else { return };
    let addr = server.addr;

    // max_tokens=5 pads to an 8-position batch schedule: the lane must
    // receive exactly 5 per-position events (early stop) even though the
    // batch runs 8 positions, plus one final {"done":true,...} summary.
    let body = "{\"max_tokens\": 5, \"stream\": true}";
    let (code, headers, raw) = request_raw(
        addr,
        &format!(
            "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        ),
    );
    assert_eq!(code, 200, "{raw}");
    assert!(headers.contains("Transfer-Encoding: chunked"), "{headers}");
    assert!(headers.contains("application/x-ndjson"), "{headers}");

    let payload = decode_chunked(&raw);
    let lines: Vec<&str> = payload.lines().collect();
    assert_eq!(lines.len(), 6, "5 events + summary, got: {payload}");
    for (idx, line) in lines[..5].iter().enumerate() {
        let j = Json::parse(line).expect("event line is JSON");
        assert_eq!(j.req_usize("pos").unwrap(), idx + 1);
        // synthetic variant streams the per-position out checksum
        assert!(j.get("checksum").or_else(|| j.get("token")).is_some(), "{line}");
    }
    let tail = Json::parse(lines[5]).expect("summary line is JSON");
    assert_eq!(tail.get("done").and_then(Json::as_bool), Some(true));
    assert_eq!(tail.req_usize("steps").unwrap(), 8, "batch padded to pow2");
    assert_eq!(tail.req_usize("tokens_emitted").unwrap(), 5, "early stop at max_tokens");
    assert!(tail.get("gen_ms").unwrap().as_f64().unwrap() > 0.0);

    // each event left the server as its own chunk: incremental delivery,
    // not one buffered flush at the end (6 payload chunks + terminator)
    let size_lines = raw
        .split("\r\n")
        .filter(|l| usize::from_str_radix(l.trim(), 16).map(|n| n > 0).unwrap_or(false))
        .count();
    assert!(size_lines >= 6, "expected >=6 chunk frames, got {size_lines}: {raw}");

    // counters saw the streaming traffic
    let (code, metrics) = request(addr, "GET /metrics HTTP/1.1\r\n\r\n");
    assert_eq!(code, 200);
    assert!(metrics.contains("fi_stream_requests 1"), "{metrics}");
    assert!(metrics.contains("fi_stream_events 5"), "{metrics}");
    assert!(metrics.contains("fi_tokens_generated 5"), "{metrics}");

    // a buffered request on the same server still works after a stream
    let (code, body) = post_generate(addr, "{\"max_tokens\": 4}");
    assert_eq!(code, 200, "{body}");
    assert_eq!(Json::parse(&body).unwrap().req_usize("steps").unwrap(), 4);

    server.stop();
}

#[test]
fn concurrent_clients_are_all_served() {
    let Some(server) = start_server() else { return };
    let addr = server.addr;
    let mut handles = Vec::new();
    for _ in 0..4 {
        handles.push(std::thread::spawn(move || post_generate(addr, "{\"max_tokens\": 8}")));
    }
    for h in handles {
        let (code, body) = h.join().unwrap();
        assert_eq!(code, 200, "{body}");
        let j = Json::parse(&body).unwrap();
        assert!(j.req_usize("steps").unwrap() >= 8);
    }
    server.stop();
}
