//! Integration: the `Session` state machine IS the engine loop — driving
//! `step()` to completion is bit-identical (same `outs_checksum`, tokens,
//! FLOP counts, residency) to the one-shot `generate()` path for every
//! scheduling method, including the Appendix D half-store and the
//! teacher-forced path. This is the refactor's safety net: streaming can
//! never serve different numbers than the batch calculator.

use std::path::Path;

use flash_inference::engine::{Engine, EngineOpts, GenOutput, Method};
use flash_inference::runtime::Runtime;
use flash_inference::tau::TauKind;
use flash_inference::util::prng::Prng;

fn runtime(variant: &str) -> Option<Runtime> {
    let dir = Path::new("artifacts").join(variant);
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&dir).expect("load runtime"))
}

fn opts(method: Method) -> EngineOpts {
    EngineOpts { method, tau: TauKind::RustFft, ..Default::default() }
}

/// Drive a default session step by step, checking the per-step contract.
fn drive(engine: &Engine, len: usize) -> GenOutput {
    let mut session = engine.session(len).expect("session");
    assert_eq!(session.steps_done(), 0);
    assert_eq!(session.steps_total(), len);
    let mut positions = Vec::new();
    while !session.is_done() {
        let step = session.step().expect("step");
        positions.push(step.pos);
        assert_eq!(step.done, session.is_done());
        assert_eq!(session.steps_done(), step.pos);
    }
    assert_eq!(positions, (1..=len).collect::<Vec<_>>());
    session.finish()
}

fn assert_identical(a: &GenOutput, b: &GenOutput, what: &str) {
    // bit-identical per-position checksums, not approximate equality: the
    // session runs the exact same FLOPs in the exact same order
    assert_eq!(a.outs_checksum, b.outs_checksum, "{what}: outs_checksum");
    assert_eq!(a.steps, b.steps, "{what}: steps");
    assert_eq!(a.tokens, b.tokens, "{what}: tokens");
    assert_eq!(a.last_out, b.last_out, "{what}: last_out");
    assert_eq!(a.resident_values, b.resident_values, "{what}: residency");
    assert_eq!(a.flops.mixer_flops, b.flops.mixer_flops, "{what}: flops");
    assert_eq!(a.flops.tau_calls, b.flops.tau_calls, "{what}: tau calls");
}

#[test]
fn session_steps_match_one_shot_generate_all_methods() {
    let Some(rt) = runtime("synthetic") else { return };
    let len = 32;
    for method in [Method::Flash, Method::Lazy, Method::Eager] {
        let mut eng = Engine::new(&rt, opts(method)).unwrap();
        let oneshot = eng.generate(len).unwrap();
        let stepped = drive(&eng, len);
        assert_identical(&oneshot, &stepped, method.as_str());
    }
}

#[test]
fn session_matches_generate_with_half_store() {
    let Some(rt) = runtime("synthetic") else { return };
    let len = 64;
    let mut eng = Engine::new(
        &rt,
        EngineOpts { half_store: true, ..opts(Method::Flash) },
    )
    .unwrap();
    let oneshot = eng.generate(len).unwrap();
    let stepped = drive(&eng, len);
    assert_identical(&oneshot, &stepped, "half_store");

    // and the halved store really is halved on the stepped path too
    let mut full = Engine::new(&rt, opts(Method::Flash)).unwrap();
    let full_out = full.generate(len).unwrap();
    assert_eq!(stepped.resident_values * 2, full_out.resident_values);
    assert_eq!(stepped.outs_checksum, full_out.outs_checksum);
}

#[test]
fn session_matches_generate_teacher_forced() {
    let Some(rt) = runtime("synthetic") else { return };
    let dims = rt.dims;
    let len = 32;
    let mut rng = Prng::new(11);
    let forced: Vec<f32> = (0..8 * dims.b * dims.d).map(|_| rng.normal_f32()).collect();

    let mut eng = Engine::new(&rt, opts(Method::Flash)).unwrap();
    let oneshot = eng.generate_teacher_forced(len, &forced).unwrap();
    let mut session = eng.session_teacher_forced(len, &forced).unwrap();
    while !session.is_done() {
        session.step().unwrap();
    }
    let stepped = session.finish();
    assert_identical(&oneshot, &stepped, "teacher_forced");
}

#[test]
fn session_streams_hyena_tokens_per_step() {
    let Some(rt) = runtime("hyena") else { return };
    let len = 16;
    let mut eng = Engine::new(&rt, opts(Method::Flash)).unwrap();
    let oneshot = eng.generate(len).unwrap();

    // collect the per-step incremental tokens the streaming layers consume
    let mut session = eng.session(len).unwrap();
    let mut lanes: Vec<Vec<u32>> = vec![Vec::new(); rt.dims.b];
    while !session.is_done() {
        let step = session.step().unwrap();
        let toks = step.tokens.expect("hyena emits a token per step");
        assert_eq!(toks.len(), rt.dims.b);
        for (bi, t) in toks.into_iter().enumerate() {
            lanes[bi].push(t);
        }
    }
    let stepped = session.finish();
    assert_identical(&oneshot, &stepped, "hyena");
    // the incremental stream concatenates to exactly the buffered result
    assert_eq!(Some(lanes), stepped.tokens);
}

#[test]
fn session_can_finish_early() {
    let Some(rt) = runtime("synthetic") else { return };
    let len = 16;
    let eng = Engine::new(&rt, opts(Method::Flash)).unwrap();
    let mut session = eng.session(len).unwrap();
    for _ in 0..len / 2 {
        session.step().unwrap();
    }
    assert!(!session.is_done());
    let out = session.finish();
    assert_eq!(out.steps, len / 2);
    assert_eq!(out.outs_checksum.len(), len / 2);
    assert_eq!(out.metrics.per_token.len(), len / 2);
}

#[test]
fn step_after_completion_errors() {
    let Some(rt) = runtime("synthetic") else { return };
    let eng = Engine::new(&rt, opts(Method::Flash)).unwrap();
    let mut session = eng.session(4).unwrap();
    while !session.is_done() {
        session.step().unwrap();
    }
    assert!(session.step().is_err());
}

#[test]
fn session_rejects_bad_lengths() {
    let Some(rt) = runtime("synthetic") else { return };
    let eng = Engine::new(&rt, EngineOpts::default()).unwrap();
    assert!(eng.session(100).is_err()); // not a power of two
    assert!(eng.session(rt.dims.l * 2).is_err()); // beyond L
}
