//! `flashinfer` — the Flash Inference coordinator binary.
//!
//! Python runs only at build time (`make artifacts`); this binary is
//! self-contained afterwards: it loads HLO-text artifacts via the PJRT CPU
//! client and serves/generates/benchmarks from rust alone.

use flash_inference::cli::commands;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match commands::run(argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
