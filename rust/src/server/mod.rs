//! Serving layer: minimal HTTP front-end, the engine worker thread, and
//! the continuous-admission scheduler — queued requests are seeded into
//! free lanes of the *running* batch at step boundaries, with per-lane
//! sampling configs and per-token streaming driven off the engine's
//! `Session` state machine (see `rust/DESIGN.md` §4).

pub mod api;
pub mod batcher;
pub mod http;

pub use api::Server;
pub use batcher::{GenRequest, LaneResult, SamplingParams, StreamEvent};
