//! Serving layer: minimal HTTP front-end, static batcher, and the
//! engine worker thread (DESIGN.md §6).

pub mod api;
pub mod batcher;
pub mod http;

pub use api::Server;
pub use batcher::{GenRequest, LaneResult};
