//! Serving layer: minimal HTTP front-end, a fleet of replica engine
//! workers, and the continuous-admission scheduler — queued requests are
//! seeded into free lanes of the *running* batch at step boundaries,
//! with per-lane sampling configs and per-token streaming driven off the
//! engine's `Session` state machine (see `rust/DESIGN.md` §4). With
//! `--replicas N` the router dispatches across N isolated failure
//! domains with supervised failover (§8).

pub mod api;
pub mod batcher;
pub mod http;
pub(crate) mod replica;
pub(crate) mod router;

pub use api::Server;
pub use batcher::{GenRequest, LaneResult, SamplingParams, StreamEvent};
