//! Serving layer: minimal HTTP front-end, static lockstep batcher, and
//! the engine worker thread, with per-token streaming lanes driven off
//! the engine's `Session` state machine (see `rust/DESIGN.md`).

pub mod api;
pub mod batcher;
pub mod http;

pub use api::Server;
pub use batcher::{GenRequest, LaneResult, StreamEvent};
