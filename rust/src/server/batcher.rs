//! Request/reply types for the serving queue, plus the idle-window
//! collector the scheduler uses to batch the *first* admissions of a
//! fresh session.
//!
//! The artifact build fixes the batch width B (shapes are baked into
//! HLO), so the engine always steps B lockstep lanes. Historically that
//! meant drain-then-refill batches; since the continuous-admission
//! scheduler (`server/api.rs::Scheduler`, DESIGN.md §4) landed, a request
//! is seeded into a *free lane of the running batch* at the next step
//! boundary instead — `collect_batch` survives as the idle-state window
//! (block for the first request, drain up to B more within
//! `batch_window_ms` so simultaneous arrivals start one session together).

use std::sync::atomic::AtomicBool;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-request sampling overrides, parsed from the request JSON and
/// threaded through the scheduler into `Session::admit` — each admitted
/// lane keeps its own temperature/top-k/sigma/seed (`None` = engine
/// default; the seed default is `engine seed + lane index`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SamplingParams {
    /// LM sampling temperature (0 = argmax).
    pub temperature: Option<f32>,
    /// LM top-k restriction (0 = all).
    pub top_k: Option<usize>,
    /// Synthetic-variant noise scale.
    pub sigma: Option<f32>,
    /// Per-request PRNG seed (reproducible rollouts under admission).
    pub seed: Option<u64>,
}

/// Serialized continuation state attached to a re-dispatched request
/// whose checkpoint was shipped off a quarantined replica: the `FICK`
/// blob plus the serving-layer progress the receiving scheduler must
/// resume (tokens already streamed, running checksum accumulator,
/// queue/eviction counters). Built by the shipping path in
/// `server/replica.rs`, consumed by the receiving scheduler's `accept`.
#[derive(Debug, Clone)]
pub struct ResumeState {
    /// Serialized checkpoint (`Pager::serialize` output).
    pub blob: Vec<u8>,
    /// Tokens the lane already produced (LM variant; empty otherwise).
    pub tokens: Vec<u32>,
    /// f64 checksum left-fold up to the suspension point.
    pub checksum_total: f64,
    /// Queue time accrued before the first admission.
    pub queue_ms: f64,
    /// Checkpoint/resume cycles so far (this shipping counts as one).
    pub evictions: u64,
    /// Busy-lane count observed at the original admission.
    pub batch_size: usize,
}

/// One queued generation request.
#[derive(Debug)]
pub struct GenRequest {
    pub max_tokens: usize,
    /// Per-lane sampling config for this request.
    pub sampling: SamplingParams,
    pub enqueued: Instant,
    pub reply: Sender<Result<LaneResult, String>>,
    /// Streaming lane: the engine worker sends one event per position as
    /// the lane advances, and stops at this lane's `max_tokens` even
    /// while the batch keeps running (per-lane early stop). `None` =
    /// classic buffered reply.
    pub stream: Option<Sender<StreamEvent>>,
    /// Absolute wall-clock deadline (config `deadline_ms` layered with
    /// the request's own `deadline_ms` field, whichever is sooner).
    /// Checked by the scheduler at step boundaries and before admission;
    /// `None` = no deadline.
    pub deadline: Option<Instant>,
    /// Set by the connection thread when the client hangs up; the
    /// scheduler cancels the lane (or dequeues the request) at the next
    /// step boundary instead of generating for a ghost.
    pub cancel: Arc<AtomicBool>,
    /// Client-supplied session key, used by the router for
    /// checkpoint-affinity: repeated requests with the same key land on
    /// the same replica so an evicted checkpoint can be resumed there.
    pub session: Option<String>,
    /// Times this request has been re-dispatched after its replica was
    /// quarantined. Requests that never produced a token are retried
    /// from scratch; requests whose checkpoint was shipped off the dying
    /// replica are retried carrying `resume` (retried-iff-zero-tokens
    /// **or** carries-its-checkpoint), bounded by
    /// `ServerConfig::failover_retries`.
    pub failovers: u32,
    /// Prefill-style pending seed (`{"prompt": [...]}`): flat
    /// `[M, span, D]` group-major future contributions handed to
    /// `LaneInit::pending_seed` at admission.
    pub prompt: Option<Vec<f32>>,
    /// Shipped continuation: restore this checkpoint instead of admitting
    /// a fresh lane. Set only by the failover path, never by clients.
    pub resume: Option<ResumeState>,
}

/// One incremental per-position event on a streaming lane.
#[derive(Debug, Clone)]
pub struct StreamEvent {
    /// 1-indexed position on the *lane's* clock (an admitted lane starts
    /// at 1 regardless of the batch's global position).
    pub pos: usize,
    /// Token id sampled for this lane at this position (LM variant).
    pub token: Option<u32>,
    /// Checksum of the lane's `out` slice (the synthetic variant's
    /// per-position observable).
    pub checksum: f32,
}

/// Per-request result.
#[derive(Debug, Clone)]
pub struct LaneResult {
    /// Sampled tokens for this lane (LM variant), truncated to max_tokens.
    pub tokens: Option<Vec<u32>>,
    /// Positions actually generated for this lane (its padded power of
    /// two), on the lane's own clock.
    pub steps: usize,
    /// Running sum of the lane's per-position checksums over its first
    /// `max_tokens` positions — the cheap whole-rollout observable the
    /// serving smoke gate compares across admission schedules.
    pub checksum_total: f64,
    /// Global batch position at which the lane was admitted (0 = session
    /// start; > 0 = a mid-batch admission).
    pub admitted_pos: usize,
    /// Time spent queued before a lane was free (enqueue → admit).
    pub queue_ms: f64,
    /// Time from admission to the lane completing its padded schedule.
    pub gen_ms: f64,
    /// Busy lanes (this one included) at the moment of admission.
    pub batch_size: usize,
    /// Times this request was checkpointed into the session pager and
    /// later resumed (0 = ran uninterrupted). Eviction is semantically
    /// invisible — the rollout stays bit-identical — so this is purely an
    /// observability/fairness signal (and what the paging probes assert).
    pub evictions: u64,
    /// Id of the replica that ran this lane (always 0 when
    /// `replicas == 1`). Rollouts are bit-identical across replicas, so
    /// this is an observability field, not a correctness one.
    pub replica: usize,
}

/// Collect up to `max_lanes` requests: blocks for the first one, then
/// drains more until `window` elapses or the batch is full.
pub fn collect_batch(
    rx: &Receiver<GenRequest>,
    max_lanes: usize,
    window: Duration,
) -> Option<Vec<GenRequest>> {
    let first = rx.recv().ok()?; // None = all senders dropped: shut down
    let mut batch = vec![first];
    let deadline = Instant::now() + window;
    while batch.len() < max_lanes {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(req) => batch.push(req),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// Lane schedule length for one request: `max_tokens` rounded up to a
/// power of two (the tile schedule needs 2^P), clamped to [1, max_len].
/// The scheduler uses it both per lane and (max'ed over a batch) to size
/// drain-then-refill sessions.
pub fn lane_len(max_tokens: usize, max_len: usize) -> usize {
    max_tokens.max(1).next_power_of_two().min(max_len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(n: usize) -> (GenRequest, Receiver<Result<LaneResult, String>>) {
        let (tx, rx) = channel();
        (
            GenRequest {
                max_tokens: n,
                sampling: SamplingParams::default(),
                enqueued: Instant::now(),
                reply: tx,
                stream: None,
                deadline: None,
                cancel: Arc::new(AtomicBool::new(false)),
                session: None,
                failovers: 0,
                prompt: None,
                resume: None,
            },
            rx,
        )
    }

    #[test]
    fn collects_up_to_capacity() {
        let (tx, rx) = channel();
        for n in [4, 8, 16] {
            tx.send(req(n).0).unwrap();
        }
        let batch = collect_batch(&rx, 2, Duration::from_millis(20)).unwrap();
        assert_eq!(batch.len(), 2);
        // third request stays queued
        let batch2 = collect_batch(&rx, 2, Duration::from_millis(5)).unwrap();
        assert_eq!(batch2.len(), 1);
    }

    #[test]
    fn returns_none_when_channel_closed() {
        let (tx, rx) = channel::<GenRequest>();
        drop(tx);
        assert!(collect_batch(&rx, 4, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn window_bounds_the_wait() {
        let (tx, rx) = channel();
        tx.send(req(4).0).unwrap();
        let t0 = Instant::now();
        let batch = collect_batch(&rx, 8, Duration::from_millis(30)).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn lane_len_rounds_and_clamps() {
        assert_eq!(lane_len(5, 4096), 8);
        assert_eq!(lane_len(16, 4096), 16);
        assert_eq!(lane_len(0, 64), 1);
        assert_eq!(lane_len(3000, 2048), 2048, "padded length clamps to L");
    }

    #[test]
    fn sampling_params_default_is_all_engine_defaults() {
        let s = SamplingParams::default();
        assert_eq!(s, SamplingParams { temperature: None, top_k: None, sigma: None, seed: None });
    }
}
