//! Minimal HTTP/1.1 substrate on `std::net` (hyper/axum unavailable
//! offline). Enough protocol for a serving API: request line, headers,
//! Content-Length bodies, chunked transfer encoding for streaming
//! responses, and opt-in keep-alive: a client sending
//! `Connection: keep-alive` gets the socket back for up to
//! `ServerConfig::keepalive_max_requests` requests (idle bounded by the
//! socket read timeout); streaming responses always close.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// Parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not UTF-8")
    }
}

/// Response under construction.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub reason: &'static str,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Emitted as a `Retry-After: <seconds>` header when set (503 shed /
    /// drain responses tell well-behaved clients when to come back).
    pub retry_after_s: Option<u64>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response {
            status,
            reason: reason_for(status),
            content_type: "application/json",
            body: body.into_bytes(),
            retry_after_s: None,
        }
    }

    pub fn text(status: u16, body: String) -> Response {
        Response {
            status,
            reason: reason_for(status),
            content_type: "text/plain; charset=utf-8",
            body: body.into_bytes(),
            retry_after_s: None,
        }
    }

    pub fn not_found() -> Response {
        Response::json(404, "{\"error\":\"not found\"}".into())
    }

    pub fn bad_request(msg: &str) -> Response {
        let j = crate::util::json::Json::from_pairs(vec![(
            "error",
            crate::util::json::Json::Str(msg.to_string()),
        )]);
        Response::json(400, j.to_string())
    }

    /// Load shed: the serving queue is at its configured bound
    /// (`ServerConfig::max_queue`), so the request is rejected up front
    /// instead of being queued toward a distant timeout.
    pub fn too_many_requests() -> Response {
        Response::shed(429, "queue full, retry later", 1)
    }

    /// 503 with a `Retry-After` hint: connection-cap shed, engine
    /// unavailable, and graceful-shutdown stragglers all use this shape.
    pub fn unavailable(msg: &str, retry_after_s: u64) -> Response {
        Response::shed(503, msg, retry_after_s)
    }

    /// The one shed constructor: every rejected-for-capacity path — the
    /// 429 queue shed, 503 connection/replica sheds, drain stragglers —
    /// emits a structured error body *and* a `Retry-After` hint through
    /// here, so no shed response can forget to tell a well-behaved
    /// client when to come back.
    pub fn shed(status: u16, msg: &str, retry_after_s: u64) -> Response {
        let j = crate::util::json::Json::from_pairs(vec![(
            "error",
            crate::util::json::Json::Str(msg.to_string()),
        )]);
        let mut r = Response::json(status, j.to_string());
        r.retry_after_s = Some(retry_after_s);
        r
    }
}

/// Apply the configured socket read/write timeouts (0 = unlimited) so a
/// stuck or malicious peer cannot pin an `fi-conn` thread forever.
pub fn configure_stream(stream: &TcpStream, read_ms: u64, write_ms: u64) -> Result<()> {
    let t = |ms: u64| (ms > 0).then(|| Duration::from_millis(ms));
    stream.set_read_timeout(t(read_ms)).context("set read timeout")?;
    stream.set_write_timeout(t(write_ms)).context("set write timeout")?;
    Ok(())
}

fn reason_for(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        429 => "Too Many Requests",
        499 => "Client Closed Request",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Maximum accepted body (1 MiB — requests here are tiny JSON).
const MAX_BODY: usize = 1 << 20;

/// Read one request from the stream.
pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone().context("clone stream")?);
    let mut line = String::new();
    reader.read_line(&mut line).context("read request line")?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_uppercase();
    let path = parts.next().unwrap_or_default().to_string();
    let version = parts.next().unwrap_or_default();
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1") {
        bail!("malformed request line: {line:?}");
    }

    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).context("read header")?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_lowercase(), v.trim().to_string());
        }
    }

    let len: usize = headers
        .get("content-length")
        .map(|v| v.parse())
        .transpose()
        .context("bad Content-Length")?
        .unwrap_or(0);
    if len > MAX_BODY {
        bail!("body too large: {len}");
    }
    let mut body = vec![0u8; len];
    if len > 0 {
        reader.read_exact(&mut body).context("read body")?;
    }
    Ok(Request { method, path, headers, body })
}

/// Start a chunked (streaming) response. The caller emits payload pieces
/// with [`write_chunk`] as they become available and terminates the body
/// with [`finish_chunks`]; each flush reaches the client immediately, so
/// tokens are observable long before the response completes.
pub fn write_chunked_head(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
        status,
        reason_for(status),
        content_type
    );
    stream.write_all(head.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Send one chunk (size line, payload, CRLF) and flush it to the wire.
/// Empty payloads are skipped — a zero-length chunk would terminate the
/// body (that is [`finish_chunks`]'s job).
pub fn write_chunk(stream: &mut TcpStream, data: &[u8]) -> Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    stream.write_all(format!("{:x}\r\n", data.len()).as_bytes())?;
    stream.write_all(data)?;
    stream.write_all(b"\r\n")?;
    stream.flush()?;
    Ok(())
}

/// Terminate a chunked body (the zero-size chunk).
pub fn finish_chunks(stream: &mut TcpStream) -> Result<()> {
    stream.write_all(b"0\r\n\r\n")?;
    stream.flush()?;
    Ok(())
}

/// Decode a chunked transfer-encoded body back into its payload (client
/// side of [`write_chunk`]; used by tests and the example clients).
/// Operates on bytes so a chunk size that cuts into a multi-byte UTF-8
/// sequence degrades to lossy replacement instead of panicking.
pub fn decode_chunked(body: &str) -> String {
    let mut out = String::new();
    let mut rest = body.as_bytes();
    loop {
        let Some(nl) = rest.windows(2).position(|w| w == b"\r\n") else { break };
        let Ok(size_line) = std::str::from_utf8(&rest[..nl]) else { break };
        let Ok(size) = usize::from_str_radix(size_line.trim(), 16) else { break };
        let tail = &rest[nl + 2..];
        if size == 0 || tail.len() < size {
            break;
        }
        out.push_str(&String::from_utf8_lossy(&tail[..size]));
        rest = tail.get(size + 2..).unwrap_or(&[]);
    }
    out
}

/// Serialize and send a response, closing the connection after.
pub fn write_response(stream: &mut TcpStream, resp: &Response) -> Result<()> {
    write_response_conn(stream, resp, false)
}

/// Serialize and send a response, advertising whether the server will
/// keep the connection open for another request (`Connection:
/// keep-alive`) or close it after this one (`Connection: close`). The
/// advertisement must match what the caller actually does — the
/// connection loop in `server/api.rs` owns that decision.
pub fn write_response_conn(
    stream: &mut TcpStream,
    resp: &Response,
    keep_alive: bool,
) -> Result<()> {
    let retry = resp
        .retry_after_s
        .map(|s| format!("Retry-After: {s}\r\n"))
        .unwrap_or_default();
    let conn = if keep_alive { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n{}Connection: {}\r\n\r\n",
        resp.status,
        resp.reason,
        resp.content_type,
        resp.body.len(),
        retry,
        conn
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};
    use std::thread;

    fn roundtrip(raw: &str) -> Result<Request> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_string();
        let h = thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            c.write_all(raw.as_bytes()).unwrap();
            c.flush().unwrap();
            // keep the socket open until the server has read everything
            thread::sleep(std::time::Duration::from_millis(50));
        });
        let (mut s, _) = listener.accept().unwrap();
        let req = read_request(&mut s);
        h.join().unwrap();
        req
    }

    #[test]
    fn parses_post_with_body() {
        let req = roundtrip(
            "POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 18\r\n\r\n{\"max_tokens\": 32}",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.body_str().unwrap(), "{\"max_tokens\": 32}");
        assert_eq!(req.headers.get("host").map(String::as_str), Some("x"));
    }

    #[test]
    fn parses_get_without_body() {
        let req = roundtrip("GET /health HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/health");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(roundtrip("NONSENSE\r\n\r\n").is_err());
    }

    #[test]
    fn chunked_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            let mut buf = String::new();
            c.read_to_string(&mut buf).unwrap();
            buf
        });
        let (mut s, _) = listener.accept().unwrap();
        write_chunked_head(&mut s, 200, "application/x-ndjson").unwrap();
        write_chunk(&mut s, b"{\"pos\":1}\n").unwrap();
        write_chunk(&mut s, b"").unwrap(); // no-op, must not terminate
        write_chunk(&mut s, b"{\"pos\":2}\n").unwrap();
        finish_chunks(&mut s).unwrap();
        drop(s);
        let got = h.join().unwrap();
        assert!(got.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(got.contains("Transfer-Encoding: chunked"));
        let body = got.split("\r\n\r\n").nth(1).unwrap();
        assert_eq!(decode_chunked(body), "{\"pos\":1}\n{\"pos\":2}\n");
        // two separate payload chunks on the wire = incremental delivery
        assert_eq!(body.matches("a\r\n").count(), 2);
    }

    #[test]
    fn unavailable_carries_retry_after() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            let mut buf = String::new();
            c.read_to_string(&mut buf).unwrap();
            buf
        });
        let (mut s, _) = listener.accept().unwrap();
        write_response(&mut s, &Response::unavailable("draining", 2)).unwrap();
        drop(s);
        let got = h.join().unwrap();
        assert!(got.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(got.contains("Retry-After: 2\r\n"));
        assert!(got.contains("\"error\":\"draining\""));
        // plain responses must not grow the header
        assert!(!format!("{:?}", Response::json(200, "{}".into())).contains("Some"));
    }

    #[test]
    fn every_shed_path_carries_retry_after() {
        // 429 queue shed and 503 unavailability route through the same
        // helper, so both carry the hint
        let r = Response::too_many_requests();
        assert_eq!(r.status, 429);
        assert_eq!(r.retry_after_s, Some(1));
        assert!(String::from_utf8_lossy(&r.body).contains("queue full"));
        let r = Response::shed(503, "all replica queues full, retry later", 2);
        assert_eq!(r.status, 503);
        assert_eq!(r.retry_after_s, Some(2));
        assert!(String::from_utf8_lossy(&r.body).contains("replica queues"));
    }

    #[test]
    fn keep_alive_header_reflects_caller_decision() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            let mut buf = String::new();
            c.read_to_string(&mut buf).unwrap();
            buf
        });
        let (mut s, _) = listener.accept().unwrap();
        write_response_conn(&mut s, &Response::json(200, "{}".into()), true).unwrap();
        write_response_conn(&mut s, &Response::json(200, "{}".into()), false).unwrap();
        drop(s);
        let got = h.join().unwrap();
        let mut parts = got.split("\r\n\r\n");
        assert!(parts.next().unwrap().contains("Connection: keep-alive"));
        // second response rides the same socket and announces the close
        assert!(got.matches("Connection: close").count() == 1);
        assert!(got.matches("HTTP/1.1 200 OK").count() == 2);
    }

    #[test]
    fn response_wire_format() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            let mut buf = String::new();
            c.read_to_string(&mut buf).unwrap();
            buf
        });
        let (mut s, _) = listener.accept().unwrap();
        write_response(&mut s, &Response::json(200, "{\"ok\":true}".into())).unwrap();
        drop(s);
        let got = h.join().unwrap();
        assert!(got.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(got.contains("Content-Length: 11"));
        assert!(got.ends_with("{\"ok\":true}"));
    }
}
