//! One engine replica: a failure-domain-isolated worker thread owning a
//! private Runtime + Engine + [`Scheduler`] + [`Pager`] + restart budget.
//!
//! PJRT handles are not `Send`, so each replica's engine lives on its own
//! dedicated `fi-engine-<id>` thread; the router hands it requests over a
//! bounded mpsc queue. Inside the worker, PR 7's supervision loop runs
//! unchanged — panics are caught at the step boundary, busy lanes get
//! structured errors, and a rolling [`RestartBudget`] decides when the
//! replica has crossed from "absorbing the occasional panic" into a crash
//! loop. What happens *then* depends on the fleet size:
//!
//! * `replicas == 1` — the PR 7 terminal latch, exactly: the server stays
//!   up serving degraded, `/health` flips to 503, nothing respawns.
//! * `replicas > 1` — the replica **quarantines**: it ejects itself from
//!   rotation, fails its in-flight lanes (structured 500s, as before),
//!   hands its never-admitted queued requests back to the supervisor for
//!   failover to healthy replicas, and exits. The supervisor respawns it
//!   with capped exponential backoff; a clean probe window later it is
//!   promoted back into full rotation.
//!
//! The quarantine → probing → serving state machine lives in [`Replica`];
//! the worker body in [`worker_main`] is the engine side of it.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{
    collect_batch, lane_len, GenRequest, LaneResult, ResumeState, SamplingParams, StreamEvent,
};
use crate::config::ServerConfig;
use crate::engine::{
    CkptRef, Engine, EngineOpts, LaneCheckpoint, LaneInit, Pager, SamplerCfg, ServingMeta,
    Session, StepOutput,
};
use crate::metrics::Counters;
use crate::model::Variant;
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::util::threadpool::payload_text;

/// Startup handshake payload: the `/v1/info` document plus the
/// *effective* `max_max_tokens` (clamped to the model's L — only the
/// worker knows dims), which front-end validation must agree on.
pub(crate) type ReadyMsg = std::result::Result<(Json, usize), String>;

/// Where a replica stands in the quarantine/respawn state machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum ReplicaState {
    /// In full rotation: preferred dispatch target.
    Serving,
    /// Respawned after quarantine, serving probe traffic; promoted to
    /// [`Serving`](ReplicaState::Serving) after a clean `probe_window_ms`.
    Probing,
    /// Out of rotation (budget exhausted or boot failed); the supervisor
    /// respawns it once its backoff wait has elapsed.
    Quarantined,
}

impl ReplicaState {
    pub(crate) fn as_str(self) -> &'static str {
        match self {
            ReplicaState::Serving => "serving",
            ReplicaState::Probing => "probing",
            ReplicaState::Quarantined => "quarantined",
        }
    }
}

/// State-machine bookkeeping, guarded by one mutex so transitions are
/// atomic with their timing fields.
#[derive(Debug, Clone, Copy)]
struct ReplicaStatus {
    state: ReplicaState,
    /// When the current state was entered.
    since: Instant,
    /// Quarantine only: how long to wait before respawning.
    wait: Duration,
    /// Backoff applied to the *next* quarantine (doubles per consecutive
    /// quarantine, capped; reset on promotion to Serving).
    backoff: Duration,
}

/// Per-replica gauges, written lock-free by the worker/router and summed
/// into the global counters at `/metrics` scrape time.
#[derive(Debug, Default)]
pub(crate) struct ReplicaGauges {
    /// Requests dispatched to this replica and not yet finished (the
    /// router's least-loaded key; incremented at dispatch, decremented
    /// when the request is replied to or failed over).
    pub load: AtomicU64,
    pub queue_depth: AtomicU64,
    pub lanes_busy: AtomicU64,
    pub pager_resident_values: AtomicU64,
    /// In-place session rebuilds inside this worker (PR 7 semantics).
    pub engine_restarts: AtomicU64,
    /// Times the supervisor respawned this replica after quarantine.
    pub respawns: AtomicU64,
}

/// Everything a replica worker needs from the server, cloneable so the
/// supervisor can mint a fresh context per respawn.
#[derive(Clone)]
pub(crate) struct ReplicaCtx {
    pub cfg: ServerConfig,
    pub counters: Counters,
    pub inflight: Arc<AtomicU64>,
    /// Fleet-of-one only: the PR 7 terminal health latch.
    pub healthy: Arc<AtomicBool>,
    pub draining: Arc<AtomicBool>,
    /// Quarantining replicas hand their never-admitted queued requests
    /// back to the supervisor here for failover to healthy replicas.
    pub failback: Sender<GenRequest>,
}

/// Handle to one replica: id, state machine, request-queue sender, and
/// the worker thread's join handle. Shared between the router (dispatch),
/// the supervisor (respawn/promote), and the worker itself (transitions).
pub(crate) struct Replica {
    pub id: usize,
    pub gauges: Arc<ReplicaGauges>,
    status: Mutex<ReplicaStatus>,
    sender: Mutex<Option<Sender<GenRequest>>>,
    thread: Mutex<Option<thread::JoinHandle<()>>>,
    backoff_initial: Duration,
    backoff_max: Duration,
}

fn plock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl Replica {
    /// A new replica starts `Quarantined` with a zero wait: not
    /// serviceable until its first boot succeeds, respawnable immediately
    /// if that boot fails fast.
    pub(crate) fn new(id: usize, cfg: &ServerConfig) -> Arc<Replica> {
        Arc::new(Replica {
            id,
            gauges: Arc::new(ReplicaGauges::default()),
            status: Mutex::new(ReplicaStatus {
                state: ReplicaState::Quarantined,
                since: Instant::now(),
                wait: Duration::ZERO,
                backoff: Duration::from_millis(cfg.quarantine_backoff_ms.max(1)),
            }),
            sender: Mutex::new(None),
            thread: Mutex::new(None),
            backoff_initial: Duration::from_millis(cfg.quarantine_backoff_ms.max(1)),
            backoff_max: Duration::from_millis(
                cfg.quarantine_backoff_max_ms.max(cfg.quarantine_backoff_ms.max(1)),
            ),
        })
    }

    pub(crate) fn state(&self) -> ReplicaState {
        plock(&self.status).state
    }

    /// In full rotation (health aggregation counts these).
    pub(crate) fn is_serving(&self) -> bool {
        self.state() == ReplicaState::Serving
    }

    /// Can take traffic at all: Serving or Probing with a live queue.
    /// `/health` only reports 503 when no replica is serviceable.
    pub(crate) fn is_serviceable(&self) -> bool {
        matches!(self.state(), ReplicaState::Serving | ReplicaState::Probing)
            && plock(&self.sender).is_some()
    }

    /// Requests dispatched but not yet admitted to a lane — this
    /// replica's waiting-queue depth, bounded by `max_queue`.
    pub(crate) fn waiting(&self) -> u64 {
        let load = self.gauges.load.load(Ordering::Relaxed);
        load.saturating_sub(self.gauges.lanes_busy.load(Ordering::Relaxed))
    }

    pub(crate) fn queue_full(&self, max_queue: usize) -> bool {
        self.waiting() >= max_queue as u64
    }

    /// Hand a request to the worker; gives it back if the queue is gone
    /// (quarantined/draining) so the caller can re-dispatch.
    pub(crate) fn send(&self, req: GenRequest) -> std::result::Result<(), GenRequest> {
        match plock(&self.sender).as_ref() {
            Some(tx) => tx.send(req).map_err(|e| e.0),
            None => Err(req),
        }
    }

    fn set_sender(&self, tx: Sender<GenRequest>) {
        *plock(&self.sender) = Some(tx);
    }

    /// Drop the queue sender: the worker's `collect_batch` unparks on the
    /// last sender drop, so this is also the per-replica shutdown nudge.
    pub(crate) fn clear_sender(&self) {
        *plock(&self.sender) = None;
    }

    fn enter(&self, state: ReplicaState) {
        let mut st = plock(&self.status);
        st.state = state;
        st.since = Instant::now();
        if state == ReplicaState::Serving {
            st.backoff = self.backoff_initial;
        }
    }

    /// Eject from rotation and schedule the respawn: wait the current
    /// backoff, then double it (capped) for the next consecutive failure.
    pub(crate) fn enter_quarantine(&self) {
        let mut st = plock(&self.status);
        st.state = ReplicaState::Quarantined;
        st.since = Instant::now();
        st.wait = st.backoff;
        st.backoff = (st.backoff * 2).min(self.backoff_max);
    }

    /// Quarantined and past its backoff wait: the supervisor may respawn.
    /// A quarantined replica with a live sender is still *booting* (the
    /// worker enters Serving/Probing only after prewarm), so the sender
    /// doubles as the not-currently-spawning guard.
    pub(crate) fn respawn_due(&self) -> bool {
        if plock(&self.sender).is_some() {
            return false;
        }
        let st = plock(&self.status);
        st.state == ReplicaState::Quarantined && st.since.elapsed() >= st.wait
    }

    /// Probing and past the clean window: promote to full rotation.
    pub(crate) fn promote_due(&self, probe_window: Duration) -> bool {
        let st = plock(&self.status);
        st.state == ReplicaState::Probing && st.since.elapsed() >= probe_window
    }

    pub(crate) fn promote(&self) {
        self.enter(ReplicaState::Serving);
    }

    /// Join the previous worker thread, if any (respawn and shutdown).
    pub(crate) fn join_worker(&self) {
        let handle = plock(&self.thread).take();
        if let Some(t) = handle {
            let _ = t.join();
        }
    }

    /// Spawn the engine worker for this replica. `ready` is `Some` on the
    /// initial boot (the server blocks on the handshake); respawns pass
    /// `None` and report boot failures to stderr + the state machine.
    pub(crate) fn spawn_worker(
        self: Arc<Self>,
        ctx: ReplicaCtx,
        ready: Option<Sender<ReadyMsg>>,
    ) {
        let (tx, rx) = channel::<GenRequest>();
        self.set_sender(tx);
        let replica = self.clone();
        let spawned = thread::Builder::new()
            .name(format!("fi-engine-{}", self.id))
            .spawn(move || worker_main(replica, ctx, ready, rx));
        match spawned {
            Ok(handle) => {
                *plock(&self.thread) = Some(handle);
            }
            Err(e) => {
                // the dropped `ready` sender surfaces as a startup error
                // on the initial boot; respawns just stay quarantined
                eprintln!("flashinfer: spawn fi-engine-{} failed: {e}", self.id);
                self.clear_sender();
                self.enter_quarantine();
            }
        }
    }

    #[cfg(test)]
    pub(crate) fn test_rig(&self) -> Receiver<GenRequest> {
        let (tx, rx) = channel();
        self.set_sender(tx);
        self.enter(ReplicaState::Serving);
        rx
    }

    #[cfg(test)]
    pub(crate) fn test_enter(&self, state: ReplicaState) {
        self.enter(state);
    }

    #[cfg(test)]
    pub(crate) fn test_status(&self) -> (ReplicaState, Duration, Duration) {
        let st = plock(&self.status);
        (st.state, st.wait, st.backoff)
    }
}

/// Rolling-window panic budget for the replica supervisor: absorbing the
/// occasional panic keeps serving alive, but a crash loop should eject
/// the replica — quarantine in a fleet, the latched `/health` 503 when it
/// is the only engine.
pub(crate) struct RestartBudget {
    budget: usize,
    window: Duration,
    panics: VecDeque<Instant>,
}

impl RestartBudget {
    pub(crate) fn new(budget: usize, window: Duration) -> RestartBudget {
        RestartBudget { budget, window, panics: VecDeque::new() }
    }

    /// Record one panic; returns `false` once the window holds more than
    /// `budget` panics (the caller quarantines or latches).
    pub(crate) fn record(&mut self, now: Instant) -> bool {
        self.panics.push_back(now);
        while let Some(&t) = self.panics.front() {
            if now.duration_since(t) > self.window {
                self.panics.pop_front();
            } else {
                break;
            }
        }
        self.panics.len() <= self.budget
    }
}

// ---------------------------------------------------------------------------
// Scheduler: one running session, per-lane request slots, a waiting queue
// ---------------------------------------------------------------------------

/// One busy lane: the request it serves plus its rebased bookkeeping.
struct LaneSlot {
    req: GenRequest,
    /// Global batch position at admission (lane-local clock offset).
    admitted_pos: usize,
    /// Padded positions this lane generates (`lane_len(max_tokens)`).
    limit: usize,
    admitted_at: Instant,
    queue_ms: f64,
    /// Busy lanes (incl. this one) at admission.
    batch_size: usize,
    tokens: Vec<u32>,
    /// Per-lane checksum running sum over the first `max_tokens` positions.
    checksum_total: f64,
    /// Times this request was evicted into the session pager.
    evictions: u64,
}

/// A request swapped out of its lane under queue pressure: its serving
/// slot (tokens so far, reply channel, stats) plus the engine-side lane
/// checkpoint — hot in the pager slab or spilled to disk. An *aligned*
/// checkpoint waits until a session's clock reaches its suspension
/// position (`Session::restore`'s same-alignment rule); a *folded* one
/// resumes into the first free lane once the clock has generated at
/// least `lane_pos` positions (the rebased admission point must be
/// non-negative) and `span` positions still remain. The scheduling
/// fields are cached here so spilled entries answer gating questions
/// without a disk read.
struct EvictedLane {
    slot: LaneSlot,
    ckpt: CkptRef,
    /// Suspension position (aligned restores happen exactly here).
    pos: usize,
    folded: bool,
    /// Positions the lane had generated when suspended.
    lane_pos: usize,
    /// Positions the lane still has to generate.
    span: usize,
    /// Monotonic suspension order — the LRU key for the spill watermark
    /// (oldest resident suspension spills first).
    suspended_at: u64,
}

impl EvictedLane {
    /// Whether this checkpoint can still restore at a strictly later
    /// boundary of a session currently at `now` with schedule length
    /// `len`. Gates both lane reservation (don't evict a victim to admit
    /// queue work when the freed lane is owed to a checkpoint) and early
    /// session retirement.
    fn restorable_later(&self, now: usize, len: usize) -> bool {
        if self.folded {
            now.max(self.lane_pos) + self.span <= len
        } else {
            self.pos > now
        }
    }

    /// Whether this checkpoint can restore at the current boundary.
    fn restorable_now(&self, now: usize, len: usize) -> bool {
        if self.folded {
            now >= self.lane_pos && now + self.span <= len
        } else {
            self.pos == now
        }
    }
}

/// Continuous-admission scheduler: owns the running [`Session`], tracks
/// free lanes, and seeds queued requests into them at step boundaries.
/// One per replica — its queue, pager, and failure domain are private.
struct Scheduler<'e, 'rt> {
    engine: &'e Engine<'rt>,
    session: Option<Session<'e, 'rt>>,
    lanes: Vec<Option<LaneSlot>>,
    queue: VecDeque<GenRequest>,
    /// Session schedule length (padded `max_max_tokens`, clamped to L) —
    /// every admissible request fits a fresh session by construction.
    horizon: usize,
    /// `false` = legacy drain-then-refill (admission only at position 0).
    admit_mid_batch: bool,
    /// Session pager for suspended-lane checkpoints (`None` = paging off;
    /// forced off under drain-then-refill, which cannot re-seed lanes).
    pager: Option<Pager>,
    /// Requests evicted under queue pressure, waiting for a session whose
    /// clock reaches their checkpoint's suspension position (aligned) or
    /// for any free lane past their rebased admission point (folded).
    evicted: Vec<EvictedLane>,
    /// Prefer folded (position-independent) suspends for long-tail
    /// victims (`ServerConfig::fold`).
    fold: bool,
    /// Slab-usage percentage above which cold resident checkpoints spill
    /// to disk (when the pager has a spill dir).
    spill_watermark_pct: u64,
    /// Monotonic suspend counter (LRU order for the spill watermark).
    suspend_seq: u64,
    counters: Counters,
    inflight: Arc<AtomicU64>,
    gauges: Arc<ReplicaGauges>,
    replica_id: usize,
}

impl<'e, 'rt> Scheduler<'e, 'rt> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        engine: &'e Engine<'rt>,
        horizon: usize,
        admit_mid_batch: bool,
        pager: Option<Pager>,
        fold: bool,
        spill_watermark_pct: u64,
        counters: Counters,
        inflight: Arc<AtomicU64>,
        gauges: Arc<ReplicaGauges>,
        replica_id: usize,
    ) -> Scheduler<'e, 'rt> {
        let b = engine.runtime().dims.b;
        Scheduler {
            engine,
            session: None,
            lanes: (0..b).map(|_| None).collect(),
            queue: VecDeque::new(),
            horizon,
            admit_mid_batch,
            pager: if admit_mid_batch { pager } else { None },
            evicted: Vec::new(),
            fold,
            spill_watermark_pct,
            suspend_seq: 0,
            counters,
            inflight,
            gauges,
            replica_id,
        }
    }

    /// Intake: shipped continuations and durable spilled sessions rejoin
    /// as evicted entries (they already hold a checkpoint and must not be
    /// admitted as fresh lanes); everything else queues.
    fn enqueue(&mut self, mut req: GenRequest) {
        if let Some(rs) = req.resume.take() {
            self.accept_resume(req, rs);
            return;
        }
        let spilled_key = match (&req.session, self.pager.as_ref()) {
            (Some(key), Some(p)) if p.has_spilled(key) => Some(key.clone()),
            _ => None,
        };
        if let Some(key) = spilled_key {
            self.accept_spilled(req, &key);
            return;
        }
        self.queue.push_back(req);
    }

    /// A checkpoint shipped off a quarantined replica: rebuild its
    /// serving slot from the [`ResumeState`] and park it as an evicted
    /// entry; the resume phase re-seats it into the first eligible lane.
    fn accept_resume(&mut self, req: GenRequest, rs: ResumeState) {
        let Some(pager) = self.pager.as_mut() else {
            let _ = req
                .reply
                .send(Err("shipped checkpoint arrived at a replica without paging".to_string()));
            self.request_done();
            return;
        };
        match pager.deserialize(&rs.blob) {
            Ok((ckpt, _meta)) => {
                // the explicit ResumeState supersedes the blob's embedded
                // ServingMeta (they agree; the struct survives in-process)
                self.park_checkpoint(
                    req,
                    ckpt,
                    rs.tokens,
                    rs.checksum_total,
                    rs.queue_ms,
                    rs.evictions,
                    rs.batch_size,
                );
            }
            Err(e) => {
                let _ = req.reply.send(Err(format!("resume shipped checkpoint: {e:#}")));
                self.request_done();
            }
        }
    }

    /// A fresh request whose session key matches a spilled checkpoint
    /// (durable handle — the blob survived a replica death or a server
    /// restart): reload it and continue the rollout instead of starting
    /// a new one.
    fn accept_spilled(&mut self, req: GenRequest, key: &str) {
        let pager = self.pager.as_mut().unwrap();
        match pager.load_spilled(key) {
            Ok((ckpt, meta)) => {
                self.counters.lock().spill_reloads_total += 1;
                let meta = meta.unwrap_or(ServingMeta {
                    checksum_total: 0.0,
                    queue_ms: 0.0,
                    evictions: 0,
                    batch_size: 1,
                });
                let tokens = ckpt.tokens.clone().unwrap_or_default();
                self.park_checkpoint(
                    req,
                    ckpt,
                    tokens,
                    meta.checksum_total,
                    meta.queue_ms,
                    meta.evictions,
                    meta.batch_size,
                );
            }
            Err(e) => {
                let _ = req.reply.send(Err(format!("resume spilled session {key:?}: {e:#}")));
                self.request_done();
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn park_checkpoint(
        &mut self,
        req: GenRequest,
        ckpt: LaneCheckpoint,
        tokens: Vec<u32>,
        checksum_total: f64,
        queue_ms: f64,
        evictions: u64,
        batch_size: usize,
    ) {
        self.suspend_seq += 1;
        let slot = LaneSlot {
            admitted_pos: 0, // rebased by the restore
            limit: ckpt.lane_limit(),
            admitted_at: Instant::now(),
            queue_ms,
            batch_size,
            tokens,
            checksum_total,
            evictions,
            req,
        };
        self.evicted.push(EvictedLane {
            pos: ckpt.pos(),
            folded: ckpt.folded(),
            lane_pos: ckpt.lane_pos(),
            span: ckpt.span(),
            suspended_at: self.suspend_seq,
            slot,
            ckpt: CkptRef::Resident(ckpt),
        });
    }

    /// Nothing running, nothing waiting, nothing paged out: the worker
    /// may block.
    fn is_idle(&self) -> bool {
        self.session.is_none() && self.queue.is_empty() && self.evicted.is_empty()
    }

    fn busy_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    /// One request has left this replica with a reply: balance the global
    /// inflight gauge and this replica's load (the router's dispatch key).
    fn request_done(&self) {
        self.inflight.fetch_sub(1, Ordering::Relaxed);
        self.gauges.load.fetch_sub(1, Ordering::Relaxed);
    }

    /// Per-request sampling override → the admitted lane's `SamplerCfg`
    /// (`None` = keep the engine default for this lane).
    fn lane_sampler_cfg(&self, s: &SamplingParams) -> Option<SamplerCfg> {
        let opts: &EngineOpts = self.engine.opts();
        match self.engine.runtime().dims.variant {
            Variant::Synthetic => s.sigma.map(|sigma| SamplerCfg::Synthetic { sigma }),
            Variant::Hyena => {
                if s.temperature.is_none() && s.top_k.is_none() {
                    None
                } else {
                    Some(SamplerCfg::Lm {
                        temperature: s.temperature.unwrap_or(opts.temperature),
                        top_k: s.top_k.unwrap_or(opts.top_k),
                    })
                }
            }
        }
    }

    /// Restore evicted lanes that are eligible at the current boundary:
    /// aligned checkpoints when the clock matches their suspension
    /// position exactly, folded checkpoints into any free lane once the
    /// clock is at or past their lane position with enough schedule left.
    /// Runs *before* `evict_phase` so a just-evicted lane is never
    /// bounced straight back in the same boundary; returns the lanes it
    /// restored so `evict_phase` cannot re-evict them before they have
    /// stepped even once (the inverse bounce).
    fn resume_phase(&mut self) -> Vec<usize> {
        let mut restored = Vec::new();
        let Some(now) = self.session.as_ref().map(Session::steps_done) else { return restored };
        let len = now + self.session.as_ref().unwrap().remaining();
        let mut i = 0;
        while i < self.evicted.len() {
            if !self.evicted[i].restorable_now(now, len) {
                i += 1;
                continue;
            }
            let Some(lane) = (0..self.lanes.len()).find(|&l| self.lanes[l].is_none()) else {
                break; // no free lane right now: wait for a later boundary
            };
            let e = self.evicted.remove(i);
            let EvictedLane { mut slot, ckpt, lane_pos, .. } = e;
            let was_spilled = ckpt.is_spilled();
            // transparently reload a spilled checkpoint; the slot already
            // carries the serving progress, so the blob's meta is unused
            let ckpt = match self.pager.as_mut().unwrap().fetch(ckpt) {
                Ok((c, _meta)) => c,
                Err(e) => {
                    let _ = slot.req.reply.send(Err(format!("resume: reload spill: {e:#}")));
                    self.request_done();
                    continue;
                }
            };
            if was_spilled {
                self.counters.lock().spill_reloads_total += 1;
            }
            let res = self
                .session
                .as_mut()
                .unwrap()
                .restore(lane, ckpt, self.pager.as_mut().unwrap());
            match res {
                Ok(()) => {
                    // rebase the lane-local clock: the folded restore
                    // re-admitted the lane at `now - lane_pos` (a no-op
                    // for aligned restores, where now == ckpt.pos)
                    slot.admitted_pos = now - lane_pos;
                    self.lanes[lane] = Some(slot);
                    restored.push(lane);
                    self.counters.lock().resumes_total += 1;
                }
                Err(e) => {
                    // the checkpoint is gone (blocks already released):
                    // fail exactly this request and keep serving
                    let _ = slot.req.reply.send(Err(format!("resume: {e:#}")));
                    self.request_done();
                }
            }
        }
        restored
    }

    /// Under queue pressure — a waiting request, no free lane — suspend
    /// the busy lane with the most remaining schedule into the pager so
    /// the waiting request can be admitted now. Eviction only pays off
    /// when the incoming request finishes before the victim would have,
    /// so shorter-than-victim requests are the only trigger. Lanes in
    /// `protected` (restored this very boundary) are never victims, and
    /// already-evicted requests are preferred last, so a paged-out
    /// request always makes forward progress between evictions instead
    /// of thrashing under sustained pressure.
    fn evict_phase(&mut self, protected: &[usize]) {
        if self.pager.is_none() || self.session.is_none() {
            return;
        }
        let sess = self.session.as_mut().unwrap();
        let now = sess.steps_done();
        if self.queue.is_empty() || self.lanes.iter().any(|l| l.is_none()) {
            return;
        }
        let remaining = sess.remaining();
        let len = now + remaining;
        // lanes freed now are reserved for checkpoints that can still
        // restore later in this session — evicting would not admit anyone
        // (a restorable checkpoint takes the freed lane first)
        if self.evicted.iter().any(|e| e.restorable_later(now, len)) {
            return;
        }
        let Some(need) = self
            .queue
            .iter()
            .map(|r| lane_len(r.max_tokens, self.horizon))
            .find(|&n| n <= remaining)
        else {
            return;
        };
        let Some(lane) = (0..self.lanes.len())
            .filter(|&l| self.lanes[l].is_some() && !protected.contains(&l))
            .max_by_key(|&l| {
                let evictions = self.lanes[l].as_ref().unwrap().evictions;
                let left = sess.lane_limit(l).saturating_sub(sess.lane_pos(l));
                // fewest prior evictions first, then most remaining
                (std::cmp::Reverse(evictions), left)
            })
        else {
            return;
        };
        let victim_remaining = sess.lane_limit(lane).saturating_sub(sess.lane_pos(lane));
        if victim_remaining <= need {
            return;
        }
        // Fold vs aligned: a folded suspend costs the history-vs-future
        // convolution but resumes anywhere; aligned is free but must wait
        // for a session to pass through this exact position again. Fold
        // long-tail victims (remaining at least half of what is left of
        // this session — they would otherwise park until a next session
        // happens to reach `now`); keep aligned for short tails that a
        // later boundary of this very session can re-seat. A fold that
        // cannot run (half-store wrap) falls back to aligned.
        let pager = self.pager.as_mut().unwrap();
        let res = if self.fold && victim_remaining * 2 >= remaining {
            sess.suspend_folded(lane, pager).or_else(|_| sess.suspend(lane, pager))
        } else {
            sess.suspend(lane, pager)
        };
        // a full pager (or any transient failure) leaves every lane
        // untouched — the waiting request simply keeps waiting
        if let Ok(ckpt) = res {
            let mut slot = self.lanes[lane].take().unwrap();
            slot.evictions += 1;
            self.suspend_seq += 1;
            let mut c = self.counters.lock();
            c.evictions_total += 1;
            if ckpt.folded() {
                c.folds_total += 1;
            }
            drop(c);
            self.evicted.push(EvictedLane {
                pos: ckpt.pos(),
                folded: ckpt.folded(),
                lane_pos: ckpt.lane_pos(),
                span: ckpt.span(),
                suspended_at: self.suspend_seq,
                slot,
                ckpt: CkptRef::Resident(ckpt),
            });
        }
    }

    /// Spill tier: when slab usage crosses the watermark, serialize the
    /// least-recently-suspended resident checkpoints to the spill dir and
    /// free their blocks. The blob carries a [`ServingMeta`] trailer so
    /// the serving-side accumulators survive even a process restart (the
    /// durable-handle path rebuilds the slot from it). Spill errors are
    /// soft: the checkpoint stays resident and we stop for this boundary.
    fn spill_phase(&mut self) {
        let Some(p) = self.pager.as_ref() else { return };
        if !p.spill_enabled() {
            return;
        }
        loop {
            let p = self.pager.as_ref().unwrap();
            let used = p.total_blocks() - p.free_blocks();
            if used * 100 <= self.spill_watermark_pct as usize * p.total_blocks() {
                return;
            }
            let Some(idx) = self
                .evicted
                .iter()
                .enumerate()
                .filter(|(_, e)| !e.ckpt.is_spilled())
                .min_by_key(|(_, e)| e.suspended_at)
                .map(|(i, _)| i)
            else {
                return;
            };
            let mut e = self.evicted.remove(idx);
            let key = e
                .slot
                .req
                .session
                .clone()
                .unwrap_or_else(|| format!("r{}.{}", self.replica_id, e.suspended_at));
            let CkptRef::Resident(ckpt) = e.ckpt else { unreachable!("filtered on resident") };
            let meta = ServingMeta {
                checksum_total: e.slot.checksum_total,
                queue_ms: e.slot.queue_ms,
                evictions: e.slot.evictions,
                batch_size: e.slot.batch_size,
            };
            let pager = self.pager.as_mut().unwrap();
            let blob = pager.serialize(&ckpt, Some(&meta));
            match pager.spill_blob(&key, &blob) {
                Ok(()) => {
                    pager.discard(ckpt);
                    e.ckpt = CkptRef::Spilled(key);
                    self.evicted.push(e);
                    self.counters.lock().spills_total += 1;
                }
                Err(_) => {
                    e.ckpt = CkptRef::Resident(ckpt);
                    self.evicted.push(e);
                    return;
                }
            }
        }
    }

    /// Open a session if needed, then admit queued requests onto free
    /// lanes (this is the step boundary: `tick` calls it before `step`).
    /// Order matters: resume (exact-position restores) → evict (free a
    /// lane under pressure) → fresh admissions (minus lanes reserved for
    /// checkpoints waiting later in this session's schedule).
    fn admit_phase(&mut self) {
        if self.session.is_none() && !(self.queue.is_empty() && self.evicted.is_empty()) {
            // with mid-batch admission, open at the full horizon so later
            // arrivals always have schedule headroom (the cost is one
            // horizon-sized store allocation per session open); under
            // drain-then-refill nothing joins later, so size the session
            // to the batch it will actually run — the first B queued
            // requests — like the legacy collector did
            let len = if self.admit_mid_batch {
                self.horizon
            } else {
                self.queue
                    .iter()
                    .take(self.lanes.len())
                    .map(|r| lane_len(r.max_tokens, self.horizon))
                    .max()
                    .unwrap_or(1)
            };
            match self.engine.session(len) {
                Ok(sess) => {
                    self.session = Some(sess);
                    for slot in &mut self.lanes {
                        *slot = None;
                    }
                    self.counters.lock().sessions_started += 1;
                }
                Err(e) => {
                    // a session that cannot even open would error forever:
                    // fail the whole queue (and any paged-out requests,
                    // which need a session to ever resume) instead of
                    // spinning on it
                    self.fail_queued(&format!("open session: {e:#}"));
                    self.fail_evicted(&format!("open session: {e:#}"));
                    return;
                }
            }
        }
        let (mid_batch, remaining, now) = match self.session.as_ref() {
            Some(sess) => (sess.steps_done() > 0, sess.remaining(), sess.steps_done()),
            None => return,
        };
        if mid_batch && !self.admit_mid_batch {
            return;
        }
        let restored = self.resume_phase();
        self.evict_phase(&restored);
        self.spill_phase();
        // lanes kept free for checkpoints that must restore later in this
        // session's schedule (strictly later: a checkpoint restorable at
        // the current position either just resumed or is lane-starved)
        let len = now + remaining;
        let reserved = self.evicted.iter().filter(|e| e.restorable_later(now, len)).count();
        for lane in 0..self.lanes.len() {
            if self.lanes[lane].is_some() {
                continue;
            }
            let free_now = self.lanes.iter().filter(|l| l.is_none()).count();
            if free_now <= reserved {
                break;
            }
            // first queued request whose padded schedule fits what's left
            let Some(qi) = self
                .queue
                .iter()
                .position(|r| lane_len(r.max_tokens, self.horizon) <= remaining)
            else {
                break;
            };
            let mut req = self.queue.remove(qi).unwrap();
            let limit = lane_len(req.max_tokens, self.horizon);
            // prompt seed: the HTTP layer validated the flat [M, span, D]
            // shape, so the span falls straight out of the length
            let dims = self.engine.runtime().dims;
            let m = dims.g / dims.b;
            let pending_seed =
                req.prompt.take().map(|fut| {
                    let span = fut.len() / (m * dims.d);
                    (fut, span)
                });
            let init = LaneInit {
                limit,
                sampler_cfg: self.lane_sampler_cfg(&req.sampling),
                seed: req.sampling.seed,
                pending_seed,
            };
            let admitted_pos = {
                let sess = self.session.as_mut().unwrap();
                match sess.admit(lane, init) {
                    Ok(()) => sess.steps_done(),
                    Err(e) => {
                        // fail exactly this request (never silently drop
                        // it or leak its inflight slot) and keep serving
                        let _ = req.reply.send(Err(format!("admit: {e:#}")));
                        self.request_done();
                        continue;
                    }
                }
            };
            let queue_ms = req.enqueued.elapsed().as_secs_f64() * 1e3;
            let batch_size = self.lanes.iter().filter(|l| l.is_some()).count() + 1;
            self.lanes[lane] = Some(LaneSlot {
                req,
                admitted_pos,
                limit,
                admitted_at: Instant::now(),
                queue_ms,
                batch_size,
                tokens: Vec::new(),
                checksum_total: 0.0,
                evictions: 0,
            });
            let mut c = self.counters.lock();
            c.admissions_total += 1;
            if mid_batch {
                c.admissions_mid_batch += 1;
            }
            c.admission_latency.record_ns(queue_ms * 1e6);
        }
    }

    /// Fail every *queued* (not yet admitted) request.
    fn fail_queued(&mut self, msg: &str) {
        while let Some(req) = self.queue.pop_front() {
            let _ = req.reply.send(Err(msg.to_string()));
            self.request_done();
        }
    }

    /// Hand every *queued* (never-admitted, zero tokens produced) request
    /// back for failover instead of failing it: re-running one of these
    /// from scratch on another replica is bit-identical by construction.
    /// The global inflight count stays — the requests are still alive —
    /// but this replica's load drops by the batch.
    fn drain_for_failover(&mut self) -> Vec<GenRequest> {
        let reqs: Vec<GenRequest> = self.queue.drain(..).collect();
        self.gauges.load.fetch_sub(reqs.len() as u64, Ordering::Relaxed);
        reqs
    }

    /// Fail every evicted (paged-out) request and release its checkpoint.
    /// Used when no session can ever resume them again: open-session
    /// failure and shutdown. (Quarantine no longer lands here — resident
    /// and spilled checkpoints are *shipped* to a healthy replica via
    /// [`Scheduler::ship_evicted`] instead.)
    fn fail_evicted(&mut self, msg: &str) {
        for e in self.evicted.drain(..).collect::<Vec<_>>() {
            if let Some(p) = self.pager.as_mut() {
                p.discard_ref(e.ckpt);
            }
            let _ = e.slot.req.reply.send(Err(msg.to_string()));
            self.request_done();
        }
    }

    /// Quarantine path: turn every evicted entry — slab-resident or
    /// spilled — into a shippable request carrying its serialized
    /// checkpoint plus serving progress, for the supervisor to re-home on
    /// a healthy replica. This amends the retried-iff-zero-tokens rule:
    /// a request is re-dispatched if it never produced a token **or** it
    /// carries its checkpoint (the continuation is bit-identical either
    /// way). Like `drain_for_failover`, shipped requests stay inflight
    /// globally but leave this replica's load.
    fn ship_evicted(&mut self) -> Vec<GenRequest> {
        let mut out = Vec::new();
        if self.pager.is_none() {
            return out;
        }
        let mut shipped = 0u64;
        for e in self.evicted.drain(..).collect::<Vec<_>>() {
            let EvictedLane { mut slot, ckpt, .. } = e;
            let pager = self.pager.as_mut().unwrap();
            let blob = match ckpt {
                CkptRef::Resident(c) => {
                    let meta = ServingMeta {
                        checksum_total: slot.checksum_total,
                        queue_ms: slot.queue_ms,
                        evictions: slot.evictions,
                        batch_size: slot.batch_size,
                    };
                    let b = pager.serialize(&c, Some(&meta));
                    pager.discard(c);
                    Ok(b)
                }
                CkptRef::Spilled(key) => pager.take_spilled_blob(&key),
            };
            match blob {
                Ok(blob) => {
                    slot.req.resume = Some(ResumeState {
                        blob,
                        tokens: std::mem::take(&mut slot.tokens),
                        checksum_total: slot.checksum_total,
                        queue_ms: slot.queue_ms,
                        // shipping is one more checkpoint/resume cycle
                        evictions: slot.evictions + 1,
                        batch_size: slot.batch_size,
                    });
                    self.gauges.load.fetch_sub(1, Ordering::Relaxed);
                    shipped += 1;
                    out.push(slot.req);
                }
                Err(err) => {
                    let _ = slot
                        .req
                        .reply
                        .send(Err(format!("replica quarantined: ship checkpoint: {err:#}")));
                    self.request_done();
                }
            }
        }
        self.counters.lock().checkpoints_shipped_total += shipped;
        out
    }

    /// Route one step's outputs to the busy lanes; complete any lane that
    /// reached its padded schedule.
    fn deliver(&mut self, step: &StepOutput) {
        for lane in 0..self.lanes.len() {
            let finished = {
                let Some(slot) = self.lanes[lane].as_mut() else { continue };
                let local = step.pos - slot.admitted_pos;
                let checksum = step.lane_checksums.get(lane).copied().unwrap_or(0.0);
                if let Some(toks) = &step.tokens {
                    slot.tokens.push(toks[lane]);
                }
                // the lane generates min(max_tokens, limit) useful
                // positions: with max_max_tokens clamped to L at startup
                // the two are equal, but stay defensive so a request
                // whose padded schedule got capped is never promised
                // (or counted as) more positions than the lane runs
                let wanted = slot.req.max_tokens.min(slot.limit);
                if local <= wanted {
                    slot.checksum_total += checksum as f64;
                    if let Some(tx) = &slot.req.stream {
                        let token = step.tokens.as_ref().map(|t| t[lane]);
                        if tx.send(StreamEvent { pos: local, token, checksum }).is_err() {
                            // receiver dropped: the streaming client hung
                            // up — flag the lane so `cancel_phase` frees
                            // it at the next step boundary
                            slot.req.cancel.store(true, Ordering::Relaxed);
                        }
                    }
                }
                if local >= wanted {
                    slot.req.stream = None; // early stop: close the event stream
                }
                local >= slot.limit
            };
            if finished {
                self.finish_lane(lane);
            }
        }
    }

    fn finish_lane(&mut self, lane: usize) {
        let Some(slot) = self.lanes[lane].take() else { return };
        let tokens = if slot.tokens.is_empty() {
            None
        } else {
            Some(slot.tokens[..slot.req.max_tokens.min(slot.tokens.len())].to_vec())
        };
        let result = LaneResult {
            tokens,
            steps: slot.limit,
            checksum_total: slot.checksum_total,
            admitted_pos: slot.admitted_pos,
            queue_ms: slot.queue_ms,
            gen_ms: slot.admitted_at.elapsed().as_secs_f64() * 1e3,
            batch_size: slot.batch_size,
            evictions: slot.evictions,
            replica: self.replica_id,
        };
        let _ = slot.req.reply.send(Ok(result));
        self.request_done();
    }

    /// Fail exactly one busy lane with a structured error; the lane frees
    /// at this step boundary and can be re-admitted immediately.
    fn fail_lane(&mut self, lane: usize, msg: &str) {
        let Some(slot) = self.lanes[lane].take() else { return };
        let _ = slot.req.reply.send(Err(msg.to_string()));
        self.request_done();
        self.counters.lock().lanes_failed_total += 1;
    }

    /// Fail every busy lane (engine error or panic): each admitted request
    /// gets the error; queued requests stay queued for the next session.
    /// Dropping the session here is the panic-safe teardown path: AsyncTau's
    /// Drop drains in-flight tile jobs swallowing join errors, and the
    /// worker-side readiness guard has already balanced `end_write` on any
    /// panicking job, so the take() can neither hang nor re-panic. Pager
    /// checkpoints live *outside* the session and survive untouched.
    fn fail_busy(&mut self, msg: &str) {
        for lane in 0..self.lanes.len() {
            self.fail_lane(lane, msg);
        }
        self.session = None;
    }

    /// Step-boundary sweep for requests that should stop early: the client
    /// hung up (cancel flag) or the deadline passed. Busy lanes are failed
    /// and freed for re-admission; queued and paged-out requests are
    /// dropped before they ever (re)occupy a lane.
    fn cancel_phase(&mut self) {
        let now = Instant::now();
        for lane in 0..self.lanes.len() {
            let Some(c) = self.lanes[lane].as_ref().and_then(|s| check_cancel(&s.req, now))
            else {
                continue;
            };
            self.note_cancel(&c);
            self.fail_lane(lane, c.message());
        }
        let mut i = 0;
        while i < self.queue.len() {
            match check_cancel(&self.queue[i], now) {
                None => i += 1,
                Some(c) => {
                    let req = self.queue.remove(i).unwrap();
                    self.note_cancel(&c);
                    let _ = req.reply.send(Err(c.message().to_string()));
                    self.request_done();
                }
            }
        }
        let mut i = 0;
        while i < self.evicted.len() {
            match check_cancel(&self.evicted[i].slot.req, now) {
                None => i += 1,
                Some(c) => {
                    let e = self.evicted.remove(i);
                    if let Some(p) = self.pager.as_mut() {
                        p.discard_ref(e.ckpt);
                    }
                    self.note_cancel(&c);
                    let _ = e.slot.req.reply.send(Err(c.message().to_string()));
                    self.request_done();
                }
            }
        }
    }

    fn note_cancel(&mut self, c: &Cancel) {
        let mut g = self.counters.lock();
        match c {
            Cancel::Deadline => g.requests_deadline_exceeded += 1,
            Cancel::Disconnected => g.clients_disconnected += 1,
        }
    }

    /// A queued request could be admitted into the current session at the
    /// next step boundary: something queued fits the remaining schedule
    /// AND this session may still take admissions (mid-batch admissions
    /// are disabled under drain-then-refill once the session has moved).
    fn queue_admissible(&self) -> bool {
        let Some(sess) = self.session.as_ref() else { return !self.queue.is_empty() };
        if sess.steps_done() > 0 && !self.admit_mid_batch {
            return false;
        }
        let remaining = sess.remaining();
        self.queue.iter().any(|r| lane_len(r.max_tokens, self.horizon) <= remaining)
    }

    /// A checkpoint can still be restored by the *current* session —
    /// aligned: its suspension position has not been stepped past;
    /// folded: its span still fits the remaining schedule (stepping keeps
    /// moving the clock toward / past its rebased admission point). Keeps
    /// an otherwise-idle session alive until the restore happens.
    fn resumes_reachable(&self) -> bool {
        let Some(sess) = self.session.as_ref() else { return false };
        let now = sess.steps_done();
        let len = now + sess.remaining();
        self.evicted
            .iter()
            .any(|e| e.restorable_now(now, len) || e.restorable_later(now, len))
    }

    fn publish_gauges(&self) {
        self.gauges.queue_depth.store(self.queue.len() as u64, Ordering::Relaxed);
        self.gauges.lanes_busy.store(self.busy_lanes() as u64, Ordering::Relaxed);
        self.gauges.pager_resident_values.store(
            self.pager.as_ref().map_or(0, |p| p.resident_values() as u64),
            Ordering::Relaxed,
        );
    }

    /// One step boundary: cancel, admit, advance one position, deliver,
    /// and retire the session when it has nothing left to do.
    fn tick(&mut self) -> Result<()> {
        self.cancel_phase();
        self.admit_phase();
        if self.session.is_some() {
            let step = self.session.as_mut().unwrap().step()?;
            self.deliver(&step);
            // retire: schedule exhausted, or every lane idle with nothing
            // admissible left (a fresh session can always fit the queue)
            // and no checkpoint still restorable at a later position of
            // this session — an idle session otherwise keeps stepping
            // toward the restore point (bounded by the horizon)
            let done = step.done;
            let parked = self.busy_lanes() == 0
                && !self.queue_admissible()
                && !self.resumes_reachable();
            if done || parked {
                if let Some(sess) = self.session.take() {
                    // finish() drains in-flight async tiles before the
                    // store drops — required even for an early retire
                    let _ = sess.finish();
                    self.counters.lock().batches_run += 1;
                }
                // a `done` session cannot have stragglers (admission
                // guarantees limit <= remaining), but stay defensive
                self.fail_busy("session retired with the lane still running");
            }
        }
        self.publish_gauges();
        Ok(())
    }
}

/// Why a request is being cancelled at a step boundary.
enum Cancel {
    Deadline,
    Disconnected,
}

impl Cancel {
    fn message(&self) -> &'static str {
        match self {
            Cancel::Deadline => "deadline exceeded",
            Cancel::Disconnected => "client disconnected",
        }
    }
}

/// Deadline first: a request that is both late *and* abandoned reports
/// the deadline (the deterministic one of the two).
fn check_cancel(req: &GenRequest, now: Instant) -> Option<Cancel> {
    if req.deadline.is_some_and(|d| now >= d) {
        return Some(Cancel::Deadline);
    }
    if req.cancel.load(Ordering::Relaxed) {
        return Some(Cancel::Disconnected);
    }
    None
}

/// Boot failure: report it (over the ready channel on the initial boot,
/// to stderr on respawns) and leave the replica quarantined so the
/// supervisor retries with backoff.
fn report_boot_failure(replica: &Replica, ready: &Option<Sender<ReadyMsg>>, msg: String) {
    match ready {
        Some(tx) => {
            let _ = tx.send(Err(msg));
        }
        None => eprintln!("flashinfer: replica {} respawn failed: {msg}", replica.id),
    }
    replica.clear_sender();
    replica.enter_quarantine();
}

/// The engine worker body: boot (load → init → prewarm → handshake),
/// then PR 7's supervised scheduler loop with the fleet-mode quarantine
/// exit grafted onto the budget-exhausted path.
pub(crate) fn worker_main(
    replica: Arc<Replica>,
    ctx: ReplicaCtx,
    ready: Option<Sender<ReadyMsg>>,
    req_rx: Receiver<GenRequest>,
) {
    let initial = ready.is_some();
    // chaos handle for fleet tests: fail/delay this replica's boot
    if let Err(e) = crate::util::faultpoint::check("replica_spawn") {
        report_boot_failure(&replica, &ready, format!("{e:#}"));
        return;
    }
    let rt = match Runtime::load(&ctx.cfg.artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            report_boot_failure(&replica, &ready, format!("load runtime: {e:#}"));
            return;
        }
    };
    let mut engine = match Engine::new(&rt, ctx.cfg.engine.clone()) {
        Ok(e) => e,
        Err(e) => {
            report_boot_failure(&replica, &ready, format!("init engine: {e:#}"));
            return;
        }
    };
    let dims = rt.dims;
    let mut ecfg = ctx.cfg.clone();
    // A request with max_tokens in (L, max_max_tokens] would get a lane
    // schedule capped at L (`lane_len`) yet be accepted — and previously
    // *accounted* — as max_tokens positions. Clamp the advertised ceiling
    // to what a lane can actually run, once per boot, loudly.
    if ecfg.max_max_tokens > dims.l {
        if initial {
            eprintln!(
                "flashinfer: max_max_tokens {} exceeds the schedule ceiling L={}; \
                 clamping (a lane can generate at most L positions)",
                ecfg.max_max_tokens, dims.l
            );
        }
        ecfg.max_max_tokens = dims.l;
    }
    // Cold-start: derive every per-U rho structure (spectra + PJRT tau
    // executables) for the largest session a request can trigger, so the
    // first request's measured gen_ms contains no one-time derivation
    // cost (and a respawned replica re-probes the same path before it
    // rejoins rotation).
    let horizon = lane_len(ecfg.max_max_tokens, dims.l);
    if let Err(e) = engine.prewarm(horizon) {
        report_boot_failure(&replica, &ready, format!("prewarm engine: {e:#}"));
        return;
    }
    if let Some(tx) = &ready {
        let info = info_json(&ecfg, &ecfg.engine, &rt);
        let _ = tx.send(Ok((info, ecfg.max_max_tokens)));
    }
    // initial boots go straight into rotation; respawns serve a probe
    // window first and are promoted by the supervisor
    replica.enter(if initial { ReplicaState::Serving } else { ReplicaState::Probing });

    let engine = engine; // freeze: the scheduler borrows it
    let fleet = ctx.cfg.replicas.max(1);
    let window = Duration::from_millis(ecfg.batch_window_ms);
    let pager = if ecfg.paging && ecfg.continuous_admission {
        let mut p = engine.make_pager(ecfg.pager_capacity_mb);
        if !ecfg.spill_dir.is_empty() {
            // per-replica subdir: replicas must not boot-scan (and race
            // over) each other's spilled sessions; a respawn of the same
            // id reclaims exactly its own
            let dir = std::path::Path::new(&ecfg.spill_dir).join(format!("replica-{}", replica.id));
            match p.set_spill_dir(&dir) {
                Ok(found) if found > 0 => eprintln!(
                    "flashinfer: replica {}: spill dir holds {found} spilled session(s); \
                     serving them as durable handles",
                    replica.id
                ),
                Ok(_) => {}
                Err(e) => eprintln!(
                    "flashinfer: replica {}: spill dir {} unavailable ({e:#}); \
                     spilling disabled",
                    replica.id,
                    dir.display()
                ),
            }
        }
        Some(p)
    } else {
        None
    };
    let mut sched = Scheduler::new(
        &engine,
        horizon,
        ecfg.continuous_admission,
        pager,
        ecfg.fold,
        ecfg.spill_watermark_pct,
        ctx.counters.clone(),
        ctx.inflight.clone(),
        replica.gauges.clone(),
        replica.id,
    );
    let mut budget =
        RestartBudget::new(ecfg.restart_budget, Duration::from_secs(ecfg.restart_window_s));
    let mut disconnected = false;
    let mut quarantine = false;
    loop {
        if ctx.draining.load(Ordering::Relaxed) {
            // graceful shutdown: stragglers get a retryable 503 instead
            // of hanging past the drain deadline
            sched.fail_busy("shutting down, retry later");
            sched.fail_queued("shutting down, retry later");
            sched.fail_evicted("shutting down, retry later");
            break;
        }
        if sched.is_idle() {
            if disconnected {
                break;
            }
            // block for the first request; drain co-arrivals within the
            // window so they share one session
            match collect_batch(&req_rx, dims.b, window) {
                Some(batch) => {
                    for r in batch {
                        sched.enqueue(r);
                    }
                }
                None => {
                    // all senders gone: re-check the drain flag at the
                    // loop top before exiting
                    disconnected = true;
                    continue;
                }
            }
        } else {
            // step boundary: pick up new arrivals non-blocking
            loop {
                match req_rx.try_recv() {
                    Ok(r) => sched.enqueue(r),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }
        }
        // One supervised step boundary. On panic every busy lane gets a
        // structured error and the (possibly inconsistent) Session is
        // dropped via the panic-safe drain, so no broken invariant
        // survives into the next iteration; pager checkpoints are
        // preserved and a fresh session opens on the next admissible
        // tick. A panic that unwound *on a pool worker* surfaces here as
        // a step error at the fence ("... panicked ...") — it tore the
        // session down the same way, so it spends restart budget the
        // same way.
        let mut panicked: Option<String> = None;
        match catch_unwind(AssertUnwindSafe(|| sched.tick())) {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                let msg = format!("{e:#}");
                let surfaced_panic = msg.contains("panicked");
                sched.fail_busy(&format!("generate: {msg}"));
                if surfaced_panic {
                    panicked = Some(msg);
                }
            }
            Err(payload) => {
                let msg = payload_text(payload.as_ref());
                sched.fail_busy(&format!("engine panicked: {msg}"));
                panicked = Some(msg);
            }
        }
        if let Some(msg) = panicked {
            eprintln!("flashinfer: replica {} engine step panicked: {msg}", replica.id);
            ctx.counters.lock().engine_restarts_total += 1;
            replica.gauges.engine_restarts.fetch_add(1, Ordering::Relaxed);
            if !budget.record(Instant::now()) {
                if fleet > 1 {
                    eprintln!(
                        "flashinfer: replica {} restart budget exhausted (> {} panics \
                         within {}s); quarantining",
                        replica.id, ecfg.restart_budget, ecfg.restart_window_s
                    );
                    quarantine = true;
                    break;
                }
                // fleet of one: the PR 7 terminal latch — keep serving
                // degraded, let a load balancer drain us
                eprintln!(
                    "flashinfer: engine restart budget exhausted (> {} panics within \
                     {}s); marking unhealthy",
                    ecfg.restart_budget, ecfg.restart_window_s
                );
                ctx.counters.lock().healthy = 0;
                ctx.healthy.store(false, Ordering::Relaxed);
            }
        }
    }
    if quarantine {
        // eject from rotation first so the router stops dispatching here,
        // then hand work back for failover: queued requests are zero-token
        // and re-run from scratch; evicted (suspended) requests *ship* —
        // each leaves with its serialized checkpoint attached, and the
        // receiving replica continues the rollout bit-identically instead
        // of this replica failing it mid-flight
        replica.clear_sender();
        replica.enter_quarantine();
        for req in sched.ship_evicted() {
            if let Err(send_err) = ctx.failback.send(req) {
                fail_request(send_err.0, "shutting down, retry later", &ctx);
            }
        }
        for req in sched.drain_for_failover() {
            if let Err(send_err) = ctx.failback.send(req) {
                fail_request(send_err.0, "shutting down, retry later", &ctx);
            }
        }
        // requests still sitting in the channel never reached the
        // scheduler: they are zero-token by construction — fail them over
        // too (each was load-counted at dispatch)
        while let Ok(req) = req_rx.try_recv() {
            replica.gauges.load.fetch_sub(1, Ordering::Relaxed);
            if let Err(send_err) = ctx.failback.send(req) {
                fail_request(send_err.0, "shutting down, retry later", &ctx);
            }
        }
    } else {
        // clean exit (drain/shutdown): nothing to fail over — anything
        // left in the channel is a straggler past the drain deadline
        while let Ok(req) = req_rx.try_recv() {
            replica.gauges.load.fetch_sub(1, Ordering::Relaxed);
            fail_request(req, "shutting down, retry later", &ctx);
        }
    }
    // zero the stale gauges so /metrics and the router's least-loaded
    // key don't keep reporting a dead worker's last published state
    sched.publish_gauges();
}

/// Fail one request that never reached a scheduler (channel stragglers,
/// failback with the supervisor gone): reply + balance the inflight
/// gauge. `requests_failed` is counted at the HTTP reply layer.
pub(crate) fn fail_request(req: GenRequest, msg: &str, ctx: &ReplicaCtx) {
    let _ = req.reply.send(Err(msg.to_string()));
    ctx.inflight.fetch_sub(1, Ordering::Relaxed);
}

/// The `/v1/info` document (model dims + engine opts + serving config).
pub(crate) fn info_json(cfg: &ServerConfig, eng: &EngineOpts, rt: &Runtime) -> Json {
    let d = rt.dims;
    Json::from_pairs(vec![
        ("variant", Json::Str(d.variant.as_str().into())),
        ("M", Json::Num(d.m as f64)),
        ("D", Json::Num(d.d as f64)),
        ("L", Json::Num(d.l as f64)),
        ("B", Json::Num(d.b as f64)),
        ("V", Json::Num(d.v as f64)),
        ("method", Json::Str(eng.method.as_str().into())),
        ("tau", Json::Str(eng.tau.as_str().into())),
        ("async_mixer", Json::Bool(eng.async_mixer)),
        ("split_min_u", Json::Num(eng.split_min_u as f64)),
        ("mixer_workers", Json::Num(eng.mixer_workers as f64)),
        ("continuous_admission", Json::Bool(cfg.continuous_admission)),
        ("max_queue", Json::Num(cfg.max_queue as f64)),
        ("paging", Json::Bool(cfg.paging && cfg.continuous_admission)),
        ("pager_capacity_mb", Json::Num(cfg.pager_capacity_mb as f64)),
        ("fold", Json::Bool(cfg.fold)),
        ("spill_dir", Json::Str(cfg.spill_dir.clone())),
        ("spill_watermark_pct", Json::Num(cfg.spill_watermark_pct as f64)),
        ("keepalive_max_requests", Json::Num(cfg.keepalive_max_requests as f64)),
        ("max_max_tokens", Json::Num(cfg.max_max_tokens as f64)),
        ("deadline_ms", Json::Num(cfg.deadline_ms as f64)),
        ("max_connections", Json::Num(cfg.max_connections as f64)),
        ("restart_budget", Json::Num(cfg.restart_budget as f64)),
        ("restart_window_s", Json::Num(cfg.restart_window_s as f64)),
        ("drain_deadline_ms", Json::Num(cfg.drain_deadline_ms as f64)),
        ("replicas", Json::Num(cfg.replicas.max(1) as f64)),
        ("failover_retries", Json::Num(cfg.failover_retries as f64)),
        ("quarantine_backoff_ms", Json::Num(cfg.quarantine_backoff_ms as f64)),
        ("quarantine_backoff_max_ms", Json::Num(cfg.quarantine_backoff_max_ms as f64)),
        ("probe_window_ms", Json::Num(cfg.probe_window_ms as f64)),
        ("artifacts", Json::Str(cfg.artifacts.display().to_string())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn restart_budget_rolls_its_window() {
        let mut b = RestartBudget::new(2, Duration::from_secs(60));
        let t0 = Instant::now();
        assert!(b.record(t0));
        assert!(b.record(t0 + Duration::from_secs(1)));
        // third panic inside the window exceeds budget=2
        assert!(!b.record(t0 + Duration::from_secs(2)));
        // far enough out, the old panics age off and the budget recovers
        assert!(b.record(t0 + Duration::from_secs(120)));
    }

    #[test]
    fn quarantine_backoff_doubles_and_caps() {
        let cfg = ServerConfig {
            quarantine_backoff_ms: 100,
            quarantine_backoff_max_ms: 350,
            ..Default::default()
        };
        let r = Replica::new(0, &cfg);
        // pre-boot: quarantined with a zero wait (first boot is immediate)
        let (state, wait, _) = r.test_status();
        assert_eq!(state, ReplicaState::Quarantined);
        assert_eq!(wait, Duration::ZERO);
        assert!(r.respawn_due(), "first boot needs no backoff");
        assert!(!r.is_serviceable());

        r.enter_quarantine();
        let (_, wait, backoff) = r.test_status();
        assert_eq!(wait, Duration::from_millis(100));
        assert_eq!(backoff, Duration::from_millis(200));
        r.enter_quarantine();
        r.enter_quarantine();
        let (_, wait, backoff) = r.test_status();
        assert_eq!(wait, Duration::from_millis(350), "wait caps at the max");
        assert_eq!(backoff, Duration::from_millis(350));

        // promotion back to Serving resets the backoff ladder
        r.promote();
        assert!(r.is_serving());
        let (_, _, backoff) = r.test_status();
        assert_eq!(backoff, Duration::from_millis(100));
    }

    #[test]
    fn probing_is_serviceable_but_not_serving() {
        let r = Replica::new(1, &ServerConfig::default());
        let _rx = r.test_rig();
        r.test_enter(ReplicaState::Probing);
        assert!(!r.is_serving());
        assert!(r.is_serviceable());
        assert!(r.promote_due(Duration::ZERO));
        r.promote();
        assert!(r.is_serving());
        // dropping the sender makes it non-serviceable even while Serving
        r.clear_sender();
        assert!(!r.is_serviceable());
    }

    #[test]
    fn waiting_subtracts_busy_lanes_from_load() {
        let r = Replica::new(0, &ServerConfig::default());
        r.gauges.load.store(5, Ordering::Relaxed);
        r.gauges.lanes_busy.store(2, Ordering::Relaxed);
        assert_eq!(r.waiting(), 3);
        assert!(r.queue_full(3));
        assert!(!r.queue_full(4));
    }
}
