//! HTTP API + the replica fleet front-end.
//!
//! Routes:
//! * `GET  /health`      — liveness; aggregates per-replica states
//!   (`healthy`/`degraded`, 503 only when zero replicas are serviceable;
//!   a fleet of one keeps PR 7's latched form exactly)
//! * `GET  /metrics`     — Prometheus-style counters + fleet breakdown
//! * `GET  /v1/info`     — model dims, engine opts, per-replica states
//! * `POST /v1/generate` — `{"max_tokens": N}` → per-lane generation
//!   result; optional per-request sampling (`"temperature"`, `"top_k"`,
//!   `"sigma"`, `"seed"`), an optional `"session"` affinity key, an
//!   optional `"prompt"` (flat `[M, span, D]` array of f32 future
//!   contributions, seeded onto the lane's pending columns at admission —
//!   prefill), and `{"stream": true}` → chunked NDJSON with one event per
//!   position as the lane advances, ending in a `{"done":true,...}`
//!   summary line (see DESIGN.md for the wire format).
//!
//! Connections are reusable: a client that sends `Connection:
//! keep-alive` gets up to `ServerConfig::keepalive_max_requests`
//! requests per socket (idle bounded by the read timeout); streaming
//! responses always close the connection.
//!
//! The engine side lives in [`super::replica`]: `--replicas N` spawns N
//! `fi-engine-<id>` worker threads, each owning a private Runtime +
//! Engine + Scheduler + Pager + restart budget (PJRT handles are not
//! `Send`, and one failure domain per engine is the point — a panic
//! storm quarantines one replica, not the server). Connection threads
//! hand requests to [`super::router::Router`], which picks a replica by
//! checkpoint affinity then least-loaded, and the `fi-router` supervisor
//! thread re-dispatches failed-over work and respawns quarantined
//! replicas (DESIGN.md §8).

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::{GenRequest, LaneResult, SamplingParams, StreamEvent};
use super::http::{
    configure_stream, finish_chunks, read_request, write_chunk, write_chunked_head,
    write_response, write_response_conn, Request, Response,
};
use super::replica::{ReadyMsg, Replica, ReplicaCtx};
use super::router::{supervise, Dispatch, Router};
use crate::config::ServerConfig;
use crate::metrics::Counters;
use crate::util::json::Json;

/// A running server (listener + replica fleet + supervisor).
pub struct Server {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    /// Flipped only after the replica workers are joined, so a final
    /// quarantine failback is still drained by the supervisor.
    sup_shutdown: Arc<AtomicBool>,
    shared: Arc<Shared>,
    accept_thread: Option<thread::JoinHandle<()>>,
    supervisor_thread: Option<thread::JoinHandle<()>>,
}

struct Shared {
    cfg: ServerConfig,
    counters: Counters,
    router: Arc<Router>,
    /// Requests accepted but not yet completed (drain gate at shutdown).
    inflight: Arc<AtomicU64>,
    /// Live `fi-conn` handler threads (accept-loop shed gate).
    conns: Arc<AtomicU64>,
    /// Fleet-of-one only: cleared (latched) once the single engine's
    /// restart budget is exhausted; `/health` mirrors it as 200 vs 503.
    /// Fleets aggregate per-replica states instead.
    healthy: Arc<AtomicBool>,
    /// Set during graceful shutdown: new and straggling requests are
    /// failed with 503 + Retry-After instead of being served.
    draining: Arc<AtomicBool>,
    info: Json,
}

/// Decrements the live-connection count even if the handler panics.
struct ConnGuard(Arc<AtomicU64>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

impl Server {
    /// Bind and start serving. `port = 0` picks an ephemeral port.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(cfg.bind_addr())
            .with_context(|| format!("bind {}", cfg.bind_addr()))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let mut cfg = cfg;
        cfg.replicas = cfg.replicas.max(1);
        let shutdown = Arc::new(AtomicBool::new(false));
        let sup_shutdown = Arc::new(AtomicBool::new(false));
        let counters = Counters::new();
        let inflight = Arc::new(AtomicU64::new(0));
        let conns = Arc::new(AtomicU64::new(0));
        let healthy = Arc::new(AtomicBool::new(true));
        let draining = Arc::new(AtomicBool::new(false));

        // fault injection: the FI_FAULTS env var wins over the config
        // spec so a chaos harness can arm faults without a config file
        match crate::util::faultpoint::install_from_env() {
            Ok(Some(spec)) => {
                eprintln!("flashinfer: fault injection armed from FI_FAULTS: {spec}");
            }
            Ok(None) => {
                if !cfg.faults.is_empty() {
                    crate::util::faultpoint::install(&cfg.faults)
                        .with_context(|| format!("install fault spec {:?}", cfg.faults))?;
                    eprintln!("flashinfer: fault injection armed from config: {}", cfg.faults);
                }
            }
            Err(e) => anyhow::bail!("invalid FI_FAULTS: {e:#}"),
        }

        // ---- replica fleet (each worker owns non-Send PJRT state) ----
        let (failback_tx, failback_rx) = channel::<GenRequest>();
        let ctx = ReplicaCtx {
            cfg: cfg.clone(),
            counters: counters.clone(),
            inflight: inflight.clone(),
            healthy: healthy.clone(),
            draining: draining.clone(),
            failback: failback_tx,
        };
        let replicas: Vec<Arc<Replica>> =
            (0..cfg.replicas).map(|i| Replica::new(i, &cfg)).collect();
        let mut readies: Vec<Receiver<ReadyMsg>> = Vec::with_capacity(replicas.len());
        for r in &replicas {
            let (ready_tx, ready_rx) = channel::<ReadyMsg>();
            r.clone().spawn_worker(ctx.clone(), Some(ready_tx));
            readies.push(ready_rx);
        }
        // Every replica serves the same artifacts, so the first clean
        // boot's info document + clamped ceiling speak for the fleet.
        // Partial boot failures leave those replicas quarantined for the
        // supervisor to retry; zero clean boots is a startup error, with
        // PR 7's message shape for the single-replica case.
        let mut adopted: Option<(Json, usize)> = None;
        let mut first_err: Option<String> = None;
        for (i, ready) in readies.into_iter().enumerate() {
            match ready.recv() {
                Ok(Ok(payload)) => {
                    if adopted.is_none() {
                        adopted = Some(payload);
                    }
                }
                Ok(Err(e)) => {
                    eprintln!(
                        "flashinfer: replica {i} failed to boot: {e} \
                         (quarantined; the supervisor will retry)"
                    );
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    eprintln!("flashinfer: replica {i} died during startup");
                }
            }
        }
        let (info, effective_max) = match adopted {
            Some(ready) => ready,
            None => match first_err {
                Some(e) => anyhow::bail!("engine failed to start: {e}"),
                None => anyhow::bail!("engine thread died during startup"),
            },
        };
        // adopt the worker's clamped ceiling so front-door validation,
        // token accounting, and the engine's lane schedules all agree
        cfg.max_max_tokens = effective_max;
        cfg.default_max_tokens = cfg.default_max_tokens.min(effective_max);
        let b = info.get("B").and_then(Json::as_usize).unwrap_or(0);
        counters.lock().lanes_total = (cfg.replicas * b) as u64;

        let router = Arc::new(Router::new(replicas, &cfg));

        // ---- supervisor: failover re-dispatch + quarantine respawn ----
        let sup_router = router.clone();
        let sup_flag = sup_shutdown.clone();
        let supervisor_thread = thread::Builder::new()
            .name("fi-router".into())
            .spawn(move || supervise(sup_router, ctx, failback_rx, sup_flag))
            .context("spawn router supervisor thread")?;

        let shared = Arc::new(Shared {
            cfg,
            counters,
            router,
            inflight,
            conns,
            healthy,
            draining,
            info,
        });

        // ---- accept loop ----
        let sd = shutdown.clone();
        let sh = shared.clone();
        let accept_thread = thread::Builder::new()
            .name("fi-accept".into())
            .spawn(move || {
                while !sd.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            // connection-cap shed: a flood of sockets must
                            // not exhaust the process's thread/fd budget
                            let cap = sh.cfg.max_connections as u64;
                            if sh.conns.load(Ordering::Relaxed) >= cap {
                                sh.counters.lock().conn_shed_total += 1;
                                let resp = Response::unavailable(
                                    "server at connection capacity, retry later",
                                    1,
                                );
                                let _ = write_response(&mut stream, &resp);
                                continue;
                            }
                            sh.conns.fetch_add(1, Ordering::Relaxed);
                            let sh2 = sh.clone();
                            let spawned =
                                thread::Builder::new().name("fi-conn".into()).spawn(move || {
                                    let _guard = ConnGuard(sh2.conns.clone());
                                    handle_connection(stream, sh2);
                                });
                            if let Err(e) = spawned {
                                // the stream moved into the dropped
                                // closure, so no response can be written —
                                // undo the count and say why
                                sh.conns.fetch_sub(1, Ordering::Relaxed);
                                eprintln!("flashinfer: spawn fi-conn failed: {e}");
                            }
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .context("spawn accept thread")?;

        Ok(Server {
            addr,
            shutdown,
            sup_shutdown,
            shared,
            accept_thread: Some(accept_thread),
            supervisor_thread: Some(supervisor_thread),
        })
    }

    /// Graceful shutdown: stop accepting, give in-flight requests up to
    /// `drain_deadline_ms` to finish, then flip the draining flag so
    /// every replica fails stragglers with a retryable 503 and exits.
    /// All replicas drain concurrently against the one deadline.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        let deadline = Instant::now() + Duration::from_millis(self.shared.cfg.drain_deadline_ms);
        while self.shared.inflight.load(Ordering::Relaxed) > 0 && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(10));
        }
        // flip draining *before* dropping the queue senders: a worker
        // blocked in collect_batch unparks on the drop and re-checks the
        // flag, failing stragglers with "shutting down, retry later"
        self.shared.draining.store(true, Ordering::Relaxed);
        self.shared.router.close();
        self.shared.router.join_workers();
        // the supervisor exits last: a replica that quarantined during
        // the drain may have handed work back, and the supervisor's own
        // shutdown path fails that straggler traffic structurally
        self.sup_shutdown.store(true, Ordering::Relaxed);
        if let Some(t) = self.supervisor_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_connection(mut stream: TcpStream, shared: Arc<Shared>) {
    let _ = configure_stream(
        &stream,
        shared.cfg.socket_read_timeout_ms,
        shared.cfg.socket_write_timeout_ms,
    );
    // Keep-alive loop: each iteration serves one request. The socket
    // read timeout doubles as the idle bound between requests, so a
    // parked keep-alive connection cannot pin an fi-conn thread longer
    // than a stuck first read could.
    let mut served: u64 = 0;
    loop {
        let req = match read_request(&mut stream) {
            Ok(req) => req,
            Err(e) => {
                // On a reused connection a read error is normally just
                // the client closing or idling past the timeout; only a
                // fresh connection's garbage earns a 400.
                if served == 0 {
                    let _ =
                        write_response(&mut stream, &Response::bad_request(&format!("{e:#}")));
                }
                return;
            }
        };
        served += 1;
        let wants_keep_alive = req
            .headers
            .get("connection")
            .map(|v| v.eq_ignore_ascii_case("keep-alive"))
            .unwrap_or(false);
        let keep = wants_keep_alive && served < shared.cfg.keepalive_max_requests;
        if req.method == "POST" && req.path == "/v1/generate" {
            // generation writes its own response: one buffered JSON
            // document (connection reusable), or a chunked NDJSON stream
            // (always Connection: close)
            if !generate(&req, &shared, &mut stream, keep) {
                return;
            }
            continue;
        }
        let resp = route(&req, &shared);
        if write_response_conn(&mut stream, &resp, keep).is_err() || !keep {
            return;
        }
    }
}

/// `true` = the server can take traffic: the PR 7 latch for a fleet of
/// one, "some replica is serviceable" for a real fleet.
fn fleet_healthy(shared: &Shared) -> bool {
    if shared.cfg.replicas <= 1 {
        shared.healthy.load(Ordering::Relaxed)
    } else {
        shared.router.serviceable() > 0
    }
}

fn health(shared: &Shared) -> Response {
    if shared.cfg.replicas <= 1 {
        // PR 7 shape, exactly: latched by the worker once the restart
        // budget is exhausted — a load balancer sees a deterministic
        // 503, not a flapping crash loop
        return if shared.healthy.load(Ordering::Relaxed) {
            Response::json(200, "{\"status\":\"ok\"}".into())
        } else {
            let restarts = shared.counters.lock().engine_restarts_total;
            let body = Json::from_pairs(vec![
                ("status", Json::Str("unhealthy".into())),
                ("engine_restarts", Json::Num(restarts as f64)),
            ]);
            Response::json(503, body.to_string())
        };
    }
    // fleet: aggregate — one quarantined replica degrades, it does not
    // condemn; 503 is reserved for a full outage
    let total = shared.cfg.replicas;
    let serving = shared.router.serving();
    let serviceable = shared.router.serviceable();
    let status = if serviceable == 0 {
        "unhealthy"
    } else if serving == total {
        "healthy"
    } else {
        "degraded"
    };
    let body = Json::from_pairs(vec![
        ("status", Json::Str(status.into())),
        ("replicas_total", Json::Num(total as f64)),
        ("replicas_serving", Json::Num(serving as f64)),
        ("replicas_serviceable", Json::Num(serviceable as f64)),
        ("replicas", shared.router.replica_states()),
    ]);
    Response::json(if serviceable == 0 { 503 } else { 200 }, body.to_string())
}

fn route(req: &Request, shared: &Shared) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => health(shared),
        ("GET", "/metrics") => {
            // roll per-replica gauges into the counters first so the
            // rendered fi_queue_depth/fi_lanes_busy lines are current
            let fleet = shared.router.publish(&shared.counters, &shared.healthy);
            let mut text = shared.counters.lock().render();
            text.push_str(&fleet);
            Response::text(200, text)
        }
        ("GET", "/v1/info") => {
            let mut info = shared.info.clone();
            let restarts = shared.counters.lock().engine_restarts_total;
            info.set("engine_restarts", Json::Num(restarts as f64));
            info.set("healthy", Json::Bool(fleet_healthy(shared)));
            let faults = crate::util::faultpoint::active_spec().unwrap_or_default();
            info.set("faults", Json::Str(faults));
            info.set(
                "replicas_serviceable",
                Json::Num(shared.router.serviceable() as f64),
            );
            info.set("replica_states", shared.router.replica_states());
            Response::json(200, info.to_string())
        }
        ("POST" | "GET", _) => Response::not_found(),
        _ => Response::json(405, "{\"error\":\"method not allowed\"}".into()),
    }
}

/// Parse the optional per-request sampling overrides.
fn parse_sampling(j: &Json) -> std::result::Result<SamplingParams, String> {
    let mut s = SamplingParams::default();
    if let Some(v) = j.get("temperature") {
        s.temperature = Some(v.as_f64().ok_or("temperature must be a number")? as f32);
    }
    if let Some(v) = j.get("top_k") {
        s.top_k = Some(v.as_usize().ok_or("top_k must be a non-negative integer")?);
    }
    if let Some(v) = j.get("sigma") {
        s.sigma = Some(v.as_f64().ok_or("sigma must be a number")? as f32);
    }
    if let Some(v) = j.get("seed") {
        s.seed = Some(v.as_i64().ok_or("seed must be an integer")? as u64);
    }
    Ok(s)
}

/// Serve one `POST /v1/generate`. Returns `true` when the connection is
/// still reusable for another request (buffered response written with a
/// `Connection: keep-alive` advertisement), `false` when the caller must
/// close it (streaming response, or keep-alive not in effect).
fn generate(req: &Request, shared: &Shared, stream: &mut TcpStream, keep: bool) -> bool {
    shared.counters.lock().requests_total += 1;
    if shared.draining.load(Ordering::Relaxed) {
        shared.counters.lock().requests_failed += 1;
        let resp = Response::unavailable("shutting down, retry later", 1);
        let _ = write_response_conn(stream, &resp, keep);
        return keep;
    }
    let reject = |msg: String| {
        shared.counters.lock().requests_failed += 1;
        Response::bad_request(&msg)
    };
    let body = match req.body_str() {
        Ok(s) if !s.trim().is_empty() => s,
        _ => "{}",
    };
    let j = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => {
            let _ = write_response_conn(stream, &reject(format!("invalid JSON: {e}")), keep);
            return keep;
        }
    };
    let max_tokens = j
        .get("max_tokens")
        .and_then(Json::as_usize)
        .unwrap_or(shared.cfg.default_max_tokens);
    if max_tokens == 0 || max_tokens > shared.cfg.max_max_tokens {
        let msg = format!("max_tokens must be in [1, {}]", shared.cfg.max_max_tokens);
        let _ = write_response_conn(stream, &reject(msg), keep);
        return keep;
    }
    let sampling = match parse_sampling(&j) {
        Ok(s) => s,
        Err(msg) => {
            let _ = write_response_conn(stream, &reject(msg), keep);
            return keep;
        }
    };
    let session = match j.get("session") {
        None => None,
        Some(v) => match v.as_str() {
            Some(s) => Some(s.to_string()),
            None => {
                let _ =
                    write_response_conn(stream, &reject("session must be a string".into()), keep);
                return keep;
            }
        },
    };
    // {"prompt": [...]} — a flat [M, span, D] group-major f32 array of
    // future contributions, seeded onto the lane's pending columns at
    // admission (prefill-style). Validated against the model geometry
    // the fleet reported at boot: length divisible by M*D, span in
    // [1, L]; anything else is a client error, not an engine panic.
    let prompt = match j.get("prompt") {
        None => None,
        Some(v) => {
            let m = shared.info.get("M").and_then(Json::as_usize).unwrap_or(0);
            let d = shared.info.get("D").and_then(Json::as_usize).unwrap_or(0);
            let l = shared.info.get("L").and_then(Json::as_usize).unwrap_or(0);
            let arr = match v.as_arr() {
                Some(a) if !a.is_empty() => a,
                _ => {
                    let msg = "prompt must be a non-empty array of numbers".to_string();
                    let _ = write_response_conn(stream, &reject(msg), keep);
                    return keep;
                }
            };
            let md = m * d;
            if md == 0 || arr.len() % md != 0 || arr.len() / md > l {
                let msg = format!(
                    "prompt must be a flat [M, span, D] array with M={m}, D={d}, \
                     span in [1, {l}] (got {} values)",
                    arr.len()
                );
                let _ = write_response_conn(stream, &reject(msg), keep);
                return keep;
            }
            let mut vals = Vec::with_capacity(arr.len());
            for x in arr {
                match x.as_f64() {
                    Some(f) => vals.push(f as f32),
                    None => {
                        let msg = "prompt entries must be numbers".to_string();
                        let _ = write_response_conn(stream, &reject(msg), keep);
                        return keep;
                    }
                }
            }
            Some(vals)
        }
    };
    let want_stream = j.get("stream").and_then(Json::as_bool).unwrap_or(false);
    let req_deadline_ms = match j.get("deadline_ms") {
        None => None,
        Some(v) => match v.as_usize() {
            Some(ms) => Some(ms as u64),
            None => {
                let msg = "deadline_ms must be a non-negative integer".to_string();
                let _ = write_response_conn(stream, &reject(msg), keep);
                return keep;
            }
        },
    };
    // effective deadline: the sooner of the server-wide and per-request
    // budgets (0 or absent = unbounded on that side)
    let mut budget_ms = u64::MAX;
    if shared.cfg.deadline_ms > 0 {
        budget_ms = budget_ms.min(shared.cfg.deadline_ms);
    }
    if let Some(ms) = req_deadline_ms {
        if ms > 0 {
            budget_ms = budget_ms.min(ms);
        }
    }
    let deadline =
        (budget_ms != u64::MAX).then(|| Instant::now() + Duration::from_millis(budget_ms));

    let (tx, rx) = channel();
    let (event_tx, event_rx) = if want_stream {
        let (etx, erx) = channel();
        (Some(etx), Some(erx))
    } else {
        (None, None)
    };
    let cancel = Arc::new(AtomicBool::new(false));
    let request = GenRequest {
        max_tokens,
        sampling,
        enqueued: Instant::now(),
        reply: tx,
        stream: event_tx,
        deadline,
        cancel: cancel.clone(),
        session,
        failovers: 0,
        prompt,
        // clients cannot ship checkpoints; only the failover path sets this
        resume: None,
    };
    // The router is the shed gate: per-replica queues are bounded at
    // `max_queue`, and only when *every* serviceable replica is full
    // does the request bounce (429 for a single engine — PR 7's shape —
    // 503 + Retry-After for a fleet, where "all queues full" is a
    // capacity statement about the whole deployment).
    shared.inflight.fetch_add(1, Ordering::Relaxed);
    match shared.router.dispatch(request) {
        Dispatch::Ok => {}
        Dispatch::Fault(msg, _req) => {
            shared.inflight.fetch_sub(1, Ordering::Relaxed);
            shared.counters.lock().requests_failed += 1;
            let _ = write_response_conn(stream, &error_response(msg), keep);
            return keep;
        }
        Dispatch::AllFull(_req) => {
            shared.inflight.fetch_sub(1, Ordering::Relaxed);
            let mut c = shared.counters.lock();
            c.requests_failed += 1;
            c.requests_shed += 1;
            drop(c);
            let resp = if shared.cfg.replicas <= 1 {
                Response::too_many_requests()
            } else {
                Response::shed(503, "all replica queues full, retry later", 1)
            };
            let _ = write_response_conn(stream, &resp, keep);
            return keep;
        }
        Dispatch::NoReplica(_req) => {
            shared.inflight.fetch_sub(1, Ordering::Relaxed);
            shared.counters.lock().requests_failed += 1;
            let resp = Response::unavailable("no healthy replica, retry later", 1);
            let _ = write_response_conn(stream, &resp, keep);
            return keep;
        }
    }
    match event_rx {
        Some(events) => {
            // streaming writes a chunked head with Connection: close
            stream_reply(shared, stream, events, rx, max_tokens, &cancel);
            false
        }
        None => {
            let resp = buffered_reply(shared, stream, rx, max_tokens, &cancel);
            let _ = write_response_conn(stream, &resp, keep);
            keep
        }
    }
}

/// Best-effort client-disconnect probe: a nonblocking `peek` returning
/// `Ok(0)` means the peer sent EOF; hard errors (reset) count as gone,
/// `WouldBlock` means the peer is simply quiet.
fn socket_closed(stream: &TcpStream) -> bool {
    let mut buf = [0u8; 1];
    if stream.set_nonblocking(true).is_err() {
        return false;
    }
    let closed = match stream.peek(&mut buf) {
        Ok(0) => true,
        Ok(_) => false,
        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    let _ = stream.set_nonblocking(false);
    closed
}

/// Map a scheduler-side failure string to a wire response: shutdown
/// stragglers and fleet outages get a retryable 503, everything else a
/// structured 500.
fn error_response(e: String) -> Response {
    if e.starts_with("shutting down") || e.starts_with("no healthy replica") {
        Response::unavailable(&e, 1)
    } else {
        Response::json(500, Json::from_pairs(vec![("error", Json::Str(e))]).to_string())
    }
}

fn buffered_reply(
    shared: &Shared,
    stream: &TcpStream,
    rx: Receiver<std::result::Result<LaneResult, String>>,
    max_tokens: usize,
    cancel: &AtomicBool,
) -> Response {
    // Poll in short slices so a hung-up client is noticed while its lane
    // is still generating: the cancel flag makes the scheduler free the
    // lane at the next step boundary instead of running for a ghost.
    let overall = Instant::now() + Duration::from_secs(600);
    let outcome = loop {
        match rx.recv_timeout(Duration::from_millis(250)) {
            Ok(r) => break r,
            Err(RecvTimeoutError::Timeout) => {
                if socket_closed(stream) {
                    cancel.store(true, Ordering::Relaxed);
                    shared.counters.lock().requests_failed += 1;
                    // nobody is listening; the write below fails harmlessly
                    return Response::json(499, "{\"error\":\"client disconnected\"}".into());
                }
                if Instant::now() >= overall {
                    shared.counters.lock().requests_failed += 1;
                    return Response::json(408, "{\"error\":\"generation timed out\"}".into());
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // replica worker died without replying
                shared.counters.lock().requests_failed += 1;
                return Response::unavailable("engine unavailable, retry later", 1);
            }
        }
    };
    match outcome {
        Ok(lane) => {
            let mut c = shared.counters.lock();
            // positions the lane actually generated for this request —
            // never the raw ask (a capped schedule generates lane.steps)
            c.tokens_generated += max_tokens.min(lane.steps) as u64;
            c.request_latency.record_ns(lane.gen_ms * 1e6);
            drop(c);
            let mut pairs = vec![
                ("steps", Json::Num(lane.steps as f64)),
                ("max_tokens", Json::Num(max_tokens as f64)),
                ("checksum", Json::Num(lane.checksum_total)),
                ("admitted_pos", Json::Num(lane.admitted_pos as f64)),
                ("queue_ms", Json::Num(lane.queue_ms)),
                ("gen_ms", Json::Num(lane.gen_ms)),
                ("batch_size", Json::Num(lane.batch_size as f64)),
                ("evictions", Json::Num(lane.evictions as f64)),
                ("replica", Json::Num(lane.replica as f64)),
            ];
            if let Some(toks) = lane.tokens {
                pairs.push((
                    "tokens",
                    Json::Arr(toks.into_iter().map(|t| Json::Num(t as f64)).collect()),
                ));
            }
            Response::json(200, Json::from_pairs(pairs).to_string())
        }
        Err(e) => {
            shared.counters.lock().requests_failed += 1;
            error_response(e)
        }
    }
}

/// Streaming reply: chunked NDJSON — one `{"pos":..,"token"|"checksum":..}`
/// line per position, flushed as the engine produces it, then one
/// `{"done":true,...}` summary line.
fn stream_reply(
    shared: &Shared,
    stream: &mut TcpStream,
    events: Receiver<StreamEvent>,
    reply: Receiver<std::result::Result<LaneResult, String>>,
    max_tokens: usize,
    cancel: &AtomicBool,
) {
    shared.counters.lock().stream_requests += 1;
    if write_chunked_head(stream, 200, "application/x-ndjson").is_err() {
        return;
    }
    let mut emitted = 0u64;
    let mut timed_out = false;
    loop {
        // same 600s guard as the buffered path: a wedged engine must not
        // hold this connection (and the server's shutdown join) forever
        match events.recv_timeout(Duration::from_secs(600)) {
            Ok(ev) => {
                let mut pairs = vec![("pos", Json::Num(ev.pos as f64))];
                match ev.token {
                    Some(t) => pairs.push(("token", Json::Num(t as f64))),
                    None => pairs.push(("checksum", Json::Num(ev.checksum as f64))),
                }
                let line = format!("{}\n", Json::from_pairs(pairs));
                if write_chunk(stream, line.as_bytes()).is_err() {
                    // client hung up: flag the lane for cancellation (the
                    // dropped event receiver alone would only stop the
                    // per-position sends, not free the lane)
                    cancel.store(true, Ordering::Relaxed);
                    break;
                }
                emitted += 1;
            }
            // lane's sender dropped: early stop reached or batch complete
            Err(RecvTimeoutError::Disconnected) => break,
            Err(RecvTimeoutError::Timeout) => {
                timed_out = true;
                break;
            }
        }
    }
    let tail = if timed_out {
        shared.counters.lock().requests_failed += 1;
        Json::from_pairs(vec![
            ("done", Json::Bool(true)),
            ("error", Json::Str("generation timed out".into())),
        ])
    } else {
        stream_tail(shared, reply, max_tokens, emitted)
    };
    let _ = write_chunk(stream, format!("{tail}\n").as_bytes());
    let _ = finish_chunks(stream);
}

/// Build the final summary line once the lane's event stream has closed:
/// the lane has completed (or errored), so the LaneResult is (or is
/// about to be) on the reply channel.
fn stream_tail(
    shared: &Shared,
    reply: Receiver<std::result::Result<LaneResult, String>>,
    max_tokens: usize,
    emitted: u64,
) -> Json {
    match reply.recv_timeout(Duration::from_secs(600)) {
        Ok(Ok(lane)) => {
            let mut c = shared.counters.lock();
            c.tokens_generated += max_tokens.min(lane.steps) as u64;
            c.stream_events += emitted;
            c.request_latency.record_ns(lane.gen_ms * 1e6);
            drop(c);
            Json::from_pairs(vec![
                ("done", Json::Bool(true)),
                ("steps", Json::Num(lane.steps as f64)),
                ("tokens_emitted", Json::Num(emitted as f64)),
                ("max_tokens", Json::Num(max_tokens as f64)),
                ("checksum", Json::Num(lane.checksum_total)),
                ("admitted_pos", Json::Num(lane.admitted_pos as f64)),
                ("queue_ms", Json::Num(lane.queue_ms)),
                ("gen_ms", Json::Num(lane.gen_ms)),
                ("batch_size", Json::Num(lane.batch_size as f64)),
                ("evictions", Json::Num(lane.evictions as f64)),
                ("replica", Json::Num(lane.replica as f64)),
            ])
        }
        Ok(Err(e)) => {
            shared.counters.lock().requests_failed += 1;
            Json::from_pairs(vec![("done", Json::Bool(true)), ("error", Json::Str(e))])
        }
        Err(_) => {
            shared.counters.lock().requests_failed += 1;
            Json::from_pairs(vec![
                ("done", Json::Bool(true)),
                ("error", Json::Str("generation timed out".into())),
            ])
        }
    }
}
