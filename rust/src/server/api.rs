//! HTTP API + engine worker thread.
//!
//! Routes:
//! * `GET  /health`      — liveness + model summary
//! * `GET  /metrics`     — Prometheus-style counters
//! * `GET  /v1/info`     — model dims, engine opts, artifact dir
//! * `POST /v1/generate` — `{"max_tokens": N}` → per-lane generation
//!   result; `{"max_tokens": N, "stream": true}` → chunked NDJSON with one
//!   event per position as the engine's `Session` advances, ending in a
//!   `{"done":true,...}` summary line (see DESIGN.md for the wire format).
//!
//! PJRT handles are not `Send`, so the `Runtime`/`Engine` live on one
//! dedicated worker thread; connection threads talk to it over an mpsc
//! queue (the batcher) and, for streaming lanes, receive per-position
//! events back over a dedicated channel. This is the same topology as a
//! vLLM-style router front-end over a single-device engine.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::{batch_len, collect_batch, GenRequest, LaneResult, StreamEvent};
use super::http::{
    finish_chunks, read_request, write_chunk, write_chunked_head, write_response, Request,
    Response,
};
use crate::config::ServerConfig;
use crate::engine::{Engine, EngineOpts, GenOutput};
use crate::metrics::ServerCounters;
use crate::runtime::Runtime;
use crate::util::json::Json;

/// A running server (listener + engine worker).
pub struct Server {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<thread::JoinHandle<()>>,
    engine_thread: Option<thread::JoinHandle<()>>,
}

struct Shared {
    cfg: ServerConfig,
    counters: Mutex<ServerCounters>,
    queue: Mutex<Sender<GenRequest>>,
    info: Json,
}

impl Server {
    /// Bind and start serving. `port = 0` picks an ephemeral port.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(cfg.bind_addr())
            .with_context(|| format!("bind {}", cfg.bind_addr()))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let (req_tx, req_rx) = channel::<GenRequest>();
        let shutdown = Arc::new(AtomicBool::new(false));

        // ---- engine worker (owns the non-Send PJRT state) ----
        let (ready_tx, ready_rx) = channel::<Result<Json, String>>();
        let ecfg = cfg.clone();
        let engine_thread = thread::Builder::new()
            .name("fi-engine".into())
            .spawn(move || {
                let rt = match Runtime::load(&ecfg.artifacts) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("load runtime: {e:#}")));
                        return;
                    }
                };
                let mut engine = match Engine::new(&rt, ecfg.engine) {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("init engine: {e:#}")));
                        return;
                    }
                };
                let dims = rt.dims;
                // Cold-start: derive every per-U rho structure (spectra +
                // PJRT tau executables) for the largest session a request
                // can trigger, so the first request's measured gen_ms
                // contains no one-time derivation cost.
                let prewarm_len = ecfg.max_max_tokens.next_power_of_two().min(dims.l);
                if let Err(e) = engine.prewarm(prewarm_len) {
                    let _ = ready_tx.send(Err(format!("prewarm engine: {e:#}")));
                    return;
                }
                let info = info_json(&ecfg, &ecfg.engine, &rt);
                let _ = ready_tx.send(Ok(info));
                let window = Duration::from_millis(ecfg.batch_window_ms);
                while let Some(mut batch) = collect_batch(&req_rx, dims.b, window) {
                    let len = batch_len(&batch, dims.l);
                    let t0 = Instant::now();
                    let result = if batch.iter().any(|r| r.stream.is_some()) {
                        stream_batch(&engine, &mut batch, len)
                    } else {
                        engine.generate(len)
                    };
                    let gen_ms = t0.elapsed().as_secs_f64() * 1e3;
                    match result {
                        Ok(out) => {
                            for (lane, req) in batch.into_iter().enumerate() {
                                let tokens = out.tokens.as_ref().map(|all| {
                                    let lane_toks = &all[lane.min(all.len() - 1)];
                                    lane_toks[..req.max_tokens.min(lane_toks.len())].to_vec()
                                });
                                let _ = req.reply.send(Ok(LaneResult {
                                    tokens,
                                    steps: out.steps,
                                    queue_ms: req.enqueued.elapsed().as_secs_f64() * 1e3
                                        - gen_ms,
                                    gen_ms,
                                    batch_size: lane + 1,
                                }));
                            }
                        }
                        Err(e) => {
                            let msg = format!("generate: {e:#}");
                            for req in batch {
                                let _ = req.reply.send(Err(msg.clone()));
                            }
                        }
                    }
                }
            })
            .context("spawn engine thread")?;

        let info = match ready_rx.recv() {
            Ok(Ok(info)) => info,
            Ok(Err(e)) => anyhow::bail!("engine failed to start: {e}"),
            Err(_) => anyhow::bail!("engine thread died during startup"),
        };

        let shared = Arc::new(Shared {
            cfg,
            counters: Mutex::new(ServerCounters::new()),
            queue: Mutex::new(req_tx),
            info,
        });

        // ---- accept loop ----
        let sd = shutdown.clone();
        let sh = shared.clone();
        let accept_thread = thread::Builder::new()
            .name("fi-accept".into())
            .spawn(move || {
                while !sd.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let sh = sh.clone();
                            let _ = thread::Builder::new()
                                .name("fi-conn".into())
                                .spawn(move || handle_connection(stream, sh));
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .context("spawn accept thread")?;

        Ok(Server {
            addr,
            shutdown,
            accept_thread: Some(accept_thread),
            engine_thread: Some(engine_thread),
        })
    }

    /// Stop accepting; the engine drains once the queue sender drops.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // engine thread exits when all GenRequest senders are gone; the
        // Shared (and its queue Sender) died with the accept/conn threads.
        if let Some(t) = self.engine_thread.take() {
            let _ = t.join();
        }
    }
}

fn info_json(cfg: &ServerConfig, eng: &EngineOpts, rt: &Runtime) -> Json {
    let d = rt.dims;
    Json::from_pairs(vec![
        ("variant", Json::Str(d.variant.as_str().into())),
        ("M", Json::Num(d.m as f64)),
        ("D", Json::Num(d.d as f64)),
        ("L", Json::Num(d.l as f64)),
        ("B", Json::Num(d.b as f64)),
        ("V", Json::Num(d.v as f64)),
        ("method", Json::Str(eng.method.as_str().into())),
        ("tau", Json::Str(eng.tau.as_str().into())),
        ("async_mixer", Json::Bool(eng.async_mixer)),
        ("split_min_u", Json::Num(eng.split_min_u as f64)),
        ("artifacts", Json::Str(cfg.artifacts.display().to_string())),
    ])
}

/// Drive one batch through the `Session` state machine, emitting a
/// [`StreamEvent`] per position to every streaming lane that has not yet
/// hit its `max_tokens`. Per-lane early stop: once a lane is satisfied its
/// event channel is dropped — the client's event stream closes at the
/// lane's own boundary — while the batch runs out its padded power-of-two
/// schedule for the other lanes. The lockstep constraint documented in
/// DESIGN.md only forces the *computation* to stay synchronized, not the
/// delivery; the summary line still arrives once the batch completes,
/// since it carries batch-level stats (steps, gen_ms).
fn stream_batch(engine: &Engine, batch: &mut [GenRequest], len: usize) -> Result<GenOutput> {
    let mut session = engine.session(len)?;
    while !session.is_done() {
        let step = session.step()?;
        for (lane, req) in batch.iter_mut().enumerate() {
            if let Some(tx) = &req.stream {
                if step.pos <= req.max_tokens {
                    let token =
                        step.tokens.as_ref().map(|toks| toks[lane.min(toks.len() - 1)]);
                    // a send error just means the client hung up; keep the
                    // batch running for the other lanes
                    let _ =
                        tx.send(StreamEvent { pos: step.pos, token, checksum: step.checksum });
                }
            } else {
                continue;
            }
            if step.pos >= req.max_tokens {
                req.stream = None; // early stop: close this lane's event stream
            }
        }
    }
    Ok(session.finish())
}

fn handle_connection(mut stream: TcpStream, shared: Arc<Shared>) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let req = match read_request(&mut stream) {
        Ok(req) => req,
        Err(e) => {
            let _ = write_response(&mut stream, &Response::bad_request(&format!("{e:#}")));
            return;
        }
    };
    if req.method == "POST" && req.path == "/v1/generate" {
        // generation writes its own response: one buffered JSON document,
        // or a chunked NDJSON stream
        generate(&req, &shared, &mut stream);
        return;
    }
    let resp = route(&req, &shared);
    let _ = write_response(&mut stream, &resp);
}

fn route(req: &Request, shared: &Shared) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => Response::json(200, "{\"status\":\"ok\"}".into()),
        ("GET", "/metrics") => {
            Response::text(200, shared.counters.lock().unwrap().render())
        }
        ("GET", "/v1/info") => Response::json(200, shared.info.to_string()),
        ("POST" | "GET", _) => Response::not_found(),
        _ => Response::json(405, "{\"error\":\"method not allowed\"}".into()),
    }
}

fn generate(req: &Request, shared: &Shared, stream: &mut TcpStream) {
    shared.counters.lock().unwrap().requests_total += 1;
    let reject = |msg: String| {
        shared.counters.lock().unwrap().requests_failed += 1;
        Response::bad_request(&msg)
    };
    let body = match req.body_str() {
        Ok(s) if !s.trim().is_empty() => s,
        _ => "{}",
    };
    let j = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => {
            let _ = write_response(stream, &reject(format!("invalid JSON: {e}")));
            return;
        }
    };
    let max_tokens = j
        .get("max_tokens")
        .and_then(Json::as_usize)
        .unwrap_or(shared.cfg.default_max_tokens);
    if max_tokens == 0 || max_tokens > shared.cfg.max_max_tokens {
        let msg = format!("max_tokens must be in [1, {}]", shared.cfg.max_max_tokens);
        let _ = write_response(stream, &reject(msg));
        return;
    }
    let want_stream = j.get("stream").and_then(Json::as_bool).unwrap_or(false);

    let (tx, rx) = channel();
    let (event_tx, event_rx) = if want_stream {
        let (etx, erx) = channel();
        (Some(etx), Some(erx))
    } else {
        (None, None)
    };
    let request =
        GenRequest { max_tokens, enqueued: Instant::now(), reply: tx, stream: event_tx };
    if shared.queue.lock().unwrap().send(request).is_err() {
        let _ =
            write_response(stream, &Response::json(503, "{\"error\":\"engine unavailable\"}".into()));
        return;
    }
    match event_rx {
        Some(events) => stream_reply(shared, stream, events, rx, max_tokens),
        None => {
            let resp = buffered_reply(shared, rx, max_tokens);
            let _ = write_response(stream, &resp);
        }
    }
}

fn buffered_reply(
    shared: &Shared,
    rx: Receiver<std::result::Result<LaneResult, String>>,
    max_tokens: usize,
) -> Response {
    match rx.recv_timeout(Duration::from_secs(600)) {
        Ok(Ok(lane)) => {
            let mut c = shared.counters.lock().unwrap();
            c.tokens_generated += max_tokens as u64;
            c.batches_run += 1;
            c.queue_latency.record_ns(lane.queue_ms.max(0.0) * 1e6);
            c.request_latency.record_ns(lane.gen_ms * 1e6);
            drop(c);
            let mut pairs = vec![
                ("steps", Json::Num(lane.steps as f64)),
                ("max_tokens", Json::Num(max_tokens as f64)),
                ("gen_ms", Json::Num(lane.gen_ms)),
                ("batch_size", Json::Num(lane.batch_size as f64)),
            ];
            if let Some(toks) = lane.tokens {
                pairs.push((
                    "tokens",
                    Json::Arr(toks.into_iter().map(|t| Json::Num(t as f64)).collect()),
                ));
            }
            Response::json(200, Json::from_pairs(pairs).to_string())
        }
        Ok(Err(e)) => {
            shared.counters.lock().unwrap().requests_failed += 1;
            Response::json(500, Json::from_pairs(vec![("error", Json::Str(e))]).to_string())
        }
        Err(_) => {
            shared.counters.lock().unwrap().requests_failed += 1;
            Response::json(408, "{\"error\":\"generation timed out\"}".into())
        }
    }
}

/// Streaming reply: chunked NDJSON — one `{"pos":..,"token"|"checksum":..}`
/// line per position, flushed as the engine produces it, then one
/// `{"done":true,...}` summary line.
fn stream_reply(
    shared: &Shared,
    stream: &mut TcpStream,
    events: Receiver<StreamEvent>,
    reply: Receiver<std::result::Result<LaneResult, String>>,
    max_tokens: usize,
) {
    shared.counters.lock().unwrap().stream_requests += 1;
    if write_chunked_head(stream, 200, "application/x-ndjson").is_err() {
        return;
    }
    let mut emitted = 0u64;
    let mut timed_out = false;
    loop {
        // same 600s guard as the buffered path: a wedged engine must not
        // hold this connection (and the server's shutdown join) forever
        match events.recv_timeout(Duration::from_secs(600)) {
            Ok(ev) => {
                let mut pairs = vec![("pos", Json::Num(ev.pos as f64))];
                match ev.token {
                    Some(t) => pairs.push(("token", Json::Num(t as f64))),
                    None => pairs.push(("checksum", Json::Num(ev.checksum as f64))),
                }
                let line = format!("{}\n", Json::from_pairs(pairs));
                if write_chunk(stream, line.as_bytes()).is_err() {
                    // client hung up; sends are non-blocking on an mpsc
                    // channel, so just dropping our receiver is enough
                    break;
                }
                emitted += 1;
            }
            // lane's sender dropped: early stop reached or batch complete
            Err(RecvTimeoutError::Disconnected) => break,
            Err(RecvTimeoutError::Timeout) => {
                timed_out = true;
                break;
            }
        }
    }
    let tail = if timed_out {
        shared.counters.lock().unwrap().requests_failed += 1;
        Json::from_pairs(vec![
            ("done", Json::Bool(true)),
            ("error", Json::Str("generation timed out".into())),
        ])
    } else {
        stream_tail(shared, reply, max_tokens, emitted)
    };
    let _ = write_chunk(stream, format!("{tail}\n").as_bytes());
    let _ = finish_chunks(stream);
}

/// Build the final summary line once the lane's event stream has closed:
/// the batch has completed (or errored), so the LaneResult is (or is
/// about to be) on the reply channel.
fn stream_tail(
    shared: &Shared,
    reply: Receiver<std::result::Result<LaneResult, String>>,
    max_tokens: usize,
    emitted: u64,
) -> Json {
    match reply.recv_timeout(Duration::from_secs(600)) {
        Ok(Ok(lane)) => {
            let mut c = shared.counters.lock().unwrap();
            c.tokens_generated += max_tokens as u64;
            c.stream_events += emitted;
            c.batches_run += 1;
            c.queue_latency.record_ns(lane.queue_ms.max(0.0) * 1e6);
            c.request_latency.record_ns(lane.gen_ms * 1e6);
            drop(c);
            Json::from_pairs(vec![
                ("done", Json::Bool(true)),
                ("steps", Json::Num(lane.steps as f64)),
                ("tokens_emitted", Json::Num(emitted as f64)),
                ("max_tokens", Json::Num(max_tokens as f64)),
                ("gen_ms", Json::Num(lane.gen_ms)),
                ("batch_size", Json::Num(lane.batch_size as f64)),
            ])
        }
        Ok(Err(e)) => {
            shared.counters.lock().unwrap().requests_failed += 1;
            Json::from_pairs(vec![("done", Json::Bool(true)), ("error", Json::Str(e))])
        }
        Err(_) => {
            shared.counters.lock().unwrap().requests_failed += 1;
            Json::from_pairs(vec![
                ("done", Json::Bool(true)),
                ("error", Json::Str("generation timed out".into())),
            ])
        }
    }
}
