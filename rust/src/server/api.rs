//! HTTP API + engine worker thread + the continuous-admission scheduler.
//!
//! Routes:
//! * `GET  /health`      — liveness + model summary
//! * `GET  /metrics`     — Prometheus-style counters
//! * `GET  /v1/info`     — model dims, engine opts, artifact dir
//! * `POST /v1/generate` — `{"max_tokens": N}` → per-lane generation
//!   result; optional per-request sampling (`"temperature"`, `"top_k"`,
//!   `"sigma"`, `"seed"`); `{"stream": true}` → chunked NDJSON with one
//!   event per position as the lane advances, ending in a
//!   `{"done":true,...}` summary line (see DESIGN.md for the wire format).
//!
//! PJRT handles are not `Send`, so the `Runtime`/`Engine` live on one
//! dedicated worker thread; connection threads talk to it over an mpsc
//! queue and, for streaming lanes, receive per-position events back over a
//! dedicated channel. The worker runs the [`Scheduler`]: one long-lived
//! `Session` whose lanes are *individually* recycled — a queued request is
//! seeded into a free lane at the next step boundary (`Session::admit`)
//! instead of waiting for the whole batch to drain. This is the LCSM
//! analogue of vLLM-style continuous batching, adapted to the lockstep
//! tile schedule: lanes can't have private schedules, but their *content*
//! can restart at any step boundary (DESIGN.md §4).
//!
//! On top of admission sits **session paging** (DESIGN.md §6): under
//! queue pressure the scheduler checkpoints the busy lane with the most
//! remaining schedule into a slab [`Pager`] (`Session::suspend`), admits
//! the waiting request immediately, and restores the evicted lane when a
//! later session's clock reaches the suspension position
//! (`Session::restore` — the alignment at which the resumed rollout is
//! bit-identical to an uninterrupted one). One engine therefore holds
//! arbitrarily many resumable requests, not just `B`.

use std::collections::VecDeque;
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::{collect_batch, lane_len, GenRequest, LaneResult, SamplingParams, StreamEvent};
use super::http::{
    configure_stream, finish_chunks, read_request, write_chunk, write_chunked_head,
    write_response, Request, Response,
};
use crate::config::ServerConfig;
use crate::engine::{
    Engine, EngineOpts, LaneCheckpoint, LaneInit, Pager, SamplerCfg, Session, StepOutput,
};
use crate::metrics::Counters;
use crate::model::Variant;
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::util::threadpool::payload_text;

/// A running server (listener + engine worker).
pub struct Server {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    shared: Arc<Shared>,
    accept_thread: Option<thread::JoinHandle<()>>,
    engine_thread: Option<thread::JoinHandle<()>>,
}

struct Shared {
    cfg: ServerConfig,
    counters: Counters,
    /// `None` once the server is draining: the engine worker unparks and
    /// exits when the last sender drops, so shutdown cannot hang.
    queue: Mutex<Option<Sender<GenRequest>>>,
    /// Requests accepted but not yet completed — the shed gate
    /// (`max_queue`) reads this without bothering the engine thread.
    inflight: Arc<AtomicU64>,
    /// Live `fi-conn` handler threads (accept-loop shed gate).
    conns: Arc<AtomicU64>,
    /// Cleared (latched) once the supervisor's restart budget is
    /// exhausted; `/health` mirrors it as 200 vs 503.
    healthy: Arc<AtomicBool>,
    /// Set during graceful shutdown: new and straggling requests are
    /// failed with 503 + Retry-After instead of being served.
    draining: Arc<AtomicBool>,
    info: Json,
}

/// Decrements the live-connection count even if the handler panics.
struct ConnGuard(Arc<AtomicU64>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Rolling-window panic budget for the engine supervisor: absorbing the
/// occasional panic keeps serving alive, but a crash loop should flip
/// `/health` to 503 (latched — no flapping) so a load balancer drains us.
struct RestartBudget {
    budget: usize,
    window: Duration,
    panics: VecDeque<Instant>,
}

impl RestartBudget {
    fn new(budget: usize, window: Duration) -> RestartBudget {
        RestartBudget { budget, window, panics: VecDeque::new() }
    }

    /// Record one panic; returns `false` once the window holds more than
    /// `budget` panics (the caller latches unhealthy).
    fn record(&mut self, now: Instant) -> bool {
        self.panics.push_back(now);
        while let Some(&t) = self.panics.front() {
            if now.duration_since(t) > self.window {
                self.panics.pop_front();
            } else {
                break;
            }
        }
        self.panics.len() <= self.budget
    }
}

// ---------------------------------------------------------------------------
// Scheduler: one running session, per-lane request slots, a waiting queue
// ---------------------------------------------------------------------------

/// One busy lane: the request it serves plus its rebased bookkeeping.
struct LaneSlot {
    req: GenRequest,
    /// Global batch position at admission (lane-local clock offset).
    admitted_pos: usize,
    /// Padded positions this lane generates (`lane_len(max_tokens)`).
    limit: usize,
    admitted_at: Instant,
    queue_ms: f64,
    /// Busy lanes (incl. this one) at admission.
    batch_size: usize,
    tokens: Vec<u32>,
    /// Per-lane checksum running sum over the first `max_tokens` positions.
    checksum_total: f64,
    /// Times this request was evicted into the session pager.
    evictions: u64,
}

/// A request swapped out of its lane under queue pressure: its serving
/// slot (tokens so far, reply channel, stats) plus the engine-side lane
/// checkpoint. Lives in the scheduler until a later session's clock
/// reaches the checkpoint's suspension position (`Session::restore`'s
/// same-alignment rule), at which point the slot goes back into a lane
/// and the rollout continues bit-identically.
struct EvictedLane {
    slot: LaneSlot,
    ckpt: LaneCheckpoint,
}

/// Continuous-admission scheduler: owns the running [`Session`], tracks
/// free lanes, and seeds queued requests into them at step boundaries.
struct Scheduler<'e, 'rt> {
    engine: &'e Engine<'rt>,
    session: Option<Session<'e, 'rt>>,
    lanes: Vec<Option<LaneSlot>>,
    queue: VecDeque<GenRequest>,
    /// Session schedule length (padded `max_max_tokens`, clamped to L) —
    /// every admissible request fits a fresh session by construction.
    horizon: usize,
    /// `false` = legacy drain-then-refill (admission only at position 0).
    admit_mid_batch: bool,
    /// Session pager for suspended-lane checkpoints (`None` = paging off;
    /// forced off under drain-then-refill, which cannot re-seed lanes).
    pager: Option<Pager>,
    /// Requests evicted under queue pressure, waiting for a session whose
    /// clock reaches their checkpoint's suspension position.
    evicted: Vec<EvictedLane>,
    counters: Counters,
    inflight: Arc<AtomicU64>,
}

impl<'e, 'rt> Scheduler<'e, 'rt> {
    fn new(
        engine: &'e Engine<'rt>,
        horizon: usize,
        admit_mid_batch: bool,
        pager: Option<Pager>,
        counters: Counters,
        inflight: Arc<AtomicU64>,
    ) -> Scheduler<'e, 'rt> {
        let b = engine.runtime().dims.b;
        counters.lock().lanes_total = b as u64;
        Scheduler {
            engine,
            session: None,
            lanes: (0..b).map(|_| None).collect(),
            queue: VecDeque::new(),
            horizon,
            admit_mid_batch,
            pager: if admit_mid_batch { pager } else { None },
            evicted: Vec::new(),
            counters,
            inflight,
        }
    }

    fn enqueue(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    /// Nothing running, nothing waiting, nothing paged out: the worker
    /// may block.
    fn is_idle(&self) -> bool {
        self.session.is_none() && self.queue.is_empty() && self.evicted.is_empty()
    }

    fn busy_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    /// Per-request sampling override → the admitted lane's `SamplerCfg`
    /// (`None` = keep the engine default for this lane).
    fn lane_sampler_cfg(&self, s: &SamplingParams) -> Option<SamplerCfg> {
        let opts: &EngineOpts = self.engine.opts();
        match self.engine.runtime().dims.variant {
            Variant::Synthetic => s.sigma.map(|sigma| SamplerCfg::Synthetic { sigma }),
            Variant::Hyena => {
                if s.temperature.is_none() && s.top_k.is_none() {
                    None
                } else {
                    Some(SamplerCfg::Lm {
                        temperature: s.temperature.unwrap_or(opts.temperature),
                        top_k: s.top_k.unwrap_or(opts.top_k),
                    })
                }
            }
        }
    }

    /// Restore evicted lanes whose checkpoint position matches the
    /// session clock (the only position `Session::restore` is exact at).
    /// Runs *before* `evict_phase` so a just-evicted lane is never
    /// bounced straight back in the same boundary; returns the lanes it
    /// restored so `evict_phase` cannot re-evict them before they have
    /// stepped even once (the inverse bounce).
    fn resume_phase(&mut self) -> Vec<usize> {
        let mut restored = Vec::new();
        let Some(sess) = self.session.as_mut() else { return restored };
        let now = sess.steps_done();
        let mut i = 0;
        while i < self.evicted.len() {
            if self.evicted[i].ckpt.pos() != now {
                i += 1;
                continue;
            }
            let Some(lane) = (0..self.lanes.len()).find(|&l| self.lanes[l].is_none()) else {
                break; // no free lane at the restore point: wait for a later session
            };
            let EvictedLane { slot, ckpt } = self.evicted.remove(i);
            match sess.restore(lane, ckpt, self.pager.as_mut().unwrap()) {
                Ok(()) => {
                    self.lanes[lane] = Some(slot);
                    restored.push(lane);
                    self.counters.lock().resumes_total += 1;
                }
                Err(e) => {
                    // the checkpoint is gone (blocks already released):
                    // fail exactly this request and keep serving
                    let _ = slot.req.reply.send(Err(format!("resume: {e:#}")));
                    self.inflight.fetch_sub(1, Ordering::Relaxed);
                }
            }
        }
        restored
    }

    /// Under queue pressure — a waiting request, no free lane — suspend
    /// the busy lane with the most remaining schedule into the pager so
    /// the waiting request can be admitted now. Eviction only pays off
    /// when the incoming request finishes before the victim would have,
    /// so shorter-than-victim requests are the only trigger. Lanes in
    /// `protected` (restored this very boundary) are never victims, and
    /// already-evicted requests are preferred last, so a paged-out
    /// request always makes forward progress between evictions instead
    /// of thrashing under sustained pressure.
    fn evict_phase(&mut self, protected: &[usize]) {
        if self.pager.is_none() || self.session.is_none() {
            return;
        }
        let sess = self.session.as_mut().unwrap();
        let now = sess.steps_done();
        if self.queue.is_empty() || self.lanes.iter().any(|l| l.is_none()) {
            return;
        }
        // lanes freed now are reserved for checkpoints waiting further
        // down this session's schedule — evicting would not admit anyone
        if self.evicted.iter().any(|e| e.ckpt.pos() > now) {
            return;
        }
        let remaining = sess.remaining();
        let Some(need) = self
            .queue
            .iter()
            .map(|r| lane_len(r.max_tokens, self.horizon))
            .find(|&n| n <= remaining)
        else {
            return;
        };
        let Some(lane) = (0..self.lanes.len())
            .filter(|&l| self.lanes[l].is_some() && !protected.contains(&l))
            .max_by_key(|&l| {
                let evictions = self.lanes[l].as_ref().unwrap().evictions;
                let left = sess.lane_limit(l).saturating_sub(sess.lane_pos(l));
                // fewest prior evictions first, then most remaining
                (std::cmp::Reverse(evictions), left)
            })
        else {
            return;
        };
        let victim_remaining = sess.lane_limit(lane).saturating_sub(sess.lane_pos(lane));
        if victim_remaining <= need {
            return;
        }
        // a full pager (or any transient failure) leaves every lane
        // untouched — the waiting request simply keeps waiting
        if let Ok(ckpt) = sess.suspend(lane, self.pager.as_mut().unwrap()) {
            let mut slot = self.lanes[lane].take().unwrap();
            slot.evictions += 1;
            self.evicted.push(EvictedLane { slot, ckpt });
            self.counters.lock().evictions_total += 1;
        }
    }

    /// Open a session if needed, then admit queued requests onto free
    /// lanes (this is the step boundary: `tick` calls it before `step`).
    /// Order matters: resume (exact-position restores) → evict (free a
    /// lane under pressure) → fresh admissions (minus lanes reserved for
    /// checkpoints waiting later in this session's schedule).
    fn admit_phase(&mut self) {
        if self.session.is_none() && !(self.queue.is_empty() && self.evicted.is_empty()) {
            // with mid-batch admission, open at the full horizon so later
            // arrivals always have schedule headroom (the cost is one
            // horizon-sized store allocation per session open); under
            // drain-then-refill nothing joins later, so size the session
            // to the batch it will actually run — the first B queued
            // requests — like the legacy collector did
            let len = if self.admit_mid_batch {
                self.horizon
            } else {
                self.queue
                    .iter()
                    .take(self.lanes.len())
                    .map(|r| lane_len(r.max_tokens, self.horizon))
                    .max()
                    .unwrap_or(1)
            };
            match self.engine.session(len) {
                Ok(sess) => {
                    self.session = Some(sess);
                    for slot in &mut self.lanes {
                        *slot = None;
                    }
                    self.counters.lock().sessions_started += 1;
                }
                Err(e) => {
                    // a session that cannot even open would error forever:
                    // fail the whole queue (and any paged-out requests,
                    // which need a session to ever resume) instead of
                    // spinning on it
                    self.fail_queued(&format!("open session: {e:#}"));
                    self.fail_evicted(&format!("open session: {e:#}"));
                    return;
                }
            }
        }
        let (mid_batch, remaining, now) = match self.session.as_ref() {
            Some(sess) => (sess.steps_done() > 0, sess.remaining(), sess.steps_done()),
            None => return,
        };
        if mid_batch && !self.admit_mid_batch {
            return;
        }
        let restored = self.resume_phase();
        self.evict_phase(&restored);
        // lanes kept free for checkpoints that must restore later in this
        // session's schedule (strictly later: a checkpoint at the current
        // position either just resumed or just got evicted)
        let reserved = self.evicted.iter().filter(|e| e.ckpt.pos() > now).count();
        for lane in 0..self.lanes.len() {
            if self.lanes[lane].is_some() {
                continue;
            }
            let free_now = self.lanes.iter().filter(|l| l.is_none()).count();
            if free_now <= reserved {
                break;
            }
            // first queued request whose padded schedule fits what's left
            let Some(qi) = self
                .queue
                .iter()
                .position(|r| lane_len(r.max_tokens, self.horizon) <= remaining)
            else {
                break;
            };
            let req = self.queue.remove(qi).unwrap();
            let limit = lane_len(req.max_tokens, self.horizon);
            let init = LaneInit {
                limit,
                sampler_cfg: self.lane_sampler_cfg(&req.sampling),
                seed: req.sampling.seed,
            };
            let admitted_pos = {
                let sess = self.session.as_mut().unwrap();
                match sess.admit(lane, init) {
                    Ok(()) => sess.steps_done(),
                    Err(e) => {
                        // fail exactly this request (never silently drop
                        // it or leak its inflight slot) and keep serving
                        let _ = req.reply.send(Err(format!("admit: {e:#}")));
                        self.inflight.fetch_sub(1, Ordering::Relaxed);
                        continue;
                    }
                }
            };
            let queue_ms = req.enqueued.elapsed().as_secs_f64() * 1e3;
            let batch_size = self.lanes.iter().filter(|l| l.is_some()).count() + 1;
            self.lanes[lane] = Some(LaneSlot {
                req,
                admitted_pos,
                limit,
                admitted_at: Instant::now(),
                queue_ms,
                batch_size,
                tokens: Vec::new(),
                checksum_total: 0.0,
                evictions: 0,
            });
            let mut c = self.counters.lock();
            c.admissions_total += 1;
            if mid_batch {
                c.admissions_mid_batch += 1;
            }
            c.admission_latency.record_ns(queue_ms * 1e6);
        }
    }

    /// Fail every *queued* (not yet admitted) request.
    fn fail_queued(&mut self, msg: &str) {
        while let Some(req) = self.queue.pop_front() {
            let _ = req.reply.send(Err(msg.to_string()));
            self.inflight.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Fail every evicted (paged-out) request and release its checkpoint.
    /// Only the cannot-even-open-a-session path uses this — a mere engine
    /// step error keeps checkpoints alive for the next session.
    fn fail_evicted(&mut self, msg: &str) {
        for e in self.evicted.drain(..) {
            if let Some(p) = self.pager.as_mut() {
                p.discard(e.ckpt);
            }
            let _ = e.slot.req.reply.send(Err(msg.to_string()));
            self.inflight.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Route one step's outputs to the busy lanes; complete any lane that
    /// reached its padded schedule.
    fn deliver(&mut self, step: &StepOutput) {
        for lane in 0..self.lanes.len() {
            let finished = {
                let Some(slot) = self.lanes[lane].as_mut() else { continue };
                let local = step.pos - slot.admitted_pos;
                let checksum = step.lane_checksums.get(lane).copied().unwrap_or(0.0);
                if let Some(toks) = &step.tokens {
                    slot.tokens.push(toks[lane]);
                }
                // the lane generates min(max_tokens, limit) useful
                // positions: with max_max_tokens clamped to L at startup
                // the two are equal, but stay defensive so a request
                // whose padded schedule got capped is never promised
                // (or counted as) more positions than the lane runs
                let wanted = slot.req.max_tokens.min(slot.limit);
                if local <= wanted {
                    slot.checksum_total += checksum as f64;
                    if let Some(tx) = &slot.req.stream {
                        let token = step.tokens.as_ref().map(|t| t[lane]);
                        if tx.send(StreamEvent { pos: local, token, checksum }).is_err() {
                            // receiver dropped: the streaming client hung
                            // up — flag the lane so `cancel_phase` frees
                            // it at the next step boundary
                            slot.req.cancel.store(true, Ordering::Relaxed);
                        }
                    }
                }
                if local >= wanted {
                    slot.req.stream = None; // early stop: close the event stream
                }
                local >= slot.limit
            };
            if finished {
                self.finish_lane(lane);
            }
        }
    }

    fn finish_lane(&mut self, lane: usize) {
        let Some(slot) = self.lanes[lane].take() else { return };
        let tokens = if slot.tokens.is_empty() {
            None
        } else {
            Some(slot.tokens[..slot.req.max_tokens.min(slot.tokens.len())].to_vec())
        };
        let result = LaneResult {
            tokens,
            steps: slot.limit,
            checksum_total: slot.checksum_total,
            admitted_pos: slot.admitted_pos,
            queue_ms: slot.queue_ms,
            gen_ms: slot.admitted_at.elapsed().as_secs_f64() * 1e3,
            batch_size: slot.batch_size,
            evictions: slot.evictions,
        };
        let _ = slot.req.reply.send(Ok(result));
        self.inflight.fetch_sub(1, Ordering::Relaxed);
    }

    /// Fail exactly one busy lane with a structured error; the lane frees
    /// at this step boundary and can be re-admitted immediately.
    fn fail_lane(&mut self, lane: usize, msg: &str) {
        let Some(slot) = self.lanes[lane].take() else { return };
        let _ = slot.req.reply.send(Err(msg.to_string()));
        self.inflight.fetch_sub(1, Ordering::Relaxed);
        self.counters.lock().lanes_failed_total += 1;
    }

    /// Fail every busy lane (engine error or panic): each admitted request
    /// gets the error; queued requests stay queued for the next session.
    /// Dropping the session here is the panic-safe teardown path: AsyncTau's
    /// Drop drains in-flight tile jobs swallowing join errors, and the
    /// worker-side readiness guard has already balanced `end_write` on any
    /// panicking job, so the take() can neither hang nor re-panic. Pager
    /// checkpoints live *outside* the session and survive untouched.
    fn fail_busy(&mut self, msg: &str) {
        for lane in 0..self.lanes.len() {
            self.fail_lane(lane, msg);
        }
        self.session = None;
    }

    /// Step-boundary sweep for requests that should stop early: the client
    /// hung up (cancel flag) or the deadline passed. Busy lanes are failed
    /// and freed for re-admission; queued and paged-out requests are
    /// dropped before they ever (re)occupy a lane.
    fn cancel_phase(&mut self) {
        let now = Instant::now();
        for lane in 0..self.lanes.len() {
            let Some(c) = self.lanes[lane].as_ref().and_then(|s| check_cancel(&s.req, now))
            else {
                continue;
            };
            self.note_cancel(&c);
            self.fail_lane(lane, c.message());
        }
        let mut i = 0;
        while i < self.queue.len() {
            match check_cancel(&self.queue[i], now) {
                None => i += 1,
                Some(c) => {
                    let req = self.queue.remove(i).unwrap();
                    self.note_cancel(&c);
                    let _ = req.reply.send(Err(c.message().to_string()));
                    self.inflight.fetch_sub(1, Ordering::Relaxed);
                }
            }
        }
        let mut i = 0;
        while i < self.evicted.len() {
            match check_cancel(&self.evicted[i].slot.req, now) {
                None => i += 1,
                Some(c) => {
                    let e = self.evicted.remove(i);
                    if let Some(p) = self.pager.as_mut() {
                        p.discard(e.ckpt);
                    }
                    self.note_cancel(&c);
                    let _ = e.slot.req.reply.send(Err(c.message().to_string()));
                    self.inflight.fetch_sub(1, Ordering::Relaxed);
                }
            }
        }
    }

    fn note_cancel(&mut self, c: &Cancel) {
        let mut g = self.counters.lock();
        match c {
            Cancel::Deadline => g.requests_deadline_exceeded += 1,
            Cancel::Disconnected => g.clients_disconnected += 1,
        }
    }

    /// A queued request could be admitted into the current session at the
    /// next step boundary: something queued fits the remaining schedule
    /// AND this session may still take admissions (mid-batch admissions
    /// are disabled under drain-then-refill once the session has moved).
    fn queue_admissible(&self) -> bool {
        let Some(sess) = self.session.as_ref() else { return !self.queue.is_empty() };
        if sess.steps_done() > 0 && !self.admit_mid_batch {
            return false;
        }
        let remaining = sess.remaining();
        self.queue.iter().any(|r| lane_len(r.max_tokens, self.horizon) <= remaining)
    }

    /// A checkpoint can still be restored by the *current* session (its
    /// suspension position has not been stepped past) — keeps an
    /// otherwise-idle session alive until the restore point.
    fn resumes_reachable(&self) -> bool {
        let Some(sess) = self.session.as_ref() else { return false };
        let now = sess.steps_done();
        self.evicted.iter().any(|e| e.ckpt.pos() >= now)
    }

    fn publish_gauges(&self) {
        let mut c = self.counters.lock();
        c.queue_depth = self.queue.len() as u64;
        c.lanes_busy = self.busy_lanes() as u64;
        c.pager_resident_values = self.pager.as_ref().map_or(0, |p| p.resident_values() as u64);
    }

    /// One step boundary: cancel, admit, advance one position, deliver,
    /// and retire the session when it has nothing left to do.
    fn tick(&mut self) -> Result<()> {
        self.cancel_phase();
        self.admit_phase();
        if self.session.is_some() {
            let step = self.session.as_mut().unwrap().step()?;
            self.deliver(&step);
            // retire: schedule exhausted, or every lane idle with nothing
            // admissible left (a fresh session can always fit the queue)
            // and no checkpoint still restorable at a later position of
            // this session — an idle session otherwise keeps stepping
            // toward the restore point (bounded by the horizon)
            let done = step.done;
            let parked = self.busy_lanes() == 0
                && !self.queue_admissible()
                && !self.resumes_reachable();
            if done || parked {
                if let Some(sess) = self.session.take() {
                    // finish() drains in-flight async tiles before the
                    // store drops — required even for an early retire
                    let _ = sess.finish();
                    self.counters.lock().batches_run += 1;
                }
                // a `done` session cannot have stragglers (admission
                // guarantees limit <= remaining), but stay defensive
                self.fail_busy("session retired with the lane still running");
            }
        }
        self.publish_gauges();
        Ok(())
    }
}

/// Why a request is being cancelled at a step boundary.
enum Cancel {
    Deadline,
    Disconnected,
}

impl Cancel {
    fn message(&self) -> &'static str {
        match self {
            Cancel::Deadline => "deadline exceeded",
            Cancel::Disconnected => "client disconnected",
        }
    }
}

/// Deadline first: a request that is both late *and* abandoned reports
/// the deadline (the deterministic one of the two).
fn check_cancel(req: &GenRequest, now: Instant) -> Option<Cancel> {
    if req.deadline.is_some_and(|d| now >= d) {
        return Some(Cancel::Deadline);
    }
    if req.cancel.load(Ordering::Relaxed) {
        return Some(Cancel::Disconnected);
    }
    None
}

impl Server {
    /// Bind and start serving. `port = 0` picks an ephemeral port.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(cfg.bind_addr())
            .with_context(|| format!("bind {}", cfg.bind_addr()))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let (req_tx, req_rx) = channel::<GenRequest>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let counters = Counters::new();
        let inflight = Arc::new(AtomicU64::new(0));
        let conns = Arc::new(AtomicU64::new(0));
        let healthy = Arc::new(AtomicBool::new(true));
        let draining = Arc::new(AtomicBool::new(false));

        // fault injection: the FI_FAULTS env var wins over the config
        // spec so a chaos harness can arm faults without a config file
        match crate::util::faultpoint::install_from_env() {
            Ok(Some(spec)) => {
                eprintln!("flashinfer: fault injection armed from FI_FAULTS: {spec}");
            }
            Ok(None) => {
                if !cfg.faults.is_empty() {
                    crate::util::faultpoint::install(&cfg.faults)
                        .with_context(|| format!("install fault spec {:?}", cfg.faults))?;
                    eprintln!("flashinfer: fault injection armed from config: {}", cfg.faults);
                }
            }
            Err(e) => anyhow::bail!("invalid FI_FAULTS: {e:#}"),
        }

        // ---- engine worker (owns the non-Send PJRT state) ----
        // ready payload: the /v1/info document plus the *effective*
        // max_max_tokens (clamped to the model's L — only the worker
        // knows dims), which the front-end validation must agree on
        let (ready_tx, ready_rx) = channel::<Result<(Json, usize), String>>();
        let ecfg = cfg.clone();
        let wcounters = counters.clone();
        let winflight = inflight.clone();
        let whealthy = healthy.clone();
        let wdraining = draining.clone();
        let engine_thread = thread::Builder::new()
            .name("fi-engine".into())
            .spawn(move || {
                let rt = match Runtime::load(&ecfg.artifacts) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("load runtime: {e:#}")));
                        return;
                    }
                };
                let mut engine = match Engine::new(&rt, ecfg.engine) {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("init engine: {e:#}")));
                        return;
                    }
                };
                let dims = rt.dims;
                let mut ecfg = ecfg;
                // A request with max_tokens in (L, max_max_tokens] would
                // get a lane schedule capped at L (`lane_len`) yet be
                // accepted — and previously *accounted* — as max_tokens
                // positions. Clamp the advertised ceiling to what a lane
                // can actually run, once, loudly.
                if ecfg.max_max_tokens > dims.l {
                    eprintln!(
                        "flashinfer: max_max_tokens {} exceeds the schedule ceiling L={}; \
                         clamping (a lane can generate at most L positions)",
                        ecfg.max_max_tokens, dims.l
                    );
                    ecfg.max_max_tokens = dims.l;
                }
                // Cold-start: derive every per-U rho structure (spectra +
                // PJRT tau executables) for the largest session a request
                // can trigger, so the first request's measured gen_ms
                // contains no one-time derivation cost.
                let horizon = lane_len(ecfg.max_max_tokens, dims.l);
                if let Err(e) = engine.prewarm(horizon) {
                    let _ = ready_tx.send(Err(format!("prewarm engine: {e:#}")));
                    return;
                }
                let info = info_json(&ecfg, &ecfg.engine, &rt);
                let _ = ready_tx.send(Ok((info, ecfg.max_max_tokens)));
                let engine = engine; // freeze: the scheduler borrows it
                let window = Duration::from_millis(ecfg.batch_window_ms);
                let pager = if ecfg.paging && ecfg.continuous_admission {
                    Some(engine.make_pager(ecfg.pager_capacity_mb))
                } else {
                    None
                };
                let lcounters = wcounters.clone();
                let mut sched = Scheduler::new(
                    &engine,
                    horizon,
                    ecfg.continuous_admission,
                    pager,
                    wcounters,
                    winflight,
                );
                let mut budget = RestartBudget::new(
                    ecfg.restart_budget,
                    Duration::from_secs(ecfg.restart_window_s),
                );
                let mut disconnected = false;
                loop {
                    if wdraining.load(Ordering::Relaxed) {
                        // graceful shutdown: stragglers get a retryable
                        // 503 instead of hanging past the drain deadline
                        sched.fail_busy("shutting down, retry later");
                        sched.fail_queued("shutting down, retry later");
                        sched.fail_evicted("shutting down, retry later");
                        break;
                    }
                    if sched.is_idle() {
                        if disconnected {
                            break;
                        }
                        // block for the first request; drain co-arrivals
                        // within the window so they share one session
                        match collect_batch(&req_rx, dims.b, window) {
                            Some(batch) => {
                                for r in batch {
                                    sched.enqueue(r);
                                }
                            }
                            None => {
                                // all senders gone: re-check the drain
                                // flag at the loop top before exiting
                                disconnected = true;
                                continue;
                            }
                        }
                    } else {
                        // step boundary: pick up new arrivals non-blocking
                        loop {
                            match req_rx.try_recv() {
                                Ok(r) => sched.enqueue(r),
                                Err(TryRecvError::Empty) => break,
                                Err(TryRecvError::Disconnected) => {
                                    disconnected = true;
                                    break;
                                }
                            }
                        }
                    }
                    // One supervised step boundary. On panic every busy
                    // lane gets a structured error and the (possibly
                    // inconsistent) Session is dropped via the panic-safe
                    // drain, so no broken invariant survives into the
                    // next iteration; pager checkpoints are preserved and
                    // a fresh session opens on the next admissible tick.
                    match catch_unwind(AssertUnwindSafe(|| sched.tick())) {
                        Ok(Ok(())) => {}
                        Ok(Err(e)) => sched.fail_busy(&format!("generate: {e:#}")),
                        Err(payload) => {
                            let msg = payload_text(payload.as_ref());
                            eprintln!("flashinfer: engine step panicked: {msg}");
                            sched.fail_busy(&format!("engine panicked: {msg}"));
                            lcounters.lock().engine_restarts_total += 1;
                            if !budget.record(Instant::now()) {
                                eprintln!(
                                    "flashinfer: engine restart budget exhausted \
                                     (> {} panics within {}s); marking unhealthy",
                                    ecfg.restart_budget, ecfg.restart_window_s
                                );
                                lcounters.lock().healthy = 0;
                                whealthy.store(false, Ordering::Relaxed);
                            }
                        }
                    }
                }
            })
            .context("spawn engine thread")?;

        let (info, effective_max) = match ready_rx.recv() {
            Ok(Ok(ready)) => ready,
            Ok(Err(e)) => anyhow::bail!("engine failed to start: {e}"),
            Err(_) => anyhow::bail!("engine thread died during startup"),
        };
        // adopt the worker's clamped ceiling so front-door validation,
        // token accounting, and the engine's lane schedules all agree
        let mut cfg = cfg;
        cfg.max_max_tokens = effective_max;
        cfg.default_max_tokens = cfg.default_max_tokens.min(effective_max);

        let shared = Arc::new(Shared {
            cfg,
            counters,
            queue: Mutex::new(Some(req_tx)),
            inflight,
            conns,
            healthy,
            draining,
            info,
        });

        // ---- accept loop ----
        let sd = shutdown.clone();
        let sh = shared.clone();
        let accept_thread = thread::Builder::new()
            .name("fi-accept".into())
            .spawn(move || {
                while !sd.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            // connection-cap shed: a flood of sockets must
                            // not exhaust the process's thread/fd budget
                            let cap = sh.cfg.max_connections as u64;
                            if sh.conns.load(Ordering::Relaxed) >= cap {
                                sh.counters.lock().conn_shed_total += 1;
                                let resp = Response::unavailable(
                                    "server at connection capacity, retry later",
                                    1,
                                );
                                let _ = write_response(&mut stream, &resp);
                                continue;
                            }
                            sh.conns.fetch_add(1, Ordering::Relaxed);
                            let sh2 = sh.clone();
                            let spawned =
                                thread::Builder::new().name("fi-conn".into()).spawn(move || {
                                    let _guard = ConnGuard(sh2.conns.clone());
                                    handle_connection(stream, sh2);
                                });
                            if let Err(e) = spawned {
                                // the stream moved into the dropped
                                // closure, so no response can be written —
                                // undo the count and say why
                                sh.conns.fetch_sub(1, Ordering::Relaxed);
                                eprintln!("flashinfer: spawn fi-conn failed: {e}");
                            }
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .context("spawn accept thread")?;

        Ok(Server {
            addr,
            shutdown,
            shared: shared.clone(),
            accept_thread: Some(accept_thread),
            engine_thread: Some(engine_thread),
        })
    }

    /// Graceful shutdown: stop accepting, give in-flight requests up to
    /// `drain_deadline_ms` to finish, then flip the draining flag so the
    /// engine fails stragglers with a retryable 503 and exits.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        let deadline = Instant::now() + Duration::from_millis(self.shared.cfg.drain_deadline_ms);
        while self.shared.inflight.load(Ordering::Relaxed) > 0 && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(10));
        }
        // flip draining *before* dropping the queue sender: a worker
        // blocked in collect_batch unparks on the drop and re-checks the
        // flag, failing stragglers with "shutting down, retry later"
        self.shared.draining.store(true, Ordering::Relaxed);
        *self.shared.queue.lock().unwrap_or_else(PoisonError::into_inner) = None;
        if let Some(t) = self.engine_thread.take() {
            let _ = t.join();
        }
    }
}

fn info_json(cfg: &ServerConfig, eng: &EngineOpts, rt: &Runtime) -> Json {
    let d = rt.dims;
    Json::from_pairs(vec![
        ("variant", Json::Str(d.variant.as_str().into())),
        ("M", Json::Num(d.m as f64)),
        ("D", Json::Num(d.d as f64)),
        ("L", Json::Num(d.l as f64)),
        ("B", Json::Num(d.b as f64)),
        ("V", Json::Num(d.v as f64)),
        ("method", Json::Str(eng.method.as_str().into())),
        ("tau", Json::Str(eng.tau.as_str().into())),
        ("async_mixer", Json::Bool(eng.async_mixer)),
        ("split_min_u", Json::Num(eng.split_min_u as f64)),
        ("mixer_workers", Json::Num(eng.mixer_workers as f64)),
        ("continuous_admission", Json::Bool(cfg.continuous_admission)),
        ("max_queue", Json::Num(cfg.max_queue as f64)),
        ("paging", Json::Bool(cfg.paging && cfg.continuous_admission)),
        ("pager_capacity_mb", Json::Num(cfg.pager_capacity_mb as f64)),
        ("max_max_tokens", Json::Num(cfg.max_max_tokens as f64)),
        ("deadline_ms", Json::Num(cfg.deadline_ms as f64)),
        ("max_connections", Json::Num(cfg.max_connections as f64)),
        ("restart_budget", Json::Num(cfg.restart_budget as f64)),
        ("restart_window_s", Json::Num(cfg.restart_window_s as f64)),
        ("drain_deadline_ms", Json::Num(cfg.drain_deadline_ms as f64)),
        ("artifacts", Json::Str(cfg.artifacts.display().to_string())),
    ])
}

fn handle_connection(mut stream: TcpStream, shared: Arc<Shared>) {
    let _ = configure_stream(
        &stream,
        shared.cfg.socket_read_timeout_ms,
        shared.cfg.socket_write_timeout_ms,
    );
    let req = match read_request(&mut stream) {
        Ok(req) => req,
        Err(e) => {
            let _ = write_response(&mut stream, &Response::bad_request(&format!("{e:#}")));
            return;
        }
    };
    if req.method == "POST" && req.path == "/v1/generate" {
        // generation writes its own response: one buffered JSON document,
        // or a chunked NDJSON stream
        generate(&req, &shared, &mut stream);
        return;
    }
    let resp = route(&req, &shared);
    let _ = write_response(&mut stream, &resp);
}

fn route(req: &Request, shared: &Shared) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => {
            // latched by the supervisor once the restart budget is
            // exhausted: a load balancer sees a deterministic 503, not a
            // flapping crash loop
            if shared.healthy.load(Ordering::Relaxed) {
                Response::json(200, "{\"status\":\"ok\"}".into())
            } else {
                let restarts = shared.counters.lock().engine_restarts_total;
                let body = Json::from_pairs(vec![
                    ("status", Json::Str("unhealthy".into())),
                    ("engine_restarts", Json::Num(restarts as f64)),
                ]);
                Response::json(503, body.to_string())
            }
        }
        ("GET", "/metrics") => Response::text(200, shared.counters.lock().render()),
        ("GET", "/v1/info") => {
            let mut info = shared.info.clone();
            let restarts = shared.counters.lock().engine_restarts_total;
            info.set("engine_restarts", Json::Num(restarts as f64));
            info.set("healthy", Json::Bool(shared.healthy.load(Ordering::Relaxed)));
            let faults = crate::util::faultpoint::active_spec().unwrap_or_default();
            info.set("faults", Json::Str(faults));
            Response::json(200, info.to_string())
        }
        ("POST" | "GET", _) => Response::not_found(),
        _ => Response::json(405, "{\"error\":\"method not allowed\"}".into()),
    }
}

/// Parse the optional per-request sampling overrides.
fn parse_sampling(j: &Json) -> std::result::Result<SamplingParams, String> {
    let mut s = SamplingParams::default();
    if let Some(v) = j.get("temperature") {
        s.temperature = Some(v.as_f64().ok_or("temperature must be a number")? as f32);
    }
    if let Some(v) = j.get("top_k") {
        s.top_k = Some(v.as_usize().ok_or("top_k must be a non-negative integer")?);
    }
    if let Some(v) = j.get("sigma") {
        s.sigma = Some(v.as_f64().ok_or("sigma must be a number")? as f32);
    }
    if let Some(v) = j.get("seed") {
        s.seed = Some(v.as_i64().ok_or("seed must be an integer")? as u64);
    }
    Ok(s)
}

fn generate(req: &Request, shared: &Shared, stream: &mut TcpStream) {
    shared.counters.lock().requests_total += 1;
    if shared.draining.load(Ordering::Relaxed) {
        shared.counters.lock().requests_failed += 1;
        let _ = write_response(stream, &Response::unavailable("shutting down, retry later", 1));
        return;
    }
    let reject = |msg: String| {
        shared.counters.lock().requests_failed += 1;
        Response::bad_request(&msg)
    };
    let body = match req.body_str() {
        Ok(s) if !s.trim().is_empty() => s,
        _ => "{}",
    };
    let j = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => {
            let _ = write_response(stream, &reject(format!("invalid JSON: {e}")));
            return;
        }
    };
    let max_tokens = j
        .get("max_tokens")
        .and_then(Json::as_usize)
        .unwrap_or(shared.cfg.default_max_tokens);
    if max_tokens == 0 || max_tokens > shared.cfg.max_max_tokens {
        let msg = format!("max_tokens must be in [1, {}]", shared.cfg.max_max_tokens);
        let _ = write_response(stream, &reject(msg));
        return;
    }
    let sampling = match parse_sampling(&j) {
        Ok(s) => s,
        Err(msg) => {
            let _ = write_response(stream, &reject(msg));
            return;
        }
    };
    let want_stream = j.get("stream").and_then(Json::as_bool).unwrap_or(false);
    let req_deadline_ms = match j.get("deadline_ms") {
        None => None,
        Some(v) => match v.as_usize() {
            Some(ms) => Some(ms as u64),
            None => {
                let msg = "deadline_ms must be a non-negative integer".to_string();
                let _ = write_response(stream, &reject(msg));
                return;
            }
        },
    };
    // effective deadline: the sooner of the server-wide and per-request
    // budgets (0 or absent = unbounded on that side)
    let mut budget_ms = u64::MAX;
    if shared.cfg.deadline_ms > 0 {
        budget_ms = budget_ms.min(shared.cfg.deadline_ms);
    }
    if let Some(ms) = req_deadline_ms {
        if ms > 0 {
            budget_ms = budget_ms.min(ms);
        }
    }
    let deadline =
        (budget_ms != u64::MAX).then(|| Instant::now() + Duration::from_millis(budget_ms));

    // shed before enqueueing: a bounded *waiting* queue keeps overload
    // failures fast and explicit instead of timing out 600 s later.
    // waiting = accepted-but-unfinished minus the lanes actively serving
    // (the busy gauge lags by at most one step boundary, which only ever
    // sheds a hair early under a full batch — never while lanes idle)
    let waiting = shared
        .inflight
        .load(Ordering::Relaxed)
        .saturating_sub(shared.counters.lock().lanes_busy);
    if waiting >= shared.cfg.max_queue as u64 {
        let mut c = shared.counters.lock();
        c.requests_failed += 1;
        c.requests_shed += 1;
        drop(c);
        let _ = write_response(stream, &Response::too_many_requests());
        return;
    }

    let (tx, rx) = channel();
    let (event_tx, event_rx) = if want_stream {
        let (etx, erx) = channel();
        (Some(etx), Some(erx))
    } else {
        (None, None)
    };
    let cancel = Arc::new(AtomicBool::new(false));
    let request = GenRequest {
        max_tokens,
        sampling,
        enqueued: Instant::now(),
        reply: tx,
        stream: event_tx,
        deadline,
        cancel: cancel.clone(),
    };
    shared.inflight.fetch_add(1, Ordering::Relaxed);
    let sent = {
        let q = shared.queue.lock().unwrap_or_else(PoisonError::into_inner);
        match q.as_ref() {
            Some(tx) => tx.send(request).is_ok(),
            None => false, // draining: the sender is already gone
        }
    };
    if !sent {
        shared.inflight.fetch_sub(1, Ordering::Relaxed);
        shared.counters.lock().requests_failed += 1;
        let resp = Response::unavailable("engine unavailable, retry later", 1);
        let _ = write_response(stream, &resp);
        return;
    }
    match event_rx {
        Some(events) => stream_reply(shared, stream, events, rx, max_tokens, &cancel),
        None => {
            let resp = buffered_reply(shared, stream, rx, max_tokens, &cancel);
            let _ = write_response(stream, &resp);
        }
    }
}

/// Best-effort client-disconnect probe: a nonblocking `peek` returning
/// `Ok(0)` means the peer sent EOF; hard errors (reset) count as gone,
/// `WouldBlock` means the peer is simply quiet.
fn socket_closed(stream: &TcpStream) -> bool {
    let mut buf = [0u8; 1];
    if stream.set_nonblocking(true).is_err() {
        return false;
    }
    let closed = match stream.peek(&mut buf) {
        Ok(0) => true,
        Ok(_) => false,
        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    let _ = stream.set_nonblocking(false);
    closed
}

/// Map a scheduler-side failure string to a wire response: shutdown
/// stragglers get a retryable 503, everything else a structured 500.
fn error_response(e: String) -> Response {
    if e.starts_with("shutting down") {
        Response::unavailable(&e, 1)
    } else {
        Response::json(500, Json::from_pairs(vec![("error", Json::Str(e))]).to_string())
    }
}

fn buffered_reply(
    shared: &Shared,
    stream: &TcpStream,
    rx: Receiver<std::result::Result<LaneResult, String>>,
    max_tokens: usize,
    cancel: &AtomicBool,
) -> Response {
    // Poll in short slices so a hung-up client is noticed while its lane
    // is still generating: the cancel flag makes the scheduler free the
    // lane at the next step boundary instead of running for a ghost.
    let overall = Instant::now() + Duration::from_secs(600);
    let outcome = loop {
        match rx.recv_timeout(Duration::from_millis(250)) {
            Ok(r) => break r,
            Err(RecvTimeoutError::Timeout) => {
                if socket_closed(stream) {
                    cancel.store(true, Ordering::Relaxed);
                    shared.counters.lock().requests_failed += 1;
                    // nobody is listening; the write below fails harmlessly
                    return Response::json(499, "{\"error\":\"client disconnected\"}".into());
                }
                if Instant::now() >= overall {
                    shared.counters.lock().requests_failed += 1;
                    return Response::json(408, "{\"error\":\"generation timed out\"}".into());
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // engine worker died without replying
                shared.counters.lock().requests_failed += 1;
                return Response::unavailable("engine unavailable, retry later", 1);
            }
        }
    };
    match outcome {
        Ok(lane) => {
            let mut c = shared.counters.lock();
            // positions the lane actually generated for this request —
            // never the raw ask (a capped schedule generates lane.steps)
            c.tokens_generated += max_tokens.min(lane.steps) as u64;
            c.request_latency.record_ns(lane.gen_ms * 1e6);
            drop(c);
            let mut pairs = vec![
                ("steps", Json::Num(lane.steps as f64)),
                ("max_tokens", Json::Num(max_tokens as f64)),
                ("checksum", Json::Num(lane.checksum_total)),
                ("admitted_pos", Json::Num(lane.admitted_pos as f64)),
                ("queue_ms", Json::Num(lane.queue_ms)),
                ("gen_ms", Json::Num(lane.gen_ms)),
                ("batch_size", Json::Num(lane.batch_size as f64)),
                ("evictions", Json::Num(lane.evictions as f64)),
            ];
            if let Some(toks) = lane.tokens {
                pairs.push((
                    "tokens",
                    Json::Arr(toks.into_iter().map(|t| Json::Num(t as f64)).collect()),
                ));
            }
            Response::json(200, Json::from_pairs(pairs).to_string())
        }
        Err(e) => {
            shared.counters.lock().requests_failed += 1;
            error_response(e)
        }
    }
}

/// Streaming reply: chunked NDJSON — one `{"pos":..,"token"|"checksum":..}`
/// line per position, flushed as the engine produces it, then one
/// `{"done":true,...}` summary line.
fn stream_reply(
    shared: &Shared,
    stream: &mut TcpStream,
    events: Receiver<StreamEvent>,
    reply: Receiver<std::result::Result<LaneResult, String>>,
    max_tokens: usize,
    cancel: &AtomicBool,
) {
    shared.counters.lock().stream_requests += 1;
    if write_chunked_head(stream, 200, "application/x-ndjson").is_err() {
        return;
    }
    let mut emitted = 0u64;
    let mut timed_out = false;
    loop {
        // same 600s guard as the buffered path: a wedged engine must not
        // hold this connection (and the server's shutdown join) forever
        match events.recv_timeout(Duration::from_secs(600)) {
            Ok(ev) => {
                let mut pairs = vec![("pos", Json::Num(ev.pos as f64))];
                match ev.token {
                    Some(t) => pairs.push(("token", Json::Num(t as f64))),
                    None => pairs.push(("checksum", Json::Num(ev.checksum as f64))),
                }
                let line = format!("{}\n", Json::from_pairs(pairs));
                if write_chunk(stream, line.as_bytes()).is_err() {
                    // client hung up: flag the lane for cancellation (the
                    // dropped event receiver alone would only stop the
                    // per-position sends, not free the lane)
                    cancel.store(true, Ordering::Relaxed);
                    break;
                }
                emitted += 1;
            }
            // lane's sender dropped: early stop reached or batch complete
            Err(RecvTimeoutError::Disconnected) => break,
            Err(RecvTimeoutError::Timeout) => {
                timed_out = true;
                break;
            }
        }
    }
    let tail = if timed_out {
        shared.counters.lock().requests_failed += 1;
        Json::from_pairs(vec![
            ("done", Json::Bool(true)),
            ("error", Json::Str("generation timed out".into())),
        ])
    } else {
        stream_tail(shared, reply, max_tokens, emitted)
    };
    let _ = write_chunk(stream, format!("{tail}\n").as_bytes());
    let _ = finish_chunks(stream);
}

/// Build the final summary line once the lane's event stream has closed:
/// the lane has completed (or errored), so the LaneResult is (or is
/// about to be) on the reply channel.
fn stream_tail(
    shared: &Shared,
    reply: Receiver<std::result::Result<LaneResult, String>>,
    max_tokens: usize,
    emitted: u64,
) -> Json {
    match reply.recv_timeout(Duration::from_secs(600)) {
        Ok(Ok(lane)) => {
            let mut c = shared.counters.lock();
            c.tokens_generated += max_tokens.min(lane.steps) as u64;
            c.stream_events += emitted;
            c.request_latency.record_ns(lane.gen_ms * 1e6);
            drop(c);
            Json::from_pairs(vec![
                ("done", Json::Bool(true)),
                ("steps", Json::Num(lane.steps as f64)),
                ("tokens_emitted", Json::Num(emitted as f64)),
                ("max_tokens", Json::Num(max_tokens as f64)),
                ("checksum", Json::Num(lane.checksum_total)),
                ("admitted_pos", Json::Num(lane.admitted_pos as f64)),
                ("queue_ms", Json::Num(lane.queue_ms)),
                ("gen_ms", Json::Num(lane.gen_ms)),
                ("batch_size", Json::Num(lane.batch_size as f64)),
                ("evictions", Json::Num(lane.evictions as f64)),
            ])
        }
        Ok(Err(e)) => {
            shared.counters.lock().requests_failed += 1;
            Json::from_pairs(vec![("done", Json::Bool(true)), ("error", Json::Str(e))])
        }
        Err(_) => {
            shared.counters.lock().requests_failed += 1;
            Json::from_pairs(vec![
                ("done", Json::Bool(true)),
                ("error", Json::Str("generation timed out".into())),
            ])
        }
    }
}
