//! Replica router + fleet supervisor.
//!
//! The router dispatches each accepted request to one replica's bounded
//! queue: checkpoint affinity first (a `"session"` key pins repeat
//! requests to the replica that may hold their evicted checkpoint), then
//! least-loaded among full-rotation replicas, falling back to probing
//! replicas when nothing is in full rotation — degraded service beats a
//! 503. The global shed only fires when *every* serviceable replica's
//! queue is full.
//!
//! The supervisor thread (`fi-router`) owns the recoverable half of the
//! failure model: it re-dispatches failed-over requests — queued work a
//! quarantining replica handed back (zero tokens produced, re-run from
//! scratch) and suspended sessions shipped out with their serialized
//! checkpoint attached (the receiving replica continues them
//! bit-identically) — respawns quarantined replicas once their
//! capped-exponential backoff has elapsed, and promotes respawned
//! replicas back into full rotation after a clean probe window.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use super::batcher::GenRequest;
use super::replica::{fail_request, Replica, ReplicaCtx, ReplicaState};
use crate::config::ServerConfig;
use crate::metrics::Counters;
use crate::util::json::Json;

/// Where a dispatch attempt ended up. The failure arms carry the request
/// back so the caller can answer its reply channel.
pub(crate) enum Dispatch {
    /// Queued on a replica; the reply flows over the request's channel.
    Ok,
    /// The `router_dispatch` fault point fired.
    Fault(String, GenRequest),
    /// Every serviceable replica's queue is at `max_queue` (global shed).
    AllFull(GenRequest),
    /// Zero serviceable replicas.
    NoReplica(GenRequest),
}

pub(crate) struct Router {
    replicas: Vec<Arc<Replica>>,
    /// session key → replica id: checkpoint-affinity pins. Stale pins
    /// (quarantined replica) are dropped on the next dispatch.
    affinity: Mutex<HashMap<String, usize>>,
    max_queue: usize,
}

fn plock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl Router {
    pub(crate) fn new(replicas: Vec<Arc<Replica>>, cfg: &ServerConfig) -> Router {
        Router { replicas, affinity: Mutex::new(HashMap::new()), max_queue: cfg.max_queue }
    }

    pub(crate) fn replicas(&self) -> &[Arc<Replica>] {
        &self.replicas
    }

    /// Replicas in full rotation.
    pub(crate) fn serving(&self) -> usize {
        self.replicas.iter().filter(|r| r.is_serving()).count()
    }

    /// Replicas that can take traffic at all (Serving or Probing).
    pub(crate) fn serviceable(&self) -> usize {
        self.replicas.iter().filter(|r| r.is_serviceable()).count()
    }

    /// Serviceable and with queue headroom: eligible for a dispatch.
    fn is_open(&self, r: &Arc<Replica>) -> bool {
        r.is_serviceable() && !r.queue_full(self.max_queue)
    }

    /// Route one request: affinity pin → least-loaded Serving → least-
    /// loaded Probing. A send that bounces (the replica quarantined
    /// between the pick and the send) retries the pick; the loop is
    /// bounded by the fleet size.
    pub(crate) fn dispatch(&self, mut req: GenRequest) -> Dispatch {
        if let Err(e) = crate::util::faultpoint::check("router_dispatch") {
            return Dispatch::Fault(format!("{e:#}"), req);
        }
        for _ in 0..=self.replicas.len() {
            if self.serviceable() == 0 {
                return Dispatch::NoReplica(req);
            }
            let mut target: Option<usize> = None;
            if let Some(key) = req.session.as_deref() {
                let pinned = plock(&self.affinity).get(key).copied();
                if let Some(id) = pinned {
                    if self.replicas.get(id).is_some_and(|r| self.is_open(r)) {
                        target = Some(id);
                    } else {
                        // the pinned replica left rotation: unpin so the
                        // session re-homes wherever it lands next. Its
                        // checkpoint is not lost — quarantine ships
                        // resident+spilled checkpoints back through the
                        // failback channel (the request re-arrives
                        // carrying its blob and re-pins on dispatch), and
                        // spilled blobs additionally survive on disk for
                        // the respawned replica's boot scan
                        plock(&self.affinity).remove(key);
                    }
                }
            }
            if target.is_none() {
                let pick = |state: ReplicaState| {
                    self.replicas
                        .iter()
                        .filter(|r| r.state() == state && self.is_open(r))
                        .min_by_key(|r| r.gauges.load.load(Ordering::Relaxed))
                        .map(|r| r.id)
                };
                target = pick(ReplicaState::Serving).or_else(|| pick(ReplicaState::Probing));
            }
            let Some(id) = target else {
                return Dispatch::AllFull(req);
            };
            let replica = &self.replicas[id];
            let session = req.session.clone();
            // count the load before the send so a racing dispatch on
            // another connection thread sees this one immediately
            replica.gauges.load.fetch_add(1, Ordering::Relaxed);
            match replica.send(req) {
                Ok(()) => {
                    if let Some(key) = session {
                        plock(&self.affinity).insert(key, id);
                    }
                    return Dispatch::Ok;
                }
                Err(back) => {
                    // quarantined under us: undo the count and re-pick
                    replica.gauges.load.fetch_sub(1, Ordering::Relaxed);
                    req = back;
                }
            }
        }
        Dispatch::NoReplica(req)
    }

    /// Roll the per-replica gauges up into the global counters (called at
    /// `/metrics` scrape time) and render the fleet-only metric lines.
    /// Single-replica servers keep every PR 7 metric name and meaning;
    /// the fleet lines are additive.
    pub(crate) fn publish(&self, counters: &Counters, healthy_latch: &AtomicBool) -> String {
        let n = self.replicas.len();
        let (mut queue_depth, mut lanes_busy, mut pager_resident) = (0u64, 0u64, 0u64);
        for r in &self.replicas {
            queue_depth += r.gauges.queue_depth.load(Ordering::Relaxed);
            lanes_busy += r.gauges.lanes_busy.load(Ordering::Relaxed);
            pager_resident += r.gauges.pager_resident_values.load(Ordering::Relaxed);
        }
        let serving = self.serving();
        {
            let mut c = counters.lock();
            c.queue_depth = queue_depth;
            c.lanes_busy = lanes_busy;
            c.pager_resident_values = pager_resident;
            if n > 1 {
                // fleet health is recoverable: serviceable replicas exist
                // = healthy enough to serve (the single-replica terminal
                // latch writes this field itself)
                c.healthy = u64::from(self.serviceable() > 0);
            }
        }
        // fi_replicas_healthy: full-rotation count for a fleet; the PR 7
        // latch for a fleet of one (so dashboards see the same 1→0 edge)
        let replicas_healthy = if n > 1 {
            serving as u64
        } else {
            u64::from(healthy_latch.load(Ordering::Relaxed))
        };
        let mut out = String::new();
        out.push_str(&format!(
            "# HELP fi_replicas engine replicas behind the router\n\
             # TYPE fi_replicas gauge\nfi_replicas {n}\n"
        ));
        out.push_str(&format!(
            "# HELP fi_replicas_healthy replicas in full rotation\n\
             # TYPE fi_replicas_healthy gauge\nfi_replicas_healthy {replicas_healthy}\n"
        ));
        out.push_str(
            "# HELP fi_router_queue_depth requests waiting in each replica's queue\n\
             # TYPE fi_router_queue_depth gauge\n",
        );
        for r in &self.replicas {
            out.push_str(&format!(
                "fi_router_queue_depth{{replica=\"{}\"}} {}\n",
                r.id,
                r.waiting()
            ));
        }
        out
    }

    /// Per-replica breakdown for `/v1/info` and the degraded `/health`
    /// body.
    pub(crate) fn replica_states(&self) -> Json {
        Json::Arr(
            self.replicas
                .iter()
                .map(|r| {
                    Json::from_pairs(vec![
                        ("replica", Json::Num(r.id as f64)),
                        ("state", Json::Str(r.state().as_str().into())),
                        (
                            "engine_restarts",
                            Json::Num(r.gauges.engine_restarts.load(Ordering::Relaxed) as f64),
                        ),
                        ("respawns", Json::Num(r.gauges.respawns.load(Ordering::Relaxed) as f64)),
                        (
                            "queue_depth",
                            Json::Num(r.gauges.queue_depth.load(Ordering::Relaxed) as f64),
                        ),
                        ("lanes_busy", Json::Num(r.gauges.lanes_busy.load(Ordering::Relaxed) as f64)),
                    ])
                })
                .collect(),
        )
    }

    /// Drop every replica's queue sender (shutdown nudge: workers blocked
    /// in `collect_batch` unpark and drain).
    pub(crate) fn close(&self) {
        for r in &self.replicas {
            r.clear_sender();
        }
    }

    /// Join every replica worker thread (shutdown, after `close`).
    pub(crate) fn join_workers(&self) {
        for r in &self.replicas {
            r.join_worker();
        }
    }
}

/// The `fi-router` supervisor loop: failover re-dispatch, quarantine
/// respawn with backoff, probe-window promotion. `shutdown` is flipped by
/// `Server::stop` after the workers have been joined, so any final
/// failback from a quarantining worker is still drained here.
pub(crate) fn supervise(
    router: Arc<Router>,
    ctx: ReplicaCtx,
    failback: Receiver<GenRequest>,
    shutdown: Arc<AtomicBool>,
) {
    let probe_window = Duration::from_millis(ctx.cfg.probe_window_ms);
    while !shutdown.load(Ordering::Relaxed) {
        match failback.recv_timeout(Duration::from_millis(50)) {
            Ok(req) => redispatch(&router, &ctx, req),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        for r in router.replicas() {
            if r.promote_due(probe_window) {
                r.promote();
                eprintln!(
                    "flashinfer: replica {} completed its probe window; back in rotation",
                    r.id
                );
            }
            if r.respawn_due() && !ctx.draining.load(Ordering::Relaxed) {
                eprintln!("flashinfer: respawning quarantined replica {}", r.id);
                r.join_worker();
                ctx.counters.lock().replica_restarts_total += 1;
                r.gauges.respawns.fetch_add(1, Ordering::Relaxed);
                r.clone().spawn_worker(ctx.clone(), None);
            }
        }
    }
    // shutdown: anything still on the failback channel is a straggler
    while let Ok(req) = failback.try_recv() {
        fail_request(req, "shutting down, retry later", &ctx);
    }
}

/// One failed-over request: spend a retry, re-dispatch to a healthy
/// replica, or fail it structurally once the retry budget is gone.
fn redispatch(router: &Router, ctx: &ReplicaCtx, mut req: GenRequest) {
    if ctx.draining.load(Ordering::Relaxed) {
        fail_request(req, "shutting down, retry later", ctx);
        return;
    }
    req.failovers += 1;
    if req.failovers > ctx.cfg.failover_retries {
        let msg = format!(
            "replica quarantined; failover budget exhausted after {} attempts",
            ctx.cfg.failover_retries
        );
        fail_request(req, &msg, ctx);
        return;
    }
    ctx.counters.lock().failovers_total += 1;
    match router.dispatch(req) {
        Dispatch::Ok => {}
        Dispatch::Fault(msg, req) => fail_request(req, &msg, ctx),
        Dispatch::AllFull(req) | Dispatch::NoReplica(req) => {
            fail_request(req, "no healthy replica, retry later", ctx)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::batcher::SamplingParams;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn req(session: Option<&str>) -> GenRequest {
        // the reply receiver is dropped immediately: these tests only
        // route, nothing ever answers the request
        let (tx, _rx) = channel();
        GenRequest {
            max_tokens: 4,
            sampling: SamplingParams::default(),
            enqueued: Instant::now(),
            reply: tx,
            stream: None,
            deadline: None,
            cancel: Arc::new(AtomicBool::new(false)),
            session: session.map(str::to_string),
            failovers: 0,
            prompt: None,
            resume: None,
        }
    }

    fn fleet(n: usize, max_queue: usize) -> (Router, Vec<Receiver<GenRequest>>) {
        let cfg = ServerConfig { max_queue, ..Default::default() };
        let replicas: Vec<Arc<Replica>> = (0..n).map(|i| Replica::new(i, &cfg)).collect();
        let rxs = replicas.iter().map(|r| r.test_rig()).collect();
        (Router::new(replicas, &cfg), rxs)
    }

    #[test]
    fn dispatch_is_least_loaded() {
        let (router, rxs) = fleet(2, 64);
        router.replicas()[0].gauges.load.store(3, Ordering::Relaxed);
        assert!(matches!(router.dispatch(req(None)), Dispatch::Ok));
        assert!(rxs[1].try_recv().is_ok(), "the emptier replica got the request");
        assert!(rxs[0].try_recv().is_err());
        // the dispatch itself bumped replica 1's load to 1
        assert_eq!(router.replicas()[1].gauges.load.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn affinity_pins_a_session_until_its_replica_leaves_rotation() {
        let (router, rxs) = fleet(2, 64);
        assert!(matches!(router.dispatch(req(Some("abc"))), Dispatch::Ok));
        let home = if rxs[0].try_recv().is_ok() { 0 } else { 1 };
        // load the home replica: the pin still wins over least-loaded
        router.replicas()[home].gauges.load.store(10, Ordering::Relaxed);
        assert!(matches!(router.dispatch(req(Some("abc"))), Dispatch::Ok));
        assert!(rxs[home].try_recv().is_ok(), "pinned despite the load");
        // home quarantines: the pin is dropped and the session re-homes
        router.replicas()[home].clear_sender();
        router.replicas()[home].test_enter(ReplicaState::Quarantined);
        assert!(matches!(router.dispatch(req(Some("abc"))), Dispatch::Ok));
        assert!(rxs[1 - home].try_recv().is_ok());
    }

    #[test]
    fn serving_beats_probing_and_shed_outcomes_are_distinct() {
        let (router, rxs) = fleet(2, 1);
        router.replicas()[0].test_enter(ReplicaState::Probing);
        // a serving replica wins even at higher load than a probing one
        router.replicas()[1].gauges.load.store(0, Ordering::Relaxed);
        assert!(matches!(router.dispatch(req(None)), Dispatch::Ok));
        assert!(rxs[1].try_recv().is_ok(), "full rotation preferred over probing");
        assert_eq!(router.serving(), 1);
        assert_eq!(router.serviceable(), 2);

        // both queues full (waiting >= max_queue=1): global shed
        for r in router.replicas() {
            r.gauges.load.store(2, Ordering::Relaxed);
            r.gauges.lanes_busy.store(0, Ordering::Relaxed);
        }
        assert!(matches!(router.dispatch(req(None)), Dispatch::AllFull(_)));

        // zero serviceable replicas: not a shed, an outage
        for r in router.replicas() {
            r.clear_sender();
            r.test_enter(ReplicaState::Quarantined);
        }
        assert!(matches!(router.dispatch(req(None)), Dispatch::NoReplica(_)));
        assert_eq!(router.serviceable(), 0);
    }

    #[test]
    fn publish_rolls_gauges_up_and_renders_fleet_lines() {
        let (router, _rxs) = fleet(2, 64);
        router.replicas()[0].gauges.queue_depth.store(2, Ordering::Relaxed);
        router.replicas()[1].gauges.queue_depth.store(3, Ordering::Relaxed);
        router.replicas()[1].gauges.lanes_busy.store(1, Ordering::Relaxed);
        router.replicas()[1].gauges.load.store(4, Ordering::Relaxed);
        let counters = Counters::new();
        let latch = AtomicBool::new(true);
        let text = router.publish(&counters, &latch);
        assert_eq!(counters.lock().queue_depth, 5);
        assert_eq!(counters.lock().lanes_busy, 1);
        assert_eq!(counters.lock().healthy, 1);
        assert!(text.contains("fi_replicas 2"));
        assert!(text.contains("fi_replicas_healthy 2"));
        assert!(text.contains("fi_router_queue_depth{replica=\"0\"} 0"));
        assert!(text.contains("fi_router_queue_depth{replica=\"1\"} 3"));
        let states = router.replica_states().to_string();
        assert!(states.contains("\"serving\""), "{states}");
    }
}
