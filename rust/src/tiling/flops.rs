//! FLOP accounting: closed-form costs (Propositions 1 & 2) and a runtime
//! counter the engines feed, so benches can print *measured* = *predicted*
//! and the §5.4(1) justification ("O(L log^2 L) vs Ω(L^2) FLOPs") is a
//! checked artifact rather than a claim.
//!
//! Conventions (per scalar op = 1 FLOP):
//! * complex radix-2 FFT of order n: (n/2)·log2(n) butterflies × 10 FLOPs;
//! * complex pointwise multiply: 6 FLOPs;
//! * multiply-accumulate: 2 FLOPs.

use super::schedule;

/// FLOPs of one direct tile of side `u` over `d` lanes (one group).
pub fn tile_direct_flops(u: usize, d: usize) -> u64 {
    // u^2 MACs per lane
    2 * (u as u64) * (u as u64) * d as u64
}

/// FLOPs of one *complex*-pipeline FFT tile of side `u` over `d` lanes,
/// with the filter spectrum precomputed (2 DFTs of order 2u + pointwise
/// product + scaled accumulation of the kept half). Kept as the model of
/// the pre-rfft kernel (`tile_conv_fft_into`), which survives as the
/// comparison baseline.
pub fn tile_fft_flops(u: usize, d: usize) -> u64 {
    let n = 2 * u as u64;
    let log = n.trailing_zeros() as u64;
    let fft = 5 * n * log; // (n/2) log2 n butterflies x 10 flops
    let per_lane = 2 * fft + 6 * n + 2 * (u as u64);
    per_lane * d as u64
}

/// FLOPs of one *rfft* (half-spectrum) tile of side `u` over `d` lanes —
/// the model of `tile_conv_rfft_into`, the native hot path: real inputs
/// pack into complex transforms of order u (not 2u), the pointwise product
/// touches u+1 bins (not 2u), plus O(u) pack/unpack twiddle passes
/// (~16 FLOPs per bin each way) and the scaled accumulation of the kept
/// half. Roughly half of [`tile_fft_flops`] once the transforms dominate.
pub fn tile_rfft_flops(u: usize, d: usize) -> u64 {
    let m = u as u64; // packed complex transform order
    let log = m.trailing_zeros() as u64;
    let fft = 5 * m * log; // (m/2) log2 m butterflies x 10 flops
    let twiddle = 2 * 16 * (m + 1); // forward unpack + inverse repack
    let per_lane = 2 * fft + twiddle + 6 * (m + 1) + 2 * m;
    per_lane * d as u64
}

/// Scratch bytes the *unfused* rfft tile kernel streams per group:
/// packed `[U][D]` re/im planes plus the `[(U+1)][D]` half-spectrum
/// re/im pair that round-trips through `TileScratch` (f32). The fused
/// kernel's FLOPs are identical to [`tile_rfft_flops`] — the win is
/// entirely in this traffic and in working-set residency, which is why
/// the models are bytes, not FLOPs (the Flash-Attention accounting).
pub fn tile_rfft_scratch_bytes(u: usize, d: usize) -> u64 {
    let packed = 2 * u as u64 * d as u64;
    let half_spec = 2 * (u as u64 + 1) * d as u64;
    4 * (packed + half_spec)
}

/// Resident scratch of one pass of the fused rfft kernel
/// (`tile_conv_rfft_fused_into`) at lane-block width `block_d`
/// (`fft::FUSED_BLOCK_D`): packed `[U][block_d]` re/im planes plus four
/// pair-temp rows. The half-spectrum never materializes, so the
/// working set shrinks by ~`d / block_d`× versus the unfused kernel and
/// total scratch traffic roughly halves (no half-spectrum write+read).
pub fn tile_rfft_fused_scratch_bytes(u: usize, block_d: usize) -> u64 {
    let packed = 2 * u as u64 * block_d as u64;
    let pair_temps = 4 * block_d as u64;
    4 * (packed + pair_temps)
}

/// Mixer-side FLOPs to generate `len` positions with the flash tiling,
/// per Proposition 2, for `g` groups (= B·M) of `d` lanes, counting red
/// cells (2 FLOPs per position-lane) plus all gray tiles. The `fft` branch
/// charges the rfft half-spectrum model — what the native FFT τ actually
/// runs — so `prop_flops` can assert measured == predicted exactly.
pub fn flash_total_flops(len: usize, g: usize, d: usize, fft: bool) -> u64 {
    let tiles: u64 = schedule::schedule(len)
        .map(|t| if fft { tile_rfft_flops(t.u, d) } else { tile_direct_flops(t.u, d) })
        .sum();
    let red = 2 * (len as u64) * d as u64;
    (tiles + red) * g as u64
}

/// Lazy baseline mixer FLOPs: position i costs i MACs per lane.
pub fn lazy_total_flops(len: usize, g: usize, d: usize) -> u64 {
    let macs: u64 = (1..=len as u64).sum::<u64>(); // includes the diagonal
    2 * macs * g as u64 * d as u64
}

/// Eager baseline mixer FLOPs: position i pushes to len-i positions, plus
/// its own diagonal.
pub fn eager_total_flops(len: usize, g: usize, d: usize) -> u64 {
    let macs: u64 = (1..=len as u64).map(|i| (len as u64 - i) + 1).sum();
    2 * macs * g as u64 * d as u64
}

/// Runtime FLOP counter fed by the engines/tau impls.
#[derive(Debug, Default, Clone)]
pub struct FlopCounter {
    pub mixer_flops: u64,
    pub tau_calls: u64,
    pub tau_call_hist: std::collections::BTreeMap<usize, u64>,
    /// Activation values read/written by tau calls (data-movement, §3.3).
    pub tau_io_values: u64,
}

impl FlopCounter {
    pub fn new() -> FlopCounter {
        FlopCounter::default()
    }

    pub fn record_tau(&mut self, u: usize, flops: u64, io_values: u64) {
        self.mixer_flops += flops;
        self.tau_calls += 1;
        *self.tau_call_hist.entry(u).or_insert(0) += 1;
        self.tau_io_values += io_values;
    }

    pub fn record_red(&mut self, flops: u64) {
        self.mixer_flops += flops;
    }

    pub fn merge(&mut self, other: &FlopCounter) {
        self.mixer_flops += other.mixer_flops;
        self.tau_calls += other.tau_calls;
        self.tau_io_values += other.tau_io_values;
        for (&u, &c) in &other.tau_call_hist {
            *self.tau_call_hist.entry(u).or_insert(0) += c;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn direct_tile_cost_is_quadratic() {
        assert_eq!(tile_direct_flops(1, 1), 2);
        assert_eq!(tile_direct_flops(4, 2), 64);
        assert_eq!(tile_direct_flops(8, 1), 128);
    }

    #[test]
    fn fft_tile_cost_is_quasilinear() {
        // ratio fft/direct should fall below 1 for large U
        let small = tile_fft_flops(2, 1) as f64 / tile_direct_flops(2, 1) as f64;
        let large = tile_fft_flops(2048, 1) as f64 / tile_direct_flops(2048, 1) as f64;
        assert!(small > 1.0, "small={small}");
        assert!(large < 0.2, "large={large}");
    }

    #[test]
    fn rfft_tile_cost_undercuts_complex_fft() {
        // the half-spectrum pipeline approaches half the complex cost as
        // the transforms dominate, and is never charged more at real sizes
        for u in [64usize, 256, 2048, 1 << 16] {
            let r = tile_rfft_flops(u, 1) as f64 / tile_fft_flops(u, 1) as f64;
            assert!(r < 1.0, "u={u}: ratio={r}");
        }
        let asymptotic = tile_rfft_flops(1 << 20, 1) as f64 / tile_fft_flops(1 << 20, 1) as f64;
        assert!(asymptotic < 0.6, "asymptotic ratio {asymptotic}");
    }

    #[test]
    fn rfft_tile_cost_is_quasilinear() {
        let small = tile_rfft_flops(2, 1) as f64 / tile_direct_flops(2, 1) as f64;
        let large = tile_rfft_flops(2048, 1) as f64 / tile_direct_flops(2048, 1) as f64;
        assert!(small > 1.0, "small={small}");
        assert!(large < 0.1, "large={large}");
    }

    #[test]
    fn fused_working_set_shrinks_with_block() {
        // the fused kernel's resident set is ~block_d/d of the unfused
        // kernel's streamed scratch (plus the pair temps), independent
        // of D — the memory-movement claim of the fused pass in numbers
        let (u, d, block_d) = (256usize, 64usize, 16usize);
        let unfused = tile_rfft_scratch_bytes(u, d);
        let fused = tile_rfft_fused_scratch_bytes(u, block_d);
        assert!(fused * 3 < unfused, "fused={fused} unfused={unfused}");
        // at block_d == d the fused pass still drops the half-spectrum pair
        let fused_full = tile_rfft_fused_scratch_bytes(u, d);
        assert!(fused_full < unfused);
        // and the resident set does not grow with D
        assert!(tile_rfft_fused_scratch_bytes(u, block_d) < tile_rfft_scratch_bytes(u, 2 * d));
    }

    #[test]
    fn flash_total_uses_rfft_model() {
        // closed form == sum over the schedule of the rfft tile model
        let (len, g, d) = (64usize, 3usize, 8usize);
        let tiles: u64 = schedule::schedule(len).map(|t| tile_rfft_flops(t.u, d)).sum();
        let want = (tiles + 2 * len as u64 * d as u64) * g as u64;
        assert_eq!(flash_total_flops(len, g, d, true), want);
    }

    #[test]
    fn lazy_equals_eager_total() {
        // both cover the same triangle (plus diagonal) — equal total MACs
        for len in [4usize, 64, 1024] {
            assert_eq!(lazy_total_flops(len, 3, 8), eager_total_flops(len, 3, 8));
        }
    }

    #[test]
    fn quadratic_vs_quasilinear_growth() {
        let (g, d) = (6, 64);
        let f1 = flash_fft_series(1 << 10, g, d);
        let f2 = flash_fft_series(1 << 12, g, d);
        let l1 = lazy_total_flops(1 << 10, g, d);
        let l2 = lazy_total_flops(1 << 12, g, d);
        // lazy grows ~16x for 4x length; flash ~4x·(log ratio)
        assert!(l2 / l1 >= 15);
        assert!(f2 / f1 <= 6);
    }

    fn flash_fft_series(len: usize, g: usize, d: usize) -> u64 {
        let tiles: u64 = schedule::schedule(len).map(|t| tile_fft_flops(t.u, d)).sum();
        (tiles + 2 * (len as u64) * d as u64) * g as u64
    }

    #[test]
    fn counter_accumulates_and_merges() {
        let mut a = FlopCounter::new();
        a.record_tau(4, 100, 8);
        a.record_tau(4, 100, 8);
        a.record_tau(8, 300, 16);
        let mut b = FlopCounter::new();
        b.record_tau(8, 300, 16);
        b.record_red(10);
        a.merge(&b);
        assert_eq!(a.mixer_flops, 810);
        assert_eq!(a.tau_calls, 4);
        assert_eq!(a.tau_call_hist[&4], 2);
        assert_eq!(a.tau_call_hist[&8], 2);
        assert_eq!(a.tau_io_values, 48);
    }
}
