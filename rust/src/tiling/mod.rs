//! The paper's core contribution: the fractal tiling of the contribution
//! triangle (Algorithm 1) and its FLOP accounting (Propositions 1 & 2).

pub mod flops;
pub mod schedule;

pub use flops::FlopCounter;
pub use schedule::{schedule, tau_call_histogram, tile_side, verify_invariants, Tile};
