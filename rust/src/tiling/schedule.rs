//! The fractal tile schedule (Algorithm 1 / Figure 1, right panel).
//!
//! At iteration `i` (1-indexed), after the red cell finalizes `z_i`, the
//! gray tile with side `U = ` largest power of two dividing `i` accounts
//! for the contribution of inputs `y[i-U+1 .. i]` to outputs
//! `z[i+1 .. i+U]`. Over `L = 2^P` positions this covers every (input,
//! output) pair with input < output exactly once, using `2^{P-1-q}` tiles
//! of side `2^q` (Proposition 1) — `O(L log^2 L)` total FLOPs when each
//! tile runs through the FFT primitive of Lemma 1.

/// Largest power of two dividing `i` — the side of the i-th gray tile.
#[inline]
pub fn tile_side(i: usize) -> usize {
    debug_assert!(i >= 1);
    1 << i.trailing_zeros()
}

/// One gray tile. Ranges are 1-indexed and inclusive, as in the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tile {
    /// Iteration at whose end this tile is processed.
    pub i: usize,
    /// Side length U (power of two, divides `i`).
    pub u: usize,
    /// Input range [src_l, src_r] = [i-U+1, i] of y.
    pub src_l: usize,
    pub src_r: usize,
    /// Output range [dst_l, dst_r] = [i+1, i+U] of z.
    pub dst_l: usize,
    pub dst_r: usize,
}

impl Tile {
    pub fn at(i: usize) -> Tile {
        let u = tile_side(i);
        Tile { i, u, src_l: i - u + 1, src_r: i, dst_l: i + 1, dst_r: i + u }
    }
}

/// The full schedule for generating `len` positions: one tile per
/// iteration `i in [1, len-1]` (iteration `len` has no future to fill).
pub fn schedule(len: usize) -> impl Iterator<Item = Tile> {
    debug_assert!(len.is_power_of_two(), "generation length must be a power of two");
    (1..len).map(Tile::at)
}

/// Histogram of tau calls by tile side: `(U, count)` pairs, ascending U.
/// Proposition 1: for L = 2^P there are 2^{P-1-q} tiles of side 2^q.
pub fn tau_call_histogram(len: usize) -> Vec<(usize, usize)> {
    let mut hist = std::collections::BTreeMap::new();
    for t in schedule(len) {
        *hist.entry(t.u).or_insert(0usize) += 1;
    }
    hist.into_iter().collect()
}

/// Check every schedule invariant by brute force (test/validation aid):
///
/// 1. availability: a tile processed at iteration i reads only y[.. i]
///    and writes only z[i+1 ..];
/// 2. coverage: every contribution pair (j -> t), j < t <= len, is covered
///    by exactly one tile; the diagonal (t -> t) belongs to red cells;
/// 3. order: the tile covering (j -> t) is processed before iteration t
///    finalizes z_t;
/// 4. bounds: tiles never write past position `len`.
pub fn verify_invariants(len: usize) -> Result<(), String> {
    let mut covered = vec![vec![0u8; len + 1]; len + 1]; // [src][dst]
    for t in schedule(len) {
        if t.src_l < 1 || t.dst_r > len {
            return Err(format!("tile {t:?} out of bounds"));
        }
        if t.src_r != t.i {
            return Err(format!("tile {t:?} reads future inputs"));
        }
        if t.dst_l != t.i + 1 {
            return Err(format!("tile {t:?} writes already-returned outputs"));
        }
        if t.u != tile_side(t.i) || t.i % t.u != 0 {
            return Err(format!("tile {t:?} has wrong side"));
        }
        for j in t.src_l..=t.src_r {
            for z in t.dst_l..=t.dst_r {
                covered[j][z] += 1;
                // order: tile runs at end of iteration t.i; z_z finalized at
                // iteration z; need t.i < z.
                if t.i >= z {
                    return Err(format!("tile {t:?} late for z_{z}"));
                }
            }
        }
    }
    for j in 1..=len {
        for z in 1..=len {
            let want = u8::from(j < z);
            if covered[j][z] != want {
                return Err(format!(
                    "pair ({j} -> {z}) covered {} times, want {want}",
                    covered[j][z]
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{self, ensure};

    #[test]
    fn tile_side_values() {
        let want = [1, 2, 1, 4, 1, 2, 1, 8, 1, 2, 1, 4, 1, 2, 1, 16];
        for (i, &w) in want.iter().enumerate() {
            assert_eq!(tile_side(i + 1), w, "i={}", i + 1);
        }
    }

    #[test]
    fn invariants_hold_for_all_small_l() {
        for p in 0..=9 {
            verify_invariants(1 << p).unwrap_or_else(|e| panic!("L=2^{p}: {e}"));
        }
    }

    #[test]
    fn histogram_matches_proposition_1() {
        for p in 1..=10u32 {
            let l = 1usize << p;
            let hist = tau_call_histogram(l);
            assert_eq!(hist.len(), p as usize);
            for (q, &(u, count)) in hist.iter().enumerate() {
                assert_eq!(u, 1 << q);
                assert_eq!(count, 1 << (p as usize - 1 - q), "L={l} q={q}");
            }
            // total tiles = L - 1
            assert_eq!(hist.iter().map(|&(_, c)| c).sum::<usize>(), l - 1);
        }
    }

    #[test]
    fn total_tau_io_is_l_log_l() {
        // §3.3: sum of tile sides = (L/2) log2 L — the data-movement claim.
        for p in 1..=12u32 {
            let l = 1usize << p;
            let total: usize = schedule(l).map(|t| t.u).sum();
            assert_eq!(total, (l / 2) * p as usize);
        }
    }

    #[test]
    fn tiles_partition_per_dst_column() {
        // every output position t receives exactly t-1 off-diagonal
        // contributions, split across tiles with power-of-two sides
        let l = 64;
        let mut per_dst = vec![0usize; l + 1];
        for t in schedule(l) {
            for z in t.dst_l..=t.dst_r {
                per_dst[z] += t.src_r - t.src_l + 1;
            }
        }
        for z in 1..=l {
            assert_eq!(per_dst[z], z - 1, "z={z}");
        }
    }

    #[test]
    fn property_random_l_invariants() {
        propcheck::check(
            "schedule-invariants",
            6,
            |rng| 1usize << rng.range(1, 8),
            |&l| {
                verify_invariants(l).map_err(|e| e)?;
                ensure(
                    schedule(l).count() == l - 1,
                    format!("tile count for L={l}"),
                )
            },
        );
    }

    #[test]
    fn large_tile_positions_are_rare() {
        // Fig 2c justification: 93.75% of positions use U <= 8
        let l = 4096;
        let small = schedule(l).filter(|t| t.u <= 8).count();
        let frac = small as f64 / (l - 1) as f64;
        assert!(frac > 0.93, "frac={frac}");
    }
}
