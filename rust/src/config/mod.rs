//! Configuration system: JSON config files + CLI overrides, layered as
//! defaults < file < flags (the launcher pattern of vLLM/MaxText-style
//! frameworks, sized to this system).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::cli::args::Args;
use crate::engine::{EngineOpts, Method};
use crate::tau::TauKind;
use crate::util::json::Json;

/// Server-level configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub host: String,
    pub port: u16,
    /// Artifact build directory (one model per server).
    pub artifacts: PathBuf,
    /// How long the scheduler waits (when idle) for co-arriving requests
    /// before starting a session.
    pub batch_window_ms: u64,
    /// Default/maximum tokens per request.
    pub default_max_tokens: usize,
    pub max_max_tokens: usize,
    /// Seed new requests into free lanes of the *running* batch at step
    /// boundaries (continuous admission). Off = legacy drain-then-refill:
    /// requests only start when the current session has fully drained.
    pub continuous_admission: bool,
    /// Waiting-queue bound: requests beyond this are shed with HTTP 429
    /// instead of growing the queue without limit.
    pub max_queue: usize,
    /// Session paging: under queue pressure the scheduler checkpoints the
    /// busy lane with the most remaining schedule into a slab pager and
    /// admits the waiting request, resuming the evicted lane later
    /// (requires continuous admission; off = evicting never happens and
    /// a request waits for a naturally free lane).
    pub paging: bool,
    /// Slab capacity for suspended-lane checkpoints, in megabytes.
    pub pager_capacity_mb: usize,
    /// Fold pending future contributions into the checkpoint at suspend
    /// (position-independent checkpoints, DESIGN.md §6). Off = every
    /// suspend takes the clock-aligned path and can only resume when the
    /// batch clock catches back up to the suspension position.
    pub fold: bool,
    /// Disk-spill directory for cold checkpoints. Empty = spilling off;
    /// each replica spills into its own `replica-<id>` subdirectory and
    /// rescans it at boot so spilled sessions survive a restart.
    pub spill_dir: String,
    /// Slab occupancy percentage above which the scheduler spills the
    /// oldest suspended checkpoints to `spill_dir`.
    pub spill_watermark_pct: u64,
    /// HTTP keep-alive: maximum requests served per connection before the
    /// server closes it (0 = no keep-alive, one request per connection).
    pub keepalive_max_requests: u64,
    /// Per-request wall-clock deadline in milliseconds, measured from
    /// enqueue (0 = none). A request may *lower* it via the JSON
    /// `deadline_ms` field; expired lanes are cancelled at the next step
    /// boundary and the request fails with a structured error.
    pub deadline_ms: u64,
    /// Concurrent connection-handler cap: accepted sockets beyond this
    /// many live `fi-conn` threads are shed with 503 + Retry-After
    /// instead of spawning threads without bound.
    pub max_connections: usize,
    /// Supervisor restart budget: more than this many engine panics
    /// inside `restart_window_s` latches the server unhealthy (`/health`
    /// 503) instead of flapping through endless restarts.
    pub restart_budget: usize,
    /// Rolling window (seconds) the restart budget is counted over.
    pub restart_window_s: u64,
    /// Graceful-shutdown drain deadline: requests still in flight this
    /// long after SIGTERM are failed with 503 + Retry-After.
    pub drain_deadline_ms: u64,
    /// Engine replicas behind the router. 1 (the default) keeps the PR 7
    /// single-engine behavior exactly: terminal health latch, unchanged
    /// metric names. N > 1 spawns N workers, each its own failure domain
    /// (private Scheduler + Pager + RestartBudget), with quarantine +
    /// supervised respawn instead of a terminal latch.
    pub replicas: usize,
    /// How many times a queued request that never produced a token may be
    /// re-dispatched to another replica after its replica is quarantined.
    pub failover_retries: u32,
    /// Initial respawn backoff after a replica is quarantined; doubles
    /// per consecutive quarantine, capped at `quarantine_backoff_max_ms`.
    pub quarantine_backoff_ms: u64,
    pub quarantine_backoff_max_ms: u64,
    /// A respawned replica serves probe traffic for this long without a
    /// panic before it is promoted back into full rotation.
    pub probe_window_ms: u64,
    /// Socket read/write timeouts for connection handlers, so one stuck
    /// peer cannot pin an `fi-conn` thread forever.
    pub socket_read_timeout_ms: u64,
    pub socket_write_timeout_ms: u64,
    /// Fault-injection spec (see `util::faultpoint`); the `FI_FAULTS`
    /// env var takes precedence. Empty = disabled.
    pub faults: String,
    pub engine: EngineOpts,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            host: "127.0.0.1".into(),
            port: 7070,
            artifacts: PathBuf::from("artifacts/synthetic"),
            batch_window_ms: 5,
            default_max_tokens: 256,
            max_max_tokens: 4096,
            continuous_admission: true,
            max_queue: 1024,
            paging: true,
            pager_capacity_mb: 256,
            fold: true,
            spill_dir: String::new(),
            spill_watermark_pct: 80,
            keepalive_max_requests: 32,
            deadline_ms: 0,
            max_connections: 256,
            restart_budget: 3,
            restart_window_s: 60,
            drain_deadline_ms: 5000,
            replicas: 1,
            failover_retries: 2,
            quarantine_backoff_ms: 500,
            quarantine_backoff_max_ms: 30_000,
            probe_window_ms: 2000,
            socket_read_timeout_ms: 10_000,
            socket_write_timeout_ms: 10_000,
            faults: String::new(),
            engine: EngineOpts {
                // serving opt-in: bound the per-position checksum ring so
                // long-lived streaming sessions cannot grow without limit
                // (library/test default stays unbounded); sized to the
                // largest request the server admits
                checksum_history: 4096,
                ..EngineOpts::default()
            },
        }
    }
}

impl ServerConfig {
    /// Layer a JSON config file over the defaults.
    pub fn from_file(path: &Path) -> Result<ServerConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let mut cfg = ServerConfig::default();
        cfg.apply_json(&j)?;
        Ok(cfg)
    }

    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        if let Some(v) = j.get("host").and_then(Json::as_str) {
            self.host = v.to_string();
        }
        if let Some(v) = j.get("port").and_then(Json::as_usize) {
            self.port = v as u16;
        }
        if let Some(v) = j.get("artifacts").and_then(Json::as_str) {
            self.artifacts = PathBuf::from(v);
        }
        if let Some(v) = j.get("batch_window_ms").and_then(Json::as_usize) {
            self.batch_window_ms = v as u64;
        }
        if let Some(v) = j.get("default_max_tokens").and_then(Json::as_usize) {
            self.default_max_tokens = v;
        }
        if let Some(v) = j.get("max_max_tokens").and_then(Json::as_usize) {
            self.max_max_tokens = v;
        }
        if let Some(v) = j.get("continuous_admission").and_then(Json::as_bool) {
            self.continuous_admission = v;
        }
        if let Some(v) = j.get("max_queue").and_then(Json::as_usize) {
            self.max_queue = v;
        }
        if let Some(v) = j.get("paging").and_then(Json::as_bool) {
            self.paging = v;
        }
        if let Some(v) = j.get("pager_capacity_mb").and_then(Json::as_usize) {
            self.pager_capacity_mb = v;
        }
        if let Some(v) = j.get("fold").and_then(Json::as_bool) {
            self.fold = v;
        }
        if let Some(v) = j.get("spill_dir").and_then(Json::as_str) {
            self.spill_dir = v.to_string();
        }
        if let Some(v) = j.get("spill_watermark_pct").and_then(Json::as_usize) {
            self.spill_watermark_pct = v as u64;
        }
        if let Some(v) = j.get("keepalive_max_requests").and_then(Json::as_usize) {
            self.keepalive_max_requests = v as u64;
        }
        if let Some(v) = j.get("deadline_ms").and_then(Json::as_usize) {
            self.deadline_ms = v as u64;
        }
        if let Some(v) = j.get("max_connections").and_then(Json::as_usize) {
            self.max_connections = v;
        }
        if let Some(v) = j.get("restart_budget").and_then(Json::as_usize) {
            self.restart_budget = v;
        }
        if let Some(v) = j.get("restart_window_s").and_then(Json::as_usize) {
            self.restart_window_s = v as u64;
        }
        if let Some(v) = j.get("drain_deadline_ms").and_then(Json::as_usize) {
            self.drain_deadline_ms = v as u64;
        }
        if let Some(v) = j.get("replicas").and_then(Json::as_usize) {
            self.replicas = v;
        }
        if let Some(v) = j.get("failover_retries").and_then(Json::as_usize) {
            self.failover_retries = v as u32;
        }
        if let Some(v) = j.get("quarantine_backoff_ms").and_then(Json::as_usize) {
            self.quarantine_backoff_ms = v as u64;
        }
        if let Some(v) = j.get("quarantine_backoff_max_ms").and_then(Json::as_usize) {
            self.quarantine_backoff_max_ms = v as u64;
        }
        if let Some(v) = j.get("probe_window_ms").and_then(Json::as_usize) {
            self.probe_window_ms = v as u64;
        }
        if let Some(v) = j.get("socket_read_timeout_ms").and_then(Json::as_usize) {
            self.socket_read_timeout_ms = v as u64;
        }
        if let Some(v) = j.get("socket_write_timeout_ms").and_then(Json::as_usize) {
            self.socket_write_timeout_ms = v as u64;
        }
        if let Some(v) = j.get("faults").and_then(Json::as_str) {
            self.faults = v.to_string();
        }
        if let Some(e) = j.get("engine") {
            if let Some(v) = e.get("method").and_then(Json::as_str) {
                self.engine.method = Method::parse(v)?;
            }
            if let Some(v) = e.get("tau").and_then(Json::as_str) {
                self.engine.tau = TauKind::parse(v)?;
            }
            if let Some(v) = e.get("threads").and_then(Json::as_usize) {
                self.engine.threads = v;
            }
            if let Some(v) = e.get("sample_sigma").and_then(Json::as_f64) {
                self.engine.sample_sigma = v as f32;
            }
            if let Some(v) = e.get("temperature").and_then(Json::as_f64) {
                self.engine.temperature = v as f32;
            }
            if let Some(v) = e.get("top_k").and_then(Json::as_usize) {
                self.engine.top_k = v;
            }
            if let Some(v) = e.get("seed").and_then(Json::as_i64) {
                self.engine.seed = v as u64;
            }
            if let Some(v) = e.get("async_mixer").and_then(Json::as_bool) {
                self.engine.async_mixer = v;
            }
            if let Some(v) = e.get("split_min_u").and_then(Json::as_usize) {
                self.engine.split_min_u = v;
            }
            if let Some(v) = e.get("mixer_workers").and_then(Json::as_usize) {
                self.engine.mixer_workers = v;
            }
            if let Some(v) = e.get("checksum_history").and_then(Json::as_usize) {
                self.engine.checksum_history = v;
            }
        }
        Ok(())
    }

    /// Layer CLI flags (highest precedence).
    pub fn apply_args(&mut self, a: &Args) -> Result<()> {
        if let Some(v) = a.get("host") {
            self.host = v.to_string();
        }
        self.port = a.get_usize("port", self.port as usize)? as u16;
        if let Some(v) = a.get("artifacts") {
            self.artifacts = PathBuf::from(v);
        }
        self.batch_window_ms = a.get_u64("batch-window-ms", self.batch_window_ms)?;
        self.default_max_tokens = a.get_usize("max-tokens", self.default_max_tokens)?;
        if a.has("no-admission") {
            self.continuous_admission = false;
        }
        self.max_queue = a.get_usize("max-queue", self.max_queue)?;
        if a.has("no-paging") {
            self.paging = false;
        }
        self.pager_capacity_mb = a.get_usize("pager-capacity-mb", self.pager_capacity_mb)?;
        if a.has("no-fold") {
            self.fold = false;
        }
        if let Some(v) = a.get("spill-dir") {
            self.spill_dir = v.to_string();
        }
        self.spill_watermark_pct =
            a.get_u64("spill-watermark-pct", self.spill_watermark_pct)?;
        self.keepalive_max_requests =
            a.get_u64("keepalive-max-requests", self.keepalive_max_requests)?;
        self.deadline_ms = a.get_u64("deadline-ms", self.deadline_ms)?;
        self.max_connections = a.get_usize("max-connections", self.max_connections)?;
        self.restart_budget = a.get_usize("restart-budget", self.restart_budget)?;
        self.restart_window_s = a.get_u64("restart-window-s", self.restart_window_s)?;
        self.drain_deadline_ms = a.get_u64("drain-deadline-ms", self.drain_deadline_ms)?;
        self.replicas = a.get_usize("replicas", self.replicas)?;
        self.failover_retries =
            a.get_usize("failover-retries", self.failover_retries as usize)? as u32;
        self.quarantine_backoff_ms =
            a.get_u64("quarantine-backoff-ms", self.quarantine_backoff_ms)?;
        self.quarantine_backoff_max_ms =
            a.get_u64("quarantine-backoff-max-ms", self.quarantine_backoff_max_ms)?;
        self.probe_window_ms = a.get_u64("probe-window-ms", self.probe_window_ms)?;
        self.socket_read_timeout_ms =
            a.get_u64("socket-read-timeout-ms", self.socket_read_timeout_ms)?;
        self.socket_write_timeout_ms =
            a.get_u64("socket-write-timeout-ms", self.socket_write_timeout_ms)?;
        if let Some(v) = a.get("faults") {
            self.faults = v.to_string();
        }
        if let Some(v) = a.get("method") {
            self.engine.method = Method::parse(v)?;
        }
        if let Some(v) = a.get("tau") {
            self.engine.tau = TauKind::parse(v)?;
        }
        self.engine.threads = a.get_usize("threads", self.engine.threads)?;
        self.engine.sample_sigma = a.get_f32("sigma", self.engine.sample_sigma)?;
        self.engine.temperature = a.get_f32("temperature", self.engine.temperature)?;
        self.engine.top_k = a.get_usize("top-k", self.engine.top_k)?;
        self.engine.seed = a.get_u64("seed", self.engine.seed)?;
        self.engine.mixer_workers = a.get_usize("mixer-workers", self.engine.mixer_workers)?;
        if a.has("sync-mixer") {
            // forcing sync wins over any --mixer-workers value: a
            // synchronous mixer is by definition single-worker
            self.engine.async_mixer = false;
            self.engine.mixer_workers = 1;
        }
        self.engine.split_min_u = a.get_usize("split-min-u", self.engine.split_min_u)?;
        self.engine.checksum_history =
            a.get_usize("checksum-history", self.engine.checksum_history)?;
        Ok(())
    }

    pub fn bind_addr(&self) -> String {
        format!("{}:{}", self.host, self.port)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cli::args::Schema;

    #[test]
    fn defaults_then_json_then_args() {
        let mut cfg = ServerConfig::default();
        let j = Json::parse(
            r#"{"port": 9000, "engine": {"method": "lazy", "tau": "rust-fft",
                "temperature": 0.5}}"#,
        )
        .unwrap();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.port, 9000);
        assert_eq!(cfg.engine.method, Method::Lazy);
        assert_eq!(cfg.engine.tau, TauKind::RustFft);

        let schema = Schema::new()
            .value("port", "")
            .value("method", "")
            .value("tau", "")
            .value("threads", "")
            .value("sigma", "")
            .value("temperature", "")
            .value("top-k", "")
            .value("seed", "")
            .value("host", "")
            .value("artifacts", "")
            .value("batch-window-ms", "")
            .value("max-tokens", "");
        let a = schema
            .parse(&["--method".to_string(), "flash".to_string(), "--port".to_string(), "7071".to_string()])
            .unwrap();
        cfg.apply_args(&a).unwrap();
        assert_eq!(cfg.port, 7071);
        assert_eq!(cfg.engine.method, Method::Flash);
        // json-set value survives when no flag overrides it
        assert!((cfg.engine.temperature - 0.5).abs() < 1e-6);
        assert_eq!(cfg.bind_addr(), "127.0.0.1:7071");
    }

    #[test]
    fn async_mixer_keys_layer_correctly() {
        let mut cfg = ServerConfig::default();
        // serving default: async on, one worker, bounded checksum ring
        assert!(cfg.engine.async_mixer);
        assert_eq!(cfg.engine.mixer_workers, 1);
        assert_eq!(cfg.engine.checksum_history, 4096);
        let j = Json::parse(
            r#"{"engine": {"async_mixer": false, "split_min_u": 64,
                "mixer_workers": 4, "checksum_history": 128}}"#,
        )
        .unwrap();
        cfg.apply_json(&j).unwrap();
        assert!(!cfg.engine.async_mixer);
        assert_eq!(cfg.engine.split_min_u, 64);
        assert_eq!(cfg.engine.mixer_workers, 4);
        assert_eq!(cfg.engine.checksum_history, 128);

        let schema = Schema::new()
            .switch("sync-mixer", "")
            .value("split-min-u", "")
            .value("mixer-workers", "")
            .value("checksum-history", "");
        let a = schema
            .parse(&[
                "--split-min-u".to_string(),
                "32".to_string(),
                "--mixer-workers".to_string(),
                "2".to_string(),
            ])
            .unwrap();
        let mut cfg2 = ServerConfig::default();
        cfg2.apply_args(&a).unwrap();
        assert!(cfg2.engine.async_mixer, "no --sync-mixer flag given");
        assert_eq!(cfg2.engine.split_min_u, 32);
        assert_eq!(cfg2.engine.mixer_workers, 2);

        // --sync-mixer forces a single worker even when --mixer-workers
        // asks for more (a synchronous mixer is single-worker by
        // definition), so the pair never reaches session validation
        let a = schema
            .parse(&[
                "--sync-mixer".to_string(),
                "--mixer-workers".to_string(),
                "8".to_string(),
            ])
            .unwrap();
        cfg2.apply_args(&a).unwrap();
        assert!(!cfg2.engine.async_mixer);
        assert_eq!(cfg2.engine.mixer_workers, 1);
    }

    #[test]
    fn admission_keys_layer_correctly() {
        let mut cfg = ServerConfig::default();
        assert!(cfg.continuous_admission, "admission on by default");
        assert_eq!(cfg.max_queue, 1024);
        let j = Json::parse(r#"{"continuous_admission": false, "max_queue": 32}"#).unwrap();
        cfg.apply_json(&j).unwrap();
        assert!(!cfg.continuous_admission);
        assert_eq!(cfg.max_queue, 32);

        let schema = Schema::new().switch("no-admission", "").value("max-queue", "");
        let a = schema
            .parse(&["--max-queue".to_string(), "8".to_string()])
            .unwrap();
        let mut cfg2 = ServerConfig::default();
        cfg2.apply_args(&a).unwrap();
        assert!(cfg2.continuous_admission, "no flag given: stays on");
        assert_eq!(cfg2.max_queue, 8);
        let a = schema.parse(&["--no-admission".to_string()]).unwrap();
        cfg2.apply_args(&a).unwrap();
        assert!(!cfg2.continuous_admission);
    }

    #[test]
    fn paging_keys_layer_correctly() {
        let mut cfg = ServerConfig::default();
        assert!(cfg.paging, "paging on by default");
        assert_eq!(cfg.pager_capacity_mb, 256);
        let j = Json::parse(r#"{"paging": false, "pager_capacity_mb": 64}"#).unwrap();
        cfg.apply_json(&j).unwrap();
        assert!(!cfg.paging);
        assert_eq!(cfg.pager_capacity_mb, 64);

        let schema = Schema::new().switch("no-paging", "").value("pager-capacity-mb", "");
        let a = schema
            .parse(&["--pager-capacity-mb".to_string(), "16".to_string()])
            .unwrap();
        let mut cfg2 = ServerConfig::default();
        cfg2.apply_args(&a).unwrap();
        assert!(cfg2.paging, "no flag given: stays on");
        assert_eq!(cfg2.pager_capacity_mb, 16);
        let a = schema.parse(&["--no-paging".to_string()]).unwrap();
        cfg2.apply_args(&a).unwrap();
        assert!(!cfg2.paging);
    }

    #[test]
    fn checkpoint_keys_layer_correctly() {
        let mut cfg = ServerConfig::default();
        assert!(cfg.fold, "folded checkpoints on by default");
        assert!(cfg.spill_dir.is_empty(), "spilling off by default");
        assert_eq!(cfg.spill_watermark_pct, 80);
        assert_eq!(cfg.keepalive_max_requests, 32);
        let j = Json::parse(
            r#"{"fold": false, "spill_dir": "/tmp/fi-spill",
                "spill_watermark_pct": 50, "keepalive_max_requests": 4}"#,
        )
        .unwrap();
        cfg.apply_json(&j).unwrap();
        assert!(!cfg.fold);
        assert_eq!(cfg.spill_dir, "/tmp/fi-spill");
        assert_eq!(cfg.spill_watermark_pct, 50);
        assert_eq!(cfg.keepalive_max_requests, 4);

        let schema = Schema::new()
            .switch("no-fold", "")
            .value("spill-dir", "")
            .value("spill-watermark-pct", "")
            .value("keepalive-max-requests", "");
        let a = schema
            .parse(&[
                "--spill-dir".to_string(),
                "/tmp/other".to_string(),
                "--keepalive-max-requests".to_string(),
                "0".to_string(),
            ])
            .unwrap();
        cfg.apply_args(&a).unwrap();
        assert!(!cfg.fold, "json-set value survives: no --no-fold given");
        assert_eq!(cfg.spill_dir, "/tmp/other", "flag wins over json");
        assert_eq!(cfg.spill_watermark_pct, 50);
        assert_eq!(cfg.keepalive_max_requests, 0, "0 disables keep-alive");
        let a = schema.parse(&["--no-fold".to_string()]).unwrap();
        let mut cfg2 = ServerConfig::default();
        cfg2.apply_args(&a).unwrap();
        assert!(!cfg2.fold);
    }

    #[test]
    fn robustness_keys_layer_correctly() {
        let mut cfg = ServerConfig::default();
        assert_eq!(cfg.deadline_ms, 0, "no deadline by default");
        assert_eq!(cfg.max_connections, 256);
        assert_eq!(cfg.restart_budget, 3);
        assert_eq!(cfg.restart_window_s, 60);
        assert_eq!(cfg.drain_deadline_ms, 5000);
        assert_eq!(cfg.socket_read_timeout_ms, 10_000);
        assert_eq!(cfg.socket_write_timeout_ms, 10_000);
        assert!(cfg.faults.is_empty(), "fault injection off by default");
        let j = Json::parse(
            r#"{"deadline_ms": 2000, "max_connections": 8, "restart_budget": 1,
                "restart_window_s": 10, "drain_deadline_ms": 250,
                "socket_read_timeout_ms": 500, "socket_write_timeout_ms": 750,
                "faults": "engine_step:panic@3"}"#,
        )
        .unwrap();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.deadline_ms, 2000);
        assert_eq!(cfg.max_connections, 8);
        assert_eq!(cfg.restart_budget, 1);
        assert_eq!(cfg.restart_window_s, 10);
        assert_eq!(cfg.drain_deadline_ms, 250);
        assert_eq!(cfg.socket_read_timeout_ms, 500);
        assert_eq!(cfg.socket_write_timeout_ms, 750);
        assert_eq!(cfg.faults, "engine_step:panic@3");

        let schema = Schema::new()
            .value("deadline-ms", "")
            .value("max-connections", "")
            .value("restart-budget", "")
            .value("restart-window-s", "")
            .value("drain-deadline-ms", "")
            .value("faults", "");
        let a = schema
            .parse(&[
                "--deadline-ms".to_string(),
                "100".to_string(),
                "--max-connections".to_string(),
                "4".to_string(),
                "--faults".to_string(),
                "tau_tile:panic@2".to_string(),
            ])
            .unwrap();
        cfg.apply_args(&a).unwrap();
        assert_eq!(cfg.deadline_ms, 100, "flag wins over json");
        assert_eq!(cfg.max_connections, 4);
        assert_eq!(cfg.faults, "tau_tile:panic@2");
        // json-set values survive when no flag overrides them
        assert_eq!(cfg.restart_budget, 1);
        assert_eq!(cfg.drain_deadline_ms, 250);
    }

    #[test]
    fn fleet_keys_layer_correctly() {
        let mut cfg = ServerConfig::default();
        assert_eq!(cfg.replicas, 1, "single replica by default (PR 7 behavior)");
        assert_eq!(cfg.failover_retries, 2);
        assert_eq!(cfg.quarantine_backoff_ms, 500);
        assert_eq!(cfg.quarantine_backoff_max_ms, 30_000);
        assert_eq!(cfg.probe_window_ms, 2000);
        let j = Json::parse(
            r#"{"replicas": 4, "failover_retries": 1, "quarantine_backoff_ms": 100,
                "quarantine_backoff_max_ms": 800, "probe_window_ms": 50}"#,
        )
        .unwrap();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.replicas, 4);
        assert_eq!(cfg.failover_retries, 1);
        assert_eq!(cfg.quarantine_backoff_ms, 100);
        assert_eq!(cfg.quarantine_backoff_max_ms, 800);
        assert_eq!(cfg.probe_window_ms, 50);

        let schema = Schema::new()
            .value("replicas", "")
            .value("failover-retries", "")
            .value("quarantine-backoff-ms", "")
            .value("quarantine-backoff-max-ms", "")
            .value("probe-window-ms", "");
        let a = schema
            .parse(&[
                "--replicas".to_string(),
                "2".to_string(),
                "--probe-window-ms".to_string(),
                "25".to_string(),
            ])
            .unwrap();
        cfg.apply_args(&a).unwrap();
        assert_eq!(cfg.replicas, 2, "flag wins over json");
        assert_eq!(cfg.probe_window_ms, 25);
        // json-set values survive when no flag overrides them
        assert_eq!(cfg.failover_retries, 1);
        assert_eq!(cfg.quarantine_backoff_ms, 100);
    }

    #[test]
    fn bad_method_in_json_is_an_error() {
        let mut cfg = ServerConfig::default();
        let j = Json::parse(r#"{"engine": {"method": "warp"}}"#).unwrap();
        assert!(cfg.apply_json(&j).is_err());
    }
}
