//! # Flash Inference
//!
//! Production reproduction of *"Flash Inference: Near Linear Time Inference
//! for Long Convolution Sequence Models and Beyond"* (ICLR 2025).
//!
//! Long-convolution sequence models (LCSMs, e.g. Hyena) train in
//! `O(L log L)` via FFT but decode naively in `Ω(L²)`: the convolution
//! input is revealed one position at a time. The paper adapts van der
//! Hoeven's *relaxed polynomial interpolation* — a fractal tiling of the
//! (input × output) contribution triangle into power-of-two square tiles —
//! to obtain **exact** `O(L log² L)` autoregressive inference, with the
//! tile primitive `τ` computable by FFT (Lemma 1) and almost all mixer work
//! parallelizable across layers (Algorithm 3).
//!
//! This crate is the **L3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas tile kernels (build-time Python, `python/compile/kernels/`),
//! * **L2** — the JAX model (`python/compile/model.py`), lowered once to
//!   HLO-text artifacts by `python/compile/aot.py`,
//! * **L3** — this crate: loads the artifacts via the PJRT CPU client
//!   ([`runtime`]), owns the token loop and the fractal tile schedule
//!   ([`tiling`], [`engine`]), dispatches `τ` across four implementations
//!   with a calibrated hybrid ([`tau`]), and serves requests ([`server`]).
//!
//! Python never runs on the request path.
//!
//! ## Quick start
//!
//! ```no_run
//! use flash_inference::engine::{Engine, EngineOpts, Method};
//! use flash_inference::runtime::Runtime;
//!
//! let rt = Runtime::load("artifacts/synthetic").unwrap();
//! let mut eng = Engine::new(&rt, EngineOpts { method: Method::Flash, ..Default::default() }).unwrap();
//! let out = eng.generate(256).unwrap();
//! println!("generated {} positions", out.steps);
//! ```
//!
//! See `examples/quickstart.rs` for the end-to-end driver and
//! `rust/benches/` for the reproductions of every figure in the paper.

pub mod cli;
pub mod config;
pub mod engine;
pub mod fft;
pub mod framework;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod server;
pub mod tau;
pub mod tiling;
pub mod trace;
pub mod util;

/// Crate-wide result type (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
