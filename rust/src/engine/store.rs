//! Activation store — the LCSM analogue of a KV cache (§3.3).
//!
//! Two `[G, T, D]` tensors:
//! * `streams` — the mixer-input sequences (`y_l`), written one column per
//!   token by `step`, read in blocks by the gray tiles;
//! * `pending` — the partially-aggregated mixer outputs (`b_l`), written in
//!   blocks by the gray tiles, consumed one column per token.
//!
//! §3.3's storage note is respected: there is no third tensor — a pending
//! column is finalized by the red cell inside `step` and immediately turned
//! into the streams column, so `b` never exists beyond one column. Peak
//! memory accounting (`peak_scratch_values`) backs the Appendix D/E claims.

use crate::util::tensor::Tensor;

/// Per-session activation state.
pub struct Store {
    pub streams: Tensor,
    pub pending: Tensor,
    g: usize,
    t: usize,
    d: usize,
}

impl Store {
    pub fn new(g: usize, t: usize, d: usize) -> Store {
        Store {
            streams: Tensor::zeros(&[g, t, d]),
            pending: Tensor::zeros(&[g, t, d]),
            g,
            t,
            d,
        }
    }

    pub fn dims(&self) -> (usize, usize, usize) {
        (self.g, self.t, self.d)
    }

    /// Gather `pending[:, col, :]` into `buf` (`[G, D]`; with `g = m·B+b`
    /// this is exactly the `[M, B, D]` layout the step artifact expects).
    pub fn gather_pending_col(&self, col: usize, buf: &mut Vec<f32>) {
        buf.resize(self.g * self.d, 0.0);
        for gi in 0..self.g {
            buf[gi * self.d..(gi + 1) * self.d].copy_from_slice(self.pending.at2(gi, col));
        }
    }

    /// Scatter a `[G, D]` step output into `streams[:, col, :]`.
    pub fn set_streams_col(&mut self, col: usize, vals: &[f32]) {
        debug_assert_eq!(vals.len(), self.g * self.d);
        for gi in 0..self.g {
            self.streams
                .at2_mut(gi, col)
                .copy_from_slice(&vals[gi * self.d..(gi + 1) * self.d]);
        }
    }

    /// Values resident in the store (activation memory, §3.3: 2·G·T·D —
    /// the same O(M L D) the lazy approach stores, no extra tensors).
    pub fn resident_values(&self) -> usize {
        self.streams.len() + self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_scatter_roundtrip() {
        let mut s = Store::new(3, 4, 2);
        let vals: Vec<f32> = (0..6).map(|i| i as f32).collect();
        s.set_streams_col(2, &vals);
        assert_eq!(s.streams.at2(0, 2), &[0.0, 1.0]);
        assert_eq!(s.streams.at2(2, 2), &[4.0, 5.0]);

        for gi in 0..3 {
            s.pending.at2_mut(gi, 1).copy_from_slice(&[gi as f32, -(gi as f32)]);
        }
        let mut buf = Vec::new();
        s.gather_pending_col(1, &mut buf);
        assert_eq!(buf, vec![0.0, -0.0, 1.0, -1.0, 2.0, -2.0]);
    }

    #[test]
    fn resident_accounting() {
        let s = Store::new(6, 8, 4);
        assert_eq!(s.resident_values(), 2 * 6 * 8 * 4);
    }
}
