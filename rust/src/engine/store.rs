//! Activation store — the LCSM analogue of a KV cache (§3.3).
//!
//! Two `[G, T, D]` planes:
//! * `streams` — the mixer-input sequences (`y_l`), written one column per
//!   token by `step`, read in blocks by the gray tiles;
//! * `pending` — the partially-aggregated mixer outputs (`b_l`), written in
//!   blocks by the gray tiles, consumed one column per token.
//!
//! §3.3's storage note is respected: there is no third tensor — a pending
//! column is finalized by the red cell inside `step` and immediately turned
//! into the streams column, so `b` never exists beyond one column. Peak
//! memory accounting (`peak_scratch_values`) backs the Appendix D/E claims.
//!
//! Both planes are [`CellTensor`]s shared via `Arc` with the async mixer's
//! in-flight tile jobs: workers on several pool threads accumulate into
//! disjoint `pending` rows while the engine thread reads and writes other
//! rows of the same planes. The `Arc` keeps the storage alive for as long
//! as any job holds it, and the cell-based accessors keep the concurrent
//! row traffic well-defined (no `&mut` aliasing, see `util::tensor`).

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::tensor::{CellTensor, Tensor};

/// Per-row *versioned* readiness tracking for the pending plane under
/// concurrent writers (the async tau executor's dependency-tracked tiles).
///
/// Each store row carries two monotonic counters: `scheduled` ticks when
/// the engine thread submits a tile (or tile chunk) that will accumulate
/// into the row, `completed` ticks when that job's accumulation lands. A
/// row is *quiet* iff `completed == scheduled` — every write that was ever
/// scheduled has landed. Consuming a pending column is only legal on a
/// quiet row — [`Store::gather_pending_col`] asserts it — which turns a
/// missed fence (the failure mode the Appendix D half-store wrap makes
/// easiest to hit, since rows are recycled between the two halves) into a
/// deterministic panic instead of silently corrupted activations.
///
/// Versions, not counts: with multiple workers retiring jobs in arbitrary
/// order, a plain in-flight counter can transit through zero while an
/// *older* scheduled write has yet to land being indistinguishable from
/// "all clear" (the ABA shape). Monotonic versions cannot be confused
/// that way — quietness states that the row has caught up with every
/// submission ever made, and the panic message can cite exactly how far
/// behind it is.
///
/// `Arc`-shared and atomic so detached jobs can check rows out/in without
/// borrowing the store. `begin_write` is engine-thread-only (submission
/// order defines the version sequence); `end_write` is called by the jobs.
#[derive(Debug)]
pub struct RowReadiness {
    scheduled: Vec<AtomicU64>,
    completed: Vec<AtomicU64>,
}

impl RowReadiness {
    pub fn new(rows: usize) -> RowReadiness {
        RowReadiness {
            scheduled: (0..rows).map(|_| AtomicU64::new(0)).collect(),
            completed: (0..rows).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn rows(&self) -> usize {
        self.scheduled.len()
    }

    /// Advance the scheduled version of `rows` (0-indexed, half-open) by
    /// one write. Called on the engine thread at submission time, before
    /// the job can run.
    pub fn begin_write(&self, rows: Range<usize>) {
        for r in rows {
            self.scheduled[r].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Advance the completed version of `rows`: one scheduled write has
    /// landed. Called by the job after its accumulation; the `Release`
    /// pairs with the `Acquire` in [`Self::is_quiet`] so a reader that
    /// observes quietness also observes the accumulated values.
    pub fn end_write(&self, rows: Range<usize>) {
        for r in rows {
            let done = self.completed[r].fetch_add(1, Ordering::Release) + 1;
            debug_assert!(
                done <= self.scheduled[r].load(Ordering::Relaxed),
                "end_write overran scheduled version on row {r}"
            );
        }
    }

    /// Every write ever scheduled against `row` has landed.
    pub fn is_quiet(&self, row: usize) -> bool {
        self.completed[row].load(Ordering::Acquire) == self.scheduled[row].load(Ordering::Relaxed)
    }

    /// Panic if `row` has not caught up with its scheduled version — the
    /// caller is about to consume a column whose fence did not drain.
    pub fn assert_quiet(&self, row: usize) {
        let done = self.completed[row].load(Ordering::Acquire);
        let sched = self.scheduled[row].load(Ordering::Relaxed);
        assert!(
            done == sched,
            "store row {row} consumed at version {done}/{sched} — missing fence \
             ({} write(s) still in flight)",
            sched - done
        );
    }
}

/// Per-session activation state.
pub struct Store {
    pub streams: Arc<CellTensor>,
    pub pending: Arc<CellTensor>,
    /// In-flight-writer tracking for `pending` rows (shared with any
    /// asynchronous tau executor working on this store).
    readiness: Arc<RowReadiness>,
    g: usize,
    t: usize,
    d: usize,
}

impl Store {
    pub fn new(g: usize, t: usize, d: usize) -> Store {
        Store {
            streams: Arc::new(CellTensor::zeros(&[g, t, d])),
            pending: Arc::new(CellTensor::zeros(&[g, t, d])),
            readiness: Arc::new(RowReadiness::new(t)),
            g,
            t,
            d,
        }
    }

    pub fn dims(&self) -> (usize, usize, usize) {
        (self.g, self.t, self.d)
    }

    /// Shared handle to this store's row-readiness tracker.
    pub fn readiness(&self) -> Arc<RowReadiness> {
        self.readiness.clone()
    }

    /// Snapshot the streams plane into an owned [`Tensor`] (the
    /// `GenOutput::streams` export). The caller fences first, so the
    /// plane is quiet.
    pub fn streams_tensor(&self) -> Tensor {
        self.streams.to_tensor()
    }

    /// Gather `pending[:, col, :]` into `buf` (`[G, D]`; with `g = m·B+b`
    /// this is exactly the `[M, B, D]` layout the step artifact expects).
    /// The column's row must be quiet (every tile writing it fenced).
    pub fn gather_pending_col(&self, col: usize, buf: &mut Vec<f32>) {
        self.readiness.assert_quiet(col);
        buf.resize(self.g * self.d, 0.0);
        for gi in 0..self.g {
            buf[gi * self.d..(gi + 1) * self.d].copy_from_slice(self.pending.at2(gi, col));
        }
    }

    /// Overwrite `pending[gi, row, :]` — session construction seeds the
    /// Appendix D prefix sums this way. The row must be quiet.
    pub fn write_pending_row(&mut self, gi: usize, row: usize, vals: &[f32]) {
        self.readiness.assert_quiet(row);
        // SAFETY: quiet row + `&mut self` — no in-flight writer, and the
        // engine thread is the only other accessor.
        unsafe { self.pending.at2_mut(gi, row) }.copy_from_slice(vals);
    }

    /// Zero `pending[:, col, :]` after the column was consumed — the
    /// half-store recycles the row for the second half (Appendix D). The
    /// row must be quiet (it was just gathered, which asserted it).
    pub fn zero_pending_col(&mut self, col: usize) {
        self.readiness.assert_quiet(col);
        for gi in 0..self.g {
            // SAFETY: quiet row + `&mut self`, as in `write_pending_row`.
            unsafe { self.pending.at2_mut(gi, col) }.fill(0.0);
        }
    }

    /// Clear every row of one batch lane's groups — the store half of
    /// continuous admission (`Session::admit`). With `g = m·B + lane`, a
    /// lane's activation history lives in groups `lane, B+lane, 2B+lane,
    /// …`; zeroing their `streams` and `pending` rows makes the recycled
    /// lane's history exactly that of a fresh session (a gray tile whose
    /// source block straddles the admission point reads true zeros for
    /// the pre-admission positions, so its contribution to the new lane
    /// is identical to a fresh run's).
    ///
    /// Every row must be quiet: a tile still in flight would read the
    /// predecessor's streams rows (or re-deposit its pending sums) *after*
    /// this reset, leaking the recycled lane's activations into the new
    /// request. The caller fences first — every in-flight tile's dst
    /// covers all groups, hence also the recycled lane — and this assert
    /// turns a missed admission fence into a deterministic panic.
    pub fn reset_lane(&mut self, lane: usize, b: usize) {
        assert!(lane < b, "lane {lane} out of range (B={b})");
        assert_eq!(self.g % b, 0, "group axis {} not a multiple of B={b}", self.g);
        for row in 0..self.t {
            self.readiness.assert_quiet(row);
        }
        let mut gi = lane;
        while gi < self.g {
            for row in 0..self.t {
                // SAFETY: all rows quiet (asserted above) — nothing else
                // touches the planes while `&mut self` is held.
                unsafe {
                    self.streams.at2_mut(gi, row).fill(0.0);
                    self.pending.at2_mut(gi, row).fill(0.0);
                }
            }
            gi += b;
        }
    }

    /// Copy one lane's activation rows out for a pager checkpoint
    /// (`Session::suspend`): the `streams_rows` range of the lane's
    /// `streams` and the `pending_rows` range of its `pending`, across
    /// all its groups, into `[M, n, D]` group-major buffers (`M = G/B` —
    /// the lane's share of the group axis). Ranges let the caller skip a
    /// known-zero prefix (rows below the lane's admission point in the
    /// unwrapped store). Every row must be quiet: the caller fences all
    /// in-flight τ tiles first, and the assert turns a missed suspend
    /// fence into a deterministic panic (same rule as `reset_lane`).
    pub fn copy_lane_rows_out(
        &self,
        lane: usize,
        b: usize,
        streams_rows: Range<usize>,
        pending_rows: Range<usize>,
        streams_buf: &mut Vec<f32>,
        pending_buf: &mut Vec<f32>,
    ) {
        assert!(lane < b, "lane {lane} out of range (B={b})");
        assert_eq!(self.g % b, 0, "group axis {} not a multiple of B={b}", self.g);
        assert!(streams_rows.end <= self.t && pending_rows.end <= self.t, "range exceeds store");
        for row in 0..self.t {
            self.readiness.assert_quiet(row);
        }
        let m = self.g / b;
        let (ns, np) = (streams_rows.len(), pending_rows.len());
        streams_buf.resize(m * ns * self.d, 0.0);
        pending_buf.resize(m * np * self.d, 0.0);
        for mi in 0..m {
            let gi = mi * b + lane;
            if ns > 0 {
                streams_buf[mi * ns * self.d..(mi + 1) * ns * self.d]
                    .copy_from_slice(self.streams.block(gi, streams_rows.start, streams_rows.end));
            }
            if np > 0 {
                pending_buf[mi * np * self.d..(mi + 1) * np * self.d]
                    .copy_from_slice(self.pending.block(gi, pending_rows.start, pending_rows.end));
            }
        }
    }

    /// The exact inverse of [`Store::copy_lane_rows_out`]
    /// (`Session::restore`): write checkpointed rows back into the lane's
    /// groups at the same row ranges. The caller resets the lane first
    /// (rows outside the checkpointed ranges must be zero, as in the
    /// uninterrupted run) and fences, so the same quiet-row assert
    /// applies.
    pub fn copy_lane_rows_in(
        &mut self,
        lane: usize,
        b: usize,
        streams_rows: Range<usize>,
        pending_rows: Range<usize>,
        streams_buf: &[f32],
        pending_buf: &[f32],
    ) {
        assert!(lane < b, "lane {lane} out of range (B={b})");
        assert_eq!(self.g % b, 0, "group axis {} not a multiple of B={b}", self.g);
        assert!(streams_rows.end <= self.t && pending_rows.end <= self.t, "range exceeds store");
        let m = self.g / b;
        let (ns, np) = (streams_rows.len(), pending_rows.len());
        debug_assert_eq!(streams_buf.len(), m * ns * self.d);
        debug_assert_eq!(pending_buf.len(), m * np * self.d);
        for row in 0..self.t {
            self.readiness.assert_quiet(row);
        }
        let (ss, ps) = (ns * self.d, np * self.d);
        for mi in 0..m {
            let gi = mi * b + lane;
            // SAFETY: all rows quiet (asserted above) + `&mut self`.
            if ns > 0 {
                unsafe { self.streams.block_mut(gi, streams_rows.start, streams_rows.end) }
                    .copy_from_slice(&streams_buf[mi * ss..(mi + 1) * ss]);
            }
            if np > 0 {
                unsafe { self.pending.block_mut(gi, pending_rows.start, pending_rows.end) }
                    .copy_from_slice(&pending_buf[mi * ps..(mi + 1) * ps]);
            }
        }
    }

    /// Copy `span` consecutive *future* pending rows of one lane out,
    /// starting at store row `r0` and wrapping modulo the row count —
    /// the folded-checkpoint tail copy (`Session::suspend_folded`). The
    /// output is `[M, span, D]` group-major, same layout as
    /// [`Store::copy_lane_rows_out`]. Rows must be quiet (caller fences).
    pub fn copy_lane_pending_rows_wrapped(
        &self,
        lane: usize,
        b: usize,
        r0: usize,
        span: usize,
        buf: &mut Vec<f32>,
    ) {
        assert!(lane < b, "lane {lane} out of range (B={b})");
        assert_eq!(self.g % b, 0, "group axis {} not a multiple of B={b}", self.g);
        assert!(span <= self.t, "wrapped span {span} exceeds {} store rows", self.t);
        for row in 0..self.t {
            self.readiness.assert_quiet(row);
        }
        let m = self.g / b;
        buf.resize(m * span * self.d, 0.0);
        for mi in 0..m {
            let gi = mi * b + lane;
            for t in 0..span {
                let row = (r0 + t) % self.t;
                buf[(mi * span + t) * self.d..(mi * span + t + 1) * self.d]
                    .copy_from_slice(self.pending.at2(gi, row));
            }
        }
    }

    /// Inverse of [`Store::copy_lane_pending_rows_wrapped`]: deposit a
    /// `[M, span, D]` pending tail onto rows `r0, r0+1, …` (mod the row
    /// count) of one lane — the folded-restore / prompt-seed write. The
    /// caller resets the lane first; rows must be quiet.
    pub fn copy_lane_pending_rows_wrapped_in(
        &mut self,
        lane: usize,
        b: usize,
        r0: usize,
        span: usize,
        buf: &[f32],
    ) {
        assert!(lane < b, "lane {lane} out of range (B={b})");
        assert_eq!(self.g % b, 0, "group axis {} not a multiple of B={b}", self.g);
        assert!(span <= self.t, "wrapped span {span} exceeds {} store rows", self.t);
        let m = self.g / b;
        debug_assert_eq!(buf.len(), m * span * self.d);
        for row in 0..self.t {
            self.readiness.assert_quiet(row);
        }
        for mi in 0..m {
            let gi = mi * b + lane;
            for t in 0..span {
                let row = (r0 + t) % self.t;
                // SAFETY: all rows quiet (asserted above) + `&mut self`.
                unsafe { self.pending.at2_mut(gi, row) }.copy_from_slice(
                    &buf[(mi * span + t) * self.d..(mi * span + t + 1) * self.d],
                );
            }
        }
    }

    /// Scatter a `[G, D]` step output into `streams[:, col, :]`.
    ///
    /// In-flight tile jobs only *read* streams, and only rows of columns
    /// produced before their tile was submitted — never `col`, which is
    /// being produced right now (the wrap analysis in `tau/async_exec.rs`
    /// covers the recycled-row case). So this write races with nothing.
    pub fn set_streams_col(&mut self, col: usize, vals: &[f32]) {
        debug_assert_eq!(vals.len(), self.g * self.d);
        for gi in 0..self.g {
            // SAFETY: no in-flight job touches this row (see doc above).
            unsafe { self.streams.at2_mut(gi, col) }
                .copy_from_slice(&vals[gi * self.d..(gi + 1) * self.d]);
        }
    }

    /// Values resident in the store (activation memory, §3.3: 2·G·T·D —
    /// the same O(M L D) the lazy approach stores, no extra tensors).
    pub fn resident_values(&self) -> usize {
        self.streams.len() + self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Test-only row write (single-threaded, no jobs in flight).
    fn fill_row(plane: &CellTensor, gi: usize, row: usize, v: f32) {
        // SAFETY: exclusive access in these single-threaded tests
        unsafe { plane.at2_mut(gi, row) }.fill(v);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut s = Store::new(3, 4, 2);
        let vals: Vec<f32> = (0..6).map(|i| i as f32).collect();
        s.set_streams_col(2, &vals);
        assert_eq!(s.streams.at2(0, 2), &[0.0, 1.0]);
        assert_eq!(s.streams.at2(2, 2), &[4.0, 5.0]);

        for gi in 0..3 {
            s.write_pending_row(gi, 1, &[gi as f32, -(gi as f32)]);
        }
        let mut buf = Vec::new();
        s.gather_pending_col(1, &mut buf);
        assert_eq!(buf, vec![0.0, -0.0, 1.0, -1.0, 2.0, -2.0]);

        s.zero_pending_col(1);
        s.gather_pending_col(1, &mut buf);
        assert!(buf.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn resident_accounting() {
        let s = Store::new(6, 8, 4);
        assert_eq!(s.resident_values(), 2 * 6 * 8 * 4);
    }

    #[test]
    fn readiness_tracks_overlapping_writers() {
        let r = RowReadiness::new(8);
        assert!(r.is_quiet(3));
        r.begin_write(2..6);
        r.begin_write(4..8); // overlap on rows 4, 5
        assert!(!r.is_quiet(2));
        assert!(!r.is_quiet(5));
        r.end_write(2..6);
        assert!(r.is_quiet(2));
        assert!(!r.is_quiet(5), "row 5 still has the second writer");
        r.end_write(4..8);
        for row in 0..8 {
            assert!(r.is_quiet(row));
        }
    }

    #[test]
    fn readiness_versions_are_monotonic_not_counts() {
        // the version pair distinguishes "caught up after N writes" from
        // "never written": both are quiet, but the versions advance
        let r = RowReadiness::new(2);
        for _ in 0..3 {
            r.begin_write(0..1);
            r.end_write(0..1);
        }
        assert!(r.is_quiet(0));
        assert!(r.is_quiet(1));
        // out-of-order retirement across two scheduled writes: the row
        // only becomes quiet once *both* land, regardless of which job's
        // end_write arrives first
        r.begin_write(0..1);
        r.begin_write(0..1);
        r.end_write(0..1); // "second" job retires first — still not quiet
        assert!(!r.is_quiet(0));
        r.end_write(0..1);
        assert!(r.is_quiet(0));
    }

    #[test]
    fn gather_on_unfenced_row_panics() {
        let s = Store::new(2, 4, 2);
        let r = s.readiness();
        r.begin_write(1..3);
        let mut buf = Vec::new();
        s.gather_pending_col(0, &mut buf); // quiet row: fine
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut b = Vec::new();
            s.gather_pending_col(2, &mut b);
        }));
        assert!(res.is_err(), "consuming an in-flight row must panic");
        r.end_write(1..3);
        s.gather_pending_col(2, &mut buf);
    }

    #[test]
    fn reset_lane_clears_only_that_lanes_groups() {
        // G = M·B with M = 2, B = 2: lane 0 -> groups {0, 2}, lane 1 -> {1, 3}
        let (m, b, t, d) = (2usize, 2usize, 4usize, 3usize);
        let mut s = Store::new(m * b, t, d);
        for gi in 0..m * b {
            for row in 0..t {
                fill_row(&s.streams, gi, row, gi as f32 + 1.0);
                fill_row(&s.pending, gi, row, -(gi as f32 + 1.0));
            }
        }
        s.reset_lane(1, b);
        for row in 0..t {
            assert!(s.streams.at2(1, row).iter().all(|&v| v == 0.0));
            assert!(s.pending.at2(3, row).iter().all(|&v| v == 0.0));
            // lane 0's groups untouched
            assert!(s.streams.at2(0, row).iter().all(|&v| v == 1.0));
            assert!(s.pending.at2(2, row).iter().all(|&v| v == -3.0));
        }
    }

    #[test]
    fn reset_lane_panics_on_inflight_writer() {
        let mut s = Store::new(2, 4, 2);
        let r = s.readiness();
        r.begin_write(1..2);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            s.reset_lane(0, 2);
        }));
        assert!(res.is_err(), "recycling a lane under an in-flight tile must panic");
        r.end_write(1..2);
        s.reset_lane(0, 2);
    }

    #[test]
    fn lane_rows_copy_out_in_roundtrip() {
        // M = 2, B = 2: lane 1 -> groups {1, 3}
        let (m, b, t, d) = (2usize, 2usize, 6usize, 2usize);
        let mut s = Store::new(m * b, t, d);
        for gi in 0..m * b {
            for row in 0..t {
                fill_row(&s.streams, gi, row, (gi * 10 + row) as f32);
                fill_row(&s.pending, gi, row, -((gi * 10 + row) as f32));
            }
        }
        let (mut sb, mut pb) = (Vec::new(), Vec::new());
        s.copy_lane_rows_out(1, b, 0..4, 0..6, &mut sb, &mut pb);
        assert_eq!(sb.len(), m * 4 * d);
        assert_eq!(pb.len(), m * 6 * d);
        // group-major layout: [m=0 (gi=1) rows 0..4, m=1 (gi=3) rows 0..4]
        assert_eq!(&sb[..d], s.streams.at2(1, 0));
        assert_eq!(&sb[4 * d..5 * d], s.streams.at2(3, 0));
        assert_eq!(&pb[6 * d..7 * d], s.pending.at2(3, 0));

        s.reset_lane(1, b);
        s.copy_lane_rows_in(1, b, 0..4, 0..6, &sb, &pb);
        for row in 0..4 {
            assert_eq!(s.streams.at2(1, row), &[(10 + row) as f32; 2]);
            assert_eq!(s.streams.at2(3, row), &[(30 + row) as f32; 2]);
        }
        // streams rows beyond the checkpointed range stay cleared
        assert!(s.streams.at2(1, 5).iter().all(|&v| v == 0.0));
        for row in 0..6 {
            assert_eq!(s.pending.at2(1, row), &[-((10 + row) as f32); 2]);
        }
        // the other lane was never touched
        assert_eq!(s.streams.at2(0, 3), &[3.0; 2]);
    }

    #[test]
    fn lane_rows_copy_respects_nonzero_range_start() {
        // rows below the range start (a lane's admission point in the
        // unwrapped store) are skipped on the way out and untouched on
        // the way in
        let (b, t, d) = (2usize, 6usize, 2usize);
        let mut s = Store::new(b, t, d);
        for row in 0..t {
            fill_row(&s.streams, 0, row, row as f32 + 1.0);
            fill_row(&s.pending, 0, row, -(row as f32 + 1.0));
        }
        let (mut sb, mut pb) = (Vec::new(), Vec::new());
        s.copy_lane_rows_out(0, b, 2..5, 3..6, &mut sb, &mut pb);
        assert_eq!(sb.len(), 3 * d);
        assert_eq!(&sb[..d], &[3.0, 3.0], "first copied row is range.start");
        assert_eq!(&pb[..d], &[-4.0, -4.0]);

        s.reset_lane(0, b);
        s.copy_lane_rows_in(0, b, 2..5, 3..6, &sb, &pb);
        assert!(s.streams.at2(0, 0).iter().all(|&v| v == 0.0), "prefix stays zero");
        assert_eq!(s.streams.at2(0, 2), &[3.0, 3.0]);
        assert_eq!(s.streams.at2(0, 4), &[5.0, 5.0]);
        assert!(s.streams.at2(0, 5).iter().all(|&v| v == 0.0));
        assert_eq!(s.pending.at2(0, 3), &[-4.0, -4.0]);
        assert_eq!(s.pending.at2(0, 5), &[-6.0, -6.0]);
        assert!(s.pending.at2(0, 2).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn wrapped_pending_rows_roundtrip_across_the_seam() {
        // M = 2, B = 2, 6 rows: a span of 4 starting at row 4 wraps to
        // rows {4, 5, 0, 1} — the half-store folded-tail case
        let (m, b, t, d) = (2usize, 2usize, 6usize, 2usize);
        let mut s = Store::new(m * b, t, d);
        for gi in 0..m * b {
            for row in 0..t {
                fill_row(&s.pending, gi, row, (gi * 10 + row) as f32);
            }
        }
        let mut buf = Vec::new();
        s.copy_lane_pending_rows_wrapped(1, b, 4, 4, &mut buf);
        assert_eq!(buf.len(), m * 4 * d);
        // group-major: [gi=1 rows 4,5,0,1][gi=3 rows 4,5,0,1]
        assert_eq!(&buf[..d], &[14.0; 2]);
        assert_eq!(&buf[2 * d..3 * d], &[10.0; 2]);
        assert_eq!(&buf[4 * d..5 * d], &[34.0; 2]);

        s.reset_lane(1, b);
        s.copy_lane_pending_rows_wrapped_in(1, b, 4, 4, &buf);
        assert_eq!(s.pending.at2(1, 4), &[14.0; 2]);
        assert_eq!(s.pending.at2(1, 0), &[10.0; 2]);
        assert_eq!(s.pending.at2(3, 1), &[31.0; 2]);
        // rows outside the wrapped span stay cleared; other lane untouched
        assert!(s.pending.at2(1, 2).iter().all(|&v| v == 0.0));
        assert_eq!(s.pending.at2(0, 3), &[3.0; 2]);
    }

    #[test]
    fn lane_rows_copy_out_panics_on_inflight_writer() {
        let s = Store::new(2, 4, 2);
        let r = s.readiness();
        r.begin_write(2..3);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let (mut sb, mut pb) = (Vec::new(), Vec::new());
            s.copy_lane_rows_out(0, 2, 0..2, 0..2, &mut sb, &mut pb);
        }));
        assert!(res.is_err(), "checkpointing under an in-flight tile must panic");
        r.end_write(2..3);
    }

    #[test]
    fn readiness_is_shared_across_clones() {
        let s = Store::new(1, 4, 1);
        let a = s.readiness();
        let b = s.readiness();
        a.begin_write(0..1);
        assert!(!b.is_quiet(0));
        b.end_write(0..1);
        assert!(a.is_quiet(0));
    }
}
