//! Samplers: map the step artifact's `out` to the next position's input.
//!
//! * synthetic (§5): `a_{0,i+1} = out_i + sigma * noise` — "a function from
//!   logits at the last layer and previous position to the next token's
//!   embedding"; sigma=0 gives the deterministic golden rollout.
//! * hyena LM: temperature / top-k sampling over V logits, then embedding
//!   lookup.

use anyhow::Result;

use crate::util::prng::Prng;
use crate::util::tensor::Tensor;

/// Sampling configuration.
#[derive(Debug, Clone, Copy)]
pub enum SamplerCfg {
    /// Next input = out + sigma * N(0, 1).
    Synthetic { sigma: f32 },
    /// Categorical over logits; `temperature == 0` means argmax.
    Lm { temperature: f32, top_k: usize },
}

pub struct Sampler {
    cfg: SamplerCfg,
    prng: Prng,
    /// `[V, D]` embedding table (LM only).
    embed: Option<Tensor>,
}

impl Sampler {
    pub fn synthetic(sigma: f32, seed: u64) -> Sampler {
        Sampler { cfg: SamplerCfg::Synthetic { sigma }, prng: Prng::new(seed), embed: None }
    }

    pub fn lm(temperature: f32, top_k: usize, embed: Tensor, seed: u64) -> Sampler {
        Sampler {
            cfg: SamplerCfg::Lm { temperature, top_k },
            prng: Prng::new(seed),
            embed: Some(embed),
        }
    }

    /// Consume `out` (`[B, W]`) and produce the next `a0` (`[B, D]`).
    /// Returns the sampled token ids for LM sampling.
    pub fn next_a0(&mut self, out: &[f32], b: usize, a0: &mut [f32]) -> Result<Option<Vec<u32>>> {
        match self.cfg {
            SamplerCfg::Synthetic { sigma } => {
                debug_assert_eq!(out.len(), a0.len());
                if sigma == 0.0 {
                    a0.copy_from_slice(out);
                } else {
                    for (dst, &src) in a0.iter_mut().zip(out) {
                        *dst = src + sigma * self.prng.normal_f32();
                    }
                }
                Ok(None)
            }
            SamplerCfg::Lm { temperature, top_k } => {
                let embed = self.embed.as_ref().expect("LM sampler needs embeddings");
                let v = out.len() / b;
                let d = embed.shape()[1];
                let mut tokens = Vec::with_capacity(b);
                for bi in 0..b {
                    let logits = &out[bi * v..(bi + 1) * v];
                    let tok = if temperature <= 0.0 {
                        argmax(logits)
                    } else {
                        categorical(logits, temperature, top_k, &mut self.prng)
                    };
                    tokens.push(tok as u32);
                    a0[bi * d..(bi + 1) * d].copy_from_slice(embed.row(tok));
                }
                Ok(Some(tokens))
            }
        }
    }
}

fn argmax(logits: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best
}

/// Temperature softmax draw, optionally restricted to the top-k logits.
fn categorical(logits: &[f32], temperature: f32, top_k: usize, prng: &mut Prng) -> usize {
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if top_k > 0 && top_k < logits.len() {
        idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        idx.truncate(top_k);
    }
    let m = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i] - m) / temperature) as f64).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut r = prng.uniform() * total;
    for (w, &i) in weights.iter().zip(&idx) {
        if r < *w {
            return i;
        }
        r -= w;
    }
    *idx.last().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_sigma_zero_is_identity() {
        let mut s = Sampler::synthetic(0.0, 1);
        let out = vec![1.0, -2.0, 3.0];
        let mut a0 = vec![0.0; 3];
        assert!(s.next_a0(&out, 1, &mut a0).unwrap().is_none());
        assert_eq!(a0, out);
    }

    #[test]
    fn synthetic_noise_is_deterministic_per_seed() {
        let out = vec![0.0; 8];
        let run = |seed| {
            let mut s = Sampler::synthetic(0.5, seed);
            let mut a0 = vec![0.0; 8];
            s.next_a0(&out, 1, &mut a0).unwrap();
            a0
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn lm_argmax_picks_max_and_embeds() {
        let embed = Tensor::from_vec(&[3, 2], vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0]).unwrap();
        let mut s = Sampler::lm(0.0, 0, embed, 0);
        let logits = vec![0.1, 5.0, -1.0];
        let mut a0 = vec![0.0; 2];
        let toks = s.next_a0(&logits, 1, &mut a0).unwrap().unwrap();
        assert_eq!(toks, vec![1]);
        assert_eq!(a0, vec![1.0, 1.0]);
    }

    #[test]
    fn lm_temperature_samples_valid_tokens() {
        let embed = Tensor::zeros(&[4, 2]);
        let mut s = Sampler::lm(1.0, 2, embed, 3);
        let logits = vec![0.0, 1.0, 2.0, 3.0];
        let mut a0 = vec![0.0; 2];
        for _ in 0..50 {
            let toks = s.next_a0(&logits, 1, &mut a0).unwrap().unwrap();
            // top_k = 2 restricts to tokens {2, 3}
            assert!(toks[0] == 2 || toks[0] == 3, "tok={}", toks[0]);
        }
    }

    #[test]
    fn lm_batch_rows_sampled_independently() {
        let embed = Tensor::from_vec(&[2, 1], vec![10.0, 20.0]).unwrap();
        let mut s = Sampler::lm(0.0, 0, embed, 0);
        let logits = vec![1.0, 0.0, 0.0, 1.0]; // b0 -> tok0, b1 -> tok1
        let mut a0 = vec![0.0; 2];
        let toks = s.next_a0(&logits, 2, &mut a0).unwrap().unwrap();
        assert_eq!(toks, vec![0, 1]);
        assert_eq!(a0, vec![10.0, 20.0]);
    }
}
