//! Samplers: map the step artifact's `out` to the next position's input.
//!
//! * synthetic (§5): `a_{0,i+1} = out_i + sigma * noise` — "a function from
//!   logits at the last layer and previous position to the next token's
//!   embedding"; sigma=0 gives the deterministic golden rollout.
//! * hyena LM: temperature / top-k sampling over V logits, then embedding
//!   lookup.
//!
//! ## Per-lane state (continuous admission)
//!
//! Serving admits requests into individual batch lanes mid-session, and
//! each request carries its own sampling config (temperature/top-k/sigma)
//! and seed. The sampler therefore keeps **one config and one PRNG per
//! lane**: a lane's random stream depends only on its own seed and on how
//! many positions *that lane* has sampled — never on the other lanes or
//! on the batch's global position. That independence is what makes an
//! admitted lane's rollout bit-identical to a fresh run of the same
//! request (`tests/integration_admission.rs`). Lanes that are not given
//! an explicit seed derive theirs as `base_seed + lane_index`, so whole
//! batches stay deterministic per engine seed and lanes still decorrelate.

use anyhow::Result;

use super::pager::SamplerSnapshot;
use crate::util::prng::Prng;
use crate::util::tensor::Tensor;

/// Sampling configuration (per lane).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SamplerCfg {
    /// Next input = out + sigma * N(0, 1).
    Synthetic { sigma: f32 },
    /// Categorical over logits; `temperature == 0` means argmax.
    Lm { temperature: f32, top_k: usize },
}

/// One lane's sampling state: its config plus its private random stream.
#[derive(Debug)]
struct LaneSampler {
    cfg: SamplerCfg,
    prng: Prng,
}

pub struct Sampler {
    lanes: Vec<LaneSampler>,
    /// Engine-default config, applied to lanes admitted without overrides.
    default_cfg: SamplerCfg,
    /// Engine seed; lane `i` defaults to stream `base_seed + i`.
    base_seed: u64,
    /// `[V, D]` embedding table (LM only, shared by all lanes).
    embed: Option<Tensor>,
}

impl Sampler {
    pub fn synthetic(sigma: f32, seed: u64, lanes: usize) -> Sampler {
        Sampler::new(SamplerCfg::Synthetic { sigma }, seed, lanes, None)
    }

    pub fn lm(temperature: f32, top_k: usize, embed: Tensor, seed: u64, lanes: usize) -> Sampler {
        Sampler::new(SamplerCfg::Lm { temperature, top_k }, seed, lanes, Some(embed))
    }

    fn new(cfg: SamplerCfg, seed: u64, lanes: usize, embed: Option<Tensor>) -> Sampler {
        let lanes = (0..lanes.max(1))
            .map(|bi| LaneSampler { cfg, prng: Prng::new(seed.wrapping_add(bi as u64)) })
            .collect();
        Sampler { lanes, default_cfg: cfg, base_seed: seed, embed }
    }

    /// Number of lanes this sampler drives.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// This lane's active config (admission tests / introspection).
    pub fn lane_cfg(&self, lane: usize) -> SamplerCfg {
        self.lanes[lane].cfg
    }

    /// Rebase one lane for a newly admitted request: fresh PRNG (the
    /// request's seed, or the engine default stream for this lane) and the
    /// request's sampling config (or the engine default). The lane's
    /// stream restarts exactly as a fresh session's lane would, which is
    /// the per-lane half of the admission bit-identity contract.
    pub fn reset_lane(&mut self, lane: usize, cfg: Option<SamplerCfg>, seed: Option<u64>) {
        let seed = seed.unwrap_or_else(|| self.base_seed.wrapping_add(lane as u64));
        self.lanes[lane] =
            LaneSampler { cfg: cfg.unwrap_or(self.default_cfg), prng: Prng::new(seed) };
    }

    /// Capture one lane's sampling state for a pager checkpoint
    /// (`Session::suspend`): its config plus the raw PRNG state, so the
    /// resumed lane's stream continues mid-sequence instead of replaying
    /// from its seed — the sampler half of evict/resume bit-identity.
    pub fn snapshot_lane(&self, lane: usize) -> SamplerSnapshot {
        SamplerSnapshot {
            cfg: self.lanes[lane].cfg,
            prng_state: self.lanes[lane].prng.state(),
        }
    }

    /// The exact inverse of [`Sampler::snapshot_lane`]
    /// (`Session::restore`): reinstate a suspended lane's config and
    /// mid-sequence PRNG state.
    pub fn restore_lane(&mut self, lane: usize, snap: &SamplerSnapshot) {
        self.lanes[lane] =
            LaneSampler { cfg: snap.cfg, prng: Prng::from_state(snap.prng_state) };
    }

    /// Consume `out` (`[B, W]`) and produce the next `a0` (`[B, D]`).
    /// Returns the sampled token ids for LM sampling. Every lane draws
    /// from its own PRNG under its own config.
    pub fn next_a0(&mut self, out: &[f32], b: usize, a0: &mut [f32]) -> Result<Option<Vec<u32>>> {
        debug_assert_eq!(b, self.lanes.len(), "sampler lane count mismatch");
        let lm = matches!(self.default_cfg, SamplerCfg::Lm { .. });
        if !lm {
            debug_assert_eq!(out.len(), a0.len());
            let d = a0.len() / b;
            for (bi, lane) in self.lanes.iter_mut().enumerate() {
                let SamplerCfg::Synthetic { sigma } = lane.cfg else {
                    anyhow::bail!("lane {bi}: LM sampling config on a synthetic model");
                };
                let src = &out[bi * d..(bi + 1) * d];
                let dst = &mut a0[bi * d..(bi + 1) * d];
                if sigma == 0.0 {
                    dst.copy_from_slice(src);
                } else {
                    for (o, &s) in dst.iter_mut().zip(src) {
                        *o = s + sigma * lane.prng.normal_f32();
                    }
                }
            }
            return Ok(None);
        }
        let embed = self.embed.as_ref().expect("LM sampler needs embeddings");
        let v = out.len() / b;
        let d = embed.shape()[1];
        let mut tokens = Vec::with_capacity(b);
        for (bi, lane) in self.lanes.iter_mut().enumerate() {
            let SamplerCfg::Lm { temperature, top_k } = lane.cfg else {
                anyhow::bail!("lane {bi}: synthetic sampling config on an LM model");
            };
            let logits = &out[bi * v..(bi + 1) * v];
            let tok = if temperature <= 0.0 {
                argmax(logits)
            } else {
                categorical(logits, temperature, top_k, &mut lane.prng)
            };
            tokens.push(tok as u32);
            a0[bi * d..(bi + 1) * d].copy_from_slice(embed.row(tok));
        }
        Ok(Some(tokens))
    }
}

/// Argmax over the *finite* logits. A NaN comparing false against
/// everything used to be able to shadow the true maximum (and a head
/// producing ±inf gave it absolute priority); non-finite entries are
/// simply never sampled. All-non-finite degenerates to token 0.
fn argmax(logits: &[f32]) -> usize {
    let mut best: Option<usize> = None;
    for (i, &v) in logits.iter().enumerate() {
        if !v.is_finite() {
            continue;
        }
        match best {
            Some(b) if v <= logits[b] => {}
            _ => best = Some(i),
        }
    }
    best.unwrap_or(0)
}

/// Temperature softmax draw, optionally restricted to the top-k logits.
///
/// Non-finite logits are skipped up front: a single NaN used to panic the
/// sort's `partial_cmp(..).unwrap()` — on the server that death of the
/// engine worker thread killed *every* lane, so one bad logit in one
/// request was a whole-process denial of service. `f32::total_cmp` keeps
/// the sort total regardless, and filtering keeps NaN/±inf out of the
/// softmax weights (a +inf weight would make `total` NaN and the draw
/// undefined). All-non-finite falls back to token 0, matching `argmax`.
fn categorical(logits: &[f32], temperature: f32, top_k: usize, prng: &mut Prng) -> usize {
    let mut idx: Vec<usize> = (0..logits.len()).filter(|&i| logits[i].is_finite()).collect();
    if idx.is_empty() {
        return 0;
    }
    if top_k > 0 && top_k < idx.len() {
        idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
        idx.truncate(top_k);
    }
    let m = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i] - m) / temperature) as f64).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut r = prng.uniform() * total;
    for (w, &i) in weights.iter().zip(&idx) {
        if r < *w {
            return i;
        }
        r -= w;
    }
    *idx.last().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_sigma_zero_is_identity() {
        let mut s = Sampler::synthetic(0.0, 1, 1);
        let out = vec![1.0, -2.0, 3.0];
        let mut a0 = vec![0.0; 3];
        assert!(s.next_a0(&out, 1, &mut a0).unwrap().is_none());
        assert_eq!(a0, out);
    }

    #[test]
    fn synthetic_noise_is_deterministic_per_seed() {
        let out = vec![0.0; 8];
        let run = |seed| {
            let mut s = Sampler::synthetic(0.5, seed, 1);
            let mut a0 = vec![0.0; 8];
            s.next_a0(&out, 1, &mut a0).unwrap();
            a0
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn lanes_draw_from_independent_streams() {
        // lane 1's draws must not depend on lane 0's existence or config
        let out = vec![0.0; 8]; // 2 lanes x d=4
        let mut pair = Sampler::synthetic(1.0, 10, 2);
        let mut a0 = vec![0.0; 8];
        pair.next_a0(&out, 2, &mut a0).unwrap();

        // lane 1 alone, seeded as base_seed + 1 = 11
        let mut solo = Sampler::synthetic(1.0, 11, 1);
        let mut a1 = vec![0.0; 4];
        solo.next_a0(&out[..4], 1, &mut a1).unwrap();
        assert_eq!(&a0[4..], &a1[..], "lane 1 stream == solo stream with its seed");
    }

    #[test]
    fn reset_lane_restarts_the_stream() {
        let out = vec![0.0; 4];
        let mut s = Sampler::synthetic(0.7, 3, 1);
        let mut first = vec![0.0; 4];
        s.next_a0(&out, 1, &mut first).unwrap();
        let mut drifted = vec![0.0; 4];
        s.next_a0(&out, 1, &mut drifted).unwrap();
        assert_ne!(first, drifted, "stream advances");

        // reset with an explicit seed replays that seed's stream from 0
        s.reset_lane(0, None, Some(3));
        let mut replay = vec![0.0; 4];
        s.next_a0(&out, 1, &mut replay).unwrap();
        assert_eq!(first, replay, "reset_lane rebased the PRNG");

        // per-lane sigma override takes effect on the named lane only
        s.reset_lane(0, Some(SamplerCfg::Synthetic { sigma: 0.0 }), None);
        let mut quiet = vec![9.0; 4];
        s.next_a0(&out, 1, &mut quiet).unwrap();
        assert_eq!(quiet, out, "sigma=0 override is identity");
    }

    #[test]
    fn lm_argmax_picks_max_and_embeds() {
        let embed = Tensor::from_vec(&[3, 2], vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0]).unwrap();
        let mut s = Sampler::lm(0.0, 0, embed, 0, 1);
        let logits = vec![0.1, 5.0, -1.0];
        let mut a0 = vec![0.0; 2];
        let toks = s.next_a0(&logits, 1, &mut a0).unwrap().unwrap();
        assert_eq!(toks, vec![1]);
        assert_eq!(a0, vec![1.0, 1.0]);
    }

    #[test]
    fn lm_temperature_samples_valid_tokens() {
        let embed = Tensor::zeros(&[4, 2]);
        let mut s = Sampler::lm(1.0, 2, embed, 3, 1);
        let logits = vec![0.0, 1.0, 2.0, 3.0];
        let mut a0 = vec![0.0; 2];
        for _ in 0..50 {
            let toks = s.next_a0(&logits, 1, &mut a0).unwrap().unwrap();
            // top_k = 2 restricts to tokens {2, 3}
            assert!(toks[0] == 2 || toks[0] == 3, "tok={}", toks[0]);
        }
    }

    #[test]
    fn lm_batch_rows_sampled_independently() {
        let embed = Tensor::from_vec(&[2, 1], vec![10.0, 20.0]).unwrap();
        let mut s = Sampler::lm(0.0, 0, embed, 0, 2);
        let logits = vec![1.0, 0.0, 0.0, 1.0]; // b0 -> tok0, b1 -> tok1
        let mut a0 = vec![0.0; 2];
        let toks = s.next_a0(&logits, 2, &mut a0).unwrap().unwrap();
        assert_eq!(toks, vec![0, 1]);
        assert_eq!(a0, vec![10.0, 20.0]);
    }

    #[test]
    fn non_finite_logits_do_not_panic_or_get_sampled() {
        // regression: a single NaN logit used to panic the categorical
        // sort (partial_cmp().unwrap()) — on the server that killed the
        // engine worker and with it every lane
        let embed = Tensor::zeros(&[5, 2]);
        let mut s = Sampler::lm(0.8, 3, embed, 11, 1);
        let logits = vec![f32::NAN, 1.0, f32::INFINITY, 0.5, f32::NEG_INFINITY];
        let mut a0 = vec![0.0; 2];
        for _ in 0..50 {
            let toks = s.next_a0(&logits, 1, &mut a0).unwrap().unwrap();
            assert!(
                toks[0] == 1 || toks[0] == 3,
                "non-finite logit sampled: tok={}",
                toks[0]
            );
        }
        // argmax path (temperature 0): NaN/inf must not win either
        s.reset_lane(0, Some(SamplerCfg::Lm { temperature: 0.0, top_k: 0 }), None);
        let toks = s.next_a0(&logits, 1, &mut a0).unwrap().unwrap();
        assert_eq!(toks[0], 1, "argmax must pick the largest finite logit");
        // fully non-finite rows degenerate to token 0 instead of panicking
        let all_bad = vec![f32::NAN; 5];
        let toks = s.next_a0(&all_bad, 1, &mut a0).unwrap().unwrap();
        assert_eq!(toks[0], 0);
        s.reset_lane(0, Some(SamplerCfg::Lm { temperature: 1.0, top_k: 2 }), None);
        let toks = s.next_a0(&all_bad, 1, &mut a0).unwrap().unwrap();
        assert_eq!(toks[0], 0);
    }

    #[test]
    fn snapshot_restore_resumes_the_stream_mid_sequence() {
        let out = vec![0.0; 4];
        let mut s = Sampler::synthetic(1.0, 5, 1);
        let mut scratch = vec![0.0; 4];
        s.next_a0(&out, 1, &mut scratch).unwrap(); // advance the stream
        let snap = s.snapshot_lane(0);
        let mut want = vec![0.0; 4];
        s.next_a0(&out, 1, &mut want).unwrap();

        // churn the lane with a different request, then restore
        s.reset_lane(0, Some(SamplerCfg::Synthetic { sigma: 0.2 }), Some(99));
        s.next_a0(&out, 1, &mut scratch).unwrap();
        s.restore_lane(0, &snap);
        let mut got = vec![0.0; 4];
        s.next_a0(&out, 1, &mut got).unwrap();
        assert_eq!(want, got, "restored lane must continue mid-stream, not replay");
    }

    #[test]
    fn lm_per_lane_temperature_overrides() {
        let embed = Tensor::zeros(&[4, 1]);
        let mut s = Sampler::lm(0.0, 0, embed, 5, 2);
        // lane 1 samples hot over the top-1 (forced to the max logit)
        s.reset_lane(1, Some(SamplerCfg::Lm { temperature: 2.0, top_k: 1 }), Some(9));
        let logits = vec![0.0, 9.0, 1.0, 0.0, 0.0, 0.0, 2.0, 0.5];
        let mut a0 = vec![0.0; 2];
        let toks = s.next_a0(&logits, 2, &mut a0).unwrap().unwrap();
        assert_eq!(toks[0], 1, "lane 0 argmax");
        assert_eq!(toks[1], 2, "lane 1 top-1 restriction");
    }
}
