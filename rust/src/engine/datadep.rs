//! Appendix B / Algorithm 5: Flash Inference with **data-dependent**
//! causal filters — van der Hoeven's original relaxed-multiplication
//! tiling, where both the stream and the filter are revealed
//! incrementally (filter tap `ρ_{l,t}` becomes available only once the
//! stream value at position t is known).
//!
//! The demo model is self-contained native rust (no artifacts): M stacked
//! depthwise long-conv mixers whose filters are gated by the data,
//!
//! ```text
//! rho[l, t, :] = base[l, t, :] * sigmoid(y_l[t, :])        (causal!)
//! a_l[t] = tanh(z_l[t]),   z_l = causal_conv(y_l, rho_l),
//! y_{l+1} = a_l,           a_0[t+1] = a_M[t]  (autoregressive)
//! ```
//!
//! and the claim under test is Appendix B's: the parallelogram tiling
//! computes exactly what the lazy O(L²) evaluation computes, in
//! O(L log² L) FLOPs — at ~2x the FLOPs of the data-independent tiling
//! (two length-2U convolutions per tile, one fresh DFT each, vs one).

use std::collections::HashMap;



use crate::fft::{vecfft, Plan, PlanCache};
use crate::tiling::FlopCounter;
use crate::util::prng::Prng;
use crate::util::tensor::Tensor;

/// Configuration for the data-dependent demo model.
#[derive(Debug, Clone, Copy)]
pub struct DataDepCfg {
    pub m: usize,
    pub d: usize,
    /// Max length (power of two).
    pub len: usize,
    pub seed: u64,
}

impl Default for DataDepCfg {
    fn default() -> Self {
        DataDepCfg { m: 4, d: 32, len: 256, seed: 0 }
    }
}

/// The data-dependent LCSM demo model + both inference algorithms.
pub struct DataDepEngine {
    cfg: DataDepCfg,
    /// Static part of the filter, `[M, L, D]` (decayed random, |sum| <= 1).
    base: Tensor,
    /// First input `a_0[0]`, `[D]`.
    input0: Vec<f32>,
    plans: PlanCache,
}

/// Output of one run: all mixer-input streams `[M, T, D]` plus counters.
pub struct DataDepOutput {
    pub streams: Tensor,
    pub flops: FlopCounter,
    pub wall: std::time::Duration,
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

impl DataDepEngine {
    pub fn new(cfg: DataDepCfg) -> DataDepEngine {
        assert!(cfg.len.is_power_of_two());
        let mut rng = Prng::new(cfg.seed);
        let mut base = Tensor::zeros(&[cfg.m, cfg.len, cfg.d]);
        // random filter with exponential decay, L1-normalized per (m, d)
        for mi in 0..cfg.m {
            for di in 0..cfg.d {
                let alpha = 2.0 + 8.0 * rng.uniform() as f32;
                let mut sum = 0.0f32;
                let mut taps = Vec::with_capacity(cfg.len);
                for t in 0..cfg.len {
                    let v = rng.normal_f32()
                        * (-alpha * t as f32 / cfg.len as f32).exp();
                    sum += v.abs();
                    taps.push(v);
                }
                for (t, v) in taps.into_iter().enumerate() {
                    base.at2_mut(mi, t)[di] = v / (sum + 1.0);
                }
            }
        }
        let input0 = (0..cfg.d).map(|_| rng.normal_f32()).collect();
        DataDepEngine { cfg, base, input0, plans: PlanCache::new() }
    }

    /// Filter tap t of layer l, given the stream value there.
    fn rho_tap(&self, l: usize, t: usize, y: &[f32], out: &mut [f32]) {
        let b = self.base.at2(l, t);
        for k in 0..self.cfg.d {
            out[k] = b[k] * sigmoid(y[k]);
        }
    }

    fn block(z: &[f32], out: &mut [f32]) {
        for (o, &v) in out.iter_mut().zip(z) {
            *o = v.tanh();
        }
    }

    /// Lazy O(T²) reference: per position, per layer, recompute the full
    /// convolution sum from scratch.
    pub fn generate_lazy(&self, t_len: usize) -> DataDepOutput {
        let (m, d) = (self.cfg.m, self.cfg.d);
        let wall0 = std::time::Instant::now();
        let mut flops = FlopCounter::new();
        let mut streams = Tensor::zeros(&[m, t_len, d]);
        let mut rho = Tensor::zeros(&[m, t_len, d]);
        let mut a0 = self.input0.clone();
        let mut z = vec![0.0f32; d];
        let mut a = vec![0.0f32; d];

        for i in 0..t_len {
            let mut y_in = a0.clone();
            for l in 0..m {
                streams.at2_mut(l, i).copy_from_slice(&y_in);
                // filter tap i needs y_l[i] — just written
                let tap: &mut [f32] = &mut vec![0.0; d];
                self.rho_tap(l, i, &y_in, tap);
                rho.at2_mut(l, i).copy_from_slice(tap);
                // z = sum_{j<=i} y[j] * rho[i-j]
                z.fill(0.0);
                for j in 0..=i {
                    let y = streams.at2(l, j);
                    let r = rho.at2(l, i - j);
                    for k in 0..d {
                        z[k] += y[k] * r[k];
                    }
                }
                flops.record_red(2 * (i as u64 + 1) * d as u64);
                Self::block(&z, &mut a);
                y_in.copy_from_slice(&a);
            }
            a0.copy_from_slice(&a); // a_0[i+1] = a_M[i]
        }
        DataDepOutput { streams, flops, wall: wall0.elapsed() }
    }

    /// Algorithm 5: the parallelogram tiling. Exact, O(L log² L).
    pub fn generate_alg5(&self, t_len: usize) -> DataDepOutput {
        let (m, d) = (self.cfg.m, self.cfg.d);
        assert!(t_len.is_power_of_two() && t_len <= self.cfg.len);
        let wall0 = std::time::Instant::now();
        let mut flops = FlopCounter::new();
        let mut streams = Tensor::zeros(&[m, t_len, d]);
        let mut rho = Tensor::zeros(&[m, t_len, d]);
        // pending[l][t] accumulates all tiled contributions to z_l[t]
        let mut pending = Tensor::zeros(&[m, t_len, d]);
        // cached spectra of the fixed blocks y_[U..2U) and rho_[U..2U)
        // per (layer, U) — Appendix C-style reuse adapted to Alg 5.
        let mut fixed_specs: HashMap<(usize, usize), FixedSpec> = HashMap::new();

        let mut a0 = self.input0.clone();
        let mut z = vec![0.0f32; d];
        let mut a = vec![0.0f32; d];
        let mut tap = vec![0.0f32; d];

        for i in 0..t_len {
            let mut y_in = a0.clone();
            for l in 0..m {
                streams.at2_mut(l, i).copy_from_slice(&y_in);
                self.rho_tap(l, i, &y_in, &mut tap);
                rho.at2_mut(l, i).copy_from_slice(&tap);

                // red cells: y_i ⊙ rho_0 (+ y_0 ⊙ rho_i for i >= 1)
                let pend = pending.at2(l, i);
                let r0 = rho.at2(l, 0);
                for k in 0..d {
                    z[k] = pend[k] + y_in[k] * r0[k];
                }
                if i >= 1 {
                    let y0 = streams.at2(l, 0);
                    let ri = rho.at2(l, i);
                    for k in 0..d {
                        z[k] += y0[k] * ri[k];
                    }
                    flops.record_red(4 * d as u64);
                } else {
                    flops.record_red(2 * d as u64);
                }
                Self::block(&z, &mut a);
                y_in.copy_from_slice(&a);

                // gray parallelogram tiles (Algorithm 5 lines 9-17)
                if i >= 1 {
                    self.alg5_tiles(l, i, t_len, &streams, &rho, &mut pending,
                                    &mut fixed_specs, &mut flops);
                }
            }
            a0.copy_from_slice(&a);
        }
        DataDepOutput { streams, flops, wall: wall0.elapsed() }
    }

    /// The eager contributions at iteration i (0-indexed, per the paper's
    /// Algorithm 5 indexing).
    #[allow(clippy::too_many_arguments)]
    fn alg5_tiles(
        &self,
        l: usize,
        i: usize,
        t_len: usize,
        streams: &Tensor,
        rho: &Tensor,
        pending: &mut Tensor,
        fixed_specs: &mut HashMap<(usize, usize), FixedSpec>,
        flops: &mut FlopCounter,
    ) {
        // NOTE on fidelity: Algorithm 5 as printed performs tiles only for
        // the *maximum* power of two dividing i+1, which leaves gaps (e.g.
        // the pair y_1·rho_3 -> z_4 is never covered). van der Hoeven's
        // tiling — which the appendix says it "precisely follows" — fires
        // one block product per EVERY power 2^p | (i+1) with 2^{p+1} <= i+1,
        // using the single diagonal square when (i+1) = 2^{p+1}. We verified
        // exact single-coverage of the contribution quadrant by simulation
        // (see tests and DESIGN.md §Deviations) and implement that.
        let d = self.cfg.d;
        let mut u = 1usize;
        while (i + 1) % u == 0 && 2 * u <= i + 1 {
            let plan = self.plans.get(2 * u);
            if i + 1 == 2 * u {
                // diagonal square: z[2U .. 4U-2] += CONV(y[U..2U), rho[U..2U))
                // both fixed blocks just completed — cache their spectra.
                let spec = fixed_specs.entry((l, u)).or_insert_with(|| {
                    FixedSpec::new(&plan, streams.block(l, u, 2 * u),
                                   rho.block(l, u, 2 * u), d)
                });
                if 2 * u < t_len {
                    let hi = (4 * u - 2).min(t_len - 1);
                    conv_add(&plan, ConvSide::Spec(&spec.y_re, &spec.y_im),
                             ConvSide::Spec(&spec.rho_re, &spec.rho_im),
                             pending, l, 2 * u, hi, d, flops, u);
                }
            } else if i + 1 < t_len {
                // two mixed parallelogram tiles:
                // z[i+1 .. i+2U-1] += CONV(y[U..2U), rho[i-U+1..i]) +
                //                     CONV(rho[U..2U), y[i-U+1..i])
                let spec = fixed_specs.get(&(l, u)).expect("fixed block cached at i=2U-1");
                let hi = (i + 2 * u - 1).min(t_len - 1);
                conv_add(&plan, ConvSide::Spec(&spec.y_re, &spec.y_im),
                         ConvSide::Raw(rho.block(l, i - u + 1, i + 1)),
                         pending, l, i + 1, hi, d, flops, u);
                conv_add(&plan, ConvSide::Spec(&spec.rho_re, &spec.rho_im),
                         ConvSide::Raw(streams.block(l, i - u + 1, i + 1)),
                         pending, l, i + 1, hi, d, flops, u);
            }
            u *= 2;
        }
    }
}

/// Cached spectra of the fixed blocks `y[U..2U)` and `rho[U..2U)`.
struct FixedSpec {
    y_re: Vec<f32>,
    y_im: Vec<f32>,
    rho_re: Vec<f32>,
    rho_im: Vec<f32>,
}

impl FixedSpec {
    fn new(plan: &Plan, y_block: &[f32], rho_block: &[f32], d: usize) -> FixedSpec {
        let (y_re, y_im) = crate::fft::spectrum_planes(plan, y_block, d);
        let (rho_re, rho_im) = crate::fft::spectrum_planes(plan, rho_block, d);
        FixedSpec { y_re, y_im, rho_re, rho_im }
    }
}

enum ConvSide<'a> {
    /// Raw time-domain block `[U][D]` (fresh DFT needed).
    Raw(&'a [f32]),
    /// Precomputed spectrum planes `[2U][D]`.
    Spec(&'a [f32], &'a [f32]),
}

/// `pending[l, dst_lo ..= dst_hi] += CONV(a, b)[0 .. hi-lo]` where CONV is
/// the full linear convolution of two length-U sequences (2U-1 outputs),
/// evaluated with an order-2U FFT.
#[allow(clippy::too_many_arguments)]
fn conv_add(
    plan: &Plan,
    a: ConvSide<'_>,
    b: ConvSide<'_>,
    pending: &mut Tensor,
    l: usize,
    dst_lo: usize,
    dst_hi: usize,
    d: usize,
    flops: &mut FlopCounter,
    u: usize,
) {
    let n = plan.n; // 2U
    let mut re = vec![0.0f32; n * d];
    let mut im = vec![0.0f32; n * d];
    let mut dfts = 1u64; // the inverse
    match a {
        ConvSide::Raw(block) => {
            re[..block.len()].copy_from_slice(block);
            vecfft::forward(plan, &mut re, &mut im, d);
            dfts += 1;
        }
        ConvSide::Spec(sre, sim) => {
            re.copy_from_slice(sre);
            im.copy_from_slice(sim);
        }
    }
    match b {
        ConvSide::Raw(block) => {
            let mut bre = vec![0.0f32; n * d];
            let mut bim = vec![0.0f32; n * d];
            bre[..block.len()].copy_from_slice(block);
            vecfft::forward(plan, &mut bre, &mut bim, d);
            dfts += 1;
            vecfft::cmul_inplace(&mut re, &mut im, &bre, &bim);
        }
        ConvSide::Spec(sre, sim) => {
            vecfft::cmul_inplace(&mut re, &mut im, sre, sim);
        }
    }
    vecfft::inverse_unscaled(plan, &mut re, &mut im, d);
    let s = 1.0 / n as f32;
    let count = dst_hi - dst_lo + 1;
    {
        let dst = pending.block_mut(l, dst_lo, dst_lo + count);
        for (o, v) in dst.iter_mut().zip(&re[..count * d]) {
            *o += v * s;
        }
    }
    let log = (n as u64).trailing_zeros() as u64;
    let fft_flops = 5 * n as u64 * log;
    flops.record_tau(u, (dfts * fft_flops + 6 * n as u64 + count as u64) * d as u64,
                     (2 * u * d + count * d) as u64);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alg5_matches_lazy_exactly() {
        for (m, d, len) in [(1usize, 4usize, 32usize), (3, 8, 64), (2, 16, 128)] {
            let eng = DataDepEngine::new(DataDepCfg { m, d, len, seed: len as u64 });
            let lazy = eng.generate_lazy(len);
            let alg5 = eng.generate_alg5(len);
            let err = alg5.streams.rel_l2(&lazy.streams);
            assert!(err < 1e-4, "m={m} d={d} len={len}: rel_l2={err}");
            assert!(alg5.streams.data().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn alg5_flops_are_quasilinear() {
        let eng = DataDepEngine::new(DataDepCfg { m: 1, d: 8, len: 4096, seed: 1 });
        let f1024 = eng.generate_alg5(1024).flops.mixer_flops;
        let f4096 = eng.generate_alg5(4096).flops.mixer_flops;
        // 4x length -> quadratic would be 16x; quasilinear stays under ~7x
        assert!(f4096 < f1024 * 8, "f1024={f1024} f4096={f4096}");
        // beyond the FFT-constant crossover the O(L²) lazy closed form loses
        let lazy4096 = crate::tiling::flops::lazy_total_flops(4096, 1, 8);
        assert!(lazy4096 > f4096, "lazy={lazy4096} alg5={f4096}");
    }

    #[test]
    fn datadep_tiling_costs_a_few_times_the_static_tiling() {
        // Appendix B: parallelogram tiles need 2 convs (with one fresh DFT
        // each) per iteration vs 1 conv with a cached filter DFT — ≈2x on
        // conv count. The static closed form additionally charges the rfft
        // half-spectrum model (this path still runs full complex DFTs on
        // its data-dependent filters), adding ≈1.4-1.6x ⇒ ≈3-4x combined.
        let (d, len) = (8usize, 1024usize);
        let eng = DataDepEngine::new(DataDepCfg { m: 1, d, len, seed: 2 });
        let dyn_flops = eng.generate_alg5(len).flops.mixer_flops as f64;
        let static_flops =
            crate::tiling::flops::flash_total_flops(len, 1, d, true) as f64;
        let ratio = dyn_flops / static_flops;
        assert!((2.2..4.6).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn deterministic_per_seed() {
        let eng = DataDepEngine::new(DataDepCfg::default());
        let a = eng.generate_alg5(64);
        let b = eng.generate_alg5(64);
        assert_eq!(a.streams.max_abs_diff(&b.streams), 0.0);
    }
}
