//! Lazy baseline (§3.1.1, Figure 1 top-left): compute each pending column
//! from scratch when it is needed — O(i) MACs per lane at position i,
//! Ω(L²) total, touching the entire stream history every token.

use crate::tiling::FlopCounter;
use crate::util::tensor::{CellTensor, Tensor};

/// Compute `col[g] = sum_{j=1}^{i-1} streams[g, j-1] ⊙ rho[m, i-j]` for
/// 1-indexed position `i` into `buf` (`[G, D]`). The red cell (j = i) is
/// handled inside `step`, exactly as in the flash engine.
pub fn lazy_pending_col(
    streams: &CellTensor,
    rho: &Tensor,
    b: usize,
    i: usize,
    buf: &mut Vec<f32>,
    flops: &mut FlopCounter,
) {
    let (g, _, d) = (streams.shape()[0], streams.shape()[1], streams.shape()[2]);
    buf.resize(g * d, 0.0);
    buf.fill(0.0);
    for gi in 0..g {
        let m = gi / b;
        let col = &mut buf[gi * d..(gi + 1) * d];
        for j in 1..i {
            let y = streams.at2(gi, j - 1);
            let r = rho.at2(m, i - j);
            crate::util::tensor::ops::add_mul(col, y, r);
        }
    }
    if i > 1 {
        flops.record_red(2 * (i as u64 - 1) * g as u64 * d as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_hand_computation() {
        // G=1, D=1: streams = [2, 3], rho = [r0, r1, r2] = [10, 100, 1000]
        let mut init = Tensor::zeros(&[1, 4, 1]);
        init.at2_mut(0, 0)[0] = 2.0;
        init.at2_mut(0, 1)[0] = 3.0;
        let streams = CellTensor::from_tensor(&init);
        let rho = Tensor::from_vec(&[1, 4, 1], vec![10.0, 100.0, 1000.0, 10000.0]).unwrap();
        let mut buf = Vec::new();
        let mut fl = FlopCounter::new();
        // i=3: col = y1*rho[2] + y2*rho[1] = 2*1000 + 3*100 = 2300
        lazy_pending_col(&streams, &rho, 1, 3, &mut buf, &mut fl);
        assert_eq!(buf, vec![2300.0]);
        assert_eq!(fl.mixer_flops, 2 * 2);
        // i=1: empty sum
        lazy_pending_col(&streams, &rho, 1, 1, &mut buf, &mut fl);
        assert_eq!(buf, vec![0.0]);
    }
}
