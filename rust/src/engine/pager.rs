//! Session pager — KV-cache-style paging for LCSM lanes (ROADMAP
//! "multi-session store sharing").
//!
//! Continuous admission recycles lanes *within* one live [`super::Store`],
//! so an engine can hold exactly `B` resumable requests: a suspended
//! request's activation rows have nowhere to live. The pager fixes that
//! with a **slab allocator** over fixed `[groups, rows_chunk, D]` blocks
//! (`groups = M`, one lane's share of the `G = M·B` group axis): a
//! suspended lane's entire state — its non-zero `streams`/`pending` store
//! rows, `a0`/short-conv slices, sampler PRNG snapshot, token buffer and
//! start/limit clocks — is copied out into a [`LaneCheckpoint`], the lane
//! is reset (freeing it for another request), and the checkpoint is
//! restored later by the exact inverse copy. Checkpoints are small: only
//! rows from the lane's admission row up to `pos` (streams) / `2·pos`
//! (pending — a gray tile at iteration `i` deposits sums up to row
//! `2i-1`) can be non-zero, so a lane pages out its own progress, not
//! the whole store.
//!
//! Slab blocks are fixed-size so free/alloc cannot fragment: a checkpoint
//! of `n` rows takes `ceil(n / rows_chunk)` blocks per tensor, handed back
//! verbatim on restore (or [`Pager::discard`]). Capacity is bounded
//! (`pager_capacity_mb`); a suspend that does not fit fails *before* any
//! lane state is touched, so the scheduler simply skips that eviction.
//!
//! The bit-identity contract (why restore is exact) lives with
//! [`super::Session::suspend`]/[`super::Session::restore`]; this module is
//! only the storage substrate. See `rust/DESIGN.md` §6.
//!
//! Two extensions make checkpoints durable and mobile (DESIGN.md §6/§8):
//! a versioned, geometry-guarded **serialization format** (`FICK` v1,
//! [`Pager::serialize`] / [`Pager::deserialize`]) capturing the full
//! checkpoint — store rows, sampler PRNG state, lane clocks — plus a
//! **disk-spill tier**: the slab stays hot, cold checkpoints spill as
//! serialized blobs into a spill directory ([`Pager::spill_blob`]), and
//! [`Pager::fetch`] transparently reloads a [`CkptRef::Spilled`] entry.
//! Spilled blobs double as the fleet's shipping format — a quarantined
//! replica's checkpoints travel to a healthy replica byte-for-byte — and
//! as durable session handles: [`Pager::set_spill_dir`] scans the
//! directory at boot, so spilled sessions survive a server restart.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::engine::SamplerCfg;

/// Monotonic arena ids: every [`Pager`] gets one, and every
/// [`PagedRows`] remembers which arena minted it, so handing a
/// checkpoint to the wrong (same-shaped) pager is a deterministic panic
/// instead of silent garbage reads + free-list corruption.
static PAGER_IDS: AtomicU64 = AtomicU64::new(1);

/// Default rows per slab block. Small enough that an early eviction
/// (few non-zero rows) wastes little tail space, large enough that a
/// full-store checkpoint stays a handful of allocations.
pub const DEFAULT_ROWS_CHUNK: usize = 16;

/// One lane's sampler state inside a checkpoint: the active config plus
/// the raw xoshiro256** state, so a resumed lane continues its private
/// random stream mid-sequence (bit-identical draws).
#[derive(Debug, Clone)]
pub struct SamplerSnapshot {
    pub cfg: SamplerCfg,
    pub prng_state: [u64; 4],
}

/// Serving-layer progress that must travel *with* a shipped checkpoint.
///
/// `checksum_total` is a left-fold f64 accumulator: the whole-sequence
/// value equals folding the remaining outputs onto the part-1 value, but
/// does **not** equal part-1 plus a separately folded part-2 (f64
/// addition is not associative). So a continuation must resume the
/// accumulator itself, which is why this rides inside the blob instead
/// of being recomputed on the receiving replica.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServingMeta {
    pub checksum_total: f64,
    pub queue_ms: f64,
    pub evictions: u64,
    pub batch_size: usize,
}

/// Handle to a row range stored in the slab: block ids plus the logical
/// row count (the last block may be partially filled) and the id of the
/// arena that owns the blocks.
#[derive(Debug)]
pub struct PagedRows {
    pager: u64,
    blocks: Vec<usize>,
    rows: usize,
}

impl PagedRows {
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Slab f32 values this range actually occupies (whole blocks).
    pub fn slab_values(&self, block_values: usize) -> usize {
        self.blocks.len() * block_values
    }
}

/// A suspended lane, ready to be re-injected by
/// [`super::Session::restore`]. Holds slab handles (the bulky store rows)
/// plus the small host-side lane state inline.
#[derive(Debug)]
pub struct LaneCheckpoint {
    /// First checkpointed store row for both tensors. Rows below it are
    /// zero by construction in the unwrapped store (the lane's admission
    /// reset them and every later write lands at or above the admission
    /// point), so a late-admitted lane's checkpoint pays for *its own*
    /// rows, not the batch's global clock. 0 in the wrapped half store,
    /// where recycled rows can sit anywhere.
    pub(crate) row0: usize,
    /// `streams` rows `row0 .. row0 + streams.rows` of each lane group.
    pub(crate) streams: PagedRows,
    /// `pending` rows `row0 .. row0 + pending.rows` (partial tile sums
    /// with deadlines past the suspension point — they complement the
    /// exact set of tiles that still run after restore, which is why
    /// restore must happen at the same global schedule position).
    pub(crate) pending: PagedRows,
    /// The lane's next-step input slice (`[D]`).
    pub(crate) a0: Vec<f32>,
    /// The lane's short-conv state slices (Hyena variant).
    pub(crate) scstate: Option<Vec<f32>>,
    pub(crate) sampler: SamplerSnapshot,
    /// Token buffer accumulated so far (LM variant).
    pub(crate) tokens: Option<Vec<u32>>,
    /// Global session position at suspension — the only position a
    /// restore is legal at (same fractal-schedule alignment).
    pub(crate) pos: usize,
    /// The lane's admission clock and padded schedule length.
    pub(crate) lane_start: usize,
    pub(crate) lane_limit: usize,
    /// Store geometry guards: a checkpoint only restores into a session
    /// with the identical row layout.
    pub(crate) rows: usize,
    pub(crate) half: bool,
    /// Checkpoint flavor. `false` = aligned (PR 5 contract: restore only
    /// at the identical global `pos`, streams + pending both paged).
    /// `true` = folded: [`super::Session::suspend_folded`] baked every
    /// history contribution into the pending tail, so `streams` is empty
    /// and restore is legal at any step boundary with
    /// `steps_done() >= lane_pos()` (fresh lane-clock rebase).
    pub(crate) folded: bool,
}

impl LaneCheckpoint {
    /// Global position this checkpoint must be restored at.
    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn lane_start(&self) -> usize {
        self.lane_start
    }

    pub fn lane_limit(&self) -> usize {
        self.lane_limit
    }

    /// Positions the lane had already generated when it was suspended.
    pub fn lane_pos(&self) -> usize {
        self.pos - self.lane_start
    }

    /// Whether this is a folded (position-independent) checkpoint.
    pub fn folded(&self) -> bool {
        self.folded
    }

    /// Future span the lane still has to generate (folded checkpoints
    /// carry exactly this many pending rows).
    pub fn span(&self) -> usize {
        self.lane_limit.saturating_sub(self.lane_pos())
    }
}

/// Where a suspended session's checkpoint currently lives: hot in the
/// slab, or cold on disk under its session key. [`Pager::fetch`] resolves
/// either into a restorable [`LaneCheckpoint`].
#[derive(Debug)]
pub enum CkptRef {
    Resident(LaneCheckpoint),
    Spilled(String),
}

impl CkptRef {
    pub fn is_spilled(&self) -> bool {
        matches!(self, CkptRef::Spilled(_))
    }
}

/// Slab allocator over `[groups, rows_chunk, D]` f32 blocks.
///
/// All blocks live in one arena allocation; a free list recycles them
/// exactly (no fragmentation, no growth). `groups` is the per-lane group
/// count `M = G / B` — every block holds `rows_chunk` rows of *all* of
/// one lane's groups, so one checkpoint's rows stay contiguous per block
/// and copy in/out as straight `memcpy`s.
pub struct Pager {
    id: u64,
    groups: usize,
    d: usize,
    rows_chunk: usize,
    data: Vec<f32>,
    free: Vec<usize>,
    total_blocks: usize,
    /// Disk-spill tier root (None = spilling disabled).
    spill_dir: Option<PathBuf>,
    /// Session key -> spill file for every blob currently on disk.
    spilled: BTreeMap<String, PathBuf>,
}

impl Pager {
    /// Build a pager with `capacity_mb` megabytes of slab storage
    /// (rounded down to whole blocks; at least one block).
    pub fn new(groups: usize, d: usize, rows_chunk: usize, capacity_mb: usize) -> Pager {
        assert!(groups > 0 && d > 0 && rows_chunk > 0, "degenerate pager shape");
        let block_values = groups * rows_chunk * d;
        let capacity_values = capacity_mb * (1 << 20) / std::mem::size_of::<f32>();
        let total_blocks = (capacity_values / block_values).max(1);
        Pager {
            id: PAGER_IDS.fetch_add(1, Ordering::Relaxed),
            groups,
            d,
            rows_chunk,
            data: vec![0.0; total_blocks * block_values],
            free: (0..total_blocks).rev().collect(),
            total_blocks,
            spill_dir: None,
            spilled: BTreeMap::new(),
        }
    }

    pub fn rows_chunk(&self) -> usize {
        self.rows_chunk
    }

    /// f32 values per slab block.
    pub fn block_values(&self) -> usize {
        self.groups * self.rows_chunk * self.d
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// f32 values currently held by live checkpoints (the
    /// `fi_pager_resident_values` gauge).
    pub fn resident_values(&self) -> usize {
        (self.total_blocks - self.free.len()) * self.block_values()
    }

    /// Blocks a range of `rows` rows needs (per tensor).
    pub fn blocks_for(&self, rows: usize) -> usize {
        rows.div_ceil(self.rows_chunk)
    }

    /// Whether a checkpoint needing `blocks` more blocks fits right now.
    pub fn fits(&self, blocks: usize) -> bool {
        blocks <= self.free.len()
    }

    fn alloc(&mut self, n: usize) -> Result<Vec<usize>> {
        // Chaos handle: `pager_alloc:fail@k` makes one suspend/store fail
        // as if the slab were full — the scheduler must skip that
        // eviction and keep serving (checkpoint-store errors are soft).
        crate::util::faultpoint::check("pager_alloc")?;
        if n > self.free.len() {
            bail!(
                "pager full: need {n} blocks, {} of {} free",
                self.free.len(),
                self.total_blocks
            );
        }
        Ok((0..n).map(|_| self.free.pop().unwrap()).collect())
    }

    pub fn groups(&self) -> usize {
        self.groups
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub(crate) fn release(&mut self, pr: PagedRows) {
        assert_eq!(pr.pager, self.id, "slab handle belongs to a different pager");
        for b in pr.blocks {
            debug_assert!(!self.free.contains(&b), "double free of slab block {b}");
            self.free.push(b);
        }
    }

    /// Page `rows` rows of lane data into freshly allocated blocks.
    /// `data` is `[groups, rows, D]` (group-major, the layout
    /// `Store::copy_lane_rows_out` produces); block `k` receives rows
    /// `k·rows_chunk ..` of **every** group.
    pub fn store_rows(&mut self, data: &[f32], rows: usize) -> Result<PagedRows> {
        debug_assert_eq!(data.len(), self.groups * rows * self.d);
        let blocks = self.alloc(self.blocks_for(rows))?;
        let (rc, d, bv) = (self.rows_chunk, self.d, self.block_values());
        for (k, &blk) in blocks.iter().enumerate() {
            let take = rc.min(rows - k * rc);
            for g in 0..self.groups {
                let src = (g * rows + k * rc) * d..(g * rows + k * rc + take) * d;
                let dst = blk * bv + g * rc * d;
                self.data[dst..dst + take * d].copy_from_slice(&data[src]);
            }
        }
        Ok(PagedRows { pager: self.id, blocks, rows })
    }

    /// Copy a paged range back out into `[groups, rows, D]` layout and
    /// return its blocks to the free list.
    pub fn fetch_rows(&mut self, pr: PagedRows, out: &mut Vec<f32>) {
        assert_eq!(pr.pager, self.id, "slab handle belongs to a different pager");
        let rows = pr.rows;
        out.resize(self.groups * rows * self.d, 0.0);
        let (rc, d, bv) = (self.rows_chunk, self.d, self.block_values());
        for (k, &blk) in pr.blocks.iter().enumerate() {
            let take = rc.min(rows - k * rc);
            for g in 0..self.groups {
                let src = blk * bv + g * rc * d;
                let dst = (g * rows + k * rc) * d..(g * rows + k * rc + take) * d;
                out[dst].copy_from_slice(&self.data[src..src + take * d]);
            }
        }
        self.release(pr);
    }

    /// Drop a checkpoint without restoring it (failed/abandoned request),
    /// returning its blocks to the free list.
    pub fn discard(&mut self, ckpt: LaneCheckpoint) {
        self.release(ckpt.streams);
        self.release(ckpt.pending);
    }

    /// Copy a paged range out into `[groups, rows, D]` layout *without*
    /// consuming the handle (serialization reads, spill writes).
    pub fn peek_rows(&self, pr: &PagedRows, out: &mut Vec<f32>) {
        assert_eq!(pr.pager, self.id, "slab handle belongs to a different pager");
        let rows = pr.rows;
        out.resize(self.groups * rows * self.d, 0.0);
        let (rc, d, bv) = (self.rows_chunk, self.d, self.block_values());
        for (k, &blk) in pr.blocks.iter().enumerate() {
            let take = rc.min(rows - k * rc);
            for g in 0..self.groups {
                let src = blk * bv + g * rc * d;
                let dst = (g * rows + k * rc) * d..(g * rows + k * rc + take) * d;
                out[dst].copy_from_slice(&self.data[src..src + take * d]);
            }
        }
    }

    /// Serialize a checkpoint (plus optional serving-layer progress) into
    /// a self-contained `FICK` v1 blob. The checkpoint stays resident;
    /// the caller decides whether to [`Pager::discard`] it afterwards
    /// (spill) or keep both (shipping a copy).
    ///
    /// Layout (little-endian): magic `"FICK"`, `u32` version, `u8` flags
    /// (bit0 folded, bit1 half, bit2 scstate, bit3 tokens, bit4 meta),
    /// nine `u32` geometry words (M, D, rows, row0, pos, lane_start,
    /// lane_limit, streams-rows, pending-rows), sampler (`u8` tag + `f32`
    /// + `u32` params), `[u64; 4]` PRNG state, `a0` (`D` f32s), optional
    /// scstate / tokens / [`ServingMeta`], then the streams and pending
    /// payloads as `[M, rows, D]` f32s. Deserialize checks every length
    /// and rejects trailing bytes, so truncated or size-corrupted blobs
    /// fail cleanly instead of panicking.
    pub fn serialize(&self, ckpt: &LaneCheckpoint, meta: Option<&ServingMeta>) -> Vec<u8> {
        let mut sbuf = Vec::new();
        let mut pbuf = Vec::new();
        self.peek_rows(&ckpt.streams, &mut sbuf);
        self.peek_rows(&ckpt.pending, &mut pbuf);
        let mut out = Vec::with_capacity(128 + 4 * (sbuf.len() + pbuf.len()));
        out.extend_from_slice(&CKPT_MAGIC);
        put_u32(&mut out, CKPT_VERSION);
        let mut flags = 0u8;
        if ckpt.folded {
            flags |= 1;
        }
        if ckpt.half {
            flags |= 2;
        }
        if ckpt.scstate.is_some() {
            flags |= 4;
        }
        if ckpt.tokens.is_some() {
            flags |= 8;
        }
        if meta.is_some() {
            flags |= 16;
        }
        out.push(flags);
        for v in [
            self.groups,
            self.d,
            ckpt.rows,
            ckpt.row0,
            ckpt.pos,
            ckpt.lane_start,
            ckpt.lane_limit,
            ckpt.streams.rows,
            ckpt.pending.rows,
        ] {
            put_u32(&mut out, v as u32);
        }
        // Sampler is a fixed-width record (tag + f32 + u32) so the two
        // variants parse identically.
        match ckpt.sampler.cfg {
            SamplerCfg::Synthetic { sigma } => {
                out.push(0);
                put_f32(&mut out, sigma);
                put_u32(&mut out, 0);
            }
            SamplerCfg::Lm { temperature, top_k } => {
                out.push(1);
                put_f32(&mut out, temperature);
                // top_k is a vocab cutoff; u32 range is ample.
                put_u32(&mut out, top_k.min(u32::MAX as usize) as u32);
            }
        }
        for w in ckpt.sampler.prng_state {
            put_u64(&mut out, w);
        }
        put_f32s(&mut out, &ckpt.a0);
        if let Some(sc) = &ckpt.scstate {
            put_u32(&mut out, sc.len() as u32);
            put_f32s(&mut out, sc);
        }
        if let Some(tk) = &ckpt.tokens {
            put_u32(&mut out, tk.len() as u32);
            for &t in tk {
                put_u32(&mut out, t);
            }
        }
        if let Some(m) = meta {
            put_f64(&mut out, m.checksum_total);
            put_f64(&mut out, m.queue_ms);
            put_u64(&mut out, m.evictions);
            put_u32(&mut out, m.batch_size as u32);
        }
        put_f32s(&mut out, &sbuf);
        put_f32s(&mut out, &pbuf);
        out
    }

    /// Parse a `FICK` blob back into a slab-resident checkpoint.
    ///
    /// Guards: magic, version, flag bits, `[M, D]` geometry against this
    /// pager's shape, and exact blob length. Slab allocation can still
    /// fail under pressure — on any error nothing stays allocated.
    pub fn deserialize(&mut self, blob: &[u8]) -> Result<(LaneCheckpoint, Option<ServingMeta>)> {
        let mut cur = Cur { b: blob, at: 0 };
        if cur.take(4)? != CKPT_MAGIC {
            bail!("checkpoint blob: bad magic");
        }
        let ver = cur.u32()?;
        if ver != CKPT_VERSION {
            bail!("checkpoint blob: unsupported version {ver} (want {CKPT_VERSION})");
        }
        let flags = cur.u8()?;
        if flags & !0x1f != 0 {
            bail!("checkpoint blob: unknown flag bits {flags:#04x}");
        }
        let mut geom = [0usize; 9];
        for g in &mut geom {
            *g = cur.u32()? as usize;
        }
        let [m, d, rows, row0, pos, lane_start, lane_limit, ns, np] = geom;
        if m != self.groups || d != self.d {
            bail!(
                "checkpoint geometry [M={m}, D={d}] does not match pager [M={}, D={}]",
                self.groups,
                self.d
            );
        }
        if rows == 0 || ns > rows || np > rows || row0 > rows || pos < lane_start {
            bail!("checkpoint blob: inconsistent geometry");
        }
        let tag = cur.u8()?;
        let p_f = cur.f32()?;
        let p_u = cur.u32()? as usize;
        let cfg = match tag {
            0 => SamplerCfg::Synthetic { sigma: p_f },
            1 => SamplerCfg::Lm { temperature: p_f, top_k: p_u },
            t => bail!("checkpoint blob: unknown sampler tag {t}"),
        };
        let mut prng_state = [0u64; 4];
        for w in &mut prng_state {
            *w = cur.u64()?;
        }
        let a0 = cur.f32s(d)?;
        let scstate = if flags & 4 != 0 {
            let n = cur.u32()? as usize;
            Some(cur.f32s(n)?)
        } else {
            None
        };
        let tokens = if flags & 8 != 0 {
            let n = cur.u32()? as usize;
            Some(cur.u32s(n)?)
        } else {
            None
        };
        let meta = if flags & 16 != 0 {
            Some(ServingMeta {
                checksum_total: cur.f64()?,
                queue_ms: cur.f64()?,
                evictions: cur.u64()?,
                batch_size: cur.u32()? as usize,
            })
        } else {
            None
        };
        let Some(sn) = m.checked_mul(ns).and_then(|x| x.checked_mul(d)) else {
            bail!("checkpoint blob: geometry overflow");
        };
        let Some(pn) = m.checked_mul(np).and_then(|x| x.checked_mul(d)) else {
            bail!("checkpoint blob: geometry overflow");
        };
        let sbuf = cur.f32s(sn)?;
        let pbuf = cur.f32s(pn)?;
        if cur.at != blob.len() {
            bail!("checkpoint blob: {} trailing bytes", blob.len() - cur.at);
        }
        let streams = self.store_rows(&sbuf, ns)?;
        let pending = match self.store_rows(&pbuf, np) {
            Ok(p) => p,
            Err(e) => {
                self.release(streams);
                return Err(e);
            }
        };
        Ok((
            LaneCheckpoint {
                row0,
                streams,
                pending,
                a0,
                scstate,
                sampler: SamplerSnapshot { cfg, prng_state },
                tokens,
                pos,
                lane_start,
                lane_limit,
                rows,
                half: flags & 2 != 0,
                folded: flags & 1 != 0,
            },
            meta,
        ))
    }

    // ---- disk-spill tier -------------------------------------------------

    /// Enable the spill tier rooted at `dir` (created if missing) and
    /// boot-scan it: every `*.fick` file whose name hex-decodes to a
    /// session key is registered as a spilled checkpoint, so sessions
    /// spilled by a previous process survive a restart as durable
    /// handles. Returns the number of checkpoints found.
    pub fn set_spill_dir(&mut self, dir: &Path) -> Result<usize> {
        std::fs::create_dir_all(dir)?;
        let mut found = 0;
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("fick") {
                continue;
            }
            let Some(stem) = path.file_stem().and_then(|s| s.to_str()) else {
                continue;
            };
            let Some(key) = hex_decode(stem) else {
                continue;
            };
            self.spilled.insert(key, path);
            found += 1;
        }
        self.spill_dir = Some(dir.to_path_buf());
        Ok(found)
    }

    pub fn spill_enabled(&self) -> bool {
        self.spill_dir.is_some()
    }

    pub fn has_spilled(&self, key: &str) -> bool {
        self.spilled.contains_key(key)
    }

    pub fn spilled_count(&self) -> usize {
        self.spilled.len()
    }

    pub fn spilled_keys(&self) -> Vec<String> {
        self.spilled.keys().cloned().collect()
    }

    /// Write a serialized blob to the spill dir under `key`. The caller
    /// composes spilling: `serialize` -> `spill_blob` -> `discard`, and
    /// keeps the checkpoint resident if the write fails (spill errors are
    /// soft, like slab-full errors).
    pub fn spill_blob(&mut self, key: &str, blob: &[u8]) -> Result<()> {
        // Chaos handle: `pager_spill:fail@k` simulates a full/broken disk.
        crate::util::faultpoint::check("pager_spill")?;
        let Some(dir) = &self.spill_dir else {
            bail!("spill tier disabled: no spill dir configured");
        };
        let path = dir.join(format!("{}.fick", hex_encode(key)));
        std::fs::write(&path, blob)?;
        self.spilled.insert(key.to_string(), path);
        Ok(())
    }

    /// Take the raw spilled blob for `key` off disk (shipping path). The
    /// file is deleted only after a successful read.
    pub fn take_spilled_blob(&mut self, key: &str) -> Result<Vec<u8>> {
        let Some(path) = self.spilled.get(key) else {
            bail!("no spilled checkpoint for session {key:?}");
        };
        let blob = std::fs::read(path)?;
        if let Some(path) = self.spilled.remove(key) {
            let _ = std::fs::remove_file(path);
        }
        Ok(blob)
    }

    /// Reload a spilled checkpoint into the slab. The file is deleted
    /// only once the blob parsed and its rows are resident, so a slab-full
    /// failure leaves the spilled copy intact for a later retry.
    pub fn load_spilled(&mut self, key: &str) -> Result<(LaneCheckpoint, Option<ServingMeta>)> {
        let Some(path) = self.spilled.get(key) else {
            bail!("no spilled checkpoint for session {key:?}");
        };
        let blob = std::fs::read(path)?;
        let out = self.deserialize(&blob)?;
        if let Some(path) = self.spilled.remove(key) {
            let _ = std::fs::remove_file(path);
        }
        Ok(out)
    }

    /// Resolve a [`CkptRef`] into a restorable checkpoint, transparently
    /// reloading from the spill tier. Spilled entries also yield the
    /// [`ServingMeta`] persisted in the blob (resident ones keep that
    /// state in the scheduler slot, so they return `None`).
    pub fn fetch(&mut self, r: CkptRef) -> Result<(LaneCheckpoint, Option<ServingMeta>)> {
        match r {
            CkptRef::Resident(c) => Ok((c, None)),
            CkptRef::Spilled(key) => self.load_spilled(&key),
        }
    }

    /// Drop a checkpoint wherever it lives (slab blocks freed, spill file
    /// unlinked best-effort).
    pub fn discard_ref(&mut self, r: CkptRef) {
        match r {
            CkptRef::Resident(c) => self.discard(c),
            CkptRef::Spilled(key) => {
                if let Some(path) = self.spilled.remove(&key) {
                    let _ = std::fs::remove_file(path);
                }
            }
        }
    }
}

const CKPT_MAGIC: [u8; 4] = *b"FICK";
const CKPT_VERSION: u32 = 1;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, vals: &[f32]) {
    out.reserve(vals.len() * 4);
    for &v in vals {
        put_f32(out, v);
    }
}

/// Length-checked little-endian reader over a blob: every read bails (no
/// panic, no partial state) when the blob is shorter than its headers
/// claim.
struct Cur<'a> {
    b: &'a [u8],
    at: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let Some(end) = self.at.checked_add(n).filter(|&e| e <= self.b.len()) else {
            bail!("checkpoint blob truncated: need {n} bytes at offset {}", self.at);
        };
        let s = &self.b[self.at..end];
        self.at = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let Some(bytes) = n.checked_mul(4) else {
            bail!("checkpoint blob: length overflow");
        };
        let raw = self.take(bytes)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        let Some(bytes) = n.checked_mul(4) else {
            bail!("checkpoint blob: length overflow");
        };
        let raw = self.take(bytes)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

/// Spill file names are the hex of the session key, so arbitrary keys
/// (any UTF-8 the HTTP layer accepts) map to safe, reversible file names.
fn hex_encode(key: &str) -> String {
    let mut s = String::with_capacity(key.len() * 2);
    for b in key.bytes() {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

fn hex_decode(name: &str) -> Option<String> {
    let bytes = name.as_bytes();
    if bytes.len() % 2 != 0 {
        return None;
    }
    let mut out = Vec::with_capacity(bytes.len() / 2);
    for pair in bytes.chunks_exact(2) {
        let hi = (pair[0] as char).to_digit(16)?;
        let lo = (pair[1] as char).to_digit(16)?;
        out.push((hi * 16 + lo) as u8);
    }
    String::from_utf8(out).ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{self, ensure};
    use crate::util::prng::Prng;

    fn tiny(total_blocks_hint_mb: usize) -> Pager {
        // groups=2, d=2, rows_chunk=4 -> 16 values (64 bytes) per block
        Pager::new(2, 2, 4, total_blocks_hint_mb)
    }

    #[test]
    fn capacity_rounds_down_to_whole_blocks() {
        let p = tiny(1); // 1 MiB / 64 B = 16384 blocks
        assert_eq!(p.total_blocks(), 16384);
        assert_eq!(p.free_blocks(), 16384);
        assert_eq!(p.block_values(), 16);
        assert_eq!(p.resident_values(), 0);
        // a capacity below one block still yields one block
        let q = Pager::new(64, 64, 64, 0);
        assert_eq!(q.total_blocks(), 1);
    }

    #[test]
    fn store_fetch_roundtrip_partial_tail_block() {
        let mut p = tiny(1);
        // 6 rows over rows_chunk=4 -> 2 blocks, second half-filled
        let rows = 6;
        let data: Vec<f32> = (0..2 * rows * 2).map(|i| i as f32).collect();
        let pr = p.store_rows(&data, rows).unwrap();
        assert_eq!(pr.rows(), 6);
        assert_eq!(p.free_blocks(), p.total_blocks() - 2);
        assert_eq!(p.resident_values(), 2 * 16);
        let mut out = Vec::new();
        p.fetch_rows(pr, &mut out);
        assert_eq!(out, data, "paged rows must round-trip bit-exactly");
        assert_eq!(p.free_blocks(), p.total_blocks(), "fetch frees the blocks");
    }

    #[test]
    fn alloc_fails_cleanly_when_full() {
        let mut p = Pager::new(2, 2, 4, 0); // exactly 1 block
        let data = vec![1.0; 2 * 4 * 2];
        let pr = p.store_rows(&data, 4).unwrap();
        assert!(p.store_rows(&data, 4).is_err(), "second alloc must fail");
        // capacity check matches
        assert!(!p.fits(1));
        let mut out = Vec::new();
        p.fetch_rows(pr, &mut out);
        assert!(p.fits(1));
        p.store_rows(&data, 4).unwrap();
    }

    /// Property: interleaved store/fetch of random-sized checkpoints
    /// never hands two live ranges the same block (payload integrity
    /// proves no overlap), and freeing everything restores full capacity.
    #[test]
    fn prop_slab_no_overlap_full_reuse() {
        propcheck::check(
            "slab_no_overlap_full_reuse",
            64,
            |rng: &mut Prng| {
                // (groups, d, rows_chunk, ops) — ops: row counts, with 0
                // meaning "free the oldest live range"
                let groups = rng.range(1, 3);
                let d = rng.range(1, 3);
                let rc = rng.range(1, 5);
                let ops: Vec<usize> = (0..rng.range(4, 24)).map(|_| rng.range(0, 9)).collect();
                (groups, d, rc, ops)
            },
            |(groups, d, rc, ops)| {
                // tiny fixed arena (8 blocks) so the ops churn through
                // full-capacity alloc/free cycles
                let mut p = Pager {
                    id: PAGER_IDS.fetch_add(1, Ordering::Relaxed),
                    groups: *groups,
                    d: *d,
                    rows_chunk: *rc,
                    data: vec![0.0; 8 * groups * rc * d],
                    free: (0..8).rev().collect(),
                    total_blocks: 8,
                    spill_dir: None,
                    spilled: BTreeMap::new(),
                };
                let mut live: Vec<(PagedRows, Vec<f32>)> = Vec::new();
                let mut stamp = 1.0f32;
                for &op in ops {
                    if op == 0 || !p.fits(p.blocks_for(op)) {
                        if !live.is_empty() {
                            let (pr, want) = live.remove(0);
                            let mut got = Vec::new();
                            p.fetch_rows(pr, &mut got);
                            ensure(
                                got == want,
                                format!("payload corrupted: {got:?} != {want:?}"),
                            )?;
                        }
                        continue;
                    }
                    let n = groups * op * d;
                    let data: Vec<f32> = (0..n).map(|i| stamp + i as f32).collect();
                    stamp += 1000.0;
                    let pr = p.store_rows(&data, op).map_err(|e| e.to_string())?;
                    live.push((pr, data));
                }
                // drain: every payload intact, every block reusable
                for (pr, want) in live.drain(..) {
                    let mut got = Vec::new();
                    p.fetch_rows(pr, &mut got);
                    ensure(got == want, "payload corrupted at drain".to_string())?;
                }
                ensure(
                    p.free_blocks() == p.total_blocks(),
                    format!("leaked blocks: {} of {} free", p.free_blocks(), p.total_blocks()),
                )
            },
        );
    }

    #[test]
    fn handles_are_bound_to_their_arena() {
        // two same-shaped pagers: a handle from one must not be honored
        // by the other (silent garbage reads + free-list corruption)
        let mut a = tiny(1);
        let mut b = tiny(1);
        let data = vec![1.0; 2 * 4 * 2];
        let pr = a.store_rows(&data, 4).unwrap();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut out = Vec::new();
            b.fetch_rows(pr, &mut out);
        }));
        assert!(res.is_err(), "cross-pager fetch must panic");
    }

    /// Build a checkpoint with every optional section populated, payload
    /// values derived from `seed` (deterministic, no Prng needed).
    fn full_ckpt(p: &mut Pager, ns: usize, np: usize, seed: u64) -> LaneCheckpoint {
        let fill = |n: usize, off: u64| -> Vec<f32> {
            (0..n).map(|i| (seed.wrapping_add(off + i as u64) % 997) as f32 - 498.5).collect()
        };
        LaneCheckpoint {
            row0: 1,
            streams: p.store_rows(&fill(2 * ns * 2, 0), ns).unwrap(),
            pending: p.store_rows(&fill(2 * np * 2, 7), np).unwrap(),
            a0: fill(2, 13),
            scstate: Some(fill(6, 17)),
            sampler: SamplerSnapshot {
                cfg: SamplerCfg::Lm { temperature: 0.75, top_k: 40 },
                prng_state: [seed | 1, seed ^ 0xdecafbad, 3, 4],
            },
            tokens: Some(vec![7, 9, 11]),
            pos: 6,
            lane_start: 2,
            lane_limit: 9,
            rows: 8,
            half: false,
            folded: false,
        }
    }

    /// Property: serialize -> deserialize into a second pager ->
    /// re-serialize is byte-identical across random payloads and every
    /// combination of optional sections, and a rejected or consumed blob
    /// never leaks slab blocks.
    #[test]
    fn prop_serde_roundtrip_byte_exact() {
        propcheck::check(
            "ckpt_serde_roundtrip",
            48,
            |rng: &mut Prng| {
                let ns = rng.range(0, 7);
                let np = rng.range(1, 7);
                let opts = rng.range(0, 32); // bit per optional/flavor toggle
                let seed = rng.range(1, 1_000_000) as u64;
                (ns, np, opts, seed)
            },
            |&(ns, np, opts, seed)| {
                let mut a = tiny(1);
                let mut ckpt = full_ckpt(&mut a, ns, np, seed);
                ckpt.folded = opts & 1 != 0;
                ckpt.half = opts & 2 != 0;
                if opts & 4 == 0 {
                    ckpt.scstate = None;
                }
                if opts & 8 == 0 {
                    ckpt.tokens = None;
                    ckpt.sampler.cfg = SamplerCfg::Synthetic { sigma: 0.25 };
                }
                if ckpt.folded {
                    ckpt.row0 = 0;
                }
                let meta = (opts & 16 != 0).then_some(ServingMeta {
                    checksum_total: seed as f64 * 0.5,
                    queue_ms: 2.25,
                    evictions: 3,
                    batch_size: 4,
                });
                let blob = a.serialize(&ckpt, meta.as_ref());
                let mut b = tiny(1);
                let (ckpt2, meta2) = b.deserialize(&blob).map_err(|e| e.to_string())?;
                ensure(meta2 == meta, format!("meta mismatch: {meta2:?} != {meta:?}"))?;
                let blob2 = b.serialize(&ckpt2, meta2.as_ref());
                ensure(blob2 == blob, "re-serialized blob differs".to_string())?;
                a.discard(ckpt);
                b.discard(ckpt2);
                ensure(
                    b.free_blocks() == b.total_blocks(),
                    "deserialize leaked slab blocks".to_string(),
                )
            },
        );
    }

    #[test]
    fn serde_rejects_corrupt_and_truncated_blobs() {
        let mut a = tiny(1);
        let ckpt = full_ckpt(&mut a, 3, 5, 42);
        let blob = a.serialize(
            &ckpt,
            Some(&ServingMeta {
                checksum_total: 1.5,
                queue_ms: 0.5,
                evictions: 1,
                batch_size: 2,
            }),
        );
        let mut b = tiny(1);
        // every strict prefix must fail (length-checked cursor + payload
        // sizes implied by the geometry header)
        for cut in 0..blob.len() {
            assert!(b.deserialize(&blob[..cut]).is_err(), "truncated at {cut} must parse as error");
        }
        // trailing garbage
        let mut long = blob.clone();
        long.push(0);
        assert!(b.deserialize(&long).is_err(), "trailing bytes must be rejected");
        // bad magic / unsupported version / unknown flag bits
        let mut bad = blob.clone();
        bad[0] ^= 0xff;
        assert!(b.deserialize(&bad).is_err(), "bad magic");
        let mut bad = blob.clone();
        bad[4] = 99;
        assert!(b.deserialize(&bad).is_err(), "future version");
        let mut bad = blob.clone();
        bad[8] |= 0x80;
        assert!(b.deserialize(&bad).is_err(), "unknown flags");
        // geometry guard: same blob, wrong-shaped pager
        let mut c = Pager::new(3, 2, 4, 1);
        assert!(c.deserialize(&blob).is_err(), "M mismatch must be rejected");
        // none of the rejects may leak slab blocks
        assert_eq!(b.free_blocks(), b.total_blocks());
        assert_eq!(c.free_blocks(), c.total_blocks());
        a.discard(ckpt);
    }

    #[test]
    fn spill_roundtrip_and_boot_scan() {
        let dir = std::env::temp_dir()
            .join(format!("fi_pager_spill_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut a = tiny(1);
        assert!(a.spill_blob("k", b"x").is_err(), "spill before set_spill_dir must fail");
        assert_eq!(a.set_spill_dir(&dir).unwrap(), 0);
        let ckpt = full_ckpt(&mut a, 2, 4, 7);
        let blob = a.serialize(&ckpt, None);
        a.discard(ckpt);
        a.spill_blob("sess-1", &blob).unwrap();
        assert!(a.has_spilled("sess-1"));
        assert!(!a.has_spilled("sess-2"));
        // shipping path: raw blob comes back byte-exact and leaves disk
        let shipped = a.take_spilled_blob("sess-1").unwrap();
        assert_eq!(shipped, blob, "spill -> reload must be byte-exact");
        assert!(!a.has_spilled("sess-1"));
        // durable-handle path: a fresh pager boot-scans the dir
        a.spill_blob("sess-1", &blob).unwrap();
        drop(a);
        let mut b = tiny(1);
        assert_eq!(b.set_spill_dir(&dir).unwrap(), 1, "boot scan must find the spill");
        assert_eq!(b.spilled_keys(), vec!["sess-1".to_string()]);
        let (ckpt2, meta2) = b.fetch(CkptRef::Spilled("sess-1".into())).unwrap();
        assert!(meta2.is_none());
        let blob2 = b.serialize(&ckpt2, None);
        assert_eq!(blob2, blob, "boot-scanned checkpoint must reload byte-exactly");
        assert!(!b.has_spilled("sess-1"), "load consumes the spill file");
        b.discard(ckpt2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn discard_frees_both_tensors() {
        let mut p = tiny(1);
        let data = vec![0.5; 2 * 4 * 2];
        let ckpt = LaneCheckpoint {
            row0: 0,
            streams: p.store_rows(&data, 4).unwrap(),
            pending: p.store_rows(&data, 4).unwrap(),
            a0: vec![0.0; 2],
            scstate: None,
            sampler: SamplerSnapshot {
                cfg: SamplerCfg::Synthetic { sigma: 0.0 },
                prng_state: [0; 4],
            },
            tokens: None,
            pos: 4,
            lane_start: 0,
            lane_limit: 8,
            rows: 8,
            half: false,
            folded: false,
        };
        assert_eq!(p.free_blocks(), p.total_blocks() - 2);
        p.discard(ckpt);
        assert_eq!(p.free_blocks(), p.total_blocks());
    }
}
