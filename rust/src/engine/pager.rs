//! Session pager — KV-cache-style paging for LCSM lanes (ROADMAP
//! "multi-session store sharing").
//!
//! Continuous admission recycles lanes *within* one live [`super::Store`],
//! so an engine can hold exactly `B` resumable requests: a suspended
//! request's activation rows have nowhere to live. The pager fixes that
//! with a **slab allocator** over fixed `[groups, rows_chunk, D]` blocks
//! (`groups = M`, one lane's share of the `G = M·B` group axis): a
//! suspended lane's entire state — its non-zero `streams`/`pending` store
//! rows, `a0`/short-conv slices, sampler PRNG snapshot, token buffer and
//! start/limit clocks — is copied out into a [`LaneCheckpoint`], the lane
//! is reset (freeing it for another request), and the checkpoint is
//! restored later by the exact inverse copy. Checkpoints are small: only
//! rows from the lane's admission row up to `pos` (streams) / `2·pos`
//! (pending — a gray tile at iteration `i` deposits sums up to row
//! `2i-1`) can be non-zero, so a lane pages out its own progress, not
//! the whole store.
//!
//! Slab blocks are fixed-size so free/alloc cannot fragment: a checkpoint
//! of `n` rows takes `ceil(n / rows_chunk)` blocks per tensor, handed back
//! verbatim on restore (or [`Pager::discard`]). Capacity is bounded
//! (`pager_capacity_mb`); a suspend that does not fit fails *before* any
//! lane state is touched, so the scheduler simply skips that eviction.
//!
//! The bit-identity contract (why restore is exact) lives with
//! [`super::Session::suspend`]/[`super::Session::restore`]; this module is
//! only the storage substrate. See `rust/DESIGN.md` §6.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::engine::SamplerCfg;

/// Monotonic arena ids: every [`Pager`] gets one, and every
/// [`PagedRows`] remembers which arena minted it, so handing a
/// checkpoint to the wrong (same-shaped) pager is a deterministic panic
/// instead of silent garbage reads + free-list corruption.
static PAGER_IDS: AtomicU64 = AtomicU64::new(1);

/// Default rows per slab block. Small enough that an early eviction
/// (few non-zero rows) wastes little tail space, large enough that a
/// full-store checkpoint stays a handful of allocations.
pub const DEFAULT_ROWS_CHUNK: usize = 16;

/// One lane's sampler state inside a checkpoint: the active config plus
/// the raw xoshiro256** state, so a resumed lane continues its private
/// random stream mid-sequence (bit-identical draws).
#[derive(Debug, Clone)]
pub struct SamplerSnapshot {
    pub cfg: SamplerCfg,
    pub prng_state: [u64; 4],
}

/// Handle to a row range stored in the slab: block ids plus the logical
/// row count (the last block may be partially filled) and the id of the
/// arena that owns the blocks.
#[derive(Debug)]
pub struct PagedRows {
    pager: u64,
    blocks: Vec<usize>,
    rows: usize,
}

impl PagedRows {
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Slab f32 values this range actually occupies (whole blocks).
    pub fn slab_values(&self, block_values: usize) -> usize {
        self.blocks.len() * block_values
    }
}

/// A suspended lane, ready to be re-injected by
/// [`super::Session::restore`]. Holds slab handles (the bulky store rows)
/// plus the small host-side lane state inline.
#[derive(Debug)]
pub struct LaneCheckpoint {
    /// First checkpointed store row for both tensors. Rows below it are
    /// zero by construction in the unwrapped store (the lane's admission
    /// reset them and every later write lands at or above the admission
    /// point), so a late-admitted lane's checkpoint pays for *its own*
    /// rows, not the batch's global clock. 0 in the wrapped half store,
    /// where recycled rows can sit anywhere.
    pub(crate) row0: usize,
    /// `streams` rows `row0 .. row0 + streams.rows` of each lane group.
    pub(crate) streams: PagedRows,
    /// `pending` rows `row0 .. row0 + pending.rows` (partial tile sums
    /// with deadlines past the suspension point — they complement the
    /// exact set of tiles that still run after restore, which is why
    /// restore must happen at the same global schedule position).
    pub(crate) pending: PagedRows,
    /// The lane's next-step input slice (`[D]`).
    pub(crate) a0: Vec<f32>,
    /// The lane's short-conv state slices (Hyena variant).
    pub(crate) scstate: Option<Vec<f32>>,
    pub(crate) sampler: SamplerSnapshot,
    /// Token buffer accumulated so far (LM variant).
    pub(crate) tokens: Option<Vec<u32>>,
    /// Global session position at suspension — the only position a
    /// restore is legal at (same fractal-schedule alignment).
    pub(crate) pos: usize,
    /// The lane's admission clock and padded schedule length.
    pub(crate) lane_start: usize,
    pub(crate) lane_limit: usize,
    /// Store geometry guards: a checkpoint only restores into a session
    /// with the identical row layout.
    pub(crate) rows: usize,
    pub(crate) half: bool,
}

impl LaneCheckpoint {
    /// Global position this checkpoint must be restored at.
    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn lane_start(&self) -> usize {
        self.lane_start
    }

    pub fn lane_limit(&self) -> usize {
        self.lane_limit
    }

    /// Positions the lane had already generated when it was suspended.
    pub fn lane_pos(&self) -> usize {
        self.pos - self.lane_start
    }
}

/// Slab allocator over `[groups, rows_chunk, D]` f32 blocks.
///
/// All blocks live in one arena allocation; a free list recycles them
/// exactly (no fragmentation, no growth). `groups` is the per-lane group
/// count `M = G / B` — every block holds `rows_chunk` rows of *all* of
/// one lane's groups, so one checkpoint's rows stay contiguous per block
/// and copy in/out as straight `memcpy`s.
pub struct Pager {
    id: u64,
    groups: usize,
    d: usize,
    rows_chunk: usize,
    data: Vec<f32>,
    free: Vec<usize>,
    total_blocks: usize,
}

impl Pager {
    /// Build a pager with `capacity_mb` megabytes of slab storage
    /// (rounded down to whole blocks; at least one block).
    pub fn new(groups: usize, d: usize, rows_chunk: usize, capacity_mb: usize) -> Pager {
        assert!(groups > 0 && d > 0 && rows_chunk > 0, "degenerate pager shape");
        let block_values = groups * rows_chunk * d;
        let capacity_values = capacity_mb * (1 << 20) / std::mem::size_of::<f32>();
        let total_blocks = (capacity_values / block_values).max(1);
        Pager {
            id: PAGER_IDS.fetch_add(1, Ordering::Relaxed),
            groups,
            d,
            rows_chunk,
            data: vec![0.0; total_blocks * block_values],
            free: (0..total_blocks).rev().collect(),
            total_blocks,
        }
    }

    pub fn rows_chunk(&self) -> usize {
        self.rows_chunk
    }

    /// f32 values per slab block.
    pub fn block_values(&self) -> usize {
        self.groups * self.rows_chunk * self.d
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// f32 values currently held by live checkpoints (the
    /// `fi_pager_resident_values` gauge).
    pub fn resident_values(&self) -> usize {
        (self.total_blocks - self.free.len()) * self.block_values()
    }

    /// Blocks a range of `rows` rows needs (per tensor).
    pub fn blocks_for(&self, rows: usize) -> usize {
        rows.div_ceil(self.rows_chunk)
    }

    /// Whether a checkpoint needing `blocks` more blocks fits right now.
    pub fn fits(&self, blocks: usize) -> bool {
        blocks <= self.free.len()
    }

    fn alloc(&mut self, n: usize) -> Result<Vec<usize>> {
        // Chaos handle: `pager_alloc:fail@k` makes one suspend/store fail
        // as if the slab were full — the scheduler must skip that
        // eviction and keep serving (checkpoint-store errors are soft).
        crate::util::faultpoint::check("pager_alloc")?;
        if n > self.free.len() {
            bail!(
                "pager full: need {n} blocks, {} of {} free",
                self.free.len(),
                self.total_blocks
            );
        }
        Ok((0..n).map(|_| self.free.pop().unwrap()).collect())
    }

    pub fn groups(&self) -> usize {
        self.groups
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub(crate) fn release(&mut self, pr: PagedRows) {
        assert_eq!(pr.pager, self.id, "slab handle belongs to a different pager");
        for b in pr.blocks {
            debug_assert!(!self.free.contains(&b), "double free of slab block {b}");
            self.free.push(b);
        }
    }

    /// Page `rows` rows of lane data into freshly allocated blocks.
    /// `data` is `[groups, rows, D]` (group-major, the layout
    /// `Store::copy_lane_rows_out` produces); block `k` receives rows
    /// `k·rows_chunk ..` of **every** group.
    pub fn store_rows(&mut self, data: &[f32], rows: usize) -> Result<PagedRows> {
        debug_assert_eq!(data.len(), self.groups * rows * self.d);
        let blocks = self.alloc(self.blocks_for(rows))?;
        let (rc, d, bv) = (self.rows_chunk, self.d, self.block_values());
        for (k, &blk) in blocks.iter().enumerate() {
            let take = rc.min(rows - k * rc);
            for g in 0..self.groups {
                let src = (g * rows + k * rc) * d..(g * rows + k * rc + take) * d;
                let dst = blk * bv + g * rc * d;
                self.data[dst..dst + take * d].copy_from_slice(&data[src]);
            }
        }
        Ok(PagedRows { pager: self.id, blocks, rows })
    }

    /// Copy a paged range back out into `[groups, rows, D]` layout and
    /// return its blocks to the free list.
    pub fn fetch_rows(&mut self, pr: PagedRows, out: &mut Vec<f32>) {
        assert_eq!(pr.pager, self.id, "slab handle belongs to a different pager");
        let rows = pr.rows;
        out.resize(self.groups * rows * self.d, 0.0);
        let (rc, d, bv) = (self.rows_chunk, self.d, self.block_values());
        for (k, &blk) in pr.blocks.iter().enumerate() {
            let take = rc.min(rows - k * rc);
            for g in 0..self.groups {
                let src = blk * bv + g * rc * d;
                let dst = (g * rows + k * rc) * d..(g * rows + k * rc + take) * d;
                out[dst].copy_from_slice(&self.data[src..src + take * d]);
            }
        }
        self.release(pr);
    }

    /// Drop a checkpoint without restoring it (failed/abandoned request),
    /// returning its blocks to the free list.
    pub fn discard(&mut self, ckpt: LaneCheckpoint) {
        self.release(ckpt.streams);
        self.release(ckpt.pending);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{self, ensure};
    use crate::util::prng::Prng;

    fn tiny(total_blocks_hint_mb: usize) -> Pager {
        // groups=2, d=2, rows_chunk=4 -> 16 values (64 bytes) per block
        Pager::new(2, 2, 4, total_blocks_hint_mb)
    }

    #[test]
    fn capacity_rounds_down_to_whole_blocks() {
        let p = tiny(1); // 1 MiB / 64 B = 16384 blocks
        assert_eq!(p.total_blocks(), 16384);
        assert_eq!(p.free_blocks(), 16384);
        assert_eq!(p.block_values(), 16);
        assert_eq!(p.resident_values(), 0);
        // a capacity below one block still yields one block
        let q = Pager::new(64, 64, 64, 0);
        assert_eq!(q.total_blocks(), 1);
    }

    #[test]
    fn store_fetch_roundtrip_partial_tail_block() {
        let mut p = tiny(1);
        // 6 rows over rows_chunk=4 -> 2 blocks, second half-filled
        let rows = 6;
        let data: Vec<f32> = (0..2 * rows * 2).map(|i| i as f32).collect();
        let pr = p.store_rows(&data, rows).unwrap();
        assert_eq!(pr.rows(), 6);
        assert_eq!(p.free_blocks(), p.total_blocks() - 2);
        assert_eq!(p.resident_values(), 2 * 16);
        let mut out = Vec::new();
        p.fetch_rows(pr, &mut out);
        assert_eq!(out, data, "paged rows must round-trip bit-exactly");
        assert_eq!(p.free_blocks(), p.total_blocks(), "fetch frees the blocks");
    }

    #[test]
    fn alloc_fails_cleanly_when_full() {
        let mut p = Pager::new(2, 2, 4, 0); // exactly 1 block
        let data = vec![1.0; 2 * 4 * 2];
        let pr = p.store_rows(&data, 4).unwrap();
        assert!(p.store_rows(&data, 4).is_err(), "second alloc must fail");
        // capacity check matches
        assert!(!p.fits(1));
        let mut out = Vec::new();
        p.fetch_rows(pr, &mut out);
        assert!(p.fits(1));
        p.store_rows(&data, 4).unwrap();
    }

    /// Property: interleaved store/fetch of random-sized checkpoints
    /// never hands two live ranges the same block (payload integrity
    /// proves no overlap), and freeing everything restores full capacity.
    #[test]
    fn prop_slab_no_overlap_full_reuse() {
        propcheck::check(
            "slab_no_overlap_full_reuse",
            64,
            |rng: &mut Prng| {
                // (groups, d, rows_chunk, ops) — ops: row counts, with 0
                // meaning "free the oldest live range"
                let groups = rng.range(1, 3);
                let d = rng.range(1, 3);
                let rc = rng.range(1, 5);
                let ops: Vec<usize> = (0..rng.range(4, 24)).map(|_| rng.range(0, 9)).collect();
                (groups, d, rc, ops)
            },
            |(groups, d, rc, ops)| {
                // tiny fixed arena (8 blocks) so the ops churn through
                // full-capacity alloc/free cycles
                let mut p = Pager {
                    id: PAGER_IDS.fetch_add(1, Ordering::Relaxed),
                    groups: *groups,
                    d: *d,
                    rows_chunk: *rc,
                    data: vec![0.0; 8 * groups * rc * d],
                    free: (0..8).rev().collect(),
                    total_blocks: 8,
                };
                let mut live: Vec<(PagedRows, Vec<f32>)> = Vec::new();
                let mut stamp = 1.0f32;
                for &op in ops {
                    if op == 0 || !p.fits(p.blocks_for(op)) {
                        if !live.is_empty() {
                            let (pr, want) = live.remove(0);
                            let mut got = Vec::new();
                            p.fetch_rows(pr, &mut got);
                            ensure(
                                got == want,
                                format!("payload corrupted: {got:?} != {want:?}"),
                            )?;
                        }
                        continue;
                    }
                    let n = groups * op * d;
                    let data: Vec<f32> = (0..n).map(|i| stamp + i as f32).collect();
                    stamp += 1000.0;
                    let pr = p.store_rows(&data, op).map_err(|e| e.to_string())?;
                    live.push((pr, data));
                }
                // drain: every payload intact, every block reusable
                for (pr, want) in live.drain(..) {
                    let mut got = Vec::new();
                    p.fetch_rows(pr, &mut got);
                    ensure(got == want, "payload corrupted at drain".to_string())?;
                }
                ensure(
                    p.free_blocks() == p.total_blocks(),
                    format!("leaked blocks: {} of {} free", p.free_blocks(), p.total_blocks()),
                )
            },
        );
    }

    #[test]
    fn handles_are_bound_to_their_arena() {
        // two same-shaped pagers: a handle from one must not be honored
        // by the other (silent garbage reads + free-list corruption)
        let mut a = tiny(1);
        let mut b = tiny(1);
        let data = vec![1.0; 2 * 4 * 2];
        let pr = a.store_rows(&data, 4).unwrap();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut out = Vec::new();
            b.fetch_rows(pr, &mut out);
        }));
        assert!(res.is_err(), "cross-pager fetch must panic");
    }

    #[test]
    fn discard_frees_both_tensors() {
        let mut p = tiny(1);
        let data = vec![0.5; 2 * 4 * 2];
        let ckpt = LaneCheckpoint {
            row0: 0,
            streams: p.store_rows(&data, 4).unwrap(),
            pending: p.store_rows(&data, 4).unwrap(),
            a0: vec![0.0; 2],
            scstate: None,
            sampler: SamplerSnapshot {
                cfg: SamplerCfg::Synthetic { sigma: 0.0 },
                prng_state: [0; 4],
            },
            tokens: None,
            pos: 4,
            lane_start: 0,
            lane_limit: 8,
            rows: 8,
            half: false,
        };
        assert_eq!(p.free_blocks(), p.total_blocks() - 2);
        p.discard(ckpt);
        assert_eq!(p.free_blocks(), p.total_blocks());
    }
}
