//! The resumable session state machine — the engine's serving substrate.
//!
//! Everything `run_session` used to keep in loop locals (activation store,
//! sampler, short-conv state, τ implementation, metrics, FLOP counter,
//! token buffers, pending-column scratch) lives in a first-class
//! [`Session`] that advances exactly one position per [`Session::step`]
//! call:
//!
//! 1. pending-column gather (lazy recomputes it, Appendix D wraps it),
//! 2. the PJRT `step` artifact (red cells + blocks + head),
//! 3. sampling / teacher forcing into the next `a0`,
//! 4. the gray tile `Tile::at(i)` (or the eager push).
//!
//! `Engine::generate*` are thin drivers (`while !done { step() }` then
//! [`Session::finish`]), so the flash/lazy/eager methods, `half_store`,
//! and prompt prefill all flow through the same machine and stay
//! checksum-identical to the one-shot path. Callers that need tokens *as
//! they are produced* — streaming HTTP lanes, the `--stream` CLI,
//! first-token-latency probes — drive `step()` themselves: the paper's
//! amortized O(log² L) per-token cost only pays off for serving if tokens
//! can leave the engine per position instead of per rollout.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::metrics::{Breakdown, SessionMetrics};
use crate::model::Variant;
use crate::runtime::Runtime;
use crate::tau::{make_impl, TauImpl};
use crate::tiling::{FlopCounter, Tile};

use super::{eager, lazy, Engine, GenOutput, Method, Sampler, Store};

/// Session initialization (prompt seeding, forcing, overrides).
#[derive(Default)]
pub struct SessionInit {
    /// Input at position 1 (`[B, D]`).
    pub a0: Vec<f32>,
    /// Teacher-forced inputs `[T0, B, D]` (row 0 duplicates `a0`).
    pub forced: Option<Vec<f32>>,
    /// Short-conv state carried over from a prefill.
    pub scstate_override: Option<Vec<f32>>,
    /// `(fut, span)` — prompt contributions to the next `span` positions.
    pub pending_seed: Option<(Vec<f32>, usize)>,
    /// Tokens sampled from the prefill's last logits.
    pub first_tokens: Option<Vec<u32>>,
}

/// What one [`Session::step`] call produced.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// 1-indexed position just computed.
    pub pos: usize,
    /// Token ids appended at this position (one per lane, LM variant).
    pub tokens: Option<Vec<u32>>,
    /// Checksum (sum) of this position's `out` — the cheap per-position
    /// observable the synthetic variant streams in place of tokens.
    pub checksum: f32,
    /// True once the session has computed all requested positions.
    pub done: bool,
}

/// One in-flight generation session over a borrowed [`Engine`].
pub struct Session<'e, 'rt> {
    engine: &'e Engine<'rt>,
    len: usize,
    /// Positions completed so far (`step` computes position `pos + 1`).
    pos: usize,
    /// Appendix D wrapped-store mode (rows = len/2).
    half: bool,
    rows: usize,
    store: Store,
    sampler: Sampler,
    a0: Vec<f32>,
    scstate: Option<Vec<f32>>,
    sc_dims: [usize; 4],
    forced: Option<Vec<f32>>,
    forced_steps: usize,
    tau: Option<Box<dyn TauImpl + 'e>>,
    metrics: SessionMetrics,
    flops: FlopCounter,
    tokens: Option<Vec<Vec<u32>>>,
    pend_col: Vec<f32>,
    last_out: Vec<f32>,
    outs_checksum: Vec<f32>,
    wall0: Instant,
}

impl<'e, 'rt> Session<'e, 'rt> {
    /// Set up a `len`-position session (power of two, ≤ L).
    pub fn new(engine: &'e Engine<'rt>, len: usize, init: SessionInit) -> Result<Session<'e, 'rt>> {
        let wall0 = Instant::now();
        let rt = engine.runtime();
        let dims = rt.dims;
        let opts = engine.opts();
        if !len.is_power_of_two() || len > dims.l {
            bail!("generation length {len} must be a power of two <= L={}", dims.l);
        }
        let (g, d, b) = (dims.g, dims.d, dims.b);
        if init.a0.len() != b * d {
            bail!("a0 must be a [B, D] tensor ({} values, got {})", b * d, init.a0.len());
        }

        // Appendix D: with the tiled method, after iteration len/2 nothing
        // before position len/2 is ever read again, so the second half can
        // reuse the first half's rows — the store holds M x (L/2) x D.
        let half = opts.half_store && opts.method == Method::Flash && len >= 4;
        if opts.half_store && opts.method != Method::Flash {
            bail!("half_store (Appendix D) applies to the tiled method only");
        }
        let rows = if half { len / 2 } else { len };

        let mut store = Store::new(g, rows, d);
        if let Some((fut, fut_span)) = &init.pending_seed {
            // seed pending with the prompt's future contributions
            let span = (*fut_span).min(rows);
            for gi in 0..g {
                for t in 0..span {
                    store
                        .pending
                        .at2_mut(gi, t)
                        .copy_from_slice(&fut[(gi * fut_span + t) * d..(gi * fut_span + t) * d + d]);
                }
            }
        }
        let sampler = engine.make_sampler()?;
        let scstate: Option<Vec<f32>> = match (&init.scstate_override, dims.variant) {
            (Some(sc), _) => Some(sc.clone()),
            (None, Variant::Hyena) => Some(vec![0.0; dims.ops() * 2 * b * 3 * d]),
            (None, Variant::Synthetic) => None,
        };
        let forced_steps = init.forced.as_ref().map(|f| f.len() / (b * d)).unwrap_or(0);

        let tau = if opts.method == Method::Flash {
            Some(make_impl(opts.tau, &engine.cache, opts.threads)?)
        } else {
            None
        };

        let mut tokens: Option<Vec<Vec<u32>>> = match dims.variant {
            Variant::Hyena => Some(vec![Vec::with_capacity(len); b]),
            Variant::Synthetic => None,
        };
        if let (Some(first), Some(all)) = (&init.first_tokens, tokens.as_mut()) {
            for (bi, t) in first.iter().enumerate() {
                all[bi].push(*t);
            }
        }

        Ok(Session {
            engine,
            len,
            pos: 0,
            half,
            rows,
            store,
            sampler,
            a0: init.a0,
            scstate,
            sc_dims: [dims.ops(), 2, b, 3 * d],
            forced: init.forced,
            forced_steps,
            tau,
            metrics: SessionMetrics::with_capacity(len),
            flops: FlopCounter::new(),
            tokens,
            pend_col: Vec::with_capacity(g * d),
            last_out: Vec::new(),
            outs_checksum: Vec::with_capacity(len),
            wall0,
        })
    }

    /// Positions completed so far.
    pub fn steps_done(&self) -> usize {
        self.pos
    }

    /// Positions this session will generate in total.
    pub fn steps_total(&self) -> usize {
        self.len
    }

    pub fn is_done(&self) -> bool {
        self.pos >= self.len
    }

    /// The step artifact's `out` at the most recent position (`[B, W]`).
    pub fn last_out(&self) -> &[f32] {
        &self.last_out
    }

    /// Advance one position: pending-column gather → `step` artifact →
    /// sample → gray tile. Errors once the session is complete.
    pub fn step(&mut self) -> Result<StepOutput> {
        if self.pos >= self.len {
            bail!("session complete: all {} positions generated", self.len);
        }
        let engine = self.engine;
        let rt = engine.runtime();
        let dims = rt.dims;
        let opts = engine.opts();
        let (g, d, b) = (dims.g, dims.d, dims.b);
        let i = self.pos + 1;
        let rows = self.rows;
        let row_of = |pos1: usize| (pos1 - 1) % rows; // 1-indexed -> store row
        let mut bd = Breakdown::default();

        // ---- pending column (lazy recomputes; others read the store)
        let t0 = Instant::now();
        match opts.method {
            Method::Lazy => {
                lazy::lazy_pending_col(
                    &self.store.streams,
                    &engine.cache.rho,
                    b,
                    i,
                    &mut self.pend_col,
                    &mut self.flops,
                );
            }
            _ => self.store.gather_pending_col(row_of(i), &mut self.pend_col),
        }
        if self.half {
            // the consumed column's row will be reused by a future tile
            for gi in 0..g {
                self.store.pending.at2_mut(gi, row_of(i)).fill(0.0);
            }
        }
        if opts.method == Method::Lazy {
            bd.mixer_ns += t0.elapsed().as_nanos() as f64;
        }

        // ---- step: red cells + blocks + head (PJRT)
        let t0 = Instant::now();
        let pb = rt.upload(&self.pend_col, &[dims.m, b, d])?;
        let ab = rt.upload(&self.a0, &[b, d])?;
        let outs = match &self.scstate {
            None => engine.step_artifact().call(&[&pb, &ab])?,
            Some(sc) => {
                let scb = rt.upload(sc, &self.sc_dims)?;
                engine.step_artifact().call(&[&pb, &ab, &scb])?
            }
        };
        let streams_col = Runtime::literal_to_vec(&outs[0], g * d)?;
        self.store.set_streams_col(row_of(i), &streams_col);
        self.last_out = Runtime::literal_to_vec(&outs[1], b * dims.out_width())?;
        let checksum: f32 = self.last_out.iter().sum();
        self.outs_checksum.push(checksum);
        if let Some(sc) = self.scstate.as_mut() {
            *sc = Runtime::literal_to_vec(&outs[2], sc.len())?;
        }
        self.flops.record_red(2 * g as u64 * d as u64); // red cells proper
        bd.step_ns = t0.elapsed().as_nanos() as f64;

        // ---- next input: teacher-forced or sampled
        let t0 = Instant::now();
        let mut step_tokens: Option<Vec<u32>> = None;
        if i < self.forced_steps {
            let stride = b * d;
            self.a0
                .copy_from_slice(&self.forced.as_ref().unwrap()[i * stride..(i + 1) * stride]);
        } else if let Some(toks) = self.sampler.next_a0(&self.last_out, b, &mut self.a0)? {
            if let Some(all) = self.tokens.as_mut() {
                for (bi, t) in toks.iter().enumerate() {
                    all[bi].push(*t);
                }
            }
            step_tokens = Some(toks);
        }
        bd.sample_ns = t0.elapsed().as_nanos() as f64;

        // ---- gray work
        if i < self.len {
            let t0 = Instant::now();
            match opts.method {
                Method::Flash => {
                    let tile = Tile::at(i);
                    // Appendix D: translate tile ranges into the wrapped
                    // store (ranges never straddle the halfway boundary —
                    // each lies in a U-aligned block, and rows | U).
                    let tile = if self.half {
                        let rs = row_of(tile.src_l);
                        let rd = row_of(tile.dst_l);
                        Tile {
                            i: tile.i,
                            u: tile.u,
                            src_l: rs + 1,
                            src_r: rs + tile.u,
                            dst_l: rd + 1,
                            dst_r: rd + tile.u,
                        }
                    } else {
                        tile
                    };
                    let imp = self.tau.as_mut().unwrap();
                    imp.apply(&self.store.streams, &mut self.store.pending, tile)?;
                    self.flops.record_tau(
                        tile.u,
                        imp.tile_flops(tile.u, g, d),
                        (2 * tile.u * g * d) as u64,
                    );
                    bd.mixer_ns += t0.elapsed().as_nanos() as f64;
                }
                Method::Eager => {
                    eager::eager_push(
                        &self.store.streams,
                        &mut self.store.pending,
                        &engine.cache.rho,
                        b,
                        i,
                        self.len,
                        &mut self.flops,
                    );
                    bd.mixer_ns += t0.elapsed().as_nanos() as f64;
                }
                Method::Lazy => {}
            }
        }

        self.metrics.push(bd);
        self.pos = i;
        Ok(StepOutput { pos: i, tokens: step_tokens, checksum, done: self.pos == self.len })
    }

    /// Consume the session into its [`GenOutput`]. Finishing early (before
    /// `is_done`) is allowed — `steps` reports the positions actually
    /// generated — so serving lanes can abandon a session cleanly.
    pub fn finish(mut self) -> GenOutput {
        self.metrics.wall = self.wall0.elapsed();
        GenOutput {
            steps: self.pos,
            tokens: self.tokens,
            last_out: self.last_out,
            outs_checksum: self.outs_checksum,
            resident_values: self.store.resident_values(),
            metrics: self.metrics,
            flops: self.flops,
            streams: if self.engine.opts().record_streams {
                Some(self.store.streams)
            } else {
                None
            },
        }
    }
}
