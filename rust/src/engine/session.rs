//! The resumable session state machine — the engine's serving substrate.
//!
//! Everything `run_session` used to keep in loop locals (activation store,
//! sampler, short-conv state, τ implementation, metrics, FLOP counter,
//! token buffers, pending-column scratch) lives in a first-class
//! [`Session`] that advances exactly one position per [`Session::step`]
//! call:
//!
//! 1. host→device upload of the *fence-independent* inputs (`a0`, the
//!    short-conv state) — async τ tiles keep running underneath;
//! 2. fence: wait for any in-flight gray tile writing pending column `i`
//!    (no-op for synchronous τ), then gather the column (lazy recomputes
//!    it, Appendix D wraps it);
//! 3. the PJRT `step` artifact (red cells + blocks + head);
//! 4. *submit* the gray tile `Tile::at(i)` the moment the streams column
//!    is stored (or run the eager push) — under the async executor the
//!    tile overlaps everything below plus the next call's phase 1;
//! 5. sampling / teacher forcing into the next `a0`, token bookkeeping,
//!    metrics.
//!
//! The fence sits immediately before `gather_pending_col(i+1)` — the
//! first point where `z[i+1]` is truly needed — so the τ deadline is as
//! late as the availability invariant allows (DESIGN.md §Pipelining).
//!
//! `Engine::generate*` are thin drivers (`while !done { step() }` then
//! [`Session::finish`]), so the flash/lazy/eager methods, `half_store`,
//! and prompt prefill all flow through the same machine and stay
//! checksum-identical to the one-shot path. Callers that need tokens *as
//! they are produced* — streaming HTTP lanes, the `--stream` CLI,
//! first-token-latency probes — drive `step()` themselves: the paper's
//! amortized O(log² L) per-token cost only pays off for serving if tokens
//! can leave the engine per position instead of per rollout.
//!
//! **Continuous admission** ([`Session::admit`]): a serving scheduler can
//! seed a *new request* into one lane of a running batch at any step
//! boundary — fence in-flight τ tiles, clear the lane's activation rows,
//! rebase its sampler/length bookkeeping — instead of waiting for the
//! batch to drain. The lockstep tile schedule is untouched (all lanes
//! still share every tile); only the recycled lane's *content* restarts,
//! and because a lane's entire state is its store rows + `a0` + sampler
//! stream, the admitted rollout is bit-identical to a fresh run of the
//! same request (DESIGN.md §4, `tests/integration_admission.rs`).

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::fft;
use crate::metrics::{Breakdown, SessionMetrics};
use crate::model::Variant;
use crate::runtime::Runtime;
use crate::tau::{make_session_impl, TauExecCfg, TauImpl, TauKind};
use crate::tiling::{FlopCounter, Tile};

use super::pager::{LaneCheckpoint, Pager};
use super::{eager, lazy, Engine, GenOutput, Method, Sampler, SamplerCfg, Store};

/// Session initialization (prompt seeding, forcing, overrides).
#[derive(Default)]
pub struct SessionInit {
    /// Input at position 1 (`[B, D]`).
    pub a0: Vec<f32>,
    /// Teacher-forced inputs `[T0, B, D]` (row 0 duplicates `a0`).
    pub forced: Option<Vec<f32>>,
    /// Short-conv state carried over from a prefill.
    pub scstate_override: Option<Vec<f32>>,
    /// `(fut, span)` — prompt contributions to the next `span` positions.
    pub pending_seed: Option<(Vec<f32>, usize)>,
    /// Tokens sampled from the prefill's last logits.
    pub first_tokens: Option<Vec<u32>>,
}

/// Per-lane initialization for continuous admission ([`Session::admit`]).
///
/// Where [`SessionInit`] seeds a whole batch at position 0, `LaneInit`
/// seeds **one lane** at the session's *current* position: the lane's
/// activation history is cleared, its sampler stream rebased, and its
/// length bookkeeping restarted, so the lane's rollout from here on is
/// bit-identical to a fresh session running the same request.
#[derive(Debug, Clone, Default)]
pub struct LaneInit {
    /// Positions this lane will generate (its padded request length).
    /// 0 means "run to the end of the session" (`len - pos`).
    pub limit: usize,
    /// Sampling config override (`None` = the engine default).
    pub sampler_cfg: Option<SamplerCfg>,
    /// Sampler seed override (`None` = engine seed + lane index).
    pub seed: Option<u64>,
    /// `(fut, span)` — a prefill-style pending seed for this lane alone:
    /// `[M, span, D]` group-major contributions to the lane's next `span`
    /// positions, written into the pending plane at admission (the lane
    /// analogue of [`SessionInit::pending_seed`]; folded restores reuse
    /// the same deposit mechanism — DESIGN.md §6).
    pub pending_seed: Option<(Vec<f32>, usize)>,
}

/// What one [`Session::step`] call produced.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// 1-indexed position just computed (the session's global clock;
    /// subtract a lane's admission position for its local clock).
    pub pos: usize,
    /// Token ids appended at this position (one per lane, LM variant).
    pub tokens: Option<Vec<u32>>,
    /// Checksum (sum) of this position's `out` — the cheap per-position
    /// observable the synthetic variant streams in place of tokens.
    pub checksum: f32,
    /// Per-lane checksums (sum over each lane's `out` slice): the
    /// per-request observable serving lanes stream and the admission
    /// bit-identity tests compare.
    pub lane_checksums: Vec<f32>,
    /// True once the session has computed all requested positions.
    pub done: bool,
}

/// Persistent per-step staging scratch (no per-token reallocation on the
/// paths we control; the PJRT binding's `$`-input buffers and literal
/// fetches still allocate inside `xla-rs` — this struct is the single
/// place a zero-copy fetch would land).
#[derive(Default)]
struct StepStage {
    /// `[G, D]` streams-column fetch target.
    streams_col: Vec<f32>,
}

/// One in-flight generation session over a borrowed [`Engine`].
pub struct Session<'e, 'rt> {
    engine: &'e Engine<'rt>,
    len: usize,
    /// Positions completed so far (`step` computes position `pos + 1`).
    pos: usize,
    /// Appendix D wrapped-store mode (rows = len/2).
    half: bool,
    rows: usize,
    /// τ executor. Declared before `store`: struct fields drop in
    /// declaration order, so the executor drains its in-flight tiles
    /// before the store drops. (In-flight jobs also hold `Arc` clones of
    /// the store's cell planes, so the allocations outlive the jobs under
    /// any drop order — the ordering here keeps readiness bookkeeping and
    /// worker-time accounting deterministic, not memory safety.)
    tau: Option<Box<dyn TauImpl + 'e>>,
    store: Store,
    sampler: Sampler,
    a0: Vec<f32>,
    scstate: Option<Vec<f32>>,
    sc_dims: [usize; 4],
    forced: Option<Vec<f32>>,
    forced_steps: usize,
    /// Pending rows seeded at creation (prompt prefill): rows `0..seed_span`
    /// hold the prompt's future contributions before any tile ran, so a
    /// suspend must checkpoint at least this many pending rows for lanes
    /// still carrying the seed (`lane_start == 0`).
    seed_span: usize,
    /// Per-lane admission clock: global position at which each lane was
    /// (re)seeded — 0 for lanes running since session start. A lane's
    /// local position is `pos - lane_start[lane]`.
    lane_start: Vec<usize>,
    /// Per-lane length bookkeeping: positions the lane generates before
    /// it is done (admission rebases this alongside `lane_start`).
    lane_limit: Vec<usize>,
    /// Per-lane exclusive upper bound of *seeded* pending store rows
    /// (prompt seeds at admission, folded-restore deposits): rows the
    /// lane's tiles did not write, so the aligned suspend's `2·pos` bound
    /// does not cover them. 0 = no seeded rows beyond the usual bounds.
    lane_pend_hi: Vec<usize>,
    metrics: SessionMetrics,
    flops: FlopCounter,
    tokens: Option<Vec<Vec<u32>>>,
    pend_col: Vec<f32>,
    stage: StepStage,
    last_out: Vec<f32>,
    /// Ring of the last `checksum_history` per-position checksums.
    outs_checksum: VecDeque<f32>,
    checksum_history: usize,
    /// Running sum over *all* positions (survives ring eviction).
    checksum_total: f64,
    wall0: Instant,
}

impl<'e, 'rt> Session<'e, 'rt> {
    /// Set up a `len`-position session (power of two, ≤ L).
    pub fn new(engine: &'e Engine<'rt>, len: usize, init: SessionInit) -> Result<Session<'e, 'rt>> {
        let wall0 = Instant::now();
        let rt = engine.runtime();
        let dims = rt.dims;
        let opts = engine.opts();
        if !len.is_power_of_two() || len > dims.l {
            bail!("generation length {len} must be a power of two <= L={}", dims.l);
        }
        let (g, d, b) = (dims.g, dims.d, dims.b);
        if init.a0.len() != b * d {
            bail!("a0 must be a [B, D] tensor ({} values, got {})", b * d, init.a0.len());
        }

        // Appendix D: with the tiled method, after iteration len/2 nothing
        // before position len/2 is ever read again, so the second half can
        // reuse the first half's rows — the store holds M x (L/2) x D.
        let half = opts.half_store && opts.method == Method::Flash && len >= 4;
        if opts.half_store && opts.method != Method::Flash {
            bail!("half_store (Appendix D) applies to the tiled method only");
        }
        let rows = if half { len / 2 } else { len };

        let mut store = Store::new(g, rows, d);
        if let Some((fut, fut_span)) = &init.pending_seed {
            // seed pending with the prompt's future contributions. In the
            // full store, truncating to `rows = len` is exact: the dropped
            // columns belong to positions past the session's end, which
            // are never generated. In the wrapped half store those same
            // columns alias rows that *will* be consumed again after the
            // wrap — silently dropping them used to generate wrong
            // activations for every position past len/2, so refuse.
            if half && *fut_span > rows {
                bail!(
                    "pending seed spans {fut_span} positions but the wrapped half store \
                     holds {rows}: prompt contributions past len/2 would be lost \
                     (disable half_store for prompt prefill)"
                );
            }
            let span = (*fut_span).min(rows);
            for gi in 0..g {
                for t in 0..span {
                    store.write_pending_row(
                        gi,
                        t,
                        &fut[(gi * fut_span + t) * d..(gi * fut_span + t) * d + d],
                    );
                }
            }
        }
        let seed_span = init.pending_seed.as_ref().map_or(0, |(_, s)| (*s).min(rows));
        let sampler = engine.make_sampler()?;
        let scstate: Option<Vec<f32>> = match (&init.scstate_override, dims.variant) {
            (Some(sc), _) => Some(sc.clone()),
            (None, Variant::Hyena) => Some(vec![0.0; dims.ops() * 2 * b * 3 * d]),
            (None, Variant::Synthetic) => None,
        };
        let forced_steps = init.forced.as_ref().map(|f| f.len() / (b * d)).unwrap_or(0);

        let tau = if opts.method == Method::Flash {
            let exec = TauExecCfg {
                async_mixer: opts.async_mixer,
                split_min_u: opts.split_min_u,
                mixer_workers: opts.mixer_workers,
            };
            let mut imp = make_session_impl(opts.tau, &engine.cache, opts.threads, exec)?;
            imp.attach_readiness(store.readiness());
            Some(imp)
        } else {
            None
        };

        let mut tokens: Option<Vec<Vec<u32>>> = match dims.variant {
            Variant::Hyena => Some(vec![Vec::with_capacity(len); b]),
            Variant::Synthetic => None,
        };
        if let (Some(first), Some(all)) = (&init.first_tokens, tokens.as_mut()) {
            for (bi, t) in first.iter().enumerate() {
                all[bi].push(*t);
            }
        }

        Ok(Session {
            engine,
            len,
            pos: 0,
            half,
            rows,
            tau,
            store,
            sampler,
            a0: init.a0,
            scstate,
            sc_dims: [dims.ops(), 2, b, 3 * d],
            forced: init.forced,
            forced_steps,
            seed_span,
            lane_start: vec![0; b],
            lane_limit: vec![len; b],
            lane_pend_hi: vec![0; b],
            metrics: SessionMetrics::with_capacity(len),
            flops: FlopCounter::new(),
            tokens,
            pend_col: Vec::with_capacity(g * d),
            stage: StepStage::default(),
            last_out: Vec::new(),
            outs_checksum: VecDeque::with_capacity(len.min(opts.checksum_history)),
            checksum_history: opts.checksum_history,
            checksum_total: 0.0,
            wall0,
        })
    }

    /// Positions completed so far.
    pub fn steps_done(&self) -> usize {
        self.pos
    }

    /// Positions this session will generate in total.
    pub fn steps_total(&self) -> usize {
        self.len
    }

    pub fn is_done(&self) -> bool {
        self.pos >= self.len
    }

    /// The step artifact's `out` at the most recent position (`[B, W]`).
    pub fn last_out(&self) -> &[f32] {
        &self.last_out
    }

    /// Positions lane `lane` has generated since it was (re)seeded.
    pub fn lane_pos(&self, lane: usize) -> usize {
        self.pos - self.lane_start[lane]
    }

    /// Positions lane `lane` will generate in total before it is done.
    pub fn lane_limit(&self, lane: usize) -> usize {
        self.lane_limit[lane]
    }

    /// Global position at which lane `lane` was last (re)seeded.
    pub fn lane_start(&self, lane: usize) -> usize {
        self.lane_start[lane]
    }

    /// This lane has generated everything its admission asked for.
    pub fn lane_done(&self, lane: usize) -> bool {
        self.lane_pos(lane) >= self.lane_limit[lane]
    }

    /// Positions left before the session's global schedule ends — the
    /// admission capacity check (`admit` requires `limit <= remaining`).
    pub fn remaining(&self) -> usize {
        self.len - self.pos
    }

    /// One lane's short-conv slice offsets: `(batch_off, packed_off)`
    /// pairs, each `sc_dims[3]` wide — the single place the
    /// `[ops, phases, B, 3D]` lane layout is derived (admission's
    /// zero-fill and the pager's pack/unpack all iterate this).
    fn sc_lane_offsets(&self, lane: usize, b: usize) -> Vec<(usize, usize)> {
        let [ops, ph, _, w] = self.sc_dims;
        let mut offs = Vec::with_capacity(ops * ph);
        for op in 0..ops {
            for p in 0..ph {
                offs.push(((((op * ph) + p) * b + lane) * w, (op * ph + p) * w));
            }
        }
        offs
    }

    /// Continuous admission: seed a new request into one lane of the
    /// running batch at the current position (a **step boundary** — never
    /// call between a step's gather and its tile submission; the public
    /// API makes that impossible since `step` is atomic).
    ///
    /// What happens, in order (DESIGN.md §4):
    ///
    /// 1. **fence**: every in-flight async τ tile is drained. A gray
    ///    tile's destination rows span all `G = M·B` groups — including
    ///    the recycled lane's — so any in-flight tile would either read
    ///    the predecessor's streams rows after the reset below (leaking
    ///    its activations into the new request) or race the reset's
    ///    zeroing of `pending`. `Store::reset_lane` asserts quiescence,
    ///    turning a missed fence into a deterministic panic. The wait is
    ///    accounted as exposed fence time on the session totals.
    /// 2. **store reset**: the lane's `streams`/`pending` rows are zeroed
    ///    across all its groups. Future tiles whose source blocks straddle
    ///    the admission point then contribute exact zeros for pre-admission
    ///    positions — the same values a fresh session's store holds — which
    ///    is why the admitted rollout is bit-identical to a fresh run (the
    ///    tile kernels accumulate term-by-term in ascending source order,
    ///    and the filter index depends only on source→destination distance,
    ///    which is shift-invariant).
    /// 3. **lane state rebase**: `a0` slice reset to the model's rollout
    ///    start, short-conv state zeroed, sampler stream re-seeded with the
    ///    request's config, token buffer cleared, and the lane's
    ///    start/limit clocks rebased to the current position.
    ///
    /// Errors if the lane is out of range, the capacity `len - pos` cannot
    /// fit `limit`, the session is complete, or teacher forcing is still
    /// active (forced inputs address the whole batch, so a mid-forcing
    /// admission would overwrite the new lane's rollout).
    pub fn admit(&mut self, lane: usize, init: LaneInit) -> Result<()> {
        let engine = self.engine;
        let dims = engine.runtime().dims;
        let (d, b) = (dims.d, dims.b);
        if lane >= b {
            bail!("lane {lane} out of range (B={b})");
        }
        if self.pos >= self.len {
            bail!("session complete: cannot admit into a finished schedule");
        }
        let limit = if init.limit == 0 { self.len - self.pos } else { init.limit };
        if self.pos + limit > self.len {
            bail!(
                "admission needs {limit} positions but only {} remain of {}",
                self.len - self.pos,
                self.len
            );
        }
        if self.pos < self.forced_steps {
            bail!("cannot admit a lane while teacher forcing is active");
        }
        let m = dims.g / b;
        // Prompt-style pending seed: validate shape before touching any
        // lane state. Contributions past the lane's own schedule are
        // never consumed by it, so the span is clipped to `limit`; in the
        // wrapped half store a clipped span that still exceeds the row
        // count would alias recycled rows (same rule as the session-level
        // seed), so refuse.
        let seed = match &init.pending_seed {
            None => None,
            Some((fut, fut_span)) => {
                if *fut_span == 0 || fut.len() != m * fut_span * d {
                    bail!(
                        "lane pending seed must be a [M={m}, span, D={d}] tensor \
                         ({} values for span {fut_span}, got {})",
                        m * fut_span * d,
                        fut.len()
                    );
                }
                let span = (*fut_span).min(limit);
                if self.half && span > self.rows {
                    bail!(
                        "lane pending seed spans {span} positions but the wrapped half \
                         store holds {}: prompt contributions past len/2 would be lost \
                         (disable half_store for prompt prefill)",
                        self.rows
                    );
                }
                Some(span)
            }
        };

        // 1. fence: drain every in-flight tile covering the recycled lane
        // (all of them — a tile's dst spans every group).
        if let Some(tau) = self.tau.as_mut() {
            let fs = tau.fence_all()?;
            self.metrics.totals.fence_ns += fs.wait_ns as f64;
            self.metrics.totals.tau_worker_ns += tau.take_worker_ns() as f64;
        }

        // 2. store: clear the lane's activation history (asserts quiet),
        // then deposit the prompt seed (if any) onto the lane's next
        // `span` pending columns — store row of position `pos + 1 + t` is
        // `(pos + t) % rows`, the same mapping the folded restore uses.
        self.store.reset_lane(lane, b);
        self.lane_pend_hi[lane] = 0;
        if let Some(span) = seed {
            let (fut, fut_span) = init.pending_seed.as_ref().unwrap();
            let r0 = self.pos % self.rows;
            for mi in 0..m {
                let gi = mi * b + lane;
                for t in 0..span {
                    self.store.write_pending_row(
                        gi,
                        (r0 + t) % self.rows,
                        &fut[(mi * fut_span + t) * d..(mi * fut_span + t + 1) * d],
                    );
                }
            }
            self.lane_pend_hi[lane] = if r0 + span > self.rows { self.rows } else { r0 + span };
        }

        // 3. lane state: rollout start input, short-conv state, sampler
        // stream, token buffer, admission clocks.
        let a0_lane = engine.initial_lane_a0()?;
        self.a0[lane * d..(lane + 1) * d].copy_from_slice(&a0_lane);
        let sc_offs = self.sc_lane_offsets(lane, b);
        let w = self.sc_dims[3];
        if let Some(sc) = self.scstate.as_mut() {
            for &(base, _) in &sc_offs {
                sc[base..base + w].fill(0.0);
            }
        }
        self.sampler.reset_lane(lane, init.sampler_cfg, init.seed);
        if let Some(all) = self.tokens.as_mut() {
            all[lane].clear();
        }
        self.lane_start[lane] = self.pos;
        self.lane_limit[lane] = limit;
        Ok(())
    }

    /// Session paging, swap-out half: checkpoint one lane into the pager
    /// and free it for another request (`fence_all` → row copy-out →
    /// `Store::reset_lane`, the same quiet-row fence rule as admission —
    /// DESIGN.md §6).
    ///
    /// The checkpoint holds everything the lane *is*: its non-zero
    /// `streams` rows (`< pos`) and `pending` rows (`< 2·pos` — a gray
    /// tile at iteration `i` deposits partial sums up to row `2i-1`,
    /// which complement exactly the tiles that have not run yet), its
    /// `a0`/short-conv slices, the sampler lane's config + raw PRNG
    /// state, its token buffer, and its start/limit clocks. Early
    /// evictions page out only a few rows.
    ///
    /// Fails — **without touching any lane state** — if the pager lacks
    /// capacity, the lane is out of range, the session is complete, or
    /// teacher forcing is active. On success the lane is idle
    /// (`lane_done` is true) and may be re-admitted immediately.
    pub fn suspend(&mut self, lane: usize, pager: &mut Pager) -> Result<LaneCheckpoint> {
        let dims = self.engine.runtime().dims;
        let (d, b) = (dims.d, dims.b);
        if lane >= b {
            bail!("lane {lane} out of range (B={b})");
        }
        if self.pos >= self.len {
            bail!("session complete: nothing to suspend");
        }
        if self.pos < self.forced_steps {
            bail!("cannot suspend a lane while teacher forcing is active");
        }
        let m = dims.g / b;
        if pager.groups() != m || pager.dim() != d {
            bail!(
                "pager shape [{}, ., {}] does not match lane shape [{m}, ., {d}]",
                pager.groups(),
                pager.dim()
            );
        }
        // Rows below the lane's admission point are zero by construction
        // in the unwrapped store (admission reset them, and every later
        // write for this lane lands at or above `lane_start`), so skip
        // them: a late-admitted lane's checkpoint pays for its own rows,
        // not the batch's global clock. The wrapped half store recycles
        // rows anywhere, so it pages from row 0.
        let row0 = if self.half { 0 } else { self.lane_start[lane] };
        // a lane still carrying the creation-time prompt seed
        // (lane_start == 0, never re-admitted) has non-zero pending rows
        // up to `seed_span` before any tile ran — checkpoint those too
        let seed_floor = if self.lane_start[lane] == 0 { self.seed_span } else { 0 };
        // `lane_pend_hi` covers rows seeded outside tile writes (a lane
        // prompt seed or a folded-restore deposit), which can reach past
        // the tile-derived `2·pos` bound.
        let streams_rows = row0..self.pos.min(self.rows);
        let pending_rows =
            row0..(2 * self.pos).max(seed_floor).max(self.lane_pend_hi[lane]).min(self.rows);
        let (ns, np) = (streams_rows.len(), pending_rows.len());
        let needed = pager.blocks_for(ns) + pager.blocks_for(np);
        if !pager.fits(needed) {
            bail!(
                "pager full: lane checkpoint needs {needed} blocks, {} free",
                pager.free_blocks()
            );
        }

        // fence: same rule as admission — every in-flight tile's dst
        // covers this lane, and the copy-out below reads rows a tile may
        // still be writing (copy_lane_rows_out asserts quiescence).
        if let Some(tau) = self.tau.as_mut() {
            let fs = tau.fence_all()?;
            self.metrics.totals.fence_ns += fs.wait_ns as f64;
            self.metrics.totals.tau_worker_ns += tau.take_worker_ns() as f64;
        }

        let (mut sbuf, mut pbuf) = (Vec::new(), Vec::new());
        self.store
            .copy_lane_rows_out(lane, b, streams_rows, pending_rows, &mut sbuf, &mut pbuf);
        let streams = pager.store_rows(&sbuf, ns)?;
        let pending = match pager.store_rows(&pbuf, np) {
            Ok(pr) => pr,
            Err(e) => {
                pager.release(streams);
                return Err(e);
            }
        };

        let a0 = self.a0[lane * d..(lane + 1) * d].to_vec();
        let sc_offs = self.sc_lane_offsets(lane, b);
        let w = self.sc_dims[3];
        let scstate = self.scstate.as_ref().map(|sc| {
            let mut out = vec![0.0; sc_offs.len() * w];
            for &(base, src) in &sc_offs {
                out[src..src + w].copy_from_slice(&sc[base..base + w]);
            }
            out
        });
        let tokens = self.tokens.as_mut().map(|all| std::mem::take(&mut all[lane]));
        let ckpt = LaneCheckpoint {
            row0,
            streams,
            pending,
            a0,
            scstate,
            sampler: self.sampler.snapshot_lane(lane),
            tokens,
            pos: self.pos,
            lane_start: self.lane_start[lane],
            lane_limit: self.lane_limit[lane],
            rows: self.rows,
            half: self.half,
            folded: false,
        };

        // the lane is now free: clear its activation history (asserts
        // quiet) and retire its clocks so lane_done() reports idle
        self.store.reset_lane(lane, b);
        self.lane_start[lane] = self.pos;
        self.lane_limit[lane] = 0;
        self.lane_pend_hi[lane] = 0;
        Ok(ckpt)
    }

    /// Session paging, FutureFill flavor: fold the lane's entire history
    /// into completed contributions to its *remaining* positions, and
    /// checkpoint only that pending tail — a **position-independent**
    /// checkpoint restorable at any step boundary of any session over the
    /// same model (DESIGN.md §6, FutureFill / arxiv 2410.03766).
    ///
    /// The fold replays, on the host, exactly the tiles of the remaining
    /// schedule whose source block straddles the suspension position `p`
    /// (~log₂ L of them), with future sources masked to zero: the fractal
    /// schedule covers every (source ≤ p → destination > p) pair exactly
    /// once across {already-run tiles (partials already in the pending
    /// plane), straddling tiles (folded here)}, so afterwards the pending
    /// tail holds the history's complete contribution to every remaining
    /// position — `O(p·(L−p))` MACs per mixer lane, paid once. The
    /// activation rows themselves are *not* checkpointed: after a folded
    /// restore they are zero, exactly like a freshly admitted lane's.
    ///
    /// Direct-τ sessions (`rust-direct`/`pjrt-direct`) fold with the
    /// direct kernel so each surviving term accumulates in the same
    /// ascending-source order as the uninterrupted run — the resumed
    /// rollout is bit-identical under the host direct kernel (the extra
    /// masked-zero terms can only flip an exact `-0.0`, the same class of
    /// ±0.0 caveat as admission's zero-prefix argument, DESIGN.md §4).
    /// FFT-τ sessions fold with `tile_conv_rfft_fused_into`; the linear
    /// split FFT(h) + FFT(f) matches FFT(h+f) only to rounding, so those
    /// resumes are tolerance-equal, not bit-equal.
    ///
    /// Fails without touching lane state if the lane has no remaining
    /// schedule, the wrapped half store cannot represent the tail
    /// (`span > rows`), or the pager lacks capacity.
    pub fn suspend_folded(&mut self, lane: usize, pager: &mut Pager) -> Result<LaneCheckpoint> {
        let dims = self.engine.runtime().dims;
        let (d, b) = (dims.d, dims.b);
        if lane >= b {
            bail!("lane {lane} out of range (B={b})");
        }
        if self.pos >= self.len {
            bail!("session complete: nothing to suspend");
        }
        if self.pos < self.forced_steps {
            bail!("cannot suspend a lane while teacher forcing is active");
        }
        let m = dims.g / b;
        if pager.groups() != m || pager.dim() != d {
            bail!(
                "pager shape [{}, ., {}] does not match lane shape [{m}, ., {d}]",
                pager.groups(),
                pager.dim()
            );
        }
        let lane_pos = self.pos - self.lane_start[lane];
        let span = self.lane_limit[lane].saturating_sub(lane_pos);
        if span == 0 {
            bail!("lane {lane} has no remaining schedule to fold");
        }
        if self.half && span > self.rows {
            bail!(
                "folded tail spans {span} positions but the wrapped half store holds {}: \
                 fold would alias recycled rows (use the aligned path)",
                self.rows
            );
        }
        let needed = pager.blocks_for(span);
        if !pager.fits(needed) {
            bail!(
                "pager full: folded checkpoint needs {needed} blocks, {} free",
                pager.free_blocks()
            );
        }

        // fence: the fold below reads streams/pending rows tiles may
        // still be writing (same rule as the aligned suspend).
        if let Some(tau) = self.tau.as_mut() {
            let fs = tau.fence_all()?;
            self.metrics.totals.fence_ns += fs.wait_ns as f64;
            self.metrics.totals.tau_worker_ns += tau.take_worker_ns() as f64;
        }

        // Start from the partial sums already deposited for the remaining
        // positions p+1..=p+span (store row of position q is (q-1) % rows;
        // in the half store these are exactly the live, distinct rows).
        // The buffer is padded to the largest straddling tile's dst reach
        // so whole tile kernels can accumulate in place; only the first
        // `span` rows are checkpointed.
        let p = self.pos;
        let lane_end = p + span;
        let mut pad = span;
        {
            let mut i = p + 1;
            while i < lane_end {
                let u = 1usize << i.trailing_zeros();
                if i + 1 - u <= p {
                    pad = pad.max(i + u - p);
                }
                i += 1;
            }
        }
        let r0 = p % self.rows;
        let mut tail = Vec::new();
        self.store.copy_lane_pending_rows_wrapped(lane, b, r0, span, &mut tail);
        let mut fut = vec![0.0f32; m * pad * d];
        for mi in 0..m {
            fut[mi * pad * d..(mi * pad + span) * d]
                .copy_from_slice(&tail[mi * span * d..(mi + 1) * span * d]);
        }

        // Replay the straddling tiles of the remaining schedule with
        // future sources masked to zero (the post-restore tiles will
        // contribute those — over zeroed history rows, closing the
        // exactly-once coverage of every pair).
        let cache = &self.engine.cache;
        let direct = matches!(self.engine.opts().tau, TauKind::RustDirect | TauKind::PjrtDirect);
        let mut scratch = fft::TileScratch::default();
        let mut y = Vec::new();
        for i in (p + 1)..lane_end {
            let u = 1usize << i.trailing_zeros();
            let src_l = i + 1 - u; // 1-indexed source block [src_l, i]
            if src_l > p {
                continue;
            }
            y.resize(u * d, 0.0);
            for mi in 0..m {
                let gi = mi * b + lane;
                for j0 in 0..u {
                    let j = src_l + j0; // global source position
                    let yr = &mut y[j0 * d..(j0 + 1) * d];
                    if j <= p {
                        yr.copy_from_slice(self.store.streams.at2(gi, (j - 1) % self.rows));
                    } else {
                        yr.fill(0.0);
                    }
                }
                // dst positions i+1..i+U land on fut rows i-p..i-p+U
                let out = &mut fut[(mi * pad + (i - p)) * d..(mi * pad + (i - p) + u) * d];
                if direct {
                    fft::tile_conv_direct_into(&y, cache.seg(mi, u), out, d);
                } else {
                    fft::tile_conv_rfft_fused_into(
                        &cache.plan(u),
                        &y,
                        cache.spectra(u).blocked(mi),
                        out,
                        &mut scratch,
                        d,
                    );
                }
            }
        }

        // Persist the first `span` rows per mixer ([M, span, D]).
        for mi in 0..m {
            tail[mi * span * d..(mi + 1) * span * d]
                .copy_from_slice(&fut[mi * pad * d..(mi * pad + span) * d]);
        }
        let streams = pager.store_rows(&[], 0)?;
        let pending = match pager.store_rows(&tail, span) {
            Ok(pr) => pr,
            Err(e) => {
                pager.release(streams);
                return Err(e);
            }
        };

        let a0 = self.a0[lane * d..(lane + 1) * d].to_vec();
        let sc_offs = self.sc_lane_offsets(lane, b);
        let w = self.sc_dims[3];
        let scstate = self.scstate.as_ref().map(|sc| {
            let mut out = vec![0.0; sc_offs.len() * w];
            for &(base, src) in &sc_offs {
                out[src..src + w].copy_from_slice(&sc[base..base + w]);
            }
            out
        });
        let tokens = self.tokens.as_mut().map(|all| std::mem::take(&mut all[lane]));
        let ckpt = LaneCheckpoint {
            row0: 0,
            streams,
            pending,
            a0,
            scstate,
            sampler: self.sampler.snapshot_lane(lane),
            tokens,
            pos: self.pos,
            lane_start: self.lane_start[lane],
            lane_limit: self.lane_limit[lane],
            rows: self.rows,
            half: self.half,
            folded: true,
        };

        self.store.reset_lane(lane, b);
        self.lane_start[lane] = self.pos;
        self.lane_limit[lane] = 0;
        self.lane_pend_hi[lane] = 0;
        Ok(ckpt)
    }

    /// Session paging, swap-in half: the exact inverse of
    /// [`Session::suspend`], under the same fence rule.
    ///
    /// **Restore position.** The checkpoint must be restored when this
    /// session's global clock equals the suspension position
    /// (`steps_done() == ckpt.pos()`). The fractal tile schedule
    /// partitions each lane's (source → destination) contribution pairs
    /// by the lane's alignment in the *global* clock; the checkpointed
    /// pending rows hold partial sums for exactly the pairs whose
    /// covering tile had already run. Only at the same alignment do the
    /// remaining tiles complement that set exactly — each contribution
    /// lands exactly once, in the same float order — which is what makes
    /// the resumed rollout **bit-identical** to an uninterrupted run
    /// (`tests/integration_paging.rs`). At any other position the
    /// restore refuses rather than double-count or drop contributions.
    ///
    /// **Folded checkpoints** ([`Session::suspend_folded`]) carry no
    /// alignment requirement: the lane's whole history is already baked
    /// into its pending tail, so the restore deposits the tail onto the
    /// next `span` pending columns (the admission-seed mechanism) and
    /// *rebases* the lane clock — `lane_start = pos − lane_pos`, a virtual
    /// admission point. Two fit conditions replace the alignment rule:
    /// the session must have at least `span` positions remaining, and its
    /// clock must be ≥ the lane's generated-position count (so the
    /// virtual admission point is not before the session's origin).
    ///
    /// The checkpoint is consumed either way; on error its slab blocks
    /// are returned to the pager and the lane is left untouched.
    pub fn restore(&mut self, lane: usize, ckpt: LaneCheckpoint, pager: &mut Pager) -> Result<()> {
        if ckpt.folded {
            return self.restore_folded(lane, ckpt, pager);
        }
        let dims = self.engine.runtime().dims;
        let (d, b) = (dims.d, dims.b);
        let check = || -> Result<()> {
            if lane >= b {
                bail!("lane {lane} out of range (B={b})");
            }
            if self.pos != ckpt.pos {
                bail!(
                    "restore at position {} but checkpoint was suspended at {} \
                     (same-alignment rule, DESIGN.md §6)",
                    self.pos,
                    ckpt.pos
                );
            }
            if self.rows != ckpt.rows || self.half != ckpt.half {
                bail!(
                    "store geometry mismatch: session rows={} half={} vs checkpoint \
                     rows={} half={}",
                    self.rows,
                    self.half,
                    ckpt.rows,
                    ckpt.half
                );
            }
            if self.pos >= self.len {
                bail!("session complete: cannot restore into a finished schedule");
            }
            if ckpt.lane_start + ckpt.lane_limit > self.len {
                bail!(
                    "checkpoint schedule [{}, {}) exceeds session length {}",
                    ckpt.lane_start,
                    ckpt.lane_start + ckpt.lane_limit,
                    self.len
                );
            }
            if self.pos < self.forced_steps {
                bail!("cannot restore a lane while teacher forcing is active");
            }
            if ckpt.scstate.is_some() != self.scstate.is_some() {
                bail!("checkpoint/session short-conv state mismatch");
            }
            Ok(())
        };
        if let Err(e) = check() {
            pager.discard(ckpt);
            return Err(e);
        }

        if let Some(tau) = self.tau.as_mut() {
            match tau.fence_all() {
                Ok(fs) => self.metrics.totals.fence_ns += fs.wait_ns as f64,
                Err(e) => {
                    // never strand the checkpoint's slab blocks
                    pager.discard(ckpt);
                    return Err(e);
                }
            }
            self.metrics.totals.tau_worker_ns += tau.take_worker_ns() as f64;
        }

        // clear whatever the lane held (a previous request's rows), then
        // write the checkpoint back — rows outside the checkpointed
        // ranges stay zero, exactly as in the uninterrupted run
        self.store.reset_lane(lane, b);
        let row0 = ckpt.row0;
        let (n_streams, n_pending) = (ckpt.streams.rows(), ckpt.pending.rows());
        let (mut sbuf, mut pbuf) = (Vec::new(), Vec::new());
        pager.fetch_rows(ckpt.streams, &mut sbuf);
        pager.fetch_rows(ckpt.pending, &mut pbuf);
        self.store.copy_lane_rows_in(
            lane,
            b,
            row0..row0 + n_streams,
            row0..row0 + n_pending,
            &sbuf,
            &pbuf,
        );

        self.a0[lane * d..(lane + 1) * d].copy_from_slice(&ckpt.a0);
        let sc_offs = self.sc_lane_offsets(lane, b);
        let w = self.sc_dims[3];
        if let Some(sc) = self.scstate.as_mut() {
            let lane_sc = ckpt.scstate.as_ref().unwrap();
            for &(base, src) in &sc_offs {
                sc[base..base + w].copy_from_slice(&lane_sc[src..src + w]);
            }
        }
        self.sampler.restore_lane(lane, &ckpt.sampler);
        if let Some(all) = self.tokens.as_mut() {
            all[lane] = ckpt.tokens.unwrap_or_default();
        }
        self.lane_start[lane] = ckpt.lane_start;
        self.lane_limit[lane] = ckpt.lane_limit;
        // a later aligned suspend must checkpoint at least the restored
        // pending range, even where `2·pos` does not reach it
        self.lane_pend_hi[lane] = row0 + n_pending;
        Ok(())
    }

    /// Folded-restore half of [`Session::restore`]: deposit the pending
    /// tail at the *current* clock and rebase the lane (DESIGN.md §6).
    fn restore_folded(
        &mut self,
        lane: usize,
        ckpt: LaneCheckpoint,
        pager: &mut Pager,
    ) -> Result<()> {
        let dims = self.engine.runtime().dims;
        let (d, b) = (dims.d, dims.b);
        let lane_pos = ckpt.pos - ckpt.lane_start;
        let span = ckpt.pending.rows();
        let check = || -> Result<()> {
            if lane >= b {
                bail!("lane {lane} out of range (B={b})");
            }
            if span != ckpt.lane_limit.saturating_sub(lane_pos) || ckpt.streams.rows() != 0 {
                bail!(
                    "malformed folded checkpoint: pending tail {} rows, streams {} rows, \
                     remaining schedule {}",
                    span,
                    ckpt.streams.rows(),
                    ckpt.lane_limit.saturating_sub(lane_pos)
                );
            }
            if self.rows != ckpt.rows || self.half != ckpt.half {
                bail!(
                    "store geometry mismatch: session rows={} half={} vs checkpoint \
                     rows={} half={}",
                    self.rows,
                    self.half,
                    ckpt.rows,
                    ckpt.half
                );
            }
            if self.pos >= self.len {
                bail!("session complete: cannot restore into a finished schedule");
            }
            if self.pos + span > self.len {
                bail!(
                    "folded checkpoint needs {span} positions but only {} remain of {}",
                    self.len - self.pos,
                    self.len
                );
            }
            if self.pos < lane_pos {
                bail!(
                    "folded restore at position {} but the lane has generated {lane_pos} \
                     positions: the rebased admission point would precede the session \
                     (wait for the clock to reach {lane_pos})",
                    self.pos
                );
            }
            if self.half && span > self.rows {
                bail!(
                    "folded tail spans {span} positions but the wrapped half store \
                     holds {}",
                    self.rows
                );
            }
            if self.pos < self.forced_steps {
                bail!("cannot restore a lane while teacher forcing is active");
            }
            if ckpt.scstate.is_some() != self.scstate.is_some() {
                bail!("checkpoint/session short-conv state mismatch");
            }
            Ok(())
        };
        if let Err(e) = check() {
            pager.discard(ckpt);
            return Err(e);
        }

        if let Some(tau) = self.tau.as_mut() {
            match tau.fence_all() {
                Ok(fs) => self.metrics.totals.fence_ns += fs.wait_ns as f64,
                Err(e) => {
                    pager.discard(ckpt);
                    return Err(e);
                }
            }
            self.metrics.totals.tau_worker_ns += tau.take_worker_ns() as f64;
        }

        // deposit the tail onto the next `span` pending columns: store row
        // of position pos+1+t is (pos+t) % rows — the admission-seed
        // mapping, wrapped for the half store
        self.store.reset_lane(lane, b);
        let (mut sbuf, mut pbuf) = (Vec::new(), Vec::new());
        pager.fetch_rows(ckpt.streams, &mut sbuf);
        pager.fetch_rows(ckpt.pending, &mut pbuf);
        let r0 = self.pos % self.rows;
        self.store.copy_lane_pending_rows_wrapped_in(lane, b, r0, span, &pbuf);

        self.a0[lane * d..(lane + 1) * d].copy_from_slice(&ckpt.a0);
        let sc_offs = self.sc_lane_offsets(lane, b);
        let w = self.sc_dims[3];
        if let Some(sc) = self.scstate.as_mut() {
            let lane_sc = ckpt.scstate.as_ref().unwrap();
            for &(base, src) in &sc_offs {
                sc[base..base + w].copy_from_slice(&lane_sc[src..src + w]);
            }
        }
        self.sampler.restore_lane(lane, &ckpt.sampler);
        if let Some(all) = self.tokens.as_mut() {
            all[lane] = ckpt.tokens.unwrap_or_default();
        }
        // fresh lane-clock rebase: the lane behaves as if admitted at
        // `pos - lane_pos` — its local clock continues from `lane_pos`
        self.lane_start[lane] = self.pos - lane_pos;
        self.lane_limit[lane] = ckpt.lane_limit;
        self.lane_pend_hi[lane] = if r0 + span > self.rows { self.rows } else { r0 + span };
        Ok(())
    }

    /// Advance one position: upload → fence → pending-column gather →
    /// `step` artifact → submit gray tile → sample. Errors once the
    /// session is complete.
    pub fn step(&mut self) -> Result<StepOutput> {
        if self.pos >= self.len {
            bail!("session complete: all {} positions generated", self.len);
        }
        // Chaos handle: `engine_step:panic@k` exercises the supervisor's
        // catch_unwind/rebuild path, `engine_step:fail@k` the plain
        // error path. Inert (one atomic load) when nothing is armed.
        crate::util::faultpoint::check("engine_step")?;
        let engine = self.engine;
        let rt = engine.runtime();
        let dims = rt.dims;
        let opts = engine.opts();
        let (g, d, b) = (dims.g, dims.d, dims.b);
        let i = self.pos + 1;
        let rows = self.rows;
        let row_of = |pos1: usize| (pos1 - 1) % rows; // 1-indexed -> store row
        let mut bd = Breakdown::default();

        // ---- fence-independent uploads first: `a0` (and the short-conv
        // state) were finalized by the previous step's sampler, so their
        // host→device copies run while async gray tiles are still flying
        let t0 = Instant::now();
        let ab = rt.upload(&self.a0, &[b, d])?;
        let scb = self
            .scstate
            .as_ref()
            .map(|sc| rt.upload(sc, &self.sc_dims))
            .transpose()?;
        let upload_ns = t0.elapsed().as_nanos() as f64;

        // ---- fence: the deadline for every tile writing pending col i.
        // Sits immediately before the gather — the first true consumer of
        // z[i] — so tau(i-1) had the whole upload above to hide behind.
        if let Some(tau) = self.tau.as_mut() {
            let fs = tau.fence(row_of(i) + 1)?;
            bd.fence_ns = fs.wait_ns as f64;
        }

        // ---- pending column (lazy recomputes; others read the store)
        let t0 = Instant::now();
        match opts.method {
            Method::Lazy => {
                lazy::lazy_pending_col(
                    &self.store.streams,
                    &engine.cache.rho,
                    b,
                    i,
                    &mut self.pend_col,
                    &mut self.flops,
                );
            }
            _ => self.store.gather_pending_col(row_of(i), &mut self.pend_col),
        }
        if self.half {
            // the consumed column's row will be reused by a future tile
            self.store.zero_pending_col(row_of(i));
        }
        if opts.method == Method::Lazy {
            bd.mixer_ns += t0.elapsed().as_nanos() as f64;
        }

        // ---- step: red cells + blocks + head (PJRT)
        let t0 = Instant::now();
        let pb = rt.upload(&self.pend_col, &[dims.m, b, d])?;
        let outs = match &scb {
            None => engine.step_artifact().call(&[&pb, &ab])?,
            Some(scb) => engine.step_artifact().call(&[&pb, &ab, scb])?,
        };
        self.stage.streams_col = Runtime::literal_to_vec(&outs[0], g * d)?;
        self.store.set_streams_col(row_of(i), &self.stage.streams_col);
        self.last_out = Runtime::literal_to_vec(&outs[1], b * dims.out_width())?;
        let w = dims.out_width();
        let lane_checksums: Vec<f32> = (0..b)
            .map(|bi| self.last_out[bi * w..(bi + 1) * w].iter().sum())
            .collect();
        let checksum: f32 = self.last_out.iter().sum();
        self.checksum_total += checksum as f64;
        if self.outs_checksum.len() == self.checksum_history {
            self.outs_checksum.pop_front();
        }
        if self.checksum_history > 0 {
            self.outs_checksum.push_back(checksum);
        }
        if let Some(sc) = self.scstate.as_mut() {
            *sc = Runtime::literal_to_vec(&outs[2], sc.len())?;
        }
        self.flops.record_red(2 * g as u64 * d as u64); // red cells proper
        bd.step_ns = upload_ns + t0.elapsed().as_nanos() as f64;

        // ---- gray work, launched the moment the streams column exists:
        // under the async executor the tile overlaps the sampling below,
        // the caller's token handling, and the next step's uploads
        if i < self.len {
            let t0 = Instant::now();
            match opts.method {
                Method::Flash => {
                    let tile = Tile::at(i);
                    // Appendix D: translate tile ranges into the wrapped
                    // store (ranges never straddle the halfway boundary —
                    // each lies in a U-aligned block, and rows | U).
                    let tile = if self.half {
                        let rs = row_of(tile.src_l);
                        let rd = row_of(tile.dst_l);
                        Tile {
                            i: tile.i,
                            u: tile.u,
                            src_l: rs + 1,
                            src_r: rs + tile.u,
                            dst_l: rd + 1,
                            dst_r: rd + tile.u,
                        }
                    } else {
                        tile
                    };
                    let imp = self.tau.as_mut().unwrap();
                    imp.submit(&self.store.streams, &self.store.pending, tile)?;
                    self.flops.record_tau(
                        tile.u,
                        imp.tile_flops(tile.u, g, d),
                        (2 * tile.u * g * d) as u64,
                    );
                    bd.mixer_ns += t0.elapsed().as_nanos() as f64;
                }
                Method::Eager => {
                    eager::eager_push(
                        &self.store.streams,
                        &self.store.pending,
                        &engine.cache.rho,
                        b,
                        i,
                        self.len,
                        &mut self.flops,
                    );
                    bd.mixer_ns += t0.elapsed().as_nanos() as f64;
                }
                Method::Lazy => {}
            }
        }

        // ---- next input: teacher-forced or sampled (overlapped work)
        let t0 = Instant::now();
        let mut step_tokens: Option<Vec<u32>> = None;
        if i < self.forced_steps {
            let stride = b * d;
            self.a0
                .copy_from_slice(&self.forced.as_ref().unwrap()[i * stride..(i + 1) * stride]);
        } else if let Some(toks) = self.sampler.next_a0(&self.last_out, b, &mut self.a0)? {
            if let Some(all) = self.tokens.as_mut() {
                for (bi, t) in toks.iter().enumerate() {
                    all[bi].push(*t);
                }
            }
            step_tokens = Some(toks);
        }
        bd.sample_ns = t0.elapsed().as_nanos() as f64;

        // worker-side tau ns drained here lands on the step that observed
        // the completion (one position after submission at the latest —
        // totals are exact, per-token attribution shifts by ≤ 1 token)
        if let Some(tau) = self.tau.as_mut() {
            bd.tau_worker_ns = tau.take_worker_ns() as f64;
        }

        self.metrics.push(bd);
        self.pos = i;
        Ok(StepOutput {
            pos: i,
            tokens: step_tokens,
            checksum,
            lane_checksums,
            done: self.pos == self.len,
        })
    }

    /// Consume the session into its [`GenOutput`]. Finishing early (before
    /// `is_done`) is allowed — `steps` reports the positions actually
    /// generated — so serving lanes can abandon a session cleanly.
    pub fn finish(mut self) -> GenOutput {
        // drain in-flight async tiles before reading the store (the
        // streams export below must observe every completed write);
        // residual worker time folds into the session totals so
        // hidden-time accounting stays complete
        if let Some(tau) = self.tau.as_mut() {
            if let Ok(fs) = tau.fence_all() {
                self.metrics.totals.fence_ns += fs.wait_ns as f64;
            }
            self.metrics.totals.tau_worker_ns += tau.take_worker_ns() as f64;
        }
        self.metrics.wall = self.wall0.elapsed();
        GenOutput {
            steps: self.pos,
            tokens: self.tokens,
            last_out: self.last_out,
            outs_checksum: self.outs_checksum.into_iter().collect(),
            checksum_total: self.checksum_total,
            resident_values: self.store.resident_values(),
            metrics: self.metrics,
            flops: self.flops,
            streams: if self.engine.opts().record_streams {
                Some(self.store.streams_tensor())
            } else {
                None
            },
        }
    }
}
