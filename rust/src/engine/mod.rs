//! The inference engine: Algorithm 2/3's token loop over the PJRT `step`
//! artifact and the τ gray tiles, plus the lazy/eager baselines (§3.1.1)
//! on identical plumbing so every method is exactly comparable.
//!
//! Loop shape (Flash, per position i = 1..len):
//!
//! 1. `pending[:, i]` column + current `a0` → `step` artifact → red cells,
//!    blocks, head (sequential across layers — the only part that must be);
//! 2. sampler: `out` → next `a0` (and token ids for the LM variant);
//! 3. gray tile `Tile::at(i)`: one τ call covering ALL layers at once
//!    (Algorithm 3's across-layer parallelism as batching over `G = M·B`).
//!
//! The lazy engine replaces (3) with an O(i) recomputation of the next
//! pending column; the eager engine replaces (3) with an O(len-i) push to
//! all future columns. All three share `step`, the sampler, the store and
//! the metrics, so Fig 2a/2b/2c compare only what the paper compares.
//!
//! The loop itself lives in [`session`]: a resumable [`Session`] state
//! machine advanced one position per [`Session::step`] call. `generate`
//! and friends are thin drivers over it; streaming callers (the HTTP
//! server's per-lane channels, the `--stream` CLI, first-token probes)
//! drive `step()` directly. See `rust/DESIGN.md`.

pub mod datadep;
pub mod eager;
pub mod lazy;
pub mod pager;
pub mod sampler;
pub mod session;
pub mod store;

use anyhow::{bail, Context, Result};

pub use pager::{CkptRef, LaneCheckpoint, Pager, SamplerSnapshot, ServingMeta};
pub use sampler::{Sampler, SamplerCfg};
pub use session::{LaneInit, Session, SessionInit, StepOutput};
pub use store::{RowReadiness, Store};

use crate::metrics::SessionMetrics;
use crate::model::Variant;
use crate::runtime::{BoundArtifact, Runtime};
use crate::tau::{RhoCache, TauKind};
use crate::tiling::FlopCounter;
use crate::util::tensor::Tensor;

/// Inference scheduling method.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// The paper's tiled O(L log² L) algorithm.
    Flash,
    /// O(L²) recompute-on-demand baseline.
    Lazy,
    /// O(L²) push-on-produce baseline.
    Eager,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "flash" => Method::Flash,
            "lazy" => Method::Lazy,
            "eager" => Method::Eager,
            other => bail!("unknown method '{other}' (flash|lazy|eager)"),
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Method::Flash => "flash",
            Method::Lazy => "lazy",
            Method::Eager => "eager",
        }
    }
}

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct EngineOpts {
    pub method: Method,
    /// τ implementation (Flash only).
    pub tau: TauKind,
    /// Worker threads for native τ across-layer parallelism (0 = inline).
    pub threads: usize,
    /// Synthetic sampler noise (0 ⇒ deterministic golden rollout).
    pub sample_sigma: f32,
    /// LM sampling temperature (0 ⇒ argmax) and top-k (0 ⇒ all).
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
    /// Keep the full streams tensor in the output (tests/validation).
    pub record_streams: bool,
    /// Appendix D: store only M x (L/2) x D activations by reusing the
    /// first half's rows for the second half (Flash method only).
    pub half_store: bool,
    /// Run gray tiles on the deadline-fenced async executor (native τ
    /// kinds only; PJRT-backed kinds — including Hybrid — stay
    /// synchronous because PJRT handles cannot leave the engine thread).
    /// On by default; force off to pin every tile to the critical path.
    pub async_mixer: bool,
    /// Async split-tile threshold: tiles with U >= this are split into an
    /// urgent first column (a staged direct chunk with the tile's own
    /// deadline) plus relaxed remainder chunks whose deadlines amortize
    /// over the following red steps. 0 (the default) disables splitting,
    /// keeping async output bit-identical to sync output.
    pub split_min_u: usize,
    /// Workers in the async mixer's dependency-tracked pool. Tiles (and
    /// staged chunks) whose dst row ranges are disjoint run concurrently;
    /// overlapping-dst work is ordered by per-job dependency edges.
    /// 1 (the default) degenerates to the FIFO pipeline; > 1 requires
    /// `async_mixer` and a native τ kind (validated at session creation).
    pub mixer_workers: usize,
    /// Per-position checksums retained in `GenOutput::outs_checksum` (a
    /// ring of the last K values). `usize::MAX` (the default) keeps the
    /// full history; serving bounds it so month-long streaming sessions
    /// cannot grow without limit. The running total survives regardless
    /// as `GenOutput::checksum_total`.
    pub checksum_history: usize,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts {
            method: Method::Flash,
            tau: TauKind::Hybrid,
            threads: 0,
            sample_sigma: 0.0,
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            record_streams: false,
            half_store: false,
            async_mixer: true,
            split_min_u: 0,
            mixer_workers: 1,
            checksum_history: usize::MAX,
        }
    }
}

/// Result of one generation session.
#[derive(Debug)]
pub struct GenOutput {
    pub steps: usize,
    /// Sampled token ids `[B][steps]` (LM variant only).
    pub tokens: Option<Vec<Vec<u32>>>,
    /// The step artifact's `out` at the last position (`[B, W]`).
    pub last_out: Vec<f32>,
    /// Per-position checksum of `out` (cheap whole-trajectory equality) —
    /// the last `EngineOpts::checksum_history` positions.
    pub outs_checksum: Vec<f32>,
    /// Running sum of every per-position checksum, bounded retention or
    /// not (f64 so long sessions don't lose low bits to cancellation).
    pub checksum_total: f64,
    /// f32 values resident in the activation store (Appendix D accounting).
    pub resident_values: usize,
    pub metrics: SessionMetrics,
    pub flops: FlopCounter,
    /// Full `[G, steps, D]` streams tensor (when `record_streams`).
    pub streams: Option<Tensor>,
}

/// A loaded model ready to run generation sessions.
pub struct Engine<'rt> {
    rt: &'rt Runtime,
    pub cache: RhoCache<'rt>,
    step: BoundArtifact,
    opts: EngineOpts,
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt Runtime, opts: EngineOpts) -> Result<Engine<'rt>> {
        let cache = RhoCache::new(rt).context("build rho cache")?;
        let mut derived = std::collections::HashMap::new();
        derived.insert("@rho0".to_string(), cache.rho0_buf.clone());
        let step = BoundArtifact::bind(rt, "step", &derived).context("bind step artifact")?;
        Ok(Engine { rt, cache, step, opts })
    }

    pub fn opts(&self) -> &EngineOpts {
        &self.opts
    }

    pub fn runtime(&self) -> &'rt Runtime {
        self.rt
    }

    pub(crate) fn step_artifact(&self) -> &BoundArtifact {
        &self.step
    }

    /// Pre-compile/pre-derive everything a `len`-token session needs so the
    /// measured loop contains no one-time costs (benches and the server's
    /// engine worker call this before taking traffic).
    pub fn prewarm(&mut self, len: usize) -> Result<()> {
        let with_pjrt = matches!(
            self.opts.tau,
            TauKind::PjrtDirect | TauKind::PjrtFft | TauKind::Hybrid
        ) && self.opts.method == Method::Flash;
        if self.opts.method == Method::Flash {
            self.cache.prewarm(len / 2, with_pjrt)?;
        }
        Ok(())
    }

    pub(crate) fn make_sampler(&self) -> Result<Sampler> {
        let dims = self.rt.dims;
        Ok(match dims.variant {
            Variant::Synthetic => {
                Sampler::synthetic(self.opts.sample_sigma, self.opts.seed, dims.b)
            }
            Variant::Hyena => {
                let embed = self.rt.weights.get("embed")?.clone();
                Sampler::lm(self.opts.temperature, self.opts.top_k, embed, self.opts.seed, dims.b)
            }
        })
    }

    /// One lane's rollout-start input (`[D]`) — must mirror aot.py's
    /// golden rollout start exactly: synthetic: 1/sqrt(D); hyena:
    /// embedding of token 0. Identical for every lane, which is what lets
    /// `Session::admit` restart a single lane mid-batch.
    pub(crate) fn initial_lane_a0(&self) -> Result<Vec<f32>> {
        let dims = self.rt.dims;
        match dims.variant {
            Variant::Synthetic => Ok(vec![1.0 / (dims.d as f32).sqrt(); dims.d]),
            Variant::Hyena => Ok(self.rt.weights.get("embed")?.row(0).to_vec()),
        }
    }

    /// Initial `a0` for the whole batch (`[B, D]`).
    fn initial_a0(&self) -> Result<Vec<f32>> {
        let dims = self.rt.dims;
        let lane = self.initial_lane_a0()?;
        let mut a0 = vec![0.0; dims.b * dims.d];
        for bi in 0..dims.b {
            a0[bi * dims.d..(bi + 1) * dims.d].copy_from_slice(&lane);
        }
        Ok(a0)
    }

    /// Build a session pager sized for this model's lanes: slab blocks of
    /// `[M, rows_chunk, D]` (one lane's share of the `G = M·B` group
    /// axis), `capacity_mb` megabytes total. Checkpoints from any session
    /// over this engine fit its blocks by construction
    /// (`Session::suspend` / `Session::restore`, DESIGN.md §6).
    pub fn make_pager(&self, capacity_mb: usize) -> Pager {
        let dims = self.rt.dims;
        Pager::new(dims.g / dims.b, dims.d, pager::DEFAULT_ROWS_CHUNK, capacity_mb)
    }

    /// Start a resumable session with the default (sampled) rollout start.
    /// Drive it with [`Session::step`]; `generate` is exactly this plus a
    /// drain loop.
    pub fn session(&self, len: usize) -> Result<Session<'_, 'rt>> {
        let init = SessionInit { a0: self.initial_a0()?, ..Default::default() };
        Session::new(self, len, init)
    }

    /// Start a resumable teacher-forced session (see
    /// [`Engine::generate_teacher_forced`] for the forcing convention).
    pub fn session_teacher_forced(&self, len: usize, forced: &[f32]) -> Result<Session<'_, 'rt>> {
        let dims = self.rt.dims;
        let stride = dims.b * dims.d;
        if forced.is_empty() || forced.len() % stride != 0 {
            bail!("forced inputs must be a nonempty [T0, B, D] tensor");
        }
        let init = SessionInit {
            a0: forced[..stride].to_vec(),
            forced: Some(forced.to_vec()),
            ..Default::default()
        };
        Session::new(self, len, init)
    }

    /// Autoregressively generate `len` positions (power of two, ≤ L).
    pub fn generate(&mut self, len: usize) -> Result<GenOutput> {
        drain(self.session(len)?)
    }

    /// Teacher-forced generation: the first `forced.len()/(B·D)` inputs are
    /// taken from `forced` (`[T0, B, D]`) instead of the sampler. Used for
    /// prompt processing validation (paper §2.3.1's setting with P > 0) and
    /// for driving the model with real input sequences.
    pub fn generate_teacher_forced(&mut self, len: usize, forced: &[f32]) -> Result<GenOutput> {
        drain(self.session_teacher_forced(len, forced)?)
    }

    /// Prompt prefill (Massaroli et al. Lemma 2.1 / paper §2.3.1): run the
    /// `prefill_P` artifact over `prompt_emb` (`[B, P, D]`), seed the
    /// pending store with the prompt's aggregated future contributions,
    /// then "forget the prompt ever existed" and run Algorithm 2 with
    /// re-based indices for `gen_len` more positions.
    pub fn generate_with_prompt(&mut self, prompt_emb: &[f32], gen_len: usize) -> Result<GenOutput> {
        let dims = self.rt.dims;
        let (g, d, b) = (dims.g, dims.d, dims.b);
        let p = prompt_emb.len() / (b * d);
        if p * b * d != prompt_emb.len() {
            bail!("prompt must be a [B, P, D] tensor");
        }
        let spec = self
            .rt
            .manifest
            .best_prefill(p)
            .filter(|a| a.param == Some(p))
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no prefill artifact for P={p}; rebuild with `python -m compile.aot --prefill {p}`"
                )
            })?
            .clone();
        if gen_len + p > dims.l {
            bail!("prompt {p} + generation {gen_len} exceeds L={}", dims.l);
        }
        if self.opts.half_store {
            bail!("half_store + prompts is not supported (prompt contributions \
                   reach past the halved store)");
        }

        // bind + run prefill (weights resolved from model.bin, @rho derived)
        let mut derived = std::collections::HashMap::new();
        derived.insert("@rho".to_string(), self.cache.rho_buf()?);
        let prefill = BoundArtifact::bind(self.rt, &spec.name, &derived)?;
        let eb = self.rt.upload(prompt_emb, &[b, p, d])?;
        let outs = prefill.call(&[&eb])?;
        // outputs: streams [M,B,P,D] (discarded — the prompt is forgotten),
        // fut [M,B,L-P,D], out [B,W], scstate (hyena)
        let fut = Runtime::literal_to_vec(&outs[1], g * (dims.l - p) * d)?;
        let out0 = Runtime::literal_to_vec(&outs[2], b * dims.out_width())?;
        let scstate = match dims.variant {
            Variant::Hyena => Some(Runtime::literal_to_vec(
                &outs[3],
                dims.ops() * 2 * b * 3 * d,
            )?),
            Variant::Synthetic => None,
        };

        // the prompt's contribution to re-based position j is fut[:, j-1]
        let mut sampler = self.make_sampler()?;
        let mut a0 = vec![0.0f32; b * d];
        let first_tokens = sampler.next_a0(&out0, b, &mut a0)?;
        let init = SessionInit {
            a0,
            scstate_override: scstate,
            pending_seed: Some((fut, dims.l - p)),
            first_tokens,
            ..Default::default()
        };
        drain(Session::new(self, gen_len, init)?)
    }
}

/// The thin-driver contract: step a session to completion and collect its
/// output. Every `generate*` entry point is exactly this over its init.
fn drain(mut session: Session<'_, '_>) -> Result<GenOutput> {
    while !session.is_done() {
        session.step()?;
    }
    Ok(session.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in [Method::Flash, Method::Lazy, Method::Eager] {
            assert_eq!(Method::parse(m.as_str()).unwrap(), m);
        }
        assert!(Method::parse("speculative").is_err());
    }

    #[test]
    fn default_opts_are_flash_hybrid() {
        let o = EngineOpts::default();
        assert_eq!(o.method, Method::Flash);
        assert_eq!(o.tau, TauKind::Hybrid);
        assert_eq!(o.sample_sigma, 0.0);
        // async execution is the default for the native flash path, but
        // with splitting off (bit-identical numerics) and full history
        assert!(o.async_mixer);
        assert_eq!(o.split_min_u, 0);
        assert_eq!(o.mixer_workers, 1);
        assert_eq!(o.checksum_history, usize::MAX);
    }
}
