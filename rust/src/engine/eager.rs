//! Eager baseline (§3.1.1, Figure 1 bottom-left): as soon as a stream
//! value is produced, push its contribution to *every* future pending
//! position — O(L-i) MACs per lane at position i, Ω(L²) total.

use crate::tiling::FlopCounter;
use crate::util::tensor::{CellTensor, Tensor};

/// After `streams[:, i-1]` is written, accumulate
/// `pending[g, t-1] += streams[g, i-1] ⊙ rho[m, t-i]` for `t in (i, len]`.
pub fn eager_push(
    streams: &CellTensor,
    pending: &CellTensor,
    rho: &Tensor,
    b: usize,
    i: usize,
    len: usize,
    flops: &mut FlopCounter,
) {
    let (g, d) = (streams.shape()[0], streams.shape()[2]);
    if i >= len {
        return;
    }
    let span = len - i;
    for gi in 0..g {
        let m = gi / b;
        let y = streams.at2(gi, i - 1);
        // SAFETY: the eager method never runs async τ tiles — the engine
        // thread is the only writer, so the mutable view is exclusive.
        let dst = unsafe { pending.block_mut(gi, i, len) };
        let rseg = rho.block(m, 1, span + 1);
        for t in 0..span {
            let o = &mut dst[t * d..(t + 1) * d];
            let r = &rseg[t * d..(t + 1) * d];
            for k in 0..d {
                o[k] += y[k] * r[k];
            }
        }
    }
    flops.record_red(2 * span as u64 * g as u64 * d as u64);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pushes_to_all_future_positions() {
        let mut init = Tensor::zeros(&[1, 4, 1]);
        init.at2_mut(0, 0)[0] = 2.0;
        let streams = CellTensor::from_tensor(&init);
        let rho = Tensor::from_vec(&[1, 4, 1], vec![10.0, 100.0, 1000.0, 10000.0]).unwrap();
        let pending = CellTensor::zeros(&[1, 4, 1]);
        let mut fl = FlopCounter::new();
        eager_push(&streams, &pending, &rho, 1, 1, 4, &mut fl);
        // pending[t] = y1 * rho[t-1] for t = 2..4
        assert_eq!(pending.at2(0, 1)[0], 200.0);
        assert_eq!(pending.at2(0, 2)[0], 2000.0);
        assert_eq!(pending.at2(0, 3)[0], 20000.0);
        assert_eq!(pending.at2(0, 0)[0], 0.0);
        assert_eq!(fl.mixer_flops, 2 * 3);
    }

    #[test]
    fn last_position_pushes_nothing() {
        let streams = CellTensor::zeros(&[1, 2, 1]);
        let rho = Tensor::zeros(&[1, 2, 1]);
        let pending = CellTensor::zeros(&[1, 2, 1]);
        let mut fl = FlopCounter::new();
        eager_push(&streams, &pending, &rho, 1, 2, 2, &mut fl);
        assert_eq!(fl.mixer_flops, 0);
    }
}
