//! "…and Beyond": a non-convolutional mixer satisfying P.1 + P.2 — the
//! exponentially-decaying causal sum, `mixer(y)_j = Σ_{i<=j} γ^{j-i} y_i`
//! (a linear-attention / LTI-SSM-flavored primitive). Its efficient `A`
//! is *rank-1*: one pass builds `S_r = Σ γ^{r-i} y_i` over the source
//! range, every output is a scalar rescale — `O((L1+L2)·D)`, even better
//! than the FFT's `O((L1+L2) log(L1+L2) D)`. The framework only needs
//! *associativity*, not convolution structure (paper §4.2).

use super::mixer::ContributionMixer;
use crate::util::tensor::Tensor;

pub struct DecaySumMixer {
    pub gamma: f32,
    d: usize,
}

impl DecaySumMixer {
    pub fn new(gamma: f32, d: usize) -> DecaySumMixer {
        assert!((0.0..=1.0).contains(&gamma));
        DecaySumMixer { gamma, d }
    }
}

impl ContributionMixer for DecaySumMixer {
    type X = Vec<f32>;

    fn neutral(&self) -> Vec<f32> {
        vec![0.0; self.d]
    }

    fn agg(&self, acc: &mut Vec<f32>, inc: &Vec<f32>) {
        for (a, b) in acc.iter_mut().zip(inc) {
            *a += b;
        }
    }

    fn cont(&self, y: &Tensor, i: usize, j: usize) -> Vec<f32> {
        let w = self.gamma.powi((j - i) as i32);
        let yi = &y.data()[(i - 1) * self.d..i * self.d];
        yi.iter().map(|v| v * w).collect()
    }

    fn read(&self, x: &Vec<f32>) -> Vec<f32> {
        x.clone()
    }

    /// Rank-1 A: S_r = Σ_{i=l..r} γ^{r-i} y_i once, then out_p = γ^{p-r} S_r.
    fn range_contrib(&self, y: &Tensor, l: usize, r: usize, lp: usize, rp: usize) -> Vec<Vec<f32>> {
        let mut s = vec![0.0f32; self.d];
        for i in l..=r {
            let w = self.gamma.powi((r - i) as i32);
            let yi = &y.data()[(i - 1) * self.d..i * self.d];
            for (acc, v) in s.iter_mut().zip(yi) {
                *acc += v * w;
            }
        }
        (lp..=rp)
            .map(|p| {
                let w = self.gamma.powi((p - r) as i32);
                s.iter().map(|v| v * w).collect()
            })
            .collect()
    }
}
