//! Algorithm 4 — Generic Flash Inference — plus the lazy evaluator it is
//! checked against (Theorem 2: identical outputs, O(L log² L) calls to A).

use anyhow::{bail, Result};

use super::mixer::ContributionMixer;
use crate::tiling::{tile_side, Tile};
use crate::util::tensor::Tensor;

/// A stack of contribution mixers with element-wise blocks and a sampler.
pub struct GenericModel<M: ContributionMixer> {
    pub mixers: Vec<M>,
    /// `block(layer, read(b_{l,i})) -> a_{l,i}`.
    pub block: Box<dyn Fn(usize, &[f32]) -> Vec<f32>>,
    /// `sampler(a_{M,i}) -> a_{0,i+1}`.
    pub sampler: Box<dyn Fn(&[f32]) -> Vec<f32>>,
    pub d: usize,
}

/// Result of a generic run: activations per level (`a_0..a_M`, each
/// `[len, D]`) and the number of calls to `A` per layer.
pub struct GenericOutput {
    pub activations: Vec<Tensor>,
    pub a_calls: usize,
}

impl<M: ContributionMixer> GenericModel<M> {
    fn levels(&self) -> usize {
        self.mixers.len()
    }

    /// Algorithm 4. Requires P.2 of every mixer.
    pub fn generate_flash(&self, a01: &[f32], len: usize) -> Result<GenericOutput> {
        if let Some(bad) = self.mixers.iter().position(|m| !m.query_independent()) {
            bail!(
                "mixer {bad} is not query-independent (P.2) — the tiling would \
                 evaluate cont() before its query is available; use the lazy \
                 engine (for attention this is exactly KV-cache decoding)"
            );
        }
        if !len.is_power_of_two() {
            bail!("len must be a power of two");
        }
        let m = self.levels();
        let mut acts: Vec<Tensor> = (0..=m).map(|_| Tensor::zeros(&[len, self.d])).collect();
        // b[l][t] incrementally aggregates cont(a_{l-1}, ., t+1)
        let mut b: Vec<Vec<M::X>> = self
            .mixers
            .iter()
            .map(|mx| vec![mx.neutral(); len])
            .collect();
        let mut a_calls = 0;

        acts[0].row_mut(0).copy_from_slice(&a01[..self.d]);
        for i in 1..=len {
            for l in 1..=m {
                let mx = &self.mixers[l - 1];
                // red cell: cont(a_{l-1}, i, i)
                let inc = mx.cont(&acts[l - 1], i, i);
                mx.agg(&mut b[l - 1][i - 1], &inc);
                let read = mx.read(&b[l - 1][i - 1]);
                let a = (self.block)(l - 1, &read);
                acts[l].row_mut(i - 1).copy_from_slice(&a);
            }
            if i < len {
                // gray tile, parallel across layers (disjoint state)
                let u = tile_side(i);
                let tile = Tile::at(i);
                for l in 1..=m {
                    let mx = &self.mixers[l - 1];
                    let contribs =
                        mx.range_contrib(&acts[l - 1], tile.src_l, tile.src_r,
                                         tile.dst_l, tile.dst_r);
                    a_calls += 1;
                    for (k, c) in contribs.iter().enumerate() {
                        mx.agg(&mut b[l - 1][tile.dst_l - 1 + k], c);
                    }
                    debug_assert_eq!(contribs.len(), u);
                }
                // a_{0,i+1} = sampler(a_{M,i})
                let next = (self.sampler)(acts[m].row(i - 1));
                acts[0].row_mut(i).copy_from_slice(&next);
            }
        }
        Ok(GenericOutput { activations: acts, a_calls })
    }

    /// Lazy evaluation — works for any P.1 mixer (including attention).
    pub fn generate_lazy(&self, a01: &[f32], len: usize) -> Result<GenericOutput> {
        let m = self.levels();
        let mut acts: Vec<Tensor> = (0..=m).map(|_| Tensor::zeros(&[len, self.d])).collect();
        acts[0].row_mut(0).copy_from_slice(&a01[..self.d]);
        for i in 1..=len {
            for l in 1..=m {
                let mx = &self.mixers[l - 1];
                let mut acc = mx.neutral();
                for j in 1..=i {
                    mx.agg(&mut acc, &mx.cont(&acts[l - 1], j, i));
                }
                let a = (self.block)(l - 1, &mx.read(&acc));
                acts[l].row_mut(i - 1).copy_from_slice(&a);
            }
            if i < len {
                let next = (self.sampler)(acts[m].row(i - 1));
                acts[0].row_mut(i).copy_from_slice(&next);
            }
        }
        Ok(GenericOutput { activations: acts, a_calls: 0 })
    }
}

/// Row helpers for rank-2 tensors (position-major activations).
/// (`row` is used by the drivers above; the dead-code lint misfires on
/// trait methods in some compilation units, hence the allow.)
#[allow(dead_code)]
pub(crate) trait Rows {
    fn row(&self, r: usize) -> &[f32];
    fn row_mut(&mut self, r: usize) -> &mut [f32];
}

impl Rows for Tensor {
    fn row(&self, r: usize) -> &[f32] {
        let d = self.shape()[1];
        &self.data()[r * d..(r + 1) * d]
    }

    fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let d = self.shape()[1];
        &mut self.data_mut()[r * d..(r + 1) * d]
    }
}
