//! P.1 / P.2 (paper §4.1): the contribution-based mixer abstraction.
//!
//! A mixer is *contribution-based* (P.1) when
//!
//! ```text
//! mixer(y)_j = read( agg( cont(y,1,j), cont(y,2,j), ..., cont(y,j,j) ) )
//! ```
//!
//! for an associative `agg` over an intermediate state space `X`, and
//! *query-independent* (P.2) when `cont(y,i,j)` does not read `y_{i+1..}`.
//! Under P.1 + P.2 the fractal tiling applies black-box (Theorem 2); P.1
//! alone still admits the lazy evaluation (self-attention is the canonical
//! P.1-but-not-P.2 example — its KV decoding *is* the lazy algorithm).

use crate::util::tensor::Tensor;

/// A position-mixing layer in contribution form. Positions are 1-indexed
/// (row `t-1` of `y` holds position `t`), matching `tiling::Tile`.
pub trait ContributionMixer {
    /// Intermediate state X.
    type X: Clone;

    /// Identity element of `agg`.
    fn neutral(&self) -> Self::X;

    /// In-place associative aggregation: `acc = agg(acc, inc)`. Calls are
    /// made in ascending input order (associativity is assumed, not
    /// commutativity — the tiling preserves order, see Theorem 2's proof).
    fn agg(&self, acc: &mut Self::X, inc: &Self::X);

    /// Contribution of input position `i` to output position `j` (i <= j).
    fn cont(&self, y: &Tensor, i: usize, j: usize) -> Self::X;

    /// Map the aggregated state back to an embedding.
    fn read(&self, x: &Self::X) -> Vec<f32>;

    /// P.2: `cont(y, i, j)` reads only `y_{1..i}`. Mixers violating this
    /// (attention: `cont` needs the query at `j`) cannot use the tiling.
    fn query_independent(&self) -> bool {
        true
    }

    /// The black-box algorithm `A` (paper §4.2): aggregated contributions
    /// of `y[l..=r]` to every output position in `[lp..=rp]`, `r < lp`.
    /// Default is the brute-force O((r-l+1)(rp-lp+1)) evaluation; efficient
    /// mixers override it (LCSM: Lemma 1's FFT; decaying sum: rank-1).
    fn range_contrib(&self, y: &Tensor, l: usize, r: usize, lp: usize, rp: usize) -> Vec<Self::X> {
        debug_assert!(l <= r && r < lp && lp <= rp);
        (lp..=rp)
            .map(|p| {
                let mut acc = self.neutral();
                for i in l..=r {
                    self.agg(&mut acc, &self.cont(y, i, p));
                }
                acc
            })
            .collect()
    }
}
