//! Self-attention in contribution form (paper §4.1's P.1 example):
//! `X = (R^D, R)`, `agg = +`, `cont(y,i,j) = (v_i e^{<k_i, q_j>}, e^{<k_i,q_j>})`,
//! `read(v, w) = v / w`. It is contribution-based but **not**
//! query-independent — `cont` needs `q_j`, a function of `y_j` — so the
//! tiling cannot apply (P.2 fails); the lazy evaluator is exactly KV-cache
//! transformer decoding.

use super::mixer::ContributionMixer;
use crate::util::tensor::Tensor;

/// Single-head causal softmax attention with projection matrices `[D, D]`.
pub struct AttentionMixer {
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    d: usize,
}

impl AttentionMixer {
    pub fn new(wq: Tensor, wk: Tensor, wv: Tensor) -> AttentionMixer {
        let d = wq.shape()[0];
        assert_eq!(wq.shape(), &[d, d]);
        assert_eq!(wk.shape(), &[d, d]);
        assert_eq!(wv.shape(), &[d, d]);
        AttentionMixer { wq, wk, wv, d }
    }

    fn proj(&self, w: &Tensor, x: &[f32]) -> Vec<f32> {
        let d = self.d;
        (0..d)
            .map(|c| (0..d).map(|r| x[r] * w.data()[r * d + c]).sum())
            .collect()
    }

    fn y_row<'a>(&self, y: &'a Tensor, pos: usize) -> &'a [f32] {
        &y.data()[(pos - 1) * self.d..pos * self.d]
    }
}

impl ContributionMixer for AttentionMixer {
    /// (weighted value accumulator, weight mass) — read() is the softmax.
    type X = (Vec<f32>, f32);

    fn neutral(&self) -> Self::X {
        (vec![0.0; self.d], 0.0)
    }

    fn agg(&self, acc: &mut Self::X, inc: &Self::X) {
        for (a, b) in acc.0.iter_mut().zip(&inc.0) {
            *a += b;
        }
        acc.1 += inc.1;
    }

    fn cont(&self, y: &Tensor, i: usize, j: usize) -> Self::X {
        // q_j depends on y_j — this is the P.2 violation.
        let q = self.proj(&self.wq, self.y_row(y, j));
        let k = self.proj(&self.wk, self.y_row(y, i));
        let v = self.proj(&self.wv, self.y_row(y, i));
        let logit: f32 = q.iter().zip(&k).map(|(a, b)| a * b).sum::<f32>()
            / (self.d as f32).sqrt();
        let w = logit.exp();
        (v.into_iter().map(|x| x * w).collect(), w)
    }

    fn read(&self, x: &Self::X) -> Vec<f32> {
        x.0.iter().map(|v| v / x.1.max(1e-30)).collect()
    }

    fn query_independent(&self) -> bool {
        false
    }
}

/// Direct O(T²) causal softmax attention — oracle for the lazy evaluator.
pub fn attention_reference(mixer: &AttentionMixer, y: &Tensor) -> Tensor {
    let t = y.shape()[0];
    let d = mixer.d;
    let mut out = Tensor::zeros(&[t, d]);
    for j in 1..=t {
        let q = mixer.proj(&mixer.wq, mixer.y_row(y, j));
        let mut weights = Vec::with_capacity(j);
        for i in 1..=j {
            let k = mixer.proj(&mixer.wk, mixer.y_row(y, i));
            let logit: f32 =
                q.iter().zip(&k).map(|(a, b)| a * b).sum::<f32>() / (d as f32).sqrt();
            weights.push(logit.exp());
        }
        let z: f32 = weights.iter().sum();
        let row = &mut out.data_mut()[(j - 1) * d..j * d];
        for i in 1..=j {
            let v = mixer.proj(&mixer.wv, mixer.y_row(y, i));
            for (o, vv) in row.iter_mut().zip(&v) {
                *o += weights[i - 1] / z * vv;
            }
        }
    }
    out
}
