//! The LCSM instance of the framework (paper §4.1): `X = R^D`, `agg = +`,
//! `read = id`, `cont(y, i, j) = y_i ⊙ rho_{j-i}`, and `A` = Lemma 1's
//! range convolution.

use super::mixer::ContributionMixer;
use crate::util::tensor::Tensor;

/// Depthwise long-convolution mixer, filter `[L, D]`.
pub struct LcsmMixer {
    pub rho: Tensor,
    d: usize,
}

impl LcsmMixer {
    pub fn new(rho: Tensor) -> LcsmMixer {
        let d = rho.shape()[1];
        LcsmMixer { rho, d }
    }

    fn rho_row(&self, lag: usize) -> &[f32] {
        &self.rho.data()[lag * self.d..(lag + 1) * self.d]
    }

    fn y_row<'a>(&self, y: &'a Tensor, pos: usize) -> &'a [f32] {
        &y.data()[(pos - 1) * self.d..pos * self.d]
    }
}

impl ContributionMixer for LcsmMixer {
    type X = Vec<f32>;

    fn neutral(&self) -> Vec<f32> {
        vec![0.0; self.d]
    }

    fn agg(&self, acc: &mut Vec<f32>, inc: &Vec<f32>) {
        for (a, b) in acc.iter_mut().zip(inc) {
            *a += b;
        }
    }

    fn cont(&self, y: &Tensor, i: usize, j: usize) -> Vec<f32> {
        let yi = self.y_row(y, i);
        let r = self.rho_row(j - i);
        yi.iter().zip(r).map(|(a, b)| a * b).collect()
    }

    fn read(&self, x: &Vec<f32>) -> Vec<f32> {
        x.clone()
    }

    /// Lemma 1: one range convolution for the whole tile (here the direct
    /// kernel; the production engine uses the FFT variant — the framework
    /// only requires *some* efficient A).
    fn range_contrib(&self, y: &Tensor, l: usize, r: usize, lp: usize, rp: usize) -> Vec<Vec<f32>> {
        let u = r - l + 1;
        debug_assert_eq!(rp - lp + 1, u);
        debug_assert_eq!(lp, r + 1);
        let yblk = &y.data()[(l - 1) * self.d..r * self.d];
        let rho_seg = &self.rho.data()[..2 * u * self.d];
        let mut out = vec![0.0f32; u * self.d];
        crate::fft::tile_conv_direct_into(yblk, rho_seg, &mut out, self.d);
        out.chunks(self.d).map(|c| c.to_vec()).collect()
    }
}
