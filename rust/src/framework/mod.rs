//! Section 4: the Flash Inference *framework* — the paper's "and Beyond".
//!
//! Any mixer that is contribution-based (P.1) with an associative
//! aggregator and query-independent contributions (P.2) admits the fractal
//! tiling black-box (Theorem 2 / Algorithm 4). This module provides the
//! abstraction, the generic driver, and three instances:
//!
//! * [`lcsm::LcsmMixer`]      — the paper's main subject (Lemma-1 A);
//! * [`wsum::DecaySumMixer`]  — a non-convolutional P.1+P.2 mixer with an
//!   O((L1+L2)D) rank-1 A, showing the framework is broader than FFTs;
//! * [`attention::AttentionMixer`] — P.1 but NOT P.2: the driver rejects
//!   it for tiling, and its lazy evaluation is precisely KV-cache decoding.

pub mod attention;
pub mod generic;
pub mod lcsm;
pub mod mixer;
pub mod wsum;

pub use attention::AttentionMixer;
pub use generic::{GenericModel, GenericOutput};
pub use lcsm::LcsmMixer;
pub use mixer::ContributionMixer;
pub use wsum::DecaySumMixer;
