//! Metrics: per-token breakdown timers (the mixer / non-mixer split every
//! figure in §5 is built on) and request-level counters for the server.

pub mod histogram;

pub use histogram::LatencyRecorder;

use std::time::Duration;

/// Per-generation-session timing breakdown.
///
/// * `mixer` — gray-tile τ work *on the critical path*: the synchronous τ
///   call (or lazy/eager pending accumulation), plus — under the async
///   executor — the submission cost and the urgent split-tile column;
/// * `fence` — critical-path stall waiting for asynchronously submitted τ
///   tiles to land (the *exposed* part of the async mixer cost);
/// * `tau_worker` — async τ compute spent on the executor worker, off the
///   critical path (the overlap candidate; `hidden_mixer_ns` is the part
///   that actually hid behind red-path work);
/// * `step` — red cells + blocks + head (the PJRT `step` call and its
///   staging);
/// * `sample` — token sampling + re-embedding.
///
/// `total_ns` is the critical-path time (`tau_worker` excluded); the
/// sync path has `fence == tau_worker == 0`, so its totals are unchanged.
#[derive(Debug, Default, Clone)]
pub struct Breakdown {
    pub mixer_ns: f64,
    pub fence_ns: f64,
    pub tau_worker_ns: f64,
    pub step_ns: f64,
    pub sample_ns: f64,
}

impl Breakdown {
    /// Critical-path time of the position (off-path worker time excluded).
    pub fn total_ns(&self) -> f64 {
        self.mixer_ns + self.fence_ns + self.step_ns + self.sample_ns
    }

    pub fn non_mixer_ns(&self) -> f64 {
        self.step_ns + self.sample_ns
    }

    /// All mixer compute, wherever it ran (critical path + worker) — the
    /// quantity Fig 2b/3b plot, invariant to sync-vs-async execution.
    pub fn mixer_total_ns(&self) -> f64 {
        self.mixer_ns + self.fence_ns + self.tau_worker_ns
    }

    /// Worker-side τ time that the fence did *not* expose — mixer work
    /// genuinely overlapped with (hidden behind) the red critical path.
    pub fn hidden_mixer_ns(&self) -> f64 {
        (self.tau_worker_ns - self.fence_ns).max(0.0)
    }

    pub fn add(&mut self, other: &Breakdown) {
        self.mixer_ns += other.mixer_ns;
        self.fence_ns += other.fence_ns;
        self.tau_worker_ns += other.tau_worker_ns;
        self.step_ns += other.step_ns;
        self.sample_ns += other.sample_ns;
    }
}

/// Full per-session metrics: one breakdown entry per generated position
/// (Fig 2c = `per_token`), plus cumulative sums.
#[derive(Debug, Default, Clone)]
pub struct SessionMetrics {
    pub per_token: Vec<Breakdown>,
    pub totals: Breakdown,
    pub wall: Duration,
}

impl SessionMetrics {
    pub fn with_capacity(n: usize) -> SessionMetrics {
        SessionMetrics { per_token: Vec::with_capacity(n), ..Default::default() }
    }

    pub fn push(&mut self, b: Breakdown) {
        self.totals.add(&b);
        self.per_token.push(b);
    }

    /// Cumulative mixer time series (Fig 2b / 3b y-axis). Uses
    /// [`Breakdown::mixer_total_ns`] so the series measures mixer FLOPs
    /// regardless of whether they ran on the critical path or were hidden
    /// on the async executor worker.
    pub fn cumulative_mixer_ns(&self) -> Vec<f64> {
        let mut acc = 0.0;
        self.per_token
            .iter()
            .map(|b| {
                acc += b.mixer_total_ns();
                acc
            })
            .collect()
    }

    /// Total per-token latency series (Fig 2c y-axis).
    pub fn token_latencies_ns(&self) -> Vec<f64> {
        self.per_token.iter().map(Breakdown::total_ns).collect()
    }
}

/// Monotonic counters + scheduler gauges for the server (`GET /metrics`).
#[derive(Debug, Default)]
pub struct ServerCounters {
    pub requests_total: u64,
    pub requests_failed: u64,
    /// Requests rejected at the front door because the waiting queue was
    /// at `max_queue` (HTTP 429).
    pub requests_shed: u64,
    pub tokens_generated: u64,
    pub batches_run: u64,
    /// Requests served in streaming (chunked NDJSON) mode.
    pub stream_requests: u64,
    /// Per-position events actually delivered to streaming lanes (early
    /// stop means this can be less than steps x lanes).
    pub stream_events: u64,
    /// Requests seeded into a lane (at session start or mid-batch).
    pub admissions_total: u64,
    /// Admissions into a batch that had already advanced past position 0
    /// — the continuous-admission path proper.
    pub admissions_mid_batch: u64,
    /// Generation sessions the scheduler has opened.
    pub sessions_started: u64,
    /// Busy lanes checkpointed into the session pager under queue
    /// pressure (each eviction freed a lane for a waiting request).
    pub evictions_total: u64,
    /// Evicted lanes restored from the pager and run to completion.
    pub resumes_total: u64,
    /// Suspends that took the position-independent fold path (history
    /// deposited onto pending columns; resumable at any step boundary).
    pub folds_total: u64,
    /// Resident checkpoints serialized and spilled to the disk tier under
    /// slab capacity pressure.
    pub spills_total: u64,
    /// Spilled checkpoints reloaded from disk (scheduler resume or
    /// session-key intake after a restart).
    pub spill_reloads_total: u64,
    /// Checkpoints serialized and shipped off a quarantined replica over
    /// the failback channel for re-homing on a healthy replica.
    pub checkpoints_shipped_total: u64,
    /// Gauge: f32 values held by live checkpoints in the session pager.
    pub pager_resident_values: u64,
    /// Gauge: requests waiting for a free lane right now.
    pub queue_depth: u64,
    /// Gauges: busy lanes / total lanes (B) in the running session.
    pub lanes_busy: u64,
    pub lanes_total: u64,
    /// Engine panics absorbed by the supervisor (session torn down and
    /// rebuilt; serving continued).
    pub engine_restarts_total: u64,
    /// Replica workers respawned after quarantine (fleet mode): distinct
    /// from `engine_restarts_total`, which counts in-place session
    /// rebuilds inside a still-running worker.
    pub replica_restarts_total: u64,
    /// Queued requests re-dispatched to a healthy replica after their
    /// replica was quarantined (retried-iff-zero-tokens).
    pub failovers_total: u64,
    /// Lanes failed with a structured error — engine panics/errors,
    /// deadline expiry, disconnects, and shutdown stragglers all count.
    pub lanes_failed_total: u64,
    /// Requests failed because their per-request deadline expired.
    pub requests_deadline_exceeded: u64,
    /// Lanes cancelled because the client hung up mid-generation.
    pub clients_disconnected: u64,
    /// Connections shed with 503 at the accept loop (`fi-conn` cap).
    pub conn_shed_total: u64,
    /// Gauge: 1 while the restart budget holds, 0 once exceeded (latched;
    /// `/health` mirrors this as 200 vs 503).
    pub healthy: u64,
    pub request_latency: LatencyRecorder,
    /// Enqueue → admission wait (the latency continuous admission is
    /// supposed to shrink versus drain-then-refill). Recorded by the
    /// scheduler for every admission — the single queue-wait family
    /// (the old front-end `fi_queue_latency_*` measured the same wait
    /// from the connection side and was retired with the scheduler).
    pub admission_latency: LatencyRecorder,
}

impl ServerCounters {
    pub fn new() -> ServerCounters {
        ServerCounters {
            healthy: 1,
            request_latency: LatencyRecorder::reservoir(4096),
            admission_latency: LatencyRecorder::reservoir(4096),
            ..Default::default()
        }
    }

    /// Prometheus-style text exposition.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut metric = |name: &str, help: &str, v: f64| {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"));
        };
        metric("fi_requests_total", "requests accepted", self.requests_total as f64);
        metric("fi_requests_failed", "requests failed", self.requests_failed as f64);
        metric("fi_requests_shed", "requests shed with 429", self.requests_shed as f64);
        metric("fi_tokens_generated", "tokens generated", self.tokens_generated as f64);
        metric("fi_batches_run", "generation batches run", self.batches_run as f64);
        metric("fi_stream_requests", "streaming requests served", self.stream_requests as f64);
        metric("fi_stream_events", "per-position events streamed", self.stream_events as f64);
        metric("fi_admissions_total", "requests admitted", self.admissions_total as f64);
        metric(
            "fi_admissions_mid_batch",
            "admissions into an already-running batch",
            self.admissions_mid_batch as f64,
        );
        metric("fi_sessions_started", "generation sessions opened", self.sessions_started as f64);
        metric(
            "fi_evictions_total",
            "lanes checkpointed to the pager under queue pressure",
            self.evictions_total as f64,
        );
        metric("fi_resumes_total", "evicted lanes restored", self.resumes_total as f64);
        metric(
            "fi_folds_total",
            "suspends that took the position-independent fold path",
            self.folds_total as f64,
        );
        metric(
            "fi_spills_total",
            "checkpoints spilled to the disk tier",
            self.spills_total as f64,
        );
        metric(
            "fi_spill_reloads_total",
            "spilled checkpoints reloaded from disk",
            self.spill_reloads_total as f64,
        );
        metric(
            "fi_checkpoints_shipped_total",
            "checkpoints shipped off a quarantined replica",
            self.checkpoints_shipped_total as f64,
        );
        metric(
            "fi_engine_restarts_total",
            "engine panics absorbed by the supervisor",
            self.engine_restarts_total as f64,
        );
        metric(
            "fi_replica_restarts_total",
            "replica workers respawned after quarantine",
            self.replica_restarts_total as f64,
        );
        metric(
            "fi_failovers_total",
            "queued requests re-dispatched after a replica quarantine",
            self.failovers_total as f64,
        );
        metric(
            "fi_lanes_failed_total",
            "lanes failed with a structured error",
            self.lanes_failed_total as f64,
        );
        metric(
            "fi_requests_deadline_exceeded",
            "requests failed on their per-request deadline",
            self.requests_deadline_exceeded as f64,
        );
        metric(
            "fi_clients_disconnected",
            "lanes cancelled after the client hung up",
            self.clients_disconnected as f64,
        );
        metric(
            "fi_conn_shed_total",
            "connections shed at the fi-conn thread cap",
            self.conn_shed_total as f64,
        );
        metric("fi_healthy", "1 while the restart budget holds, else 0", self.healthy as f64);
        metric(
            "fi_pager_resident_values",
            "f32 values held by live pager checkpoints",
            self.pager_resident_values as f64,
        );
        metric("fi_queue_depth", "requests waiting for a lane", self.queue_depth as f64);
        metric("fi_lanes_busy", "lanes serving a request", self.lanes_busy as f64);
        metric("fi_lanes_total", "batch lanes available (B)", self.lanes_total as f64);
        let occupancy = if self.lanes_total > 0 {
            100.0 * self.lanes_busy as f64 / self.lanes_total as f64
        } else {
            0.0
        };
        metric("fi_lane_occupancy_pct", "busy lanes as a percent of B", occupancy);
        metric("fi_request_latency_p50_ms", "request latency p50", self.request_latency.percentile_ns(50.0) / 1e6);
        metric("fi_request_latency_p99_ms", "request latency p99", self.request_latency.percentile_ns(99.0) / 1e6);
        metric(
            "fi_admission_latency_p50_ms",
            "enqueue-to-admission wait p50",
            self.admission_latency.percentile_ns(50.0) / 1e6,
        );
        metric(
            "fi_admission_latency_p99_ms",
            "enqueue-to-admission wait p99",
            self.admission_latency.percentile_ns(99.0) / 1e6,
        );
        out
    }
}

/// Shared, poison-tolerant handle to the server counters.
///
/// Every HTTP handler and the engine thread funnel through [`lock`]; if a
/// holder ever panicked mid-update, the counters would be at worst one
/// increment off — not worth cascading `PoisonError` panics into every
/// `/metrics` scrape and request handler, so the guard is recovered.
///
/// [`lock`]: Counters::lock
#[derive(Clone)]
pub struct Counters(std::sync::Arc<std::sync::Mutex<ServerCounters>>);

impl Counters {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Counters {
        Counters(std::sync::Arc::new(std::sync::Mutex::new(ServerCounters::new())))
    }

    pub fn lock(&self) -> std::sync::MutexGuard<'_, ServerCounters> {
        self.0.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_sums() {
        let mut m = SessionMetrics::with_capacity(2);
        m.push(Breakdown { mixer_ns: 10.0, step_ns: 5.0, sample_ns: 1.0, ..Default::default() });
        m.push(Breakdown { mixer_ns: 20.0, step_ns: 5.0, sample_ns: 1.0, ..Default::default() });
        assert_eq!(m.totals.total_ns(), 42.0);
        assert_eq!(m.totals.non_mixer_ns(), 12.0);
        assert_eq!(m.cumulative_mixer_ns(), vec![10.0, 30.0]);
        assert_eq!(m.token_latencies_ns(), vec![16.0, 26.0]);
    }

    #[test]
    fn async_breakdown_splits_exposed_and_hidden() {
        // async step: 2ns submit+urgent on path, 3ns fence stall, 9ns of
        // worker-side tau, 5ns red step, 1ns sampling
        let b = Breakdown {
            mixer_ns: 2.0,
            fence_ns: 3.0,
            tau_worker_ns: 9.0,
            step_ns: 5.0,
            sample_ns: 1.0,
        };
        // critical path excludes worker time but includes the fence stall
        assert_eq!(b.total_ns(), 11.0);
        // mixer compute is invariant to where it ran
        assert_eq!(b.mixer_total_ns(), 14.0);
        // 9ns ran on the worker, 3ns of it was re-exposed by the fence
        assert_eq!(b.hidden_mixer_ns(), 6.0);

        // a fully-exposed fence hides nothing
        let worst = Breakdown { fence_ns: 9.0, tau_worker_ns: 4.0, ..Default::default() };
        assert_eq!(worst.hidden_mixer_ns(), 0.0);

        let mut totals = Breakdown::default();
        totals.add(&b);
        totals.add(&worst);
        assert_eq!(totals.fence_ns, 12.0);
        assert_eq!(totals.tau_worker_ns, 13.0);
    }

    #[test]
    fn counters_render_prometheus_text() {
        let mut c = ServerCounters::new();
        c.requests_total = 3;
        c.stream_requests = 1;
        c.stream_events = 5;
        c.request_latency.record_ns(1e6);
        let text = c.render();
        assert!(text.contains("fi_requests_total 3"));
        assert!(text.contains("fi_stream_requests 1"));
        assert!(text.contains("fi_stream_events 5"));
        assert!(text.contains("# TYPE fi_request_latency_p50_ms gauge"));
    }

    #[test]
    fn admission_counters_render() {
        let mut c = ServerCounters::new();
        c.admissions_total = 7;
        c.admissions_mid_batch = 3;
        c.sessions_started = 2;
        c.queue_depth = 4;
        c.lanes_busy = 3;
        c.lanes_total = 4;
        c.admission_latency.record_ns(2e6);
        let text = c.render();
        assert!(text.contains("fi_admissions_total 7"));
        assert!(text.contains("fi_admissions_mid_batch 3"));
        assert!(text.contains("fi_sessions_started 2"));
        assert!(text.contains("fi_queue_depth 4"));
        assert!(text.contains("fi_lane_occupancy_pct 75"));
        assert!(text.contains("fi_admission_latency_p50_ms 2"));
    }

    #[test]
    fn robustness_counters_render() {
        let mut c = ServerCounters::new();
        assert_eq!(c.healthy, 1, "servers start healthy");
        c.engine_restarts_total = 2;
        c.lanes_failed_total = 3;
        c.requests_deadline_exceeded = 1;
        c.clients_disconnected = 4;
        c.conn_shed_total = 6;
        c.healthy = 0;
        let text = c.render();
        assert!(text.contains("fi_engine_restarts_total 2"));
        assert!(text.contains("fi_lanes_failed_total 3"));
        assert!(text.contains("fi_requests_deadline_exceeded 1"));
        assert!(text.contains("fi_clients_disconnected 4"));
        assert!(text.contains("fi_conn_shed_total 6"));
        assert!(text.contains("fi_healthy 0"));
    }

    #[test]
    fn fleet_counters_render() {
        let mut c = ServerCounters::new();
        c.replica_restarts_total = 2;
        c.failovers_total = 5;
        let text = c.render();
        assert!(text.contains("fi_replica_restarts_total 2"));
        assert!(text.contains("fi_failovers_total 5"));
        // the fleet counters render even at zero so dashboards can rely
        // on the series existing in single-replica mode too
        let text = ServerCounters::new().render();
        assert!(text.contains("fi_replica_restarts_total 0"));
        assert!(text.contains("fi_failovers_total 0"));
    }

    #[test]
    fn counters_survive_a_poisoned_holder() {
        let c = Counters::new();
        c.lock().requests_total = 1;
        // a panic while holding the lock poisons the mutex ...
        let c2 = c.clone();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let _g = c2.lock();
            panic!("handler died mid-update");
        }));
        assert!(r.is_err());
        // ... and every later holder still gets through
        c.lock().requests_total += 1;
        assert_eq!(c.lock().requests_total, 2);
        assert!(c.lock().render().contains("fi_requests_total 2"));
    }

    #[test]
    fn paging_counters_render() {
        let mut c = ServerCounters::new();
        c.evictions_total = 5;
        c.resumes_total = 4;
        c.pager_resident_values = 8192;
        let text = c.render();
        assert!(text.contains("fi_evictions_total 5"));
        assert!(text.contains("fi_resumes_total 4"));
        assert!(text.contains("fi_pager_resident_values 8192"));
    }

    #[test]
    fn checkpoint_counters_render() {
        let mut c = ServerCounters::new();
        c.folds_total = 3;
        c.spills_total = 2;
        c.spill_reloads_total = 2;
        c.checkpoints_shipped_total = 1;
        let text = c.render();
        assert!(text.contains("fi_folds_total 3"));
        assert!(text.contains("fi_spills_total 2"));
        assert!(text.contains("fi_spill_reloads_total 2"));
        assert!(text.contains("fi_checkpoints_shipped_total 1"));
        // series exist at zero so dashboards can rely on them even when
        // folding/spilling/shipping never triggered
        let text = ServerCounters::new().render();
        assert!(text.contains("fi_folds_total 0"));
        assert!(text.contains("fi_checkpoints_shipped_total 0"));
    }
}
