//! Log-bucketed latency histogram + exact-percentile recorder.

/// Records raw samples (ns) and serves percentiles/summaries.
/// For the request-level server metrics a bounded reservoir keeps memory
/// constant; per-token traces (Fig 2c) use `samples()` directly.
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    samples: Vec<f64>,
    /// 0 = unbounded (bench traces); otherwise reservoir size.
    cap: usize,
    seen: u64,
    sum_ns: f64,
    max_ns: f64,
}

impl LatencyRecorder {
    pub fn unbounded() -> LatencyRecorder {
        LatencyRecorder { samples: Vec::new(), cap: 0, seen: 0, sum_ns: 0.0, max_ns: 0.0 }
    }

    pub fn reservoir(cap: usize) -> LatencyRecorder {
        LatencyRecorder { samples: Vec::with_capacity(cap), cap, seen: 0, sum_ns: 0.0, max_ns: 0.0 }
    }

    pub fn record_ns(&mut self, ns: f64) {
        self.seen += 1;
        self.sum_ns += ns;
        self.max_ns = self.max_ns.max(ns);
        if self.cap == 0 || self.samples.len() < self.cap {
            self.samples.push(ns);
        } else {
            // reservoir sampling with deterministic stride (metrics only)
            let idx = (self.seen as usize * 2654435761) % self.cap;
            self.samples[idx] = ns;
        }
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as f64);
    }

    pub fn count(&self) -> u64 {
        self.seen
    }

    pub fn mean_ns(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.sum_ns / self.seen as f64
        }
    }

    pub fn total_ns(&self) -> f64 {
        self.sum_ns
    }

    pub fn max_ns(&self) -> f64 {
        self.max_ns
    }

    pub fn percentile_ns(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_max_percentiles() {
        let mut r = LatencyRecorder::unbounded();
        for v in [10.0, 20.0, 30.0, 40.0, 100.0] {
            r.record_ns(v);
        }
        assert_eq!(r.count(), 5);
        assert_eq!(r.mean_ns(), 40.0);
        assert_eq!(r.max_ns(), 100.0);
        assert_eq!(r.percentile_ns(0.0), 10.0);
        assert_eq!(r.percentile_ns(50.0), 30.0);
        assert_eq!(r.percentile_ns(100.0), 100.0);
    }

    #[test]
    fn reservoir_stays_bounded() {
        let mut r = LatencyRecorder::reservoir(16);
        for i in 0..10_000 {
            r.record_ns(i as f64);
        }
        assert_eq!(r.count(), 10_000);
        assert_eq!(r.samples().len(), 16);
        assert_eq!(r.max_ns(), 9999.0);
    }

    #[test]
    fn empty_recorder_is_zero() {
        let r = LatencyRecorder::unbounded();
        assert_eq!(r.mean_ns(), 0.0);
        assert_eq!(r.percentile_ns(99.0), 0.0);
    }
}
