//! Model dimensions/ABI as read from the artifact manifest.

use crate::util::json::Json;
use anyhow::{bail, Result};

/// Which model family the artifacts implement (paper §5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// §5 synthetic: MLP blocks, sampler = last activation (+ noise).
    Synthetic,
    /// §5.1 Hyena: order-3 operators, gated mixers, LM head.
    Hyena,
}

impl Variant {
    pub fn parse(s: &str) -> Result<Variant> {
        match s {
            "synthetic" => Ok(Variant::Synthetic),
            "hyena" => Ok(Variant::Hyena),
            other => bail!("unknown model variant '{other}'"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Variant::Synthetic => "synthetic",
            Variant::Hyena => "hyena",
        }
    }
}

/// Static dimensions of one artifact build (shapes are baked into HLO).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelDims {
    pub variant: Variant,
    /// Mixer layers.
    pub m: usize,
    /// Embedding dim.
    pub d: usize,
    /// Block MLP hidden dim.
    pub h: usize,
    /// Max sequence length (power of two); tau artifacts exist for
    /// U in {1, 2, .., L/2}.
    pub l: usize,
    /// Batch lanes stepped in lockstep.
    pub b: usize,
    /// Vocab (hyena LM head).
    pub v: usize,
    /// Fused tile group axis: b * m.
    pub g: usize,
}

impl ModelDims {
    pub fn from_json(j: &Json) -> Result<ModelDims> {
        let dims = ModelDims {
            variant: Variant::parse(j.req_str("variant")?)?,
            m: j.req_usize("M")?,
            d: j.req_usize("D")?,
            h: j.req_usize("H")?,
            l: j.req_usize("L")?,
            b: j.req_usize("B")?,
            v: j.req_usize("V")?,
            g: j.req_usize("G")?,
        };
        dims.validate()?;
        Ok(dims)
    }

    pub fn validate(&self) -> Result<()> {
        if !self.l.is_power_of_two() {
            bail!("L={} must be a power of two", self.l);
        }
        if self.g != self.b * self.m {
            bail!("G={} != B*M={}", self.g, self.b * self.m);
        }
        if self.variant == Variant::Hyena && self.m % 2 != 0 {
            bail!("hyena needs even M, got {}", self.m);
        }
        Ok(())
    }

    /// Hyena operators (M/2).
    pub fn ops(&self) -> usize {
        self.m / 2
    }

    /// Output width of the step artifact: D (synthetic) or V (hyena logits).
    pub fn out_width(&self) -> usize {
        match self.variant {
            Variant::Synthetic => self.d,
            Variant::Hyena => self.v,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_json() -> Json {
        Json::parse(
            r#"{"variant": "synthetic", "M": 6, "D": 64, "H": 128,
                "L": 4096, "B": 1, "V": 256, "G": 6}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_valid_config() {
        let dims = ModelDims::from_json(&base_json()).unwrap();
        assert_eq!(dims.m, 6);
        assert_eq!(dims.out_width(), 64);
        assert_eq!(dims.variant, Variant::Synthetic);
    }

    #[test]
    fn rejects_non_pow2_l() {
        let mut j = base_json();
        j.set("L", Json::Num(100.0));
        assert!(ModelDims::from_json(&j).is_err());
    }

    #[test]
    fn rejects_bad_g() {
        let mut j = base_json();
        j.set("G", Json::Num(7.0));
        assert!(ModelDims::from_json(&j).is_err());
    }

    #[test]
    fn hyena_out_width_is_vocab() {
        let mut j = base_json();
        j.set("variant", Json::Str("hyena".into()));
        let dims = ModelDims::from_json(&j).unwrap();
        assert_eq!(dims.out_width(), 256);
        assert_eq!(dims.ops(), 3);
    }

    #[test]
    fn variant_roundtrip() {
        for v in [Variant::Synthetic, Variant::Hyena] {
            assert_eq!(Variant::parse(v.as_str()).unwrap(), v);
        }
        assert!(Variant::parse("gpt").is_err());
    }
}
