//! tensorbin v1 reader — the weight half of the aot.py ↔ rust ABI.
//! Format documented in python/compile/tensorbin.py.

use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::tensor::Tensor;

const MAGIC: &[u8; 8] = b"FTBIN1\x00\x00";

/// Named weight tensors loaded from model.bin.
#[derive(Debug)]
pub struct Weights {
    tensors: HashMap<String, Tensor>,
}

impl Weights {
    pub fn load(path: &Path) -> Result<Weights> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open weights {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic).context("read magic")?;
        if &magic != MAGIC {
            bail!("{}: bad tensorbin magic {:?}", path.display(), magic);
        }
        let mut lenb = [0u8; 8];
        f.read_exact(&mut lenb).context("read header len")?;
        let hlen = u64::from_le_bytes(lenb) as usize;
        let mut header = vec![0u8; hlen];
        f.read_exact(&mut header).context("read header")?;
        let header: Json = Json::parse(std::str::from_utf8(&header)?)
            .map_err(|e| anyhow::anyhow!("tensorbin header: {e}"))?;
        let mut data = Vec::new();
        f.read_to_end(&mut data).context("read data")?;

        let mut tensors = HashMap::new();
        for e in header.req_arr("tensors")? {
            let name = e.req_str("name")?.to_string();
            let dtype = e.req_str("dtype")?;
            if dtype != "f32" {
                bail!("tensor '{name}': unsupported dtype {dtype}");
            }
            let shape: Vec<usize> = e
                .req_arr("shape")?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad shape")))
                .collect::<Result<_>>()?;
            let offset = e.req_usize("offset")?;
            let nbytes = e.req_usize("nbytes")?;
            if offset + nbytes > data.len() {
                bail!("tensor '{name}' overruns data section");
            }
            if nbytes % 4 != 0 {
                bail!("tensor '{name}' nbytes not a multiple of 4");
            }
            let floats: Vec<f32> = data[offset..offset + nbytes]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(name.clone(), Tensor::from_vec(&shape, floats)
                .with_context(|| format!("tensor '{name}'"))?);
        }
        Ok(Weights { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing weight tensor '{name}'"))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// Mirror of the python writer, for round-trip tests.
    pub fn write_tensorbin(path: &Path, tensors: &[(&str, &[usize], &[f32])]) {
        let mut entries = Vec::new();
        let mut blobs: Vec<u8> = Vec::new();
        let mut sorted: Vec<_> = tensors.to_vec();
        sorted.sort_by_key(|(n, _, _)| n.to_string());
        for (name, shape, data) in sorted {
            let offset = blobs.len();
            for v in data {
                blobs.extend_from_slice(&v.to_le_bytes());
            }
            let shape_json = shape.iter().map(|&s| s.to_string()).collect::<Vec<_>>().join(",");
            entries.push(format!(
                r#"{{"name":"{name}","shape":[{shape_json}],"dtype":"f32","offset":{offset},"nbytes":{}}}"#,
                data.len() * 4
            ));
        }
        let header = format!(r#"{{"tensors":[{}]}}"#, entries.join(","));
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(MAGIC).unwrap();
        f.write_all(&(header.len() as u64).to_le_bytes()).unwrap();
        f.write_all(header.as_bytes()).unwrap();
        f.write_all(&blobs).unwrap();
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("fi_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        write_tensorbin(
            &path,
            &[
                ("a", &[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
                ("b.c", &[1], &[-0.5]),
            ],
        );
        let w = Weights::load(&path).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w.get("a").unwrap().shape(), &[2, 3]);
        assert_eq!(w.get("a").unwrap().data()[4], 5.0);
        assert_eq!(w.get("b.c").unwrap().data(), &[-0.5]);
        assert!(w.get("missing").is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("fi_weights_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTMAGIC________").unwrap();
        assert!(Weights::load(&path).is_err());
    }

    #[test]
    fn rejects_overrun_offsets() {
        let dir = std::env::temp_dir().join("fi_weights_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("overrun.bin");
        let header = r#"{"tensors":[{"name":"x","shape":[8],"dtype":"f32","offset":0,"nbytes":32}]}"#;
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(MAGIC).unwrap();
        f.write_all(&(header.len() as u64).to_le_bytes()).unwrap();
        f.write_all(header.as_bytes()).unwrap();
        f.write_all(&[0u8; 4]).unwrap(); // only 4 bytes of data, not 32
        assert!(Weights::load(&path).is_err());
    }
}
