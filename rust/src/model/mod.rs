//! Model ABI: dimensions (from the manifest) and weights (tensorbin).

pub mod spec;
pub mod weights;

pub use spec::{ModelDims, Variant};
pub use weights::Weights;
