//! Synthetic request traces: Poisson arrivals + length distributions.

use crate::util::prng::Prng;

/// One request in a trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestSpec {
    /// Arrival offset from trace start, seconds.
    pub arrival_s: f64,
    pub max_tokens: usize,
}

/// Trace shape parameters.
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    /// Mean arrival rate (requests/second).
    pub rate: f64,
    pub num_requests: usize,
    /// Token-count distribution: log-uniform over [min_tokens, max_tokens].
    pub min_tokens: usize,
    pub max_tokens: usize,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { rate: 2.0, num_requests: 32, min_tokens: 16, max_tokens: 256, seed: 0 }
    }
}

/// A generated trace (sorted by arrival time).
#[derive(Debug, Clone)]
pub struct WorkloadTrace {
    pub requests: Vec<RequestSpec>,
}

impl WorkloadTrace {
    pub fn generate(cfg: TraceConfig) -> WorkloadTrace {
        assert!(cfg.min_tokens >= 1 && cfg.min_tokens <= cfg.max_tokens);
        let mut rng = Prng::new(cfg.seed);
        let mut t = 0.0;
        let lo = (cfg.min_tokens as f64).ln();
        let hi = (cfg.max_tokens as f64).ln();
        let requests = (0..cfg.num_requests)
            .map(|_| {
                t += rng.exponential(cfg.rate);
                let tokens = (lo + rng.uniform() * (hi - lo)).exp().round() as usize;
                RequestSpec {
                    arrival_s: t,
                    max_tokens: tokens.clamp(cfg.min_tokens, cfg.max_tokens),
                }
            })
            .collect();
        WorkloadTrace { requests }
    }

    pub fn duration_s(&self) -> f64 {
        self.requests.last().map(|r| r.arrival_s).unwrap_or(0.0)
    }

    pub fn total_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.max_tokens).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_sorted_arrivals_in_range() {
        let cfg = TraceConfig { rate: 10.0, num_requests: 100, min_tokens: 8,
                                max_tokens: 64, seed: 1 };
        let tr = WorkloadTrace::generate(cfg);
        assert_eq!(tr.requests.len(), 100);
        let mut prev = 0.0;
        for r in &tr.requests {
            assert!(r.arrival_s >= prev);
            prev = r.arrival_s;
            assert!((8..=64).contains(&r.max_tokens));
        }
    }

    #[test]
    fn rate_controls_density() {
        let fast = WorkloadTrace::generate(TraceConfig { rate: 100.0, ..Default::default() });
        let slow = WorkloadTrace::generate(TraceConfig { rate: 1.0, ..Default::default() });
        assert!(fast.duration_s() < slow.duration_s());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = WorkloadTrace::generate(TraceConfig::default());
        let b = WorkloadTrace::generate(TraceConfig::default());
        assert_eq!(a.requests, b.requests);
    }
}
