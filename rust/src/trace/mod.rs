//! Workload/trace generation for the serving benchmarks: Poisson arrivals
//! with configurable request-length distributions (the synthetic stand-in
//! for production traces, per the substitution rule in DESIGN.md §9).

pub mod workload;

pub use workload::{RequestSpec, TraceConfig, WorkloadTrace};
