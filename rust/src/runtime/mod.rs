//! PJRT runtime: load HLO-text artifacts, compile once, execute from the
//! rust request path. Wraps the `xla` crate (xla_extension 0.5.1, CPU).
//!
//! Buffer discipline (the PJRT analogue of the paper's §5.4(4)
//! "pre-initialized configurations"):
//! * weights upload once per process → persistent `PjRtBuffer`s;
//! * `@`-inputs (rho0, filter spectra per tile size) upload once at engine
//!   init → persistent buffers owned by the engine;
//! * `$`-inputs are the only per-call host→device copies.
//!
//! Executables are compiled lazily on first use and cached; a generation
//! run compiles `step` + the tau sizes its schedule touches, once.

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

pub use manifest::{ArtifactSpec, GoldenSpec, IoSpec, Manifest};

use crate::model::{ModelDims, Weights};

/// A compiled artifact plus its ABI spec.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute, leaving the outputs on device (no host transfer). The
    /// result is the PJRT output tuple buffer.
    pub fn call_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<Vec<xla::PjRtBuffer>>> {
        self.exe
            .execute_b(args)
            .with_context(|| format!("execute artifact '{}'", self.spec.name))
    }

    /// Execute with device buffers in manifest input order; returns the
    /// output literals in manifest output order.
    pub fn call(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "artifact '{}' wants {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                args.len()
            );
        }
        let outs = self
            .exe
            .execute_b(args)
            .with_context(|| format!("execute artifact '{}'", self.spec.name))?;
        let tuple = outs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch outputs of '{}'", self.spec.name))?;
        // aot.py lowers with return_tuple=True: always a tuple literal.
        let parts = tuple.to_tuple().context("decompose output tuple")?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "artifact '{}' returned {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        Ok(parts)
    }
}

/// The loaded model: manifest + weights + PJRT client + executable cache.
///
/// NOTE: PJRT handles are not `Send`; a `Runtime` lives on the thread that
/// created it (the engine thread). The server hands requests over channels.
pub struct Runtime {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub dims: ModelDims,
    pub weights: Weights,
    client: xla::PjRtClient,
    exes: Mutex<HashMap<String, Arc<Executable>>>,
    weight_bufs: Mutex<HashMap<String, Arc<xla::PjRtBuffer>>>,
}

impl Runtime {
    /// Load a build directory produced by `make artifacts`
    /// (e.g. `artifacts/synthetic`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir)?;
        let weights = Weights::load(&manifest.weights_file)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime {
            dir: dir.to_path_buf(),
            dims: manifest.dims,
            manifest,
            weights,
            client,
            exes: Mutex::new(HashMap::new()),
            weight_bufs: Mutex::new(HashMap::new()),
        })
    }

    /// Compile (or fetch cached) an artifact by name.
    pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.exes.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.find(name)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile '{name}'"))?;
        let e = Arc::new(Executable { spec, exe });
        self.exes.lock().unwrap().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Host → device upload of an f32 tensor.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("upload buffer")
    }

    /// Persistent device buffer of a named weight (uploaded on first use).
    pub fn weight_buffer(&self, name: &str) -> Result<Arc<xla::PjRtBuffer>> {
        if let Some(b) = self.weight_bufs.lock().unwrap().get(name) {
            return Ok(b.clone());
        }
        let t = self.weights.get(name)?;
        let buf = Arc::new(self.upload(t.data(), t.shape())?);
        self.weight_bufs.lock().unwrap().insert(name.to_string(), buf.clone());
        Ok(buf)
    }

    /// Read an f32 literal back to host, checking the element count.
    pub fn literal_to_vec(lit: &xla::Literal, want_elems: usize) -> Result<Vec<f32>> {
        let v: Vec<f32> = lit.to_vec().context("literal to host vec")?;
        if v.len() != want_elems {
            bail!("literal has {} elems, want {}", v.len(), want_elems);
        }
        Ok(v)
    }
}

/// An artifact bound to its argument sources: weights resolved to
/// persistent buffers, `@`-inputs resolved against an engine-provided set,
/// `$`-inputs supplied per call (in manifest order).
pub struct BoundArtifact {
    pub exe: Arc<Executable>,
    slots: Vec<Slot>,
    runtime_arity: usize,
}

enum Slot {
    Weight(Arc<xla::PjRtBuffer>),
    Derived(Arc<xla::PjRtBuffer>),
    Runtime(usize),
}

impl BoundArtifact {
    /// Resolve weight and derived inputs. `derived` maps `@name` → buffer.
    pub fn bind(
        rt: &Runtime,
        name: &str,
        derived: &HashMap<String, Arc<xla::PjRtBuffer>>,
    ) -> Result<BoundArtifact> {
        let exe = rt.executable(name)?;
        let mut slots = Vec::with_capacity(exe.spec.inputs.len());
        let mut runtime_arity = 0;
        for input in &exe.spec.inputs {
            if input.is_runtime() {
                slots.push(Slot::Runtime(runtime_arity));
                runtime_arity += 1;
            } else if input.is_derived() {
                let buf = derived.get(&input.name).ok_or_else(|| {
                    anyhow::anyhow!("artifact '{name}': derived input '{}' not provided", input.name)
                })?;
                slots.push(Slot::Derived(buf.clone()));
            } else {
                slots.push(Slot::Weight(rt.weight_buffer(&input.name)?));
            }
        }
        Ok(BoundArtifact { exe, slots, runtime_arity })
    }

    pub fn runtime_arity(&self) -> usize {
        self.runtime_arity
    }

    /// Execute with the `$`-inputs (in manifest order).
    pub fn call(&self, runtime_args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        if runtime_args.len() != self.runtime_arity {
            bail!(
                "artifact '{}' wants {} runtime args, got {}",
                self.exe.spec.name,
                self.runtime_arity,
                runtime_args.len()
            );
        }
        let args: Vec<&xla::PjRtBuffer> = self
            .slots
            .iter()
            .map(|s| match s {
                Slot::Weight(b) | Slot::Derived(b) => b.as_ref(),
                Slot::Runtime(i) => runtime_args[*i],
            })
            .collect();
        self.exe.call(&args)
    }
}
