//! Artifact manifest: the aot.py → rust contract (names, shapes, files).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::model::ModelDims;
use crate::util::json::Json;

/// One tensor in an artifact's signature.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    /// Runtime value, fresh every call (`$` prefix).
    pub fn is_runtime(&self) -> bool {
        self.name.starts_with('$')
    }

    /// Derived once at engine init (`@` prefix).
    pub fn is_derived(&self) -> bool {
        self.name.starts_with('@')
    }

    /// Weight from model.bin (no prefix).
    pub fn is_weight(&self) -> bool {
        !self.is_runtime() && !self.is_derived()
    }
}

/// One HLO artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: Option<String>,
    /// Tile side for tau artifacts; prompt length for prefill.
    pub param: Option<usize>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// Parsed manifest.json for one build directory.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dims: ModelDims,
    pub weights_file: PathBuf,
    pub golden: Option<GoldenSpec>,
    pub artifacts: Vec<ArtifactSpec>,
}

/// Reference rollout emitted by aot.py (exactness oracle).
#[derive(Debug, Clone)]
pub struct GoldenSpec {
    pub file: PathBuf,
    pub steps: usize,
}

fn parse_io(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: j.req_str("name")?.to_string(),
        shape: j
            .req_arr("shape")?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad shape entry")))
            .collect::<Result<_>>()?,
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read manifest {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;

        let dims = ModelDims::from_json(
            j.get("config").ok_or_else(|| anyhow::anyhow!("manifest missing 'config'"))?,
        )?;
        let weights_file = dir.join(j.req_str("weights_file")?);

        let golden = match j.get("golden") {
            Some(Json::Null) | None => None,
            Some(g) => Some(GoldenSpec {
                file: dir.join(g.req_str("file")?),
                steps: g.req_usize("steps")?,
            }),
        };

        let mut artifacts = Vec::new();
        for a in j.req_arr("artifacts")? {
            artifacts.push(ArtifactSpec {
                name: a.req_str("name")?.to_string(),
                file: a.req_str("file")?.to_string(),
                kind: a.get("kind").and_then(Json::as_str).map(String::from),
                param: a
                    .get("u")
                    .or_else(|| a.get("p"))
                    .and_then(Json::as_usize),
                inputs: a.req_arr("inputs")?.iter().map(parse_io).collect::<Result<_>>()?,
                outputs: a.req_arr("outputs")?.iter().map(parse_io).collect::<Result<_>>()?,
            });
        }
        let man = Manifest { dir: dir.to_path_buf(), dims, weights_file, golden, artifacts };
        man.validate()?;
        Ok(man)
    }

    fn validate(&self) -> Result<()> {
        self.find("step")?;
        self.find("filter_gen")?;
        // every tau size up to L/2 must exist in both families
        let mut u = 1;
        while u <= self.dims.l / 2 {
            self.find(&format!("tau_fft_{u}"))?;
            self.find(&format!("tau_direct_{u}"))?;
            u *= 2;
        }
        for a in &self.artifacts {
            if !self.dir.join(&a.file).exists() {
                bail!("artifact file missing: {}", a.file);
            }
        }
        Ok(())
    }

    pub fn find(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow::anyhow!("manifest has no artifact '{name}'"))
    }

    /// Prefill artifact with the largest prompt length <= `p`, if any.
    pub fn best_prefill(&self, p: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.kind.as_deref() == Some("prefill"))
            .filter(|a| a.param.unwrap_or(0) <= p)
            .max_by_key(|a| a.param.unwrap_or(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_spec_prefixes() {
        let r = IoSpec { name: "$y".into(), shape: vec![2, 3] };
        let d = IoSpec { name: "@rho0".into(), shape: vec![4] };
        let w = IoSpec { name: "blk.w1".into(), shape: vec![1] };
        assert!(r.is_runtime() && !r.is_weight());
        assert!(d.is_derived() && !d.is_weight());
        assert!(w.is_weight());
        assert_eq!(r.elems(), 6);
    }

    #[test]
    fn loads_real_manifest_if_built() {
        // integration-grade check, but cheap: only runs when artifacts exist
        let dir = Path::new("artifacts/synthetic");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(dir).unwrap();
        assert!(m.dims.l >= 2);
        let step = m.find("step").unwrap();
        assert_eq!(step.inputs[0].name, "$pending_col");
        assert!(m.find("nope").is_err());
        let tau = m.find("tau_fft_1").unwrap();
        assert_eq!(tau.param, Some(1));
        assert_eq!(tau.kind.as_deref(), Some("tau_fft"));
    }
}
