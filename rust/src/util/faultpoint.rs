//! Named fault points for chaos testing, zero-cost when disabled.
//!
//! A fault point is a named call site (`engine_step`, `tau_tile`,
//! `tile_delay`, `pager_alloc`, ...) that consults a process-global
//! registry. With no faults armed the whole check is a single relaxed
//! atomic load — safe to leave in the hot step loop.
//!
//! Spec grammar (`FI_FAULTS` env var or the `faults` config key), comma
//! separated:
//!
//! ```text
//! <point>:<action>@<nth>
//!   action := panic          panic on the nth hit (once)
//!           | fail           return an error on the nth hit (once)
//!           | delay:<ms>     sleep <ms> milliseconds; nth = 0 fires on
//!                            every hit, otherwise on the nth hit only
//! ```
//!
//! `nth` is 1-indexed; `engine_step:panic@3` panics on the third call to
//! `check("engine_step")` and is inert before and after, so a supervised
//! server recovers deterministically once the fault has fired.
//!
//! Armed points and their call sites:
//!
//! | point            | site                                             |
//! |------------------|--------------------------------------------------|
//! | `engine_step`    | per step, inside `Session::step_once`            |
//! | `tau_tile`       | per gray τ tile, on the async-executor worker    |
//! | `tile_delay`     | per gray τ tile, before compute (delay only)     |
//! | `pager_alloc`    | per checkpoint allocation in the session pager   |
//! | `replica_spawn`  | per replica engine boot (initial spawn + respawn)|
//! | `router_dispatch`| per request dispatch in the replica router       |

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Result};

/// What an armed fault point does when its trigger count is reached.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Action {
    Panic,
    Fail,
    DelayMs(u64),
}

#[derive(Debug)]
struct Point {
    name: String,
    action: Action,
    /// 1-indexed hit that triggers the action; 0 = every hit (delay only).
    nth: u64,
    hits: AtomicU64,
}

#[derive(Debug, Default)]
struct Registry {
    spec: String,
    points: Vec<Point>,
}

/// Fast path: false means `check` returns immediately without touching
/// the registry mutex. Armed/disarmed only through `install`/`clear`.
static ARMED: AtomicBool = AtomicBool::new(false);
static REGISTRY: Mutex<Option<Registry>> = Mutex::new(None);

fn registry() -> std::sync::MutexGuard<'static, Option<Registry>> {
    // Poison-tolerant: an injected panic may unwind through a caller
    // while a sibling thread holds this lock; the registry itself is
    // never left mid-update.
    REGISTRY
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn parse_spec(spec: &str) -> Result<Vec<Point>> {
    let mut points = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (name, rest) = part
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("fault spec '{part}': expected <point>:<action>@<n>"))?;
        let (action_s, nth_s) = rest
            .split_once('@')
            .ok_or_else(|| anyhow::anyhow!("fault spec '{part}': expected <action>@<n>"))?;
        let nth: u64 = nth_s
            .parse()
            .map_err(|_| anyhow::anyhow!("fault spec '{part}': bad trigger count '{nth_s}'"))?;
        let action = match action_s {
            "panic" => Action::Panic,
            "fail" => Action::Fail,
            _ => match action_s.strip_prefix("delay:") {
                Some(ms) => Action::DelayMs(ms.parse().map_err(|_| {
                    anyhow::anyhow!("fault spec '{part}': bad delay millis '{ms}'")
                })?),
                None => bail!("fault spec '{part}': unknown action '{action_s}'"),
            },
        };
        if nth == 0 && !matches!(action, Action::DelayMs(_)) {
            bail!("fault spec '{part}': @0 (every hit) is only valid for delay");
        }
        points.push(Point {
            name: name.to_string(),
            action,
            nth,
            hits: AtomicU64::new(0),
        });
    }
    Ok(points)
}

/// Parse `spec` and arm it process-wide, replacing any previous
/// installation and resetting all hit counters. An empty spec disarms.
pub fn install(spec: &str) -> Result<()> {
    let points = parse_spec(spec)?;
    let mut reg = registry();
    if points.is_empty() {
        *reg = None;
        ARMED.store(false, Ordering::Release);
    } else {
        *reg = Some(Registry {
            spec: spec.trim().to_string(),
            points,
        });
        ARMED.store(true, Ordering::Release);
    }
    Ok(())
}

/// Arm from the `FI_FAULTS` environment variable if set and non-empty.
/// Returns the installed spec, if any.
pub fn install_from_env() -> Result<Option<String>> {
    match std::env::var("FI_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => {
            install(&spec)?;
            Ok(Some(spec))
        }
        _ => Ok(None),
    }
}

/// Disarm every fault point.
pub fn clear() {
    *registry() = None;
    ARMED.store(false, Ordering::Release);
}

/// The currently armed spec string (for `/v1/info`), if any.
pub fn active_spec() -> Option<String> {
    registry().as_ref().map(|r| r.spec.clone())
}

/// Consult the fault point `name`. Zero-cost when nothing is armed.
/// Panics for `panic` actions, sleeps for `delay`, and returns an error
/// for `fail` — callers on no-`Result` paths may `expect` the return,
/// which degrades a misconfigured `fail` into a panic at the same site.
#[inline]
pub fn check(name: &str) -> Result<()> {
    if !ARMED.load(Ordering::Acquire) {
        return Ok(());
    }
    check_slow(name)
}

#[cold]
fn check_slow(name: &str) -> Result<()> {
    // Decide under the lock, act (panic/sleep) after releasing it.
    let mut fire: Option<(Action, u64)> = None;
    if let Some(reg) = registry().as_ref() {
        for p in reg.points.iter().filter(|p| p.name == name) {
            let hit = p.hits.fetch_add(1, Ordering::Relaxed) + 1;
            let triggered = if p.nth == 0 { true } else { hit == p.nth };
            if triggered {
                fire = Some((p.action, hit));
                break;
            }
        }
    }
    match fire {
        None => Ok(()),
        Some((Action::DelayMs(ms), _)) => {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(())
        }
        Some((Action::Fail, hit)) => {
            bail!("fault injection: {name} fail@{hit}")
        }
        Some((Action::Panic, hit)) => {
            panic!("fault injection: {name} panic@{hit}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; tests that arm it serialize here so
    // they cannot observe each other's installs under the parallel runner.
    static SERIAL: Mutex<()> = Mutex::new(());

    fn serial() -> std::sync::MutexGuard<'static, ()> {
        SERIAL
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    #[test]
    fn disabled_registry_is_inert() {
        let _s = serial();
        clear();
        for _ in 0..100 {
            check("engine_step").unwrap();
        }
        assert_eq!(active_spec(), None);
    }

    #[test]
    fn fail_triggers_on_exact_nth_hit_once() {
        let _s = serial();
        install("pager_alloc:fail@3").unwrap();
        assert!(check("pager_alloc").is_ok());
        assert!(check("pager_alloc").is_ok());
        let err = check("pager_alloc").unwrap_err();
        assert!(err.to_string().contains("pager_alloc fail@3"), "{err}");
        // one-shot: later hits pass, so a supervised server can recover
        assert!(check("pager_alloc").is_ok());
        // unrelated points never trip
        assert!(check("engine_step").is_ok());
        clear();
    }

    #[test]
    fn panic_action_panics_with_point_name() {
        let _s = serial();
        install("tau_tile:panic@1").unwrap();
        let r = std::panic::catch_unwind(|| check("tau_tile").unwrap());
        clear();
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("fault injection: tau_tile panic@1"), "{msg}");
    }

    #[test]
    fn delay_every_hit_and_spec_roundtrip() {
        let _s = serial();
        install("tile_delay:delay:1@0, engine_step:panic@9").unwrap();
        assert_eq!(
            active_spec().as_deref(),
            Some("tile_delay:delay:1@0, engine_step:panic@9")
        );
        let t0 = std::time::Instant::now();
        check("tile_delay").unwrap();
        check("tile_delay").unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(2));
        clear();
    }

    #[test]
    fn fleet_points_follow_the_same_grammar() {
        let _s = serial();
        // the fleet points are plain registry names — same one-shot
        // semantics as the engine points, no special casing
        install("replica_spawn:fail@1,router_dispatch:fail@2").unwrap();
        let err = check("replica_spawn").unwrap_err();
        assert!(err.to_string().contains("replica_spawn fail@1"), "{err}");
        assert!(check("replica_spawn").is_ok(), "one-shot: a respawn boots clean");
        assert!(check("router_dispatch").is_ok());
        let err = check("router_dispatch").unwrap_err();
        assert!(err.to_string().contains("router_dispatch fail@2"), "{err}");
        assert!(check("router_dispatch").is_ok());
        clear();
    }

    #[test]
    fn bad_specs_are_rejected() {
        let _s = serial();
        clear();
        for bad in [
            "engine_step",           // no action
            "engine_step:panic",     // no trigger count
            "engine_step:panic@x",   // bad count
            "engine_step:explode@1", // unknown action
            "engine_step:panic@0",   // @0 only valid for delay
            "tile_delay:delay:ms@0", // bad delay millis
        ] {
            assert!(install(bad).is_err(), "spec '{bad}' should be rejected");
        }
        // a failed install leaves the registry disarmed
        assert_eq!(active_spec(), None);
        check("engine_step").unwrap();
    }
}
