//! Scoped fork-join parallelism substrate (rayon is unavailable offline).
//!
//! Serves Algorithm 3's across-layer parallelism for the *native* tau
//! implementations: the gray-tile calls at different layers have disjoint
//! inputs/outputs, so they are embarrassingly parallel. On this testbed
//! (1 core) the pool degenerates gracefully to inline execution; the
//! topology and correctness are tested regardless.
//!
//! Implementation: `std::thread::scope` with work-stealing via a shared
//! atomic counter — spawning a handful of scoped threads per fork-join is
//! cheap relative to a gray tile, and borrow checking stays fully safe.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::thread;

/// Fork-join executor with a fixed degree of parallelism.
pub struct ThreadPool {
    size: usize,
}

impl ThreadPool {
    /// `size == 0` requests inline execution (no threads spawned).
    pub fn new(size: usize) -> ThreadPool {
        ThreadPool { size }
    }

    /// Sized to the machine (cores - 1; 0 ⇒ inline on a 1-core box).
    pub fn for_machine() -> ThreadPool {
        let cores = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(cores.saturating_sub(1))
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `f(i)` for `i in 0..n` and wait for all. Parallel iff the pool
    /// has workers and `n > 1`; otherwise inline, in order.
    pub fn scoped_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if n == 0 {
            return;
        }
        if self.size == 0 || n == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let threads = self.size.min(n);
        let counter = AtomicUsize::new(0);
        thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| loop {
                    let i = counter.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn inline_pool_runs_everything_in_order() {
        let pool = ThreadPool::new(0);
        let seen = Mutex::new(Vec::new());
        pool.scoped_for(17, |i| seen.lock().unwrap().push(i));
        assert_eq!(*seen.lock().unwrap(), (0..17).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_pool_covers_all_indices_once() {
        let pool = ThreadPool::new(4);
        let n = 100;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.scoped_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn disjoint_slice_mutation() {
        // The tau use-case: each index owns a disjoint output slice.
        let pool = ThreadPool::new(3);
        let n = 8;
        let data: Vec<Mutex<u64>> = (0..n).map(|_| Mutex::new(0)).collect();
        pool.scoped_for(n, |i| {
            *data[i].lock().unwrap() = i as u64 * 2;
        });
        for (i, d) in data.iter().enumerate() {
            assert_eq!(*d.lock().unwrap(), i as u64 * 2);
        }
    }

    #[test]
    fn zero_tasks_is_noop() {
        ThreadPool::new(2).scoped_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn for_machine_constructs_and_runs() {
        let p = ThreadPool::for_machine();
        let hits = AtomicUsize::new(0);
        p.scoped_for(5, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 5);
    }
}
