//! Scoped fork-join parallelism substrate (rayon is unavailable offline).
//!
//! Serves Algorithm 3's across-layer parallelism for the *native* tau
//! implementations: the gray-tile calls at different layers have disjoint
//! inputs/outputs, so they are embarrassingly parallel. On this testbed
//! (1 core) the pool degenerates gracefully to inline execution; the
//! topology and correctness are tested regardless.
//!
//! Implementation: a *persistent* pool — `size` workers are spawned once
//! (lazily, on the first parallel `scoped_for` or `submit`) and parked on
//! a condvar; each `scoped_for` call publishes one lifetime-erased job
//! (work-stealing over a shared atomic counter) and blocks until every
//! worker has checked in, so borrowed closures remain sound without
//! per-call thread spawns. Gray tiles arrive every token, so the former
//! spawn-per-call design paid an OS thread create/join per tile; the
//! parked pool reduces that to a wake. Nested `scoped_for` on the same
//! pool degrades to inline.
//!
//! Two submission modes share the workers:
//! * [`ThreadPool::scoped_for`] — fork-join over borrowed closures, the
//!   caller blocks until done (the tau across-group fan-out);
//! * [`ThreadPool::submit`] / [`ThreadPool::submit_after`] — fire one
//!   `'static` job and get a [`JobHandle`] back; the caller continues and
//!   joins later (the async tau executor's deadline-fenced tiles).
//!
//! The submit queue is *dependency-tracked*: `submit_after` records
//! happens-before edges on earlier handles, and a worker only dequeues a
//! task once every dependency is terminal. Among ready tasks, workers pick
//! in FIFO submission order; dependency-free tasks therefore still run in
//! submission order on a single-worker pool, while on a multi-worker pool
//! tasks with no edges between them run concurrently. `tau::AsyncTau`
//! builds its overlapping-destination-write safety on these edges: tiles
//! whose `+=` destinations overlap are chained, disjoint tiles fan out
//! across workers.

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::{self, JoinHandle};

thread_local! {
    /// Address of the [`Shared`] whose job the current thread is running
    /// (0 outside pool workers). Lets a nested `scoped_for` on the *same*
    /// pool degrade to inline execution instead of deadlocking on the
    /// one-job-at-a-time submit lock.
    static ACTIVE_POOL: Cell<usize> = const { Cell::new(0) };
}

/// Fork-join executor with a fixed degree of parallelism and persistent
/// workers.
pub struct ThreadPool {
    size: usize,
    /// Workers + coordination state, spawned lazily on the first parallel
    /// `scoped_for` — constructing a pool (e.g. the two native impls inside
    /// every `Hybrid`) stays free until it is actually exercised.
    inner: OnceLock<Inner>,
}

struct Inner {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    /// Serializes `scoped_for` calls: one job in flight at a time.
    submit: Mutex<()>,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between jobs.
    work: Condvar,
    /// The caller parks here until `active` drains to zero.
    done: Condvar,
}

#[derive(Default)]
struct State {
    job: Option<Job>,
    epoch: u64,
    shutdown: bool,
    /// Workers that have not yet finished the current job.
    active: usize,
    /// A worker closure panicked during the current job.
    panicked: bool,
    /// One-shot jobs queued by [`ThreadPool::submit`] /
    /// [`ThreadPool::submit_after`]. Workers dequeue the first task whose
    /// dependencies are all terminal (FIFO among ready tasks) whenever no
    /// scoped job is pending (scoped callers block a whole fork-join, so
    /// they take priority over latency-relaxed submitted work).
    queue: VecDeque<QueuedTask>,
}

/// Terminal / in-flight status of a submitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TaskStatus {
    Queued,
    Running,
    Done,
    Panicked,
    Cancelled,
}

impl TaskStatus {
    fn is_terminal(self) -> bool {
        matches!(self, TaskStatus::Done | TaskStatus::Panicked | TaskStatus::Cancelled)
    }
}

/// Why [`JobHandle::join`] did not return success.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobError {
    /// The job closure panicked on the worker.
    Panicked,
    /// The pool shut down before the job ran.
    Cancelled,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::Panicked => write!(f, "submitted job panicked on the worker"),
            JobError::Cancelled => write!(f, "submitted job cancelled by pool shutdown"),
        }
    }
}

impl std::error::Error for JobError {}

struct TaskShared {
    status: Mutex<TaskStatus>,
    cv: Condvar,
    /// Panic payload text captured when the job panics on the worker —
    /// written before the status flips to `Panicked`, so any joiner that
    /// observes the terminal state also sees the message.
    panic_msg: Mutex<Option<String>>,
}

struct QueuedTask {
    f: Box<dyn FnOnce() + Send + 'static>,
    shared: Arc<TaskShared>,
    /// Happens-before edges: this task may not start until every listed
    /// task is terminal. Already-terminal deps are filtered at submit, so
    /// the scan stays cheap in the steady state.
    deps: Vec<Arc<TaskShared>>,
}

impl QueuedTask {
    fn is_ready(&self) -> bool {
        self.deps.iter().all(|d| d.status.lock().unwrap().is_terminal())
    }
}

/// Completion handle for a job submitted with [`ThreadPool::submit`].
pub struct JobHandle {
    shared: Arc<TaskShared>,
}

impl JobHandle {
    fn completed() -> JobHandle {
        JobHandle {
            shared: Arc::new(TaskShared {
                status: Mutex::new(TaskStatus::Done),
                cv: Condvar::new(),
                panic_msg: Mutex::new(None),
            }),
        }
    }

    /// Non-blocking: has the job reached a terminal state?
    pub fn is_done(&self) -> bool {
        self.shared.status.lock().unwrap().is_terminal()
    }

    /// Block until the job finishes. A worker-side panic or a pool
    /// shutdown surfaces as an error instead of poisoning the caller.
    pub fn join(&self) -> Result<(), JobError> {
        let mut st = self.shared.status.lock().unwrap();
        while !st.is_terminal() {
            st = self.shared.cv.wait(st).unwrap();
        }
        match *st {
            TaskStatus::Done => Ok(()),
            TaskStatus::Panicked => Err(JobError::Panicked),
            TaskStatus::Cancelled => Err(JobError::Cancelled),
            TaskStatus::Queued | TaskStatus::Running => unreachable!(),
        }
    }

    /// The captured panic payload text, if the job panicked on a worker.
    /// Non-blocking; `None` while the job is in flight or for non-panic
    /// terminal states. Lets joiners build a structured error (e.g. a
    /// lane-level 500 naming the fault) instead of a bare "panicked".
    pub fn panic_message(&self) -> Option<String> {
        self.shared
            .panic_msg
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .clone()
    }
}

fn finish_task(shared: &TaskShared, status: TaskStatus) {
    *shared.status.lock().unwrap() = status;
    shared.cv.notify_all();
}

/// Best-effort text of a panic payload (`panic!` with a literal carries a
/// `&str`, with a format string a `String`; anything else is opaque).
/// Public: the engine supervisor uses it on `catch_unwind` payloads too.
pub fn payload_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Lifetime-erased job description published to the workers.
///
/// SAFETY contract: the `'static` on `f` and `counter` is a lie — both
/// borrow the `scoped_for` caller's stack. It is sound because
/// `scoped_for` does not return until every worker has decremented
/// `active` for this epoch (and workers never touch a job again after
/// that), so no dereference outlives the frame.
#[derive(Clone, Copy)]
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    counter: &'static AtomicUsize,
    n: usize,
    epoch: u64,
}

impl Inner {
    fn spawn(size: usize) -> Inner {
        let shared = Arc::new(Shared {
            state: Mutex::new(State::default()),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        // Pin workers to distinct cores when the pool undersubscribes the
        // machine: each persistent worker carries thread-local TileScratch
        // (tau::rust_fft / tau::async_exec), and OS migration invalidates
        // the private-cache residency the fused D-blocked kernel is built
        // around. Core 0 is left for the engine/sampler thread; an exactly-
        // or over-subscribed pool is not pinned (the scheduler needs the
        // freedom), and FI_PIN_WORKERS=0 opts out entirely.
        let cores = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let pin = size < cores
            && !matches!(std::env::var("FI_PIN_WORKERS").as_deref(), Ok("0") | Ok("off"));
        let workers = (0..size)
            .map(|i| {
                let shared = shared.clone();
                thread::spawn(move || {
                    if pin {
                        pin_current_thread((i + 1) % cores);
                    }
                    worker_loop(&shared)
                })
            })
            .collect();
        Inner { shared, workers, submit: Mutex::new(()) }
    }
}

impl ThreadPool {
    /// `size == 0` requests inline execution (no threads spawned).
    pub fn new(size: usize) -> ThreadPool {
        ThreadPool { size, inner: OnceLock::new() }
    }

    /// Sized to the machine (cores - 1; 0 ⇒ inline on a 1-core box).
    pub fn for_machine() -> ThreadPool {
        let cores = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(cores.saturating_sub(1))
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `f(i)` for `i in 0..n` and wait for all. Parallel iff the pool
    /// has workers and `n > 1`; otherwise inline, in order. One job runs at
    /// a time: concurrent callers serialize, and a *nested* call from
    /// inside a worker closure of this same pool runs inline (the workers
    /// are all busy with the outer job anyway).
    pub fn scoped_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if n == 0 {
            return;
        }
        if self.size == 0 || n == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let inner = self.inner.get_or_init(|| Inner::spawn(self.size));
        if ACTIVE_POOL.with(Cell::get) == Arc::as_ptr(&inner.shared) as usize {
            for i in 0..n {
                f(i);
            }
            return;
        }

        // poison-tolerant: a propagated worker panic unwinds through a
        // prior caller while it held this guard; the pool itself is left
        // consistent (the job was fully drained), so keep serving.
        let _guard = inner.submit.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let counter = AtomicUsize::new(0);
        // SAFETY: lifetime erasure per the `Job` contract — we block below
        // until every worker has finished with these references.
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f_ref) };
        let c_static: &'static AtomicUsize =
            unsafe { std::mem::transmute::<&AtomicUsize, &'static AtomicUsize>(&counter) };

        let mut st = inner.shared.state.lock().unwrap();
        st.epoch += 1;
        st.active = inner.workers.len();
        st.panicked = false;
        st.job = Some(Job { f: f_static, counter: c_static, n, epoch: st.epoch });
        inner.shared.work.notify_all();
        while st.active > 0 {
            st = inner.shared.done.wait(st).unwrap();
        }
        st.job = None; // drop the erased borrows before the frame unwinds
        let panicked = st.panicked;
        drop(st);
        if panicked {
            panic!("worker closure panicked in ThreadPool::scoped_for");
        }
    }

    /// Queue `f` for asynchronous execution on a pool worker and return a
    /// completion handle. Equivalent to [`Self::submit_after`] with no
    /// dependencies: ready immediately, FIFO among ready tasks — on a
    /// **single-worker** pool that makes execution order == submission
    /// order for dependency-free tasks.
    pub fn submit(&self, f: Box<dyn FnOnce() + Send + 'static>) -> JobHandle {
        self.submit_after(&[], f)
    }

    /// Queue `f` with happens-before edges: it will not start until every
    /// job in `deps` is terminal (done, panicked, or cancelled). Workers
    /// pick the first *ready* task in submission order, so two tasks whose
    /// dep sets chain them run in submission order, while independent
    /// tasks fan out across workers. A completed dep's effects are visible
    /// to `f` (the dep's status mutex carries the happens-before).
    ///
    /// Degenerate cases run `f` inline and return an already-completed
    /// handle: a `size == 0` pool (everything, deps included, already ran
    /// inline) and a call from inside a worker closure of this same pool
    /// (handing off could deadlock a joiner against itself; outstanding
    /// deps are joined first, which requires them to be runnable on the
    /// remaining workers — the async executor only submits from the engine
    /// thread, so this path never carries deps in practice).
    pub fn submit_after(
        &self,
        deps: &[&JobHandle],
        f: Box<dyn FnOnce() + Send + 'static>,
    ) -> JobHandle {
        if self.size == 0 {
            f();
            return JobHandle::completed();
        }
        let inner = self.inner.get_or_init(|| Inner::spawn(self.size));
        if ACTIVE_POOL.with(Cell::get) == Arc::as_ptr(&inner.shared) as usize {
            for d in deps {
                let _ = d.join();
            }
            f();
            return JobHandle::completed();
        }
        let shared = Arc::new(TaskShared {
            status: Mutex::new(TaskStatus::Queued),
            cv: Condvar::new(),
            panic_msg: Mutex::new(None),
        });
        let handle = JobHandle { shared: shared.clone() };
        let deps: Vec<Arc<TaskShared>> = deps
            .iter()
            .filter(|h| !h.is_done())
            .map(|h| h.shared.clone())
            .collect();
        {
            let mut st = inner.shared.state.lock().unwrap();
            st.queue.push_back(QueuedTask { f, shared, deps });
            inner.shared.work.notify_all();
        }
        handle
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.take() {
            // workers exist only if a parallel scoped_for ran
            {
                let mut st = inner.shared.state.lock().unwrap();
                st.shutdown = true;
                inner.shared.work.notify_all();
            }
            for w in inner.workers {
                let _ = w.join();
            }
        }
    }
}

enum Work {
    Scoped(Job),
    Task(QueuedTask),
}

/// Maximum CPUs representable in the hand-rolled affinity mask (16 × 64).
const AFFINITY_WORDS: usize = 16;

/// Pin the calling thread to `cpu`. Linux-only; a no-op that returns
/// `false` elsewhere or on failure (pinning is an optimization, never a
/// correctness requirement). Hand-rolled `sched_setaffinity(2)` binding —
/// the libc crate is unavailable offline, and glibc is always linked
/// (same pattern as the `signal(2)` binding in `cli/commands/serve.rs`).
#[cfg(target_os = "linux")]
fn pin_current_thread(cpu: usize) -> bool {
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    if cpu >= AFFINITY_WORDS * 64 {
        return false;
    }
    let mut mask = [0u64; AFFINITY_WORDS];
    mask[cpu / 64] = 1u64 << (cpu % 64);
    // pid 0 = the calling thread
    unsafe { sched_setaffinity(0, AFFINITY_WORDS * 8, mask.as_ptr()) == 0 }
}

#[cfg(not(target_os = "linux"))]
fn pin_current_thread(_cpu: usize) -> bool {
    false
}

fn worker_loop(shared: &Shared) {
    let mut last_epoch = 0u64;
    loop {
        let work = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    // cancel whatever is still queued so joiners unblock
                    while let Some(t) = st.queue.pop_front() {
                        finish_task(&t.shared, TaskStatus::Cancelled);
                    }
                    return;
                }
                match st.job {
                    Some(job) if job.epoch > last_epoch => break Work::Scoped(job),
                    _ => {}
                }
                // first *ready* task in submission order: dependency-free
                // tasks keep FIFO; a task behind an unfinished dep is
                // skipped so an independent later task can run concurrently
                if let Some(idx) = st.queue.iter().position(QueuedTask::is_ready) {
                    break Work::Task(st.queue.remove(idx).expect("index in bounds"));
                }
                st = shared.work.wait(st).unwrap();
            }
        };

        match work {
            Work::Scoped(job) => {
                last_epoch = job.epoch;

                ACTIVE_POOL.with(|c| c.set(shared as *const Shared as usize));
                let mut hit_panic = false;
                loop {
                    let i = job.counter.fetch_add(1, Ordering::Relaxed);
                    if i >= job.n {
                        break;
                    }
                    if panic::catch_unwind(AssertUnwindSafe(|| (job.f)(i))).is_err() {
                        hit_panic = true;
                        break; // stop stealing; surface on the caller below
                    }
                }
                ACTIVE_POOL.with(|c| c.set(0));

                let mut st = shared.state.lock().unwrap();
                st.panicked |= hit_panic;
                st.active -= 1;
                if st.active == 0 {
                    shared.done.notify_one();
                }
            }
            Work::Task(task) => {
                *task.shared.status.lock().unwrap() = TaskStatus::Running;
                ACTIVE_POOL.with(|c| c.set(shared as *const Shared as usize));
                let result = panic::catch_unwind(AssertUnwindSafe(task.f));
                ACTIVE_POOL.with(|c| c.set(0));
                let status = match result {
                    Ok(()) => TaskStatus::Done,
                    Err(payload) => {
                        *task
                            .shared
                            .panic_msg
                            .lock()
                            .unwrap_or_else(std::sync::PoisonError::into_inner) =
                            Some(payload_text(payload.as_ref()));
                        TaskStatus::Panicked
                    }
                };
                finish_task(&task.shared, status);
                // finishing this task may have made a queued dependent
                // ready; parked workers only rescan on a wakeup
                let st = shared.state.lock().unwrap();
                if !st.queue.is_empty() {
                    shared.work.notify_all();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Mutex;

    /// The pin primitive must actually narrow the affinity mask (read
    /// back via sched_getaffinity) and be restorable — run on a spawned
    /// thread so the harness thread's affinity is never touched.
    #[test]
    #[cfg(target_os = "linux")]
    fn pin_primitive_restricts_affinity() {
        extern "C" {
            fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
        }
        thread::spawn(|| {
            let mut before = [0u64; AFFINITY_WORDS];
            let rc = unsafe { sched_getaffinity(0, AFFINITY_WORDS * 8, before.as_mut_ptr()) };
            assert_eq!(rc, 0, "sched_getaffinity failed");
            assert!(pin_current_thread(0), "pinning to cpu 0 must succeed");
            let mut after = [0u64; AFFINITY_WORDS];
            let rc = unsafe { sched_getaffinity(0, AFFINITY_WORDS * 8, after.as_mut_ptr()) };
            assert_eq!(rc, 0);
            assert_eq!(after[0], 1, "mask must be exactly {{cpu 0}}");
            assert!(after[1..].iter().all(|&w| w == 0));
            // out-of-range cpu is rejected without touching the mask
            assert!(!pin_current_thread(AFFINITY_WORDS * 64));
        })
        .join()
        .unwrap();
    }

    #[test]
    fn inline_pool_runs_everything_in_order() {
        let pool = ThreadPool::new(0);
        let seen = Mutex::new(Vec::new());
        pool.scoped_for(17, |i| seen.lock().unwrap().push(i));
        assert_eq!(*seen.lock().unwrap(), (0..17).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_pool_covers_all_indices_once() {
        let pool = ThreadPool::new(4);
        let n = 100;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.scoped_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn disjoint_slice_mutation() {
        // The tau use-case: each index owns a disjoint output slice.
        let pool = ThreadPool::new(3);
        let n = 8;
        let data: Vec<Mutex<u64>> = (0..n).map(|_| Mutex::new(0)).collect();
        pool.scoped_for(n, |i| {
            *data[i].lock().unwrap() = i as u64 * 2;
        });
        for (i, d) in data.iter().enumerate() {
            assert_eq!(*d.lock().unwrap(), i as u64 * 2);
        }
    }

    #[test]
    fn zero_tasks_is_noop() {
        ThreadPool::new(2).scoped_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn single_task_runs_inline() {
        let pool = ThreadPool::new(2);
        let caller = thread::current().id();
        pool.scoped_for(1, |i| {
            assert_eq!(i, 0);
            assert_eq!(thread::current().id(), caller);
        });
    }

    #[test]
    fn for_machine_constructs_and_runs() {
        let p = ThreadPool::for_machine();
        let hits = AtomicUsize::new(0);
        p.scoped_for(5, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn workers_are_reused_across_calls() {
        // persistent pool: every index runs on one of the `size` parked
        // workers, never on fresh threads and never on the caller — so two
        // consecutive calls can only ever touch the same `size` thread ids
        // (the old spawn-per-call design produced new ids each call).
        let pool = ThreadPool::new(2);
        let caller = thread::current().id();
        let ids = Mutex::new(HashSet::new());
        for _ in 0..2 {
            pool.scoped_for(64, |_| {
                ids.lock().unwrap().insert(thread::current().id());
            });
        }
        let ids = ids.into_inner().unwrap();
        assert!(!ids.is_empty());
        assert!(ids.len() <= 2, "expected worker reuse, saw {} distinct threads", ids.len());
        assert!(!ids.contains(&caller));
    }

    #[test]
    fn many_consecutive_jobs_complete() {
        // exercise the epoch/wakeup protocol across many quick jobs
        let pool = ThreadPool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.scoped_for(7, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 50 * 7);
    }

    #[test]
    fn nested_scoped_for_runs_inline_not_deadlocking() {
        // a nested call on the same pool from inside a worker closure must
        // degrade to inline execution (all workers are busy with the outer
        // job), not block on the submit lock
        let pool = ThreadPool::new(2);
        let total = AtomicUsize::new(0);
        pool.scoped_for(4, |_| {
            pool.scoped_for(3, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn construction_spawns_no_threads_until_used() {
        // pools are built eagerly all over (e.g. two per Hybrid) — they
        // must stay free until a parallel scoped_for actually runs
        let pool = ThreadPool::new(4);
        assert!(pool.inner.get().is_none());
        pool.scoped_for(1, |_| {}); // n == 1 stays inline
        assert!(pool.inner.get().is_none());
        pool.scoped_for(2, |_| {});
        assert!(pool.inner.get().is_some());
    }

    #[test]
    fn drop_joins_idle_workers() {
        let pool = ThreadPool::new(2);
        pool.scoped_for(4, |_| {});
        drop(pool); // must not hang
    }

    #[test]
    fn submit_runs_and_joins() {
        let pool = ThreadPool::new(2);
        let hits = Arc::new(AtomicUsize::new(0));
        let handles: Vec<JobHandle> = (0..16)
            .map(|_| {
                let hits = hits.clone();
                pool.submit(Box::new(move || {
                    hits.fetch_add(1, Ordering::Relaxed);
                }))
            })
            .collect();
        for h in &handles {
            h.join().unwrap();
            assert!(h.is_done());
        }
        assert_eq!(hits.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn submit_on_single_worker_pool_is_fifo() {
        // dependency-free tasks keep FIFO pick order, so one worker ⇒
        // execution order == submission order (the pre-dependency-queue
        // AsyncTau contract still holds at mixer_workers = 1)
        let pool = ThreadPool::new(1);
        let order = Arc::new(Mutex::new(Vec::new()));
        let handles: Vec<JobHandle> = (0..64)
            .map(|i| {
                let order = order.clone();
                pool.submit(Box::new(move || order.lock().unwrap().push(i)))
            })
            .collect();
        for h in &handles {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn submit_inline_on_empty_pool() {
        let pool = ThreadPool::new(0);
        let hits = Arc::new(AtomicUsize::new(0));
        let h = {
            let hits = hits.clone();
            pool.submit(Box::new(move || {
                hits.fetch_add(1, Ordering::Relaxed);
            }))
        };
        // ran inline: already complete before join
        assert!(h.is_done());
        h.join().unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn submit_panic_surfaces_on_join_not_caller() {
        let pool = ThreadPool::new(1);
        let h = pool.submit(Box::new(|| panic!("task boom")));
        assert_eq!(h.join(), Err(JobError::Panicked));
        // pool still serves afterwards
        let ok = pool.submit(Box::new(|| {}));
        ok.join().unwrap();
    }

    #[test]
    fn submit_from_worker_runs_inline() {
        // a job submitting to its own pool must not deadlock a same-thread
        // join against itself; it degrades to inline execution
        let pool = Arc::new(ThreadPool::new(1));
        let p2 = pool.clone();
        let h = pool.submit(Box::new(move || {
            let inner = p2.submit(Box::new(|| {}));
            inner.join().unwrap();
        }));
        h.join().unwrap();
    }

    #[test]
    fn drop_cancels_queued_jobs() {
        let pool = ThreadPool::new(1);
        // first job blocks the single worker long enough for more to queue
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g = gate.clone();
        let blocker = pool.submit(Box::new(move || {
            let (m, cv) = &*g;
            let mut open = m.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        }));
        let queued: Vec<JobHandle> =
            (0..4).map(|_| pool.submit(Box::new(|| {}))).collect();
        {
            let (m, cv) = &*gate;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
        blocker.join().unwrap();
        drop(pool);
        // after shutdown every handle is terminal: Done if the worker got
        // to it, Cancelled otherwise — none left dangling
        for h in &queued {
            assert!(h.is_done());
            assert!(matches!(h.join(), Ok(()) | Err(JobError::Cancelled)));
        }
    }

    #[test]
    fn submit_and_scoped_for_coexist() {
        let pool = ThreadPool::new(2);
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let h = {
                let hits = hits.clone();
                pool.submit(Box::new(move || {
                    hits.fetch_add(1, Ordering::Relaxed);
                }))
            };
            pool.scoped_for(4, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            h.join().unwrap();
        }
        assert_eq!(hits.load(Ordering::Relaxed), 8 * 5);
    }

    #[test]
    fn submit_after_orders_dependent_tasks() {
        // A is held open by a gate; B depends on A and must not start
        // until A finishes even though three other workers sit idle
        let pool = ThreadPool::new(4);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let order = Arc::new(Mutex::new(Vec::new()));
        let (g, o) = (gate.clone(), order.clone());
        let a = pool.submit(Box::new(move || {
            let (m, cv) = &*g;
            let mut open = m.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
            o.lock().unwrap().push("a");
        }));
        let o = order.clone();
        let b = pool.submit_after(&[&a], Box::new(move || o.lock().unwrap().push("b")));
        // B stays queued behind the gated A
        thread::sleep(std::time::Duration::from_millis(20));
        assert!(!b.is_done());
        assert!(order.lock().unwrap().is_empty());
        {
            let (m, cv) = &*gate;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
        b.join().unwrap();
        assert_eq!(*order.lock().unwrap(), vec!["a", "b"]);
    }

    #[test]
    fn submit_after_chain_is_sequential_on_many_workers() {
        // a dependency chain serializes even when workers are plentiful
        let pool = ThreadPool::new(4);
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut prev: Option<JobHandle> = None;
        for i in 0..16 {
            let o = order.clone();
            let f = Box::new(move || o.lock().unwrap().push(i));
            let h = match &prev {
                Some(p) => pool.submit_after(&[p], f),
                None => pool.submit(f),
            };
            prev = Some(h);
        }
        prev.unwrap().join().unwrap();
        assert_eq!(*order.lock().unwrap(), (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn independent_tasks_bypass_a_blocked_dependent() {
        // with 2 workers: A gated, B depends on A, C independent. C must
        // run to completion while B waits — the ready-scan skips B.
        let pool = ThreadPool::new(2);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g = gate.clone();
        let a = pool.submit(Box::new(move || {
            let (m, cv) = &*g;
            let mut open = m.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        }));
        let b = pool.submit_after(&[&a], Box::new(|| {}));
        let c = pool.submit(Box::new(|| {}));
        c.join().unwrap(); // completes while A is still gated
        assert!(!a.is_done());
        assert!(!b.is_done());
        {
            let (m, cv) = &*gate;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
        a.join().unwrap();
        b.join().unwrap();
    }

    #[test]
    fn independent_tasks_run_concurrently_on_multi_worker_pool() {
        // two tasks that each wait for the other's arrival can only finish
        // if they are genuinely on two workers at the same time
        let pool = ThreadPool::new(2);
        let arrived = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mk = |arrived: Arc<(Mutex<usize>, Condvar)>| {
            Box::new(move || {
                let (m, cv) = &*arrived;
                let mut n = m.lock().unwrap();
                *n += 1;
                cv.notify_all();
                while *n < 2 {
                    n = cv.wait(n).unwrap();
                }
            })
        };
        let h1 = pool.submit(mk(arrived.clone()));
        let h2 = pool.submit(mk(arrived.clone()));
        h1.join().unwrap();
        h2.join().unwrap();
    }

    #[test]
    fn submit_after_terminal_dep_runs_immediately() {
        let pool = ThreadPool::new(1);
        let a = pool.submit(Box::new(|| {}));
        a.join().unwrap();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = {
            let hits = hits.clone();
            pool.submit_after(
                &[&a],
                Box::new(move || {
                    hits.fetch_add(1, Ordering::Relaxed);
                }),
            )
        };
        h.join().unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn dependent_of_panicked_dep_still_runs() {
        // a panicked dep is terminal — the dependent proceeds (the async
        // executor surfaces the dep's panic at its own fence/retire)
        let pool = ThreadPool::new(2);
        let bad = pool.submit(Box::new(|| panic!("dep boom")));
        let h = pool.submit_after(&[&bad], Box::new(|| {}));
        h.join().unwrap();
        assert_eq!(bad.join(), Err(JobError::Panicked));
    }

    #[test]
    fn drop_cancels_queued_dependents() {
        let pool = ThreadPool::new(1);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g = gate.clone();
        let blocker = pool.submit(Box::new(move || {
            let (m, cv) = &*g;
            let mut open = m.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        }));
        let dep = pool.submit_after(&[&blocker], Box::new(|| {}));
        {
            let (m, cv) = &*gate;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
        blocker.join().unwrap();
        drop(pool);
        assert!(matches!(dep.join(), Ok(()) | Err(JobError::Cancelled)));
    }

    #[test]
    fn panicked_job_surfaces_payload_text() {
        let pool = ThreadPool::new(1);
        let h = pool.submit(Box::new(|| panic!("boom at tile {}", 7)));
        assert_eq!(h.join(), Err(JobError::Panicked));
        let msg = h.panic_message().expect("payload text captured");
        assert!(msg.contains("boom at tile 7"), "{msg}");
        // non-panic terminal states carry no message
        let ok = pool.submit(Box::new(|| {}));
        ok.join().unwrap();
        assert_eq!(ok.panic_message(), None);
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let pool = ThreadPool::new(2);
        let r = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scoped_for(8, |i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        // pool remains usable after a panicked job
        let hits = AtomicUsize::new(0);
        pool.scoped_for(4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }
}
