//! Minimal JSON substrate (serde is unavailable in the offline crate set).
//!
//! A full RFC-8259 parser + writer over an owned [`Json`] value tree, with
//! the accessor helpers the rest of the crate needs (manifest, configs,
//! metrics export, HTTP bodies). Numbers are kept as `f64`, which is exact
//! for every integer the manifests contain (< 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// An owned JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps serialization deterministic (stable key order).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    // ---- constructors --------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---- accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["config", "M"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        let n = self.as_f64()?;
        if n >= 0.0 && n.fract() == 0.0 {
            Some(n as usize)
        } else {
            None
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        let n = self.as_f64()?;
        if n.fract() == 0.0 {
            Some(n as i64)
        } else {
            None
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers: error messages carry the key name.
    pub fn req_usize(&self, key: &str) -> crate::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid usize field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> crate::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    pub fn req_arr(&self, key: &str) -> crate::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field '{key}'"))
    }

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    // ---- parsing -------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- serialization -------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(2 * (ind + 1)));
                        item.write(out, Some(ind + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if indent.is_some() && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(2 * indent.unwrap()));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(2 * (ind + 1)));
                        write_escaped(out, k);
                        out.push_str(": ");
                        val.write(out, Some(ind + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        val.write(out, None);
                    }
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(2 * indent.unwrap()));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "x"}, null], "c": false}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap()[1].req_str("b").unwrap(), "x");
        assert_eq!(j.get("c").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parse_unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"m": 6, "name": "tau_fft_4", "shape": [6, 4, 64], "f": 0.5}"#;
        let j = Json::parse(src).unwrap();
        for text in [j.to_string(), j.to_string_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), j);
        }
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "\"x", "{\"a\" 1}", "tru", "1 2", "{,}"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn integers_stay_integers_in_output() {
        let j = Json::parse("{\"n\": 4096}").unwrap();
        assert_eq!(j.to_string(), "{\"n\":4096}");
    }

    #[test]
    fn escaped_output_reparses() {
        let j = Json::Str("quote\" slash\\ tab\t nl\n".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
