//! Bench harness substrate (criterion is unavailable offline).
//!
//! Warmup + repeated timed runs + robust statistics, plus aligned-table and
//! CSV emission so every `rust/benches/*.rs` target prints the paper's
//! rows/series and leaves machine-readable output next to it.

use std::time::{Duration, Instant};

/// Statistics over repeated timed runs.
#[derive(Debug, Clone)]
pub struct Stats {
    pub runs: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub stddev_ns: f64,
}

impl Stats {
    pub fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            runs: n,
            mean_ns: mean,
            median_ns: ns[n / 2],
            min_ns: ns[0],
            max_ns: ns[n - 1],
            stddev_ns: var.sqrt(),
        }
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn median_us(&self) -> f64 {
        self.median_ns / 1e3
    }
}

/// Benchmark `f` with `warmup` unmeasured runs then `runs` measured runs
/// (paper protocol: "averaging over 4 runs following 2 runs of warm-up").
pub fn bench<F: FnMut()>(warmup: usize, runs: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let samples = (0..runs.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_nanos() as f64
        })
        .collect();
    Stats::from_samples(samples)
}

/// Time a single closure (per-token latency traces, breakdown timers).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Fixed-width table writer for terminal output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let cols: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            println!("  {}", cols.join("  "));
        };
        line(&self.headers);
        println!(
            "  {}",
            widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  ")
        );
        for row in &self.rows {
            line(row);
        }
    }

    /// CSV besides the human table (written under `bench_csv/`).
    pub fn write_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all("bench_csv")?;
        let path = std::path::Path::new("bench_csv").join(format!("{name}.csv"));
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        std::fs::write(&path, out)?;
        Ok(path)
    }
}

/// Env-var knobs so `cargo bench` scale can be tuned without rebuilds
/// (e.g. `FI_MAX_LEN=1024 cargo bench`).
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn env_str(name: &str, default: &str) -> String {
    std::env::var(name).unwrap_or_else(|_| default.to_string())
}

/// Scrape one numeric metric off a running server's `GET /metrics`
/// (Prometheus text exposition; shared by the serving bench and the
/// serving-smoke example so the parse lives in one place).
pub fn scrape_metric(addr: std::net::SocketAddr, name: &str) -> Option<f64> {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).ok()?;
    s.write_all(b"GET /metrics HTTP/1.1\r\n\r\n").ok()?;
    let mut buf = String::new();
    s.read_to_string(&mut buf).ok()?;
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("");
    body.lines()
        .find(|l| !l.starts_with('#') && l.starts_with(name))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
}

/// Skip helper: benches need `make artifacts` to have run.
pub fn require_artifacts(dir: &str) -> Option<std::path::PathBuf> {
    let p = std::path::PathBuf::from(dir);
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        println!("SKIP: artifacts not found at {dir} — run `make artifacts` first");
        None
    }
}

/// `meta` header stamped into every emitted `BENCH_*.json` so trajectory
/// diffs are attributable across runners: git sha, cpu brand + runtime
/// feature flags, simd compile/dispatch state, and (when the bench has
/// one) the worker count. `bench_compare.py` prints this attribution and
/// warns when the cpu differs from the committed baseline's.
pub fn bench_meta(workers: Option<usize>) -> crate::util::json::Json {
    use crate::util::json::Json;
    let sha = std::env::var("GITHUB_SHA")
        .ok()
        .or_else(|| {
            std::process::Command::new("git")
                .args(["rev-parse", "HEAD"])
                .output()
                .ok()
                .filter(|o| o.status.success())
                .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        })
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into());
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    Json::from_pairs(vec![
        ("sha", Json::Str(sha)),
        ("cpu", Json::Str(cpu_brand().unwrap_or_else(|| std::env::consts::ARCH.to_string()))),
        ("cpu_features", Json::Str(cpu_features())),
        ("os", Json::Str(std::env::consts::OS.into())),
        ("arch", Json::Str(std::env::consts::ARCH.into())),
        ("threads", Json::Num(threads as f64)),
        ("simd_compiled", Json::Bool(cfg!(feature = "simd"))),
        ("simd_backend", Json::Str(crate::fft::simd::backend_name().into())),
        ("workers", workers.map_or(Json::Null, |w| Json::Num(w as f64))),
    ])
}

/// CPU brand string from /proc/cpuinfo (Linux); None elsewhere.
fn cpu_brand() -> Option<String> {
    let text = std::fs::read_to_string("/proc/cpuinfo").ok()?;
    text.lines()
        .find(|l| l.starts_with("model name"))
        .and_then(|l| l.split(':').nth(1))
        .map(|s| s.trim().to_string())
}

/// Runtime-detected vector feature flags relevant to `fft::simd`.
fn cpu_features() -> String {
    #[cfg(target_arch = "x86_64")]
    {
        let mut f = Vec::new();
        if is_x86_feature_detected!("avx2") {
            f.push("avx2");
        }
        if is_x86_feature_detected!("fma") {
            f.push("fma");
        }
        if is_x86_feature_detected!("avx512f") {
            f.push("avx512f");
        }
        f.join(",")
    }
    #[cfg(target_arch = "aarch64")]
    {
        "neon".to_string()
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        String::new()
    }
}

/// Format a nanosecond count human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.1}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_samples() {
        let s = Stats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.runs, 3);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 3.0);
        assert_eq!(s.median_ns, 2.0);
        assert!((s.mean_ns - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_expected_count() {
        let mut count = 0;
        let s = bench(2, 4, || count += 1);
        assert_eq!(count, 6);
        assert_eq!(s.runs, 4);
    }

    #[test]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(vec!["1".into()])
        }));
        assert!(r.is_err());
    }

    #[test]
    fn bench_meta_has_attribution_keys() {
        let m = bench_meta(Some(3));
        for key in ["sha", "cpu", "cpu_features", "os", "arch", "simd_compiled", "simd_backend"] {
            assert!(m.get(key).is_some(), "missing meta key {key}");
        }
        assert!(!m.get("sha").unwrap().as_str().unwrap().is_empty());
        assert_eq!(m.get("workers").unwrap().as_usize(), Some(3));
        assert!(matches!(
            m.get("simd_backend").unwrap().as_str(),
            Some("scalar" | "avx2" | "neon")
        ));
        // without a worker count the field is explicit null, not absent
        assert!(bench_meta(None).get("workers").is_some());
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.5us");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.200s");
    }
}
