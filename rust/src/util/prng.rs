//! Deterministic PRNG substrate (the `rand` crate family is unavailable
//! offline). SplitMix64 for seeding + xoshiro256** for the stream — the
//! standard pairing, passes BigCrush, and is reproducible across runs,
//! which the exactness tests and golden traces rely on.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the 256-bit state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Prng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias is < 2^-64 * n, irrelevant here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi].
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard normal f32, the common case for activations/noise.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, sigma) noise.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out {
            *v = self.normal_f32() * sigma;
        }
    }

    /// Exponential with rate lambda (Poisson arrival gaps in the trace gen).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.uniform().max(1e-300).ln() / lambda
    }

    /// Fork an independent stream (for per-request / per-thread rngs).
    pub fn fork(&mut self) -> Prng {
        Prng::new(self.next_u64())
    }

    /// The full 256-bit generator state — a suspended stream resumes
    /// *exactly* where it left off via [`Prng::from_state`] (session
    /// paging checkpoints a lane's sampler through this, so an
    /// evicted-then-resumed rollout replays the identical draw sequence).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a captured [`Prng::state`].
    pub fn from_state(s: [u64; 4]) -> Prng {
        Prng { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Prng::new(1).next_u64(), Prng::new(2).next_u64());
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut p = Prng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = p.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(3);
        let n = 40_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = p.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut p = Prng::new(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let v = p.below(8);
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exponential_mean() {
        let mut p = Prng::new(5);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| p.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut p = Prng::new(1);
        let mut a = p.fork();
        let mut b = p.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn state_roundtrip_resumes_mid_stream() {
        let mut p = Prng::new(42);
        for _ in 0..17 {
            p.next_u64();
        }
        let snap = p.state();
        let tail: Vec<u64> = (0..32).map(|_| p.next_u64()).collect();
        let mut resumed = Prng::from_state(snap);
        let replay: Vec<u64> = (0..32).map(|_| resumed.next_u64()).collect();
        assert_eq!(tail, replay, "restored state must replay the exact stream");
    }
}
