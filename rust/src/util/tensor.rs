//! Dense row-major f32 tensors — the host-side data substrate.
//!
//! Deliberately tiny: the hot path works on raw `&[f32]` slices carved out
//! of [`Tensor`] storage; the struct only carries shape metadata and the
//! indexing helpers the engines need ([G, T, D] activation layouts).

use anyhow::{bail, Result};

/// Owned row-major f32 tensor with runtime shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?}: element count mismatch", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Row stride of the trailing `k` axes.
    pub fn stride_of(&self, axis: usize) -> usize {
        self.shape[axis + 1..].iter().product()
    }

    /// Immutable row `[i, ..]` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.shape.len(), 2);
        let d = self.shape[1];
        &self.data[i * d..(i + 1) * d]
    }

    /// Slice `[g, t, ..]` of a rank-3 tensor.
    pub fn at2(&self, g: usize, t: usize) -> &[f32] {
        debug_assert_eq!(self.shape.len(), 3);
        let d = self.shape[2];
        let off = (g * self.shape[1] + t) * d;
        &self.data[off..off + d]
    }

    pub fn at2_mut(&mut self, g: usize, t: usize) -> &mut [f32] {
        debug_assert_eq!(self.shape.len(), 3);
        let d = self.shape[2];
        let off = (g * self.shape[1] + t) * d;
        &mut self.data[off..off + d]
    }

    /// Contiguous block `[g, t0..t1, :]` of a rank-3 tensor.
    pub fn block(&self, g: usize, t0: usize, t1: usize) -> &[f32] {
        debug_assert_eq!(self.shape.len(), 3);
        let d = self.shape[2];
        let off = (g * self.shape[1] + t0) * d;
        &self.data[off..off + (t1 - t0) * d]
    }

    pub fn block_mut(&mut self, g: usize, t0: usize, t1: usize) -> &mut [f32] {
        debug_assert_eq!(self.shape.len(), 3);
        let d = self.shape[2];
        let off = (g * self.shape[1] + t0) * d;
        &mut self.data[off..off + (t1 - t0) * d]
    }

    /// Max |a - b| over two equal-shaped tensors.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative L2 error ||a-b|| / (||b|| + eps).
    pub fn rel_l2(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for (a, b) in self.data.iter().zip(&other.data) {
            num += ((a - b) * (a - b)) as f64;
            den += (b * b) as f64;
        }
        (num.sqrt() / (den.sqrt() + 1e-12)) as f32
    }
}

/// `axpy`-style helpers used by the native tau kernels and engines.
pub mod ops {
    /// out += a ⊙ b (elementwise), all length-n.
    #[inline]
    pub fn add_mul(out: &mut [f32], a: &[f32], b: &[f32]) {
        debug_assert_eq!(out.len(), a.len());
        debug_assert_eq!(out.len(), b.len());
        for i in 0..out.len() {
            out[i] += a[i] * b[i];
        }
    }

    /// out += a (elementwise).
    #[inline]
    pub fn add_assign(out: &mut [f32], a: &[f32]) {
        debug_assert_eq!(out.len(), a.len());
        for i in 0..out.len() {
            out[i] += a[i];
        }
    }

    /// Euclidean norm.
    pub fn l2(a: &[f32]) -> f32 {
        a.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.shape(), &[2, 3, 4]);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn from_vec_checks_len() {
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 4]).is_ok());
    }

    #[test]
    fn rank3_indexing() {
        let mut t = Tensor::zeros(&[2, 3, 2]);
        t.at2_mut(1, 2).copy_from_slice(&[5.0, 6.0]);
        assert_eq!(t.at2(1, 2), &[5.0, 6.0]);
        assert_eq!(t.data()[10..12], [5.0, 6.0]);
        assert_eq!(t.block(1, 1, 3).len(), 4);
    }

    #[test]
    fn reshape_roundtrip() {
        let t = Tensor::from_vec(&[2, 6], (0..12).map(|i| i as f32).collect()).unwrap();
        let t = t.reshape(&[3, 4]).unwrap();
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0, 7.0]);
        assert!(t.clone().reshape(&[5, 5]).is_err());
    }

    #[test]
    fn diff_metrics() {
        let a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::from_vec(&[3], vec![1.0, 2.5, 3.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.5);
        assert!(a.rel_l2(&a) < 1e-12);
    }

    #[test]
    fn ops_add_mul() {
        let mut out = vec![1.0, 1.0];
        ops::add_mul(&mut out, &[2.0, 3.0], &[10.0, 100.0]);
        assert_eq!(out, vec![21.0, 301.0]);
    }
}
