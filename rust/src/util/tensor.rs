//! Dense row-major f32 tensors — the host-side data substrate.
//!
//! Deliberately tiny: the hot path works on raw `&[f32]` slices carved out
//! of [`Tensor`] storage; the struct only carries shape metadata and the
//! indexing helpers the engines need ([G, T, D] activation layouts).

use std::cell::UnsafeCell;

use anyhow::{bail, Result};

/// Owned row-major f32 tensor with runtime shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?}: element count mismatch", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Row stride of the trailing `k` axes.
    pub fn stride_of(&self, axis: usize) -> usize {
        self.shape[axis + 1..].iter().product()
    }

    /// Immutable row `[i, ..]` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.shape.len(), 2);
        let d = self.shape[1];
        &self.data[i * d..(i + 1) * d]
    }

    /// Slice `[g, t, ..]` of a rank-3 tensor.
    pub fn at2(&self, g: usize, t: usize) -> &[f32] {
        debug_assert_eq!(self.shape.len(), 3);
        let d = self.shape[2];
        let off = (g * self.shape[1] + t) * d;
        &self.data[off..off + d]
    }

    pub fn at2_mut(&mut self, g: usize, t: usize) -> &mut [f32] {
        debug_assert_eq!(self.shape.len(), 3);
        let d = self.shape[2];
        let off = (g * self.shape[1] + t) * d;
        &mut self.data[off..off + d]
    }

    /// Contiguous block `[g, t0..t1, :]` of a rank-3 tensor.
    pub fn block(&self, g: usize, t0: usize, t1: usize) -> &[f32] {
        debug_assert_eq!(self.shape.len(), 3);
        let d = self.shape[2];
        let off = (g * self.shape[1] + t0) * d;
        &self.data[off..off + (t1 - t0) * d]
    }

    pub fn block_mut(&mut self, g: usize, t0: usize, t1: usize) -> &mut [f32] {
        debug_assert_eq!(self.shape.len(), 3);
        let d = self.shape[2];
        let off = (g * self.shape[1] + t0) * d;
        &mut self.data[off..off + (t1 - t0) * d]
    }

    /// Max |a - b| over two equal-shaped tensors.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative L2 error ||a-b|| / (||b|| + eps).
    pub fn rel_l2(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for (a, b) in self.data.iter().zip(&other.data) {
            num += ((a - b) * (a - b)) as f64;
            den += (b * b) as f64;
        }
        (num.sqrt() / (den.sqrt() + 1e-12)) as f32
    }
}

/// Shared-mutation rank-3 `[G, T, D]` f32 plane for the async mixer.
///
/// The deadline-fenced executor keeps tile jobs in flight on pool workers
/// while the engine thread reads *other* rows of the same plane. A plain
/// [`Tensor`] cannot express that: handing a worker a raw pointer carved
/// from `data_mut()` and then touching the tensor through `&mut` again on
/// the engine thread invalidates the worker's pointer under Stacked
/// Borrows. `CellTensor` makes the aliasing legal at the type level —
/// storage is element-wise `UnsafeCell<f32>`, every accessor (read *and*
/// write) goes through `&self`, and pointers are derived with
/// [`UnsafeCell::raw_get`] so no transient `&mut` is ever materialized.
///
/// Safety discipline, enforced dynamically by the store's row-readiness
/// fences (see `engine/store.rs`):
/// * writers hold row-exclusive access for the duration of the write
///   (`begin_write` .. `end_write` around the unsafe `*_mut` accessors);
/// * safe readers (`at2`, `block`, `to_tensor`) may only touch rows that
///   are *quiet* — the caller fences before reading.
///
/// There is deliberately no `&mut CellTensor` API: sessions share the
/// plane via `Arc<CellTensor>` with in-flight jobs, so exclusive borrows
/// would be both unobtainable and, if conjured, unsound.
pub struct CellTensor {
    shape: Vec<usize>,
    data: Box<[UnsafeCell<f32>]>,
}

// SAFETY: all mutation goes through `unsafe` accessors whose contract is
// caller-guaranteed row exclusivity (the store's readiness fences); with
// that contract upheld there are no data races, so sharing across threads
// is sound.
unsafe impl Sync for CellTensor {}
// SAFETY: `UnsafeCell<f32>` is `Send`; the struct owns its storage.
unsafe impl Send for CellTensor {}

impl CellTensor {
    pub fn zeros(shape: &[usize]) -> CellTensor {
        let n: usize = shape.iter().product();
        let data: Box<[UnsafeCell<f32>]> =
            (0..n).map(|_| UnsafeCell::new(0.0)).collect();
        CellTensor { shape: shape.to_vec(), data }
    }

    /// Copy a [`Tensor`]'s shape and contents into a fresh cell plane.
    pub fn from_tensor(t: &Tensor) -> CellTensor {
        let data: Box<[UnsafeCell<f32>]> =
            t.data().iter().map(|&v| UnsafeCell::new(v)).collect();
        CellTensor { shape: t.shape().to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Base pointer into the cell storage. A pure cast — deriving it does
    /// not retag the allocation, so pointers handed to in-flight jobs stay
    /// valid no matter what the engine thread does through `&self`.
    #[inline]
    fn base_ptr(&self) -> *mut f32 {
        UnsafeCell::raw_get(self.data.as_ptr())
    }

    #[inline]
    fn offset(&self, g: usize, t: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 3);
        debug_assert!(g < self.shape[0] && t < self.shape[1]);
        (g * self.shape[1] + t) * self.shape[2]
    }

    /// Read row `[g, t, :]`. The caller must have fenced: the row must be
    /// quiet (no in-flight writer) for the lifetime of the slice.
    #[inline]
    pub fn at2(&self, g: usize, t: usize) -> &[f32] {
        let d = self.shape[2];
        let off = self.offset(g, t);
        assert!(off + d <= self.data.len());
        // SAFETY: in-bounds; quietness per the method contract means no
        // concurrent writer overlaps this range.
        unsafe { std::slice::from_raw_parts(self.base_ptr().add(off), d) }
    }

    /// Read block `[g, t0..t1, :]`. Same quietness contract as [`Self::at2`].
    #[inline]
    pub fn block(&self, g: usize, t0: usize, t1: usize) -> &[f32] {
        let d = self.shape[2];
        let off = self.offset(g, t0);
        let n = (t1 - t0) * d;
        assert!(t1 <= self.shape[1] && off + n <= self.data.len());
        // SAFETY: in-bounds; quiet rows per the method contract.
        unsafe { std::slice::from_raw_parts(self.base_ptr().add(off), n) }
    }

    /// Mutable row `[g, t, :]`.
    ///
    /// # Safety
    /// The caller must guarantee exclusive access to this row for the
    /// lifetime of the slice — in the engine that means the row is inside
    /// a `begin_write`..`end_write` window this caller owns, or no jobs
    /// are in flight at all.
    #[allow(clippy::mut_from_ref)] // shared-mutation container; exclusivity is the unsafe contract
    #[inline]
    pub unsafe fn at2_mut(&self, g: usize, t: usize) -> &mut [f32] {
        let d = self.shape[2];
        let off = self.offset(g, t);
        assert!(off + d <= self.data.len());
        std::slice::from_raw_parts_mut(self.base_ptr().add(off), d)
    }

    /// Mutable block `[g, t0..t1, :]`.
    ///
    /// # Safety
    /// Same row-exclusivity contract as [`Self::at2_mut`], over every row
    /// in `t0..t1`.
    #[allow(clippy::mut_from_ref)] // shared-mutation container; exclusivity is the unsafe contract
    #[inline]
    pub unsafe fn block_mut(&self, g: usize, t0: usize, t1: usize) -> &mut [f32] {
        let d = self.shape[2];
        let off = self.offset(g, t0);
        let n = (t1 - t0) * d;
        assert!(t1 <= self.shape[1] && off + n <= self.data.len());
        std::slice::from_raw_parts_mut(self.base_ptr().add(off), n)
    }

    /// Snapshot into an owned [`Tensor`]. The whole plane must be quiet.
    pub fn to_tensor(&self) -> Tensor {
        let data: Vec<f32> = self
            .data
            .iter()
            // SAFETY: quiet plane per the method contract — plain reads.
            .map(|c| unsafe { *c.get() })
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }
}

/// `axpy`-style helpers used by the native tau kernels and engines.
pub mod ops {
    /// out += a ⊙ b (elementwise), all length-n.
    #[inline]
    pub fn add_mul(out: &mut [f32], a: &[f32], b: &[f32]) {
        debug_assert_eq!(out.len(), a.len());
        debug_assert_eq!(out.len(), b.len());
        for i in 0..out.len() {
            out[i] += a[i] * b[i];
        }
    }

    /// out += a (elementwise).
    #[inline]
    pub fn add_assign(out: &mut [f32], a: &[f32]) {
        debug_assert_eq!(out.len(), a.len());
        for i in 0..out.len() {
            out[i] += a[i];
        }
    }

    /// Euclidean norm.
    pub fn l2(a: &[f32]) -> f32 {
        a.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.shape(), &[2, 3, 4]);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn from_vec_checks_len() {
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 4]).is_ok());
    }

    #[test]
    fn rank3_indexing() {
        let mut t = Tensor::zeros(&[2, 3, 2]);
        t.at2_mut(1, 2).copy_from_slice(&[5.0, 6.0]);
        assert_eq!(t.at2(1, 2), &[5.0, 6.0]);
        assert_eq!(t.data()[10..12], [5.0, 6.0]);
        assert_eq!(t.block(1, 1, 3).len(), 4);
    }

    #[test]
    fn reshape_roundtrip() {
        let t = Tensor::from_vec(&[2, 6], (0..12).map(|i| i as f32).collect()).unwrap();
        let t = t.reshape(&[3, 4]).unwrap();
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0, 7.0]);
        assert!(t.clone().reshape(&[5, 5]).is_err());
    }

    #[test]
    fn diff_metrics() {
        let a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::from_vec(&[3], vec![1.0, 2.5, 3.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.5);
        assert!(a.rel_l2(&a) < 1e-12);
    }

    #[test]
    fn ops_add_mul() {
        let mut out = vec![1.0, 1.0];
        ops::add_mul(&mut out, &[2.0, 3.0], &[10.0, 100.0]);
        assert_eq!(out, vec![21.0, 301.0]);
    }

    #[test]
    fn cell_tensor_roundtrips_tensor() {
        let mut t = Tensor::zeros(&[2, 3, 2]);
        t.at2_mut(1, 2).copy_from_slice(&[5.0, 6.0]);
        let c = CellTensor::from_tensor(&t);
        assert_eq!(c.shape(), &[2, 3, 2]);
        assert_eq!(c.len(), 12);
        assert_eq!(c.at2(1, 2), &[5.0, 6.0]);
        assert_eq!(c.block(1, 1, 3).len(), 4);
        assert_eq!(c.to_tensor().max_abs_diff(&t), 0.0);
    }

    #[test]
    fn cell_tensor_writes_through_shared_ref() {
        let c = CellTensor::zeros(&[1, 4, 2]);
        // SAFETY: single-threaded test, no other access to these rows
        unsafe {
            c.at2_mut(0, 1).copy_from_slice(&[1.0, 2.0]);
            c.block_mut(0, 2, 4).fill(7.0);
        }
        assert_eq!(c.at2(0, 0), &[0.0, 0.0]);
        assert_eq!(c.at2(0, 1), &[1.0, 2.0]);
        assert_eq!(c.at2(0, 3), &[7.0, 7.0]);
    }

    #[test]
    fn cell_tensor_disjoint_rows_written_from_threads() {
        use std::sync::Arc;
        let c = Arc::new(CellTensor::zeros(&[1, 8, 4]));
        let handles: Vec<_> = (0..8usize)
            .map(|t| {
                let c = c.clone();
                std::thread::spawn(move || {
                    // SAFETY: each thread owns exactly one row
                    unsafe { c.at2_mut(0, t) }.fill(t as f32);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for t in 0..8 {
            assert!(c.at2(0, t).iter().all(|&v| v == t as f32));
        }
    }
}
