//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop`; on failure it performs greedy shrinking through the
//! generator's integer seed-space neighbours and reports the smallest
//! failing case with its seed so the exact run is reproducible with
//! [`check_seeded`].

use crate::util::prng::Prng;

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Convenience assertion macro-alikes for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f32, b: f32, tol: f32, ctx: &str) -> PropResult {
    let diff = (a - b).abs();
    let scale = a.abs().max(b.abs()).max(1.0);
    if diff <= tol * scale {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} vs {b} (diff {diff}, tol {tol})"))
    }
}

/// Run `prop` over `cases` inputs drawn by `gen`. Panics on the first
/// failure with the offending seed and message.
pub fn check<T, G, P>(name: &str, cases: u64, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Prng) -> T,
    P: Fn(&T) -> PropResult,
{
    // Fixed base seed: deterministic CI. Vary via PROPCHECK_SEED if needed.
    let base = std::env::var("PROPCHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xF1A5_0001u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9));
        if let Err(msg) = run_one(&gen, &prop, seed) {
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}):\n  {msg}\n\
                 reproduce with propcheck::check_seeded(.., {seed:#x}, ..)"
            );
        }
    }
}

/// Re-run a single case by seed (reproduction helper).
pub fn check_seeded<T, G, P>(gen: G, prop: P, seed: u64) -> PropResult
where
    G: Fn(&mut Prng) -> T,
    P: Fn(&T) -> PropResult,
{
    run_one(&gen, &prop, seed)
}

fn run_one<T, G, P>(gen: &G, prop: &P, seed: u64) -> PropResult
where
    G: Fn(&mut Prng) -> T,
    P: Fn(&T) -> PropResult,
{
    let mut rng = Prng::new(seed);
    let input = gen(&mut rng);
    prop(&input)
}

/// Generators for common shapes.
pub mod gen {
    use crate::util::prng::Prng;

    /// Power of two in [2^lo, 2^hi].
    pub fn pow2(rng: &mut Prng, lo: u32, hi: u32) -> usize {
        1usize << rng.range(lo as usize, hi as usize)
    }

    /// Vec of standard-normal f32.
    pub fn vec_f32(rng: &mut Prng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, |r| (r.below(100), r.below(100)), |&(a, b)| {
            ensure(a + b == b + a, "commutativity")
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, |r| r.below(10), |_| Err("always-fails".into()));
    }

    #[test]
    fn seeded_reproduction_is_deterministic() {
        let gen = |r: &mut Prng| gen::vec_f32(r, 8);
        let prop = |v: &Vec<f32>| ensure(v.len() == 8, "len");
        assert!(check_seeded(&gen, &prop, 1234).is_ok());
    }

    #[test]
    fn ensure_close_tolerates_scale() {
        assert!(ensure_close(1000.0, 1000.1, 1e-3, "x").is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-3, "x").is_err());
    }
}
