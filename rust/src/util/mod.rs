//! Infrastructure substrates built in-repo (the offline crate set has no
//! serde/rand/rayon/proptest/criterion — see DESIGN.md §2.2).

pub mod benchkit;
pub mod faultpoint;
pub mod json;
pub mod prng;
pub mod propcheck;
pub mod tensor;
pub mod threadpool;
