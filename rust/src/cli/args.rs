//! CLI argument parsing substrate (clap is unavailable offline).
//!
//! Grammar: `flashinfer <command> [--flag value] [--switch] [positional..]`
//! Flags may be `--name value` or `--name=value`; unknown flags are
//! rejected against a per-command schema so typos fail loudly.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, Result};

/// Declarative flag schema for one command.
pub struct Schema {
    /// flag name -> (takes_value, help)
    entries: BTreeMap<&'static str, (bool, &'static str)>,
}

impl Schema {
    pub fn new() -> Schema {
        Schema { entries: BTreeMap::new() }
    }

    pub fn value(mut self, name: &'static str, help: &'static str) -> Schema {
        self.entries.insert(name, (true, help));
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Schema {
        self.entries.insert(name, (false, help));
        self
    }

    pub fn help_text(&self) -> String {
        let mut out = String::new();
        for (name, (takes, help)) in &self.entries {
            let arg = if *takes { format!("--{name} <v>") } else { format!("--{name}") };
            out.push_str(&format!("    {arg:<28} {help}\n"));
        }
        out
    }

    /// Parse `argv` (after the command word).
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut values = BTreeMap::new();
        let mut switches = BTreeSet::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let Some((takes_value, _)) = self.entries.get(name.as_str()) else {
                    bail!("unknown flag --{name}\nvalid flags:\n{}", self.help_text());
                };
                if *takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?
                        }
                    };
                    values.insert(name, v);
                } else {
                    if inline.is_some() {
                        bail!("--{name} takes no value");
                    }
                    switches.insert(name);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { values, switches, positional })
    }
}

impl Default for Schema {
    fn default() -> Self {
        Self::new()
    }
}

/// Parsed arguments.
#[derive(Debug)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: BTreeSet<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: '{v}' is not a valid integer")),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: '{v}' is not a valid number")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: '{v}' is not a valid integer")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.contains(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn schema() -> Schema {
        Schema::new()
            .value("len", "tokens to generate")
            .value("tau", "tau impl")
            .switch("verbose", "chatty output")
    }

    fn parse(s: &[&str]) -> Result<Args> {
        schema().parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn parses_values_switches_positionals() {
        let a = parse(&["--len", "256", "--verbose", "artifacts/x", "--tau=hybrid"]).unwrap();
        assert_eq!(a.get_usize("len", 0).unwrap(), 256);
        assert_eq!(a.get("tau"), Some("hybrid"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["artifacts/x"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.get_usize("len", 42).unwrap(), 42);
        assert_eq!(a.get_or("tau", "hybrid"), "hybrid");
        assert!(!a.has("verbose"));
    }

    #[test]
    fn rejects_unknown_flag() {
        assert!(parse(&["--nope"]).is_err());
    }

    #[test]
    fn rejects_missing_value_and_bad_ints() {
        assert!(parse(&["--len"]).is_err());
        let a = parse(&["--len", "abc"]).unwrap();
        assert!(a.get_usize("len", 0).is_err());
    }

    #[test]
    fn switch_with_value_rejected() {
        assert!(parse(&["--verbose=yes"]).is_err());
    }
}
