//! `flashinfer validate` — exactness audit: flash == lazy == eager across
//! every τ implementation, plus the python golden rollout when present.
//! This is the runnable form of the paper's "exact inference" claim.

use anyhow::Result;

use crate::cli::args::Schema;
use crate::engine::{Engine, EngineOpts, Method};
use crate::model::Weights;
use crate::runtime::Runtime;
use crate::tau::TauKind;
use crate::util::benchkit::Table;

pub fn run(argv: &[String]) -> Result<i32> {
    let schema = Schema::new()
        .value("artifacts", "artifact build dir (default artifacts/synthetic)")
        .value("len", "positions to generate (default 64)")
        .value("tol", "relative L2 tolerance (default 1e-4)")
        .switch("help", "show this help");
    if super::maybe_help("flashinfer validate", &schema, argv) {
        return Ok(0);
    }
    let a = schema.parse(argv)?;
    let dir = std::path::PathBuf::from(a.get_or("artifacts", "artifacts/synthetic"));
    let len = a.get_usize("len", 64)?;
    let tol = a.get_f32("tol", 1e-4)?;

    let rt = Runtime::load(&dir)?;
    println!("validating {} at len={len}, tol={tol}", dir.display());

    let gen = |method: Method, tau: TauKind| -> Result<crate::engine::GenOutput> {
        let mut eng = Engine::new(
            &rt,
            EngineOpts { method, tau, record_streams: true, ..Default::default() },
        )?;
        eng.generate(len)
    };

    let reference = gen(Method::Lazy, TauKind::RustDirect)?;
    let ref_streams = reference.streams.as_ref().unwrap();

    let mut table = Table::new(&["engine", "tau", "rel_l2_vs_lazy", "status"]);
    let mut failures = 0;
    let mut check = |name: &str, tau: &str, err: f32| {
        let ok = err < tol;
        if !ok {
            failures += 1;
        }
        table.row(vec![
            name.into(),
            tau.into(),
            format!("{err:.2e}"),
            if ok { "OK".into() } else { "FAIL".into() },
        ]);
    };

    let eager = gen(Method::Eager, TauKind::RustDirect)?;
    check("eager", "-", eager.streams.as_ref().unwrap().rel_l2(ref_streams));
    for tau in TauKind::ALL_FIXED.iter().chain([TauKind::Hybrid].iter()) {
        let out = gen(Method::Flash, *tau)?;
        check("flash", tau.as_str(), out.streams.as_ref().unwrap().rel_l2(ref_streams));
    }
    table.print();

    // golden rollout comparison (python lazy reference from aot.py)
    if let Some(golden) = &rt.manifest.golden {
        let g = Weights::load(&golden.file)?;
        let want = g.get("streams")?;
        let steps = golden.steps.min(len);
        let dims = rt.dims;
        let mut max_err = 0.0f32;
        for m in 0..dims.m {
            for b in 0..dims.b {
                let gi = m * dims.b + b;
                for t in 0..steps {
                    let row = ref_streams.at2(gi, t);
                    for k in 0..dims.d {
                        let w = want.data()[((m * dims.b + b) * golden.steps + t) * dims.d + k];
                        max_err = max_err.max((row[k] - w).abs());
                    }
                }
            }
        }
        let ok = max_err < 5e-3;
        println!(
            "python golden ({} steps): max_abs_err = {max_err:.2e} {}",
            steps,
            if ok { "OK" } else { "FAIL" }
        );
        if !ok {
            failures += 1;
        }
    }

    if failures == 0 {
        println!("validate: ALL OK");
        Ok(0)
    } else {
        println!("validate: {failures} FAILURES");
        Ok(1)
    }
}
