//! `flashinfer calibrate` — measure every τ impl per tile size, print the
//! Pareto table (Fig 3a's data) and write hybrid.json for the Hybrid τ.

use anyhow::Result;

use crate::cli::args::Schema;
use crate::runtime::Runtime;
use crate::tau::{calibrate, RhoCache};
use crate::util::benchkit::{fmt_ns, Table};

pub fn run(argv: &[String]) -> Result<i32> {
    let schema = Schema::new()
        .value("artifacts", "artifact build dir (default artifacts/synthetic)")
        .value("max-u", "largest tile size to calibrate (default L/2)")
        .value("warmup", "warmup runs per point (default 2, paper protocol)")
        .value("runs", "measured runs per point (default 4, paper protocol)")
        .switch("dry-run", "measure and print but do not write hybrid.json")
        .switch("help", "show this help");
    if super::maybe_help("flashinfer calibrate", &schema, argv) {
        return Ok(0);
    }
    let a = schema.parse(argv)?;
    let dir = std::path::PathBuf::from(a.get_or("artifacts", "artifacts/synthetic"));

    let rt = Runtime::load(&dir)?;
    let max_u = a.get_usize("max-u", rt.dims.l / 2)?;
    let warmup = a.get_usize("warmup", 2)?;
    let runs = a.get_usize("runs", 4)?;

    println!(
        "calibrating tau impls on {} (G={}, D={}, U up to {max_u})",
        dir.display(), rt.dims.g, rt.dims.d
    );
    let cache = RhoCache::new(&rt)?;
    let (table, rows) = calibrate(&cache, max_u, warmup, runs)?;

    let mut t = Table::new(&["U", "rust-direct", "rust-fft", "pjrt-direct", "pjrt-fft", "winner"]);
    for row in &rows {
        let mut cells = vec![row.u.to_string()];
        for (_, ns) in &row.medians_ns {
            cells.push(fmt_ns(*ns));
        }
        cells.push(row.winner.as_str().to_string());
        t.row(cells);
    }
    t.print();

    if !a.has("dry-run") {
        let path = dir.join("hybrid.json");
        table.save(&path)?;
        println!("wrote {}", path.display());
    }
    Ok(0)
}
