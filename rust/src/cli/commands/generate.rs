//! `flashinfer generate` — one generation session with a timing report.

use anyhow::Result;

use crate::cli::args::Schema;
use crate::config::ServerConfig;
use crate::engine::Engine;
use crate::runtime::Runtime;
use crate::util::benchkit::fmt_ns;

pub fn run(argv: &[String]) -> Result<i32> {
    let schema = super::engine_schema(Schema::new())
        .value("len", "positions to generate (power of two, default 256)")
        .switch("stream", "emit each position as it is generated (Session::step loop)")
        .switch("per-token", "print the per-token latency trace")
        .switch("flops", "print the FLOP/tau-call accounting");
    if super::maybe_help("flashinfer generate", &schema, argv) {
        return Ok(0);
    }
    let a = schema.parse(argv)?;
    let mut cfg = ServerConfig::default();
    cfg.apply_args(&a)?;
    let len = a.get_usize("len", 256)?;

    let rt = Runtime::load(&cfg.artifacts)?;
    let d = rt.dims;
    println!(
        "model: variant={} M={} D={} L={} B={} | method={} tau={}",
        d.variant.as_str(), d.m, d.d, d.l, d.b,
        cfg.engine.method.as_str(), cfg.engine.tau.as_str()
    );

    let mut engine = Engine::new(&rt, cfg.engine)?;
    let t0 = std::time::Instant::now();
    engine.prewarm(len)?;
    println!("prewarm: {}", fmt_ns(t0.elapsed().as_nanos() as f64));

    let out = if a.has("stream") {
        // drive the session manually: tokens leave the loop per position,
        // exactly what a streaming serving lane sees
        let mut session = engine.session(len)?;
        let t0 = std::time::Instant::now();
        let mut first_ns: Option<f64> = None;
        while !session.is_done() {
            let step = session.step()?;
            if first_ns.is_none() {
                first_ns = Some(t0.elapsed().as_nanos() as f64);
            }
            match &step.tokens {
                Some(toks) => println!("pos {:>6}  token {}", step.pos, toks[0]),
                None => println!("pos {:>6}  out-checksum {:+.5}", step.pos, step.checksum),
            }
        }
        if let Some(ns) = first_ns {
            println!("first-token latency: {}", fmt_ns(ns));
        }
        session.finish()
    } else {
        engine.generate(len)?
    };
    let m = &out.metrics;
    println!(
        "generated {} positions in {} (mixer {}, step {}, sample {})",
        out.steps,
        fmt_ns(m.wall.as_nanos() as f64),
        fmt_ns(m.totals.mixer_ns),
        fmt_ns(m.totals.step_ns),
        fmt_ns(m.totals.sample_ns),
    );
    println!(
        "throughput: {:.1} tok/s | critical-path mixer share {:.1}%",
        out.steps as f64 / m.wall.as_secs_f64(),
        100.0 * m.totals.mixer_ns / m.totals.total_ns()
    );
    if m.totals.tau_worker_ns > 0.0 {
        // async executor ran: show how much tau left the critical path
        println!(
            "async mixer: {} on worker, fence-wait {} exposed, {} hidden ({:.1}% of tau compute)",
            fmt_ns(m.totals.tau_worker_ns),
            fmt_ns(m.totals.fence_ns),
            fmt_ns(m.totals.hidden_mixer_ns()),
            100.0 * m.totals.hidden_mixer_ns() / m.totals.mixer_total_ns().max(1.0),
        );
    }
    if let Some(tokens) = &out.tokens {
        let prefix: Vec<String> =
            tokens[0].iter().take(16).map(|t| t.to_string()).collect();
        println!("lane 0 tokens: [{} ...]", prefix.join(", "));
    }

    if a.has("flops") {
        println!(
            "mixer FLOPs: {:.3e} | tau calls: {} | tau IO values: {:.3e}",
            out.flops.mixer_flops as f64,
            out.flops.tau_calls,
            out.flops.tau_io_values as f64
        );
        for (u, c) in &out.flops.tau_call_hist {
            println!("  U={u:>5}: {c} calls");
        }
    }
    if a.has("per-token") {
        for (i, ns) in out.metrics.token_latencies_ns().iter().enumerate() {
            println!("{:>6} {}", i + 1, fmt_ns(*ns));
        }
    }
    Ok(0)
}
