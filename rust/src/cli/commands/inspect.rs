//! `flashinfer inspect` — print an artifact build's manifest, ABI and
//! weight inventory (debugging / ops aid).

use anyhow::Result;

use crate::cli::args::Schema;
use crate::runtime::Manifest;
use crate::model::Weights;
use crate::util::benchkit::Table;

pub fn run(argv: &[String]) -> Result<i32> {
    let schema = Schema::new()
        .value("artifacts", "artifact build dir (default artifacts/synthetic)")
        .switch("weights", "list every weight tensor")
        .switch("abi", "list every artifact's inputs/outputs")
        .switch("help", "show this help");
    if super::maybe_help("flashinfer inspect", &schema, argv) {
        return Ok(0);
    }
    let a = schema.parse(argv)?;
    let dir = std::path::PathBuf::from(a.get_or("artifacts", "artifacts/synthetic"));

    let man = Manifest::load(&dir)?;
    let d = man.dims;
    println!("artifact build: {}", dir.display());
    println!(
        "  variant={} M={} D={} H={} L={} B={} V={} G={}",
        d.variant.as_str(), d.m, d.d, d.h, d.l, d.b, d.v, d.g
    );
    println!("  artifacts: {}", man.artifacts.len());
    if let Some(g) = &man.golden {
        println!("  golden: {} steps ({})", g.steps, g.file.display());
    }

    let mut t = Table::new(&["artifact", "kind", "param", "inputs", "outputs", "file_kb"]);
    for art in &man.artifacts {
        let size = std::fs::metadata(man.dir.join(&art.file))
            .map(|m| m.len() / 1024)
            .unwrap_or(0);
        t.row(vec![
            art.name.clone(),
            art.kind.clone().unwrap_or_else(|| "-".into()),
            art.param.map(|p| p.to_string()).unwrap_or_else(|| "-".into()),
            art.inputs.len().to_string(),
            art.outputs.len().to_string(),
            size.to_string(),
        ]);
    }
    t.print();

    if a.has("abi") {
        for art in &man.artifacts {
            println!("\n{}:", art.name);
            for i in &art.inputs {
                println!("  in  {:<16} {:?}", i.name, i.shape);
            }
            for o in &art.outputs {
                println!("  out {:<16} {:?}", o.name, o.shape);
            }
        }
    }

    if a.has("weights") {
        let w = Weights::load(&man.weights_file)?;
        let mut names: Vec<&str> = w.names().collect();
        names.sort();
        println!("\nweights ({} tensors):", w.len());
        for n in names {
            let t = w.get(n)?;
            println!("  {:<16} {:?} ({} values)", n, t.shape(), t.len());
        }
    }
    Ok(0)
}
