//! Subcommand implementations for the `flashinfer` binary.

pub mod calibrate;
pub mod generate;
pub mod inspect;
pub mod serve;
pub mod validate;

use anyhow::Result;

use super::args::Schema;

pub const USAGE: &str = "\
flashinfer — Flash Inference for long convolution sequence models (ICLR 2025)

USAGE: flashinfer <command> [flags]

COMMANDS:
    generate    run one generation session and print timing/output summary
    serve       start the HTTP serving front-end
    calibrate   micro-bench tau impls per tile size, write hybrid.json
    validate    cross-check flash == lazy == eager == python golden
    inspect     print manifest/config/weights summary for an artifact dir

Run `flashinfer <command> --help` for per-command flags.
";

/// Dispatch on the command word.
pub fn run(argv: Vec<String>) -> Result<i32> {
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(2);
    };
    let rest = argv[1..].to_vec();
    match cmd.as_str() {
        "generate" => generate::run(&rest),
        "serve" => serve::run(&rest),
        "calibrate" => calibrate::run(&rest),
        "validate" => validate::run(&rest),
        "inspect" => inspect::run(&rest),
        "--help" | "-h" | "help" => {
            print!("{USAGE}");
            Ok(0)
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print!("{USAGE}");
            Ok(2)
        }
    }
}

/// Flags shared by engine-running commands.
pub fn engine_schema(s: Schema) -> Schema {
    s.value("artifacts", "artifact build dir (default artifacts/synthetic)")
        .value("method", "flash|lazy|eager (default flash)")
        .value("tau", "rust-direct|rust-fft|pjrt-direct|pjrt-fft|hybrid")
        .value("threads", "native-tau worker threads (default 0 = inline)")
        .value("sigma", "synthetic sampler noise (default 0)")
        .value("temperature", "LM sampling temperature (default 0 = argmax)")
        .value("top-k", "LM top-k (default 0 = all)")
        .value("seed", "sampler seed (default 0)")
        .switch("sync-mixer", "force gray tiles onto the critical path (async off, 1 worker)")
        .value("split-min-u", "async split-tile threshold (0 = never split, default)")
        .value("mixer-workers", "async mixer worker threads (default 1; >1 needs native tau)")
        .value("checksum-history", "per-position checksums retained (default 4096)")
        .switch("help", "show this help")
}

pub fn maybe_help(args_help: &str, schema: &Schema, argv: &[String]) -> bool {
    if argv.iter().any(|a| a == "--help" || a == "-h") {
        println!("{args_help}\nFLAGS:\n{}", schema.help_text());
        return true;
    }
    false
}
