//! `flashinfer serve` — start the HTTP serving front-end.

use anyhow::Result;

use crate::cli::args::Schema;
use crate::config::ServerConfig;
use crate::server::Server;

pub fn run(argv: &[String]) -> Result<i32> {
    let schema = super::engine_schema(Schema::new())
        .value("config", "JSON config file (defaults < file < flags)")
        .value("host", "bind host (default 127.0.0.1)")
        .value("port", "bind port (default 7070)")
        .value("batch-window-ms", "idle-state co-arrival window (default 5)")
        .value("max-tokens", "default tokens per request (default 256)")
        .switch("no-admission", "disable continuous admission (drain-then-refill batches)")
        .value("max-queue", "waiting-queue bound before shedding 429s (default 1024)")
        .switch("no-paging", "disable session paging (no lane eviction under queue pressure)")
        .value("pager-capacity-mb", "slab capacity for suspended-lane checkpoints (default 256)");
    if super::maybe_help("flashinfer serve", &schema, argv) {
        return Ok(0);
    }
    let a = schema.parse(argv)?;
    let mut cfg = match a.get("config") {
        Some(path) => ServerConfig::from_file(std::path::Path::new(path))?,
        None => ServerConfig::default(),
    };
    cfg.apply_args(&a)?;

    let server = Server::start(cfg.clone())?;
    println!(
        "flashinfer serving {} on http://{} (batch B from artifacts, window {}ms, \
         continuous admission {}, paging {})",
        cfg.artifacts.display(),
        server.addr,
        cfg.batch_window_ms,
        if cfg.continuous_admission { "on" } else { "off" },
        if cfg.paging && cfg.continuous_admission {
            format!("on ({} MB)", cfg.pager_capacity_mb)
        } else {
            "off".into()
        }
    );
    println!("  GET  /health | GET /metrics | GET /v1/info");
    println!("  POST /v1/generate  {{\"max_tokens\": 128}}");
    println!("  POST /v1/generate  {{\"max_tokens\": 128, \"seed\": 7, \"temperature\": 0.8, \"top_k\": 40}}  (per-lane sampling)");
    println!("  POST /v1/generate  {{\"max_tokens\": 128, \"stream\": true}}  (chunked NDJSON, one event per position)");

    // serve until killed
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}
