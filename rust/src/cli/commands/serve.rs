//! `flashinfer serve` — start the HTTP serving front-end.

use std::sync::atomic::{AtomicBool, Ordering};

use anyhow::Result;

use crate::cli::args::Schema;
use crate::config::ServerConfig;
use crate::server::Server;

/// Latched by the SIGTERM/SIGINT handler; the serve loop polls it and
/// runs the graceful drain (`Server::stop`) instead of dying mid-request.
static TERM: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod sig {
    use super::TERM;
    use std::sync::atomic::Ordering;

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    // No libc crate in the offline build: bind the two POSIX calls we
    // need directly. `signal` is enough here — the handler only stores
    // to an atomic, which is async-signal-safe.
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    extern "C" fn on_term(_sig: i32) {
        TERM.store(true, Ordering::Relaxed);
    }

    pub fn install() {
        unsafe {
            signal(SIGTERM, on_term);
            signal(SIGINT, on_term);
        }
    }
}

#[cfg(not(unix))]
mod sig {
    /// Non-unix: no signal hook; the process stops when killed.
    pub fn install() {}
}

pub fn run(argv: &[String]) -> Result<i32> {
    let schema = super::engine_schema(Schema::new())
        .value("config", "JSON config file (defaults < file < flags)")
        .value("host", "bind host (default 127.0.0.1)")
        .value("port", "bind port (default 7070)")
        .value("batch-window-ms", "idle-state co-arrival window (default 5)")
        .value("max-tokens", "default tokens per request (default 256)")
        .switch("no-admission", "disable continuous admission (drain-then-refill batches)")
        .value("max-queue", "waiting-queue bound before shedding 429s (default 1024)")
        .switch("no-paging", "disable session paging (no lane eviction under queue pressure)")
        .value("pager-capacity-mb", "slab capacity for suspended-lane checkpoints (default 256)")
        .switch("no-fold", "disable position-independent (folded) checkpoints at suspend")
        .value("spill-dir", "disk-spill directory for cold checkpoints (default: spilling off)")
        .value("spill-watermark-pct", "slab occupancy percent that triggers spilling (default 80)")
        .value("keepalive-max-requests", "HTTP requests per connection, 0 = no keep-alive (default 32)")
        .value("deadline-ms", "per-request wall-clock budget, 0 = unlimited (default 0)")
        .value("max-connections", "live connection cap before shedding 503s (default 256)")
        .value("restart-budget", "engine panics tolerated per rolling window (default 3)")
        .value("restart-window-s", "rolling window for the restart budget (default 60)")
        .value("replicas", "engine replicas, each an isolated failure domain (default 1)")
        .value("failover-retries", "re-dispatches for a queued request whose replica died (default 2)")
        .value("quarantine-backoff-ms", "initial respawn backoff for a quarantined replica (default 500)")
        .value("quarantine-backoff-max-ms", "respawn backoff cap (default 30000)")
        .value("probe-window-ms", "clean probe window before a respawned replica rejoins (default 2000)")
        .value("drain-deadline-ms", "graceful-shutdown drain window (default 5000)")
        .value("socket-read-timeout-ms", "per-connection read timeout, 0 = none (default 10000)")
        .value("socket-write-timeout-ms", "per-connection write timeout, 0 = none (default 10000)")
        .value("faults", "fault-injection spec, e.g. engine_step:panic@3 (FI_FAULTS wins)");
    if super::maybe_help("flashinfer serve", &schema, argv) {
        return Ok(0);
    }
    let a = schema.parse(argv)?;
    let mut cfg = match a.get("config") {
        Some(path) => ServerConfig::from_file(std::path::Path::new(path))?,
        None => ServerConfig::default(),
    };
    cfg.apply_args(&a)?;

    let server = Server::start(cfg.clone())?;
    println!(
        "flashinfer serving {} on http://{} ({} replica{}, batch B from artifacts, window {}ms, \
         continuous admission {}, paging {})",
        cfg.artifacts.display(),
        server.addr,
        cfg.replicas.max(1),
        if cfg.replicas.max(1) == 1 { "" } else { "s" },
        cfg.batch_window_ms,
        if cfg.continuous_admission { "on" } else { "off" },
        if cfg.paging && cfg.continuous_admission {
            format!("on ({} MB)", cfg.pager_capacity_mb)
        } else {
            "off".into()
        }
    );
    println!("  GET  /health | GET /metrics | GET /v1/info");
    println!("  POST /v1/generate  {{\"max_tokens\": 128}}");
    println!("  POST /v1/generate  {{\"max_tokens\": 128, \"seed\": 7, \"temperature\": 0.8, \"top_k\": 40}}  (per-lane sampling)");
    println!("  POST /v1/generate  {{\"max_tokens\": 128, \"stream\": true}}  (chunked NDJSON, one event per position)");

    // serve until SIGTERM/SIGINT, then drain gracefully
    sig::install();
    while !TERM.load(Ordering::Relaxed) {
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    println!(
        "flashinfer: shutdown signal received; draining (deadline {} ms)",
        cfg.drain_deadline_ms
    );
    server.stop();
    println!("flashinfer: drained, exiting");
    Ok(0)
}
