//! Command-line interface: argument parsing substrate + subcommands.

pub mod args;
pub mod commands;
