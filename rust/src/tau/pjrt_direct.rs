//! PJRT direct τ — the Pallas direct-tile kernel compiled AOT and executed
//! through the PJRT CPU client (the paper's Conv1D point: quadratic FLOPs
//! *and* framework dispatch overhead; on the Pareto frontier only where
//! quadratic beats FFT but the framework call is amortized).

use anyhow::Result;

use super::{scatter_add, stage_y, RhoCache, TauImpl, TauKind};
use crate::runtime::Runtime;
use crate::tiling::Tile;
use crate::util::tensor::CellTensor;

pub struct PjrtDirect<'c, 'rt> {
    cache: &'c RhoCache<'rt>,
    stage: Vec<f32>,
}

impl<'c, 'rt> PjrtDirect<'c, 'rt> {
    pub fn new(cache: &'c RhoCache<'rt>) -> Self {
        PjrtDirect { cache, stage: Vec::new() }
    }
}

impl TauImpl for PjrtDirect<'_, '_> {
    fn kind(&self) -> TauKind {
        TauKind::PjrtDirect
    }

    fn apply(&mut self, streams: &CellTensor, pending: &CellTensor, tile: Tile) -> Result<()> {
        let rt = self.cache.runtime();
        let dims = rt.dims;
        let u = tile.u;
        let bundle = self.cache.pjrt(u)?;

        stage_y(streams, tile, &mut self.stage);
        let yb = rt.upload(&self.stage, &[dims.g, u, dims.d])?;
        let outs = bundle.direct.call(&[&yb])?;
        let vals = Runtime::literal_to_vec(&outs[0], dims.g * u * dims.d)?;
        scatter_add(pending, tile, &vals);
        Ok(())
    }
}
