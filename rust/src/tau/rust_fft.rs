//! Native FFT τ — the FlashFFTConv analogue: Appendix-C engineered
//! (order-2U cyclic convolution via the real-input half-spectrum rfft
//! pipeline, precomputed filter half-spectra ⇒ 2 packed transforms of
//! order U per tile), quasilinear FLOPs. The large-U winner on the Pareto
//! frontier (Fig 3a). Since PR 9 the kernel is the *fused* D-blocked
//! pass (`fft::tile_conv_rfft_fused_into`): SIMD-dispatched row ops and
//! no half-spectrum round-trip through scratch, bit-identical to the
//! unfused pipeline.

use std::cell::RefCell;

use anyhow::Result;

use super::{RhoCache, TauImpl, TauKind};
use crate::fft::{tile_conv_rfft_fused_into, TileScratch};
use crate::tiling::Tile;
use crate::util::tensor::CellTensor;
use crate::util::threadpool::ThreadPool;

thread_local! {
    /// Per-worker tile scratch for the parallel path. Pool workers are
    /// persistent (util::threadpool), so after the first tile each worker
    /// reuses its own planes and the token loop stays allocation-free, as
    /// documented in `fft/conv.rs`.
    static WORKER_SCRATCH: RefCell<TileScratch> = RefCell::new(TileScratch::default());
}

pub struct RustFft<'c, 'rt> {
    cache: &'c RhoCache<'rt>,
    pool: ThreadPool,
    scratch: TileScratch,
}

impl<'c, 'rt> RustFft<'c, 'rt> {
    pub fn new(cache: &'c RhoCache<'rt>, threads: usize) -> Self {
        let dims = cache.runtime().dims;
        RustFft {
            cache,
            pool: ThreadPool::new(threads),
            scratch: TileScratch::with_capacity(dims.l, dims.d),
        }
    }
}

impl TauImpl for RustFft<'_, '_> {
    fn kind(&self) -> TauKind {
        TauKind::RustFft
    }

    fn apply(&mut self, streams: &CellTensor, pending: &CellTensor, tile: Tile) -> Result<()> {
        let dims = self.cache.runtime().dims;
        let (g, d, b) = (dims.g, dims.d, dims.b);
        let plan = self.cache.plan(tile.u);
        let spectra = self.cache.spectra(tile.u);

        if self.pool.size() == 0 {
            for gi in 0..g {
                let m = gi / b;
                let spec = spectra.blocked(m);
                let y = streams.block(gi, tile.src_l - 1, tile.src_r);
                // SAFETY: synchronous apply under the deadline contract —
                // the tile's dst rows are exclusively this caller's
                let out = unsafe { pending.block_mut(gi, tile.dst_l - 1, tile.dst_r) };
                tile_conv_rfft_fused_into(&plan, y, spec, out, &mut self.scratch, d);
            }
            return Ok(());
        }

        // parallel across groups; each persistent worker brings its own
        // thread-local scratch (no allocation per task). The cell plane
        // is Sync, so the closure borrows it directly — each worker
        // derives a &mut over its own group's disjoint dst block.
        let plan_ref = plan.as_ref();
        let spectra_ref = spectra.as_ref();
        self.pool.scoped_for(g, |gi| {
            let m = gi / b;
            let spec = spectra_ref.blocked(m);
            let y = streams.block(gi, tile.src_l - 1, tile.src_r);
            // SAFETY: dst blocks are disjoint across gi, and the tile's
            // rows are this apply call's per the deadline contract.
            let out = unsafe { pending.block_mut(gi, tile.dst_l - 1, tile.dst_r) };
            WORKER_SCRATCH.with(|scratch| {
                tile_conv_rfft_fused_into(plan_ref, y, spec, out, &mut scratch.borrow_mut(), d);
            });
        });
        Ok(())
    }
}
