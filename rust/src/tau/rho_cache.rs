//! Filter state derived once at engine init (paper §5.4(4)):
//!
//! * `rho` itself, produced by the `filter_gen` artifact (the Hyena
//!   implicit filter lives in L2; rust only sees the materialized tensor);
//! * `rho0` (the red-cell taps) as a persistent PJRT buffer for `step`;
//! * per tile size U: the filter-prefix DFTs for the native FFT path and
//!   the `@`-bound PJRT tau executables with their persistent filter
//!   buffers ("the DFT for the convolutional kernel is pre-computed ahead
//!   of time for log2(L)-1 tile sizes").
//!
//! Group axis convention everywhere: `g = m * B + b` (mixer-major), the
//! same order `step`'s `[M, B, D]` tensors flatten to.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::fft::{self, RfftPlan, RfftPlanCache};
use crate::runtime::{BoundArtifact, Runtime};
use crate::util::tensor::Tensor;

/// Native filter-prefix *half*-spectrum state for one tile size U: per
/// mixer m, the rfft bins [0, U] of the order-2U prefix DFT, stored in
/// the D-blocked layout the fused tile kernel consumes
/// ([`fft::BlockedSpectrum`], `[nblocks][U+1][bd]` per m).
///
/// Real filters have conjugate-symmetric spectra, so the half layout
/// holds the full information at half the cached memory of the former
/// `[M, 2U, D]` planes; blocking is a pure permutation (same footprint).
/// The PJRT `@rho_re/@rho_im` buffers still want flat `[U+1, D]` planes —
/// [`Spectra::halfplanes`] reconstructs them (an init-time copy, off the
/// token loop).
pub struct Spectra {
    pub u: usize,
    pub d: usize,
    blocks: Vec<fft::BlockedSpectrum>,
}

impl Spectra {
    /// Half-spectrum bin count, U + 1.
    pub fn bins(&self) -> usize {
        self.u + 1
    }

    /// Blocked filter planes of mixer `m` — the fused-kernel operand.
    pub fn blocked(&self, m: usize) -> &fft::BlockedSpectrum {
        &self.blocks[m]
    }

    /// Flat `[U+1, D]` re/im planes of mixer `m` (PJRT upload layout and
    /// the unfused-kernel operand). Allocates: init-time callers only.
    pub fn halfplanes(&self, m: usize) -> (Vec<f32>, Vec<f32>) {
        self.blocks[m].to_halfplanes()
    }
}

/// PJRT executables + persistent filter buffers for one tile size U.
pub struct PjrtTau {
    pub fft: BoundArtifact,
    pub direct: BoundArtifact,
}

/// All rho-derived state for one loaded model.
pub struct RhoCache<'rt> {
    rt: &'rt Runtime,
    /// Materialized filter, `[M, L, D]`.
    pub rho: Tensor,
    /// `rho[:, 0, :]` as `[M, D]` (host copy + persistent device buffer).
    pub rho0: Vec<f32>,
    pub rho0_buf: Arc<xla::PjRtBuffer>,
    plans: RfftPlanCache,
    spectra: RefCell<HashMap<usize, Arc<Spectra>>>,
    pjrt: RefCell<HashMap<usize, Arc<PjrtTau>>>,
    rho_dev: RefCell<Option<Arc<xla::PjRtBuffer>>>,
}

impl<'rt> RhoCache<'rt> {
    /// Run `filter_gen` and set up the derived state.
    pub fn new(rt: &'rt Runtime) -> Result<RhoCache<'rt>> {
        let dims = rt.dims;
        let exe = rt.executable("filter_gen").context("compile filter_gen")?;
        let bufs: Vec<_> = exe
            .spec
            .inputs
            .iter()
            .map(|i| rt.weight_buffer(&i.name))
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().map(|b| b.as_ref()).collect();
        let outs = exe.call(&refs).context("run filter_gen")?;
        let rho_v = Runtime::literal_to_vec(&outs[0], dims.m * dims.l * dims.d)?;
        let rho = Tensor::from_vec(&[dims.m, dims.l, dims.d], rho_v)?;

        let mut rho0 = vec![0.0f32; dims.m * dims.d];
        for m in 0..dims.m {
            rho0[m * dims.d..(m + 1) * dims.d].copy_from_slice(rho.at2(m, 0));
        }
        let rho0_buf = Arc::new(rt.upload(&rho0, &[dims.m, dims.d])?);

        Ok(RhoCache {
            rt,
            rho,
            rho0,
            rho0_buf,
            plans: RfftPlanCache::new(),
            spectra: RefCell::new(HashMap::new()),
            pjrt: RefCell::new(HashMap::new()),
            rho_dev: RefCell::new(None),
        })
    }

    pub fn runtime(&self) -> &'rt Runtime {
        self.rt
    }

    /// Persistent device buffer of the full rho tensor (prefill input).
    pub fn rho_buf(&self) -> Result<Arc<xla::PjRtBuffer>> {
        if let Some(b) = self.rho_dev.borrow().as_ref() {
            return Ok(b.clone());
        }
        let dims = self.rt.dims;
        let buf = Arc::new(self.rt.upload(self.rho.data(), &[dims.m, dims.l, dims.d])?);
        *self.rho_dev.borrow_mut() = Some(buf.clone());
        Ok(buf)
    }

    /// Rfft plan of real order 2U (packed complex transforms of order U).
    pub fn plan(&self, u: usize) -> Arc<RfftPlan> {
        self.plans.get(2 * u)
    }

    /// Filter-prefix segment `rho[m, 0..2U, :]` (contiguous view).
    pub fn seg(&self, m: usize, u: usize) -> &[f32] {
        self.rho.block(m, 0, 2 * u)
    }

    /// Native half-spectrum planes for tile size U (built on first use).
    pub fn spectra(&self, u: usize) -> Arc<Spectra> {
        if let Some(s) = self.spectra.borrow().get(&u) {
            return s.clone();
        }
        let dims = self.rt.dims;
        let plan = self.plan(u);
        let mut blocks = Vec::with_capacity(dims.m);
        for m in 0..dims.m {
            let (r, i) = fft::spectrum_halfplanes(&plan, self.seg(m, u), dims.d);
            blocks.push(fft::BlockedSpectrum::from_halfplanes(&r, &i, dims.d));
        }
        let s = Arc::new(Spectra { u, d: dims.d, blocks });
        self.spectra.borrow_mut().insert(u, s.clone());
        s
    }

    /// Bound PJRT tau executables for tile size U (built on first use).
    ///
    /// The `@rho_re/@rho_im` buffers hold rfft bins `[0, U]` of the filter
    /// prefix, repeated across the batch lanes of the `G = M·B` axis —
    /// flat planes un-blocked from [`Spectra`] at bind time; the
    /// `@rho_seg` buffer holds the raw prefix for the Pallas direct
    /// kernel.
    pub fn pjrt(&self, u: usize) -> Result<Arc<PjrtTau>> {
        if let Some(p) = self.pjrt.borrow().get(&u) {
            return Ok(p.clone());
        }
        let dims = self.rt.dims;
        let (g, d, b) = (dims.g, dims.d, dims.b);
        let spectra = self.spectra(u);
        let bins = spectra.bins();

        let mut re = vec![0.0f32; g * bins * d];
        let mut im = vec![0.0f32; g * bins * d];
        let mut seg = vec![0.0f32; g * 2 * u * d];
        for m in 0..dims.m {
            let (sre, sim) = spectra.halfplanes(m);
            for bi in 0..b {
                let gi = m * b + bi;
                re[gi * bins * d..(gi + 1) * bins * d].copy_from_slice(&sre);
                im[gi * bins * d..(gi + 1) * bins * d].copy_from_slice(&sim);
                seg[gi * 2 * u * d..(gi + 1) * 2 * u * d].copy_from_slice(self.seg(m, u));
            }
        }
        let mut derived = HashMap::new();
        derived.insert("@rho_re".to_string(), Arc::new(self.rt.upload(&re, &[g, bins, d])?));
        derived.insert("@rho_im".to_string(), Arc::new(self.rt.upload(&im, &[g, bins, d])?));
        let fft = BoundArtifact::bind(self.rt, &format!("tau_fft_{u}"), &derived)?;

        let mut derived = HashMap::new();
        derived.insert("@rho_seg".to_string(), Arc::new(self.rt.upload(&seg, &[g, 2 * u, d])?));
        let direct = BoundArtifact::bind(self.rt, &format!("tau_direct_{u}"), &derived)?;

        let p = Arc::new(PjrtTau { fft, direct });
        self.pjrt.borrow_mut().insert(u, p.clone());
        Ok(p)
    }

    /// Eagerly build every per-U structure (bench warmup; engine init cost
    /// measured separately from the token loop).
    pub fn prewarm(&self, max_u: usize, with_pjrt: bool) -> Result<()> {
        let mut u = 1;
        while u <= max_u {
            self.spectra(u);
            if with_pjrt {
                self.pjrt(u)?;
            }
            u *= 2;
        }
        Ok(())
    }
}
